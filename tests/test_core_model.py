"""Unit tests for the FiCCO core: DIL/CIL models, simulator, heuristics.

These validate the paper-fidelity properties the cost model was built to
reproduce (paper §IV trends + §VI headline numbers).
"""

import math

import pytest

from repro.core import (
    MI300X,
    TABLE_I,
    TPU_V5E,
    GemmShape,
    Schedule,
    SCENARIOS,
    STUDIED,
    best_schedule,
    comm_cil,
    gemm_cil,
    gemm_dil,
    gemm_exec,
    geomean,
    machine_threshold,
    select_schedule,
    simulate,
    synthetic_scenarios,
)
from repro.core.inefficiency import (
    a2a_chunk_step_time,
    ag_serial_time,
    calibrated_s_half,
    comm_time,
    p2p_step_time,
)
from repro.core.schedule_types import ALL_VARIANTS, SIGNATURES, Level
from repro.core.explorer import explore, prune_report


MI = MI300X


class TestGemmModel:
    def test_monolithic_large_gemm_is_efficient(self):
        e = gemm_exec(GemmShape(16384, 16384, 16384), MI)
        assert e.occupancy > 0.95
        ideal = 2.0 * 16384**3 / MI.peak_flops
        assert e.time < ideal * 1.1

    def test_dil_at_least_one(self):
        for sc in TABLE_I:
            for ways in (8, 64):
                for axis in ("m", "k"):
                    assert gemm_dil(sc.gemm, MI, ways, axis) >= 0.999

    def test_dil_64way_worse_than_8way(self):
        """Paper Fig. 7: deeper decomposition has higher DIL."""
        for sc in TABLE_I:
            for axis in ("m", "k"):
                assert (
                    gemm_dil(sc.gemm, MI, 64, axis)
                    >= gemm_dil(sc.gemm, MI, 8, axis) - 1e-9
                )

    def test_dil_row_vs_column_asymmetry(self):
        """Paper Fig. 7: row-sharding worse when M < K; col when M > K."""
        for sc in TABLE_I:
            g = sc.gemm
            row = gemm_dil(g, MI, 64, "m")
            col = gemm_dil(g, MI, 64, "k")
            if g.m < g.k:
                assert row > col, sc.name
            else:
                assert col > row, sc.name

    def test_accumulate_adds_traffic(self):
        g = GemmShape(8192, 8192, 1024)
        assert (
            gemm_exec(g, MI, accumulate=True).bytes_hbm
            > gemm_exec(g, MI).bytes_hbm
        )


class TestCommModel:
    def test_comm_dil_geomean_matches_paper(self):
        """Paper Fig. 8: ~10% geomean DIL for 8x-finer all-gather."""
        sh = calibrated_s_half(MI)
        vals = []
        for sc in TABLE_I:
            total = sc.gemm.m * sc.gemm.k * sc.gemm.dtype_bytes
            per_link = total / MI.group / MI.a2a_links
            base = comm_time(per_link, MI, s_half=0.0)
            fine = comm_time(per_link, MI, s_half=sh, n_transfers=MI.group)
            vals.append(fine / base)
        gm = geomean(vals)
        assert 1.08 <= gm <= 1.12

    def test_comm_dil_decreases_with_size(self):
        """Paper: larger transfers are more resilient to DIL."""
        sh = calibrated_s_half(MI)

        def dil(total):
            per_link = total / MI.group / MI.a2a_links
            return comm_time(
                per_link, MI, s_half=sh, n_transfers=MI.group
            ) / comm_time(per_link, MI, s_half=0.0)

        assert dil(64 * 2**20) > dil(1 * 2**30) > dil(8 * 2**30)

    def test_p2p_ring_much_slower_than_a2a_on_full_mesh(self):
        """Paper Fig. 13: ~7x comm slowdown for P2P shard streaming."""
        mk = 1 << 30
        shard = mk / MI.group
        serial = ag_serial_time(mk, MI)
        p2p_total = (MI.group - 1) * p2p_step_time(shard, MI)
        assert 5.0 < p2p_total / serial < 9.0

    def test_ficco_a2a_total_close_to_serial_ag(self):
        mk = 1 << 30
        chunk = mk / MI.group**2
        a2a_total = MI.group * a2a_chunk_step_time(chunk, MI)
        serial = ag_serial_time(mk, MI)
        assert a2a_total / serial < 1.3


class TestCilModel:
    def test_cil_geomeans_match_paper(self):
        shards = [s.gemm.shard(8, "m") for s in TABLE_I]
        gm_gemm_ficco = geomean(gemm_cil(sh, MI, degree=3) for sh in shards)
        gm_gemm_shard = geomean(gemm_cil(sh, MI, degree=2) for sh in shards)
        gm_comm_ficco = geomean(comm_cil(sh, MI, degree=3) for sh in shards)
        gm_comm_shard = geomean(comm_cil(sh, MI, degree=2) for sh in shards)
        assert abs(gm_gemm_ficco - 1.11) < 0.01  # paper §IV-D1
        assert abs(gm_gemm_shard - 1.07) < 0.01
        assert abs(gm_comm_ficco - 1.12) < 0.01  # paper §IV-D2
        assert abs(gm_comm_shard - 1.03) < 0.01

    def test_cil_increases_with_mt(self):
        small = GemmShape(4096, 4096, 4096)
        big = GemmShape(65536, 8192, 65536)
        assert gemm_cil(big, MI, degree=3) > gemm_cil(small, MI, degree=3)

    def test_rccl_worse_than_dma(self):
        """Paper Fig. 9: DMA comm causes far lower CIL than RCCL."""
        for sc in TABLE_I[:4]:
            sh = sc.gemm.shard(8, "m")
            assert gemm_cil(sh, MI, degree=3, dma=False) > gemm_cil(
                sh, MI, degree=3, dma=True
            )


class TestSimulator:
    def test_serial_is_sum(self):
        r = simulate(SCENARIOS["g1"].gemm, MI, Schedule.SERIAL)
        assert r.total == pytest.approx(r.serial_comm + r.serial_gemm)

    def test_shard_p2p_loses_on_full_mesh(self):
        """Paper Fig. 13: shard-overlap does not attain speedups on
        direct-connection topologies (up to 3.9x slower than serial)."""
        sps = [
            simulate(s.gemm, MI, Schedule.SHARD_P2P).speedup for s in TABLE_I
        ]
        assert max(sps) < 1.05
        assert min(sps) < 0.35  # worst cases are several-x slowdowns

    def test_ficco_max_speedup_matches_paper(self):
        """Paper §VI-C: up to ~1.6x (1D) / ~1.7x (2D) speedup."""
        best = 0.0
        for s in TABLE_I:
            _, res = best_schedule(s.gemm, MI)
            best = max(best, max(r.speedup for r in res.values()))
        assert 1.55 <= best <= 1.80

    def test_ficco_beats_shard_p2p_geomean(self):
        """Paper Fig. 14 ordering: FiCCO >> shard overlap on full mesh."""
        f, p = [], []
        for s in TABLE_I:
            _, res = best_schedule(s.gemm, MI)
            f.append(max(res[x].speedup for x in STUDIED))
            p.append(res[Schedule.SHARD_P2P].speedup)
        assert geomean(f) > 1.2
        assert geomean(f) > 2.5 * geomean(p)

    def test_dma_beats_rccl_geomean(self):
        """Paper Fig. 14: FiCCO-rccl < FiCCO (DMA)."""
        d, r = [], []
        for s in TABLE_I:
            _, res_d = best_schedule(s.gemm, MI, dma=True)
            _, res_r = best_schedule(s.gemm, MI, dma=False)
            d.append(max(res_d[x].speedup for x in STUDIED))
            r.append(max(res_r[x].speedup for x in STUDIED))
        assert geomean(d) > geomean(r)

    def test_ideal_is_upper_bound(self):
        for s in TABLE_I:
            for sched in (Schedule.SHARD_P2P, *STUDIED):
                r = simulate(s.gemm, MI, sched)
                assert r.total >= r.ideal_total * 0.999

    def test_tpu_machine_simulates(self):
        g = GemmShape(65536, 4096, 8192)
        _, res = best_schedule(g, TPU_V5E)
        assert all(r.total > 0 for r in res.values())


class TestHeuristics:
    def test_2d_iff_m_lt_k(self):
        for s in TABLE_I:
            dec = select_schedule(s.gemm, MI)
            if s.gemm.m < s.gemm.k:
                assert dec.schedule is Schedule.UNIFORM_FUSED_2D, s.name
            else:
                assert dec.schedule is not Schedule.UNIFORM_FUSED_2D, s.name

    def test_metric_is_flops(self):
        g = SCENARIOS["g1"].gemm
        dec = select_schedule(g, MI)
        assert dec.metric == pytest.approx(g.flops)

    def test_tranche_ordering(self):
        """Bigger OTBxMT within 1D moves uf1 -> hf1 -> hu1."""
        t = machine_threshold(MI)
        small = GemmShape(16384, 2048, 2048)  # flops ~1.4e11 < T
        dec = select_schedule(small, MI)
        assert dec.schedule in (
            Schedule.UNIFORM_FUSED_1D, Schedule.SERIAL
        )
        huge = SCENARIOS["g13"].gemm
        assert huge.flops > 5 * t
        assert select_schedule(huge, MI).schedule is Schedule.HETERO_UNFUSED_1D

    def test_studied_scenarios_mostly_within_5pct_of_optimal(self):
        """Our analogue of the paper's '100% correct on studied scenarios':
        against *our* analytic ground truth the heuristic lands within 5%
        of optimal on >= 14/16 studied scenarios, and never loses more
        than ~16% (paper's own mispredictions lose ~14%)."""
        good, worst = 0, 1.0
        for s in TABLE_I:
            ex = explore(s, MI)
            ratio = (
                ex.results[ex.heuristic.schedule].total
                / ex.results[ex.best].total
            )
            good += ratio <= 1.05
            worst = max(worst, ratio)
        assert good >= 14, f"only {good}/16 within 5%"
        assert worst <= 1.20, f"worst heuristic loss {worst:.3f}"

    def test_synthetic_accuracy_at_least_81pct(self):
        """Paper §VI-D: >= 81% of unseen scenarios picked well."""
        syn = synthetic_scenarios(16)
        good = 0
        for s in syn:
            ex = explore(s, MI)
            best_t = ex.results[ex.best].total
            got_t = ex.results[ex.heuristic.schedule].total
            good += got_t <= 1.05 * best_t
        assert good / len(syn) >= 0.81

    def test_misprediction_loss_small(self):
        """Paper §VI-D: mispredictions lose ~14% of the optimal speedup."""
        losses = []
        for s in (*TABLE_I, *synthetic_scenarios(16)):
            ex = explore(s, MI)
            if not ex.heuristic_correct:
                losses.append(ex.heuristic_loss)
        if losses:
            assert sum(losses) / len(losses) <= 0.30

    def test_serial_guard_for_tiny_ops(self):
        dec = select_schedule(GemmShape(512, 512, 512), MI)
        assert dec.schedule is Schedule.SERIAL


class TestExplorer:
    def test_prune_report_contains_all_eight(self):
        rows = prune_report(SCENARIOS["g2"], MI)
        assert len(rows) == len(ALL_VARIANTS) == 8

    def test_studied_variants_rank_well(self):
        """The paper's pruning argument: unstudied variants never strictly
        dominate; a studied variant is always at/near the top."""
        for name in ("g2", "g6", "g12", "g14"):
            rows = prune_report(SCENARIOS[name], MI)
            # best variant overall is a studied one
            assert rows[0][2], f"{name}: unstudied variant won {rows[0][0]}"

    def test_signatures_cover_studied(self):
        assert set(SIGNATURES) == set(STUDIED)
        dil, cil = SIGNATURES[Schedule.UNIFORM_FUSED_1D]
        assert dil is Level.LOW and cil is Level.HIGH
        dil, cil = SIGNATURES[Schedule.HETERO_UNFUSED_1D]
        assert dil is Level.HIGH and cil is Level.LOW


class TestBeyondPaper:
    def test_dma_into_place_never_slower(self):
        """The fused kernel removes gather/scatter streams: modelled time
        must never regress vs the paper-faithful schedule."""
        from repro.core.simulator import simulate as sim

        for s in TABLE_I:
            for sched in STUDIED:
                base = sim(s.gemm, MI, sched)
                fused = sim(s.gemm, MI, sched, dma_into_place=True)
                assert fused.total <= base.total * 1.0001, (s.name, sched)

    def test_tpu_torus_shard_p2p_not_catastrophic(self):
        """DESIGN.md §2: on a torus ring P2P is bandwidth-reasonable —
        the full-mesh pathology (paper Fig. 13) is topology-specific."""
        from repro.core.simulator import simulate as sim

        sp = [
            sim(s.gemm, TPU_V5E, Schedule.SHARD_P2P).speedup
            for s in TABLE_I
        ]
        assert geomean(sp) > 0.6  # vs 0.32 on the full mesh
