import sys

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (subprocess drivers, sweeps)"
    )
    config.addinivalue_line(
        "markers",
        "multidev: multi-device subprocess tests (8 simulated devices); "
        "deselect with -m 'not multidev' for the fast tier-1 subset",
    )
    config.addinivalue_line(
        "markers",
        "autotune: repro.autotune subsystem tests (jitted grid engine, "
        "tuner, persistent cache)",
    )


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path, monkeypatch):
    """Cache-isolate every test by default: the autotune decision cache
    lives under the test's tmp dir, never the user's home, and the
    process-wide tuner singleton is dropped so it re-reads the env var.

    The singleton reset goes through ``sys.modules`` so tests that never
    import repro.autotune don't pay the jax import for it.
    """
    monkeypatch.setenv(
        "REPRO_AUTOTUNE_CACHE_DIR", str(tmp_path / "autotune_cache")
    )
    # Observability stays off unless a test turns it on explicitly.
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_AUDIT", raising=False)
    monkeypatch.delenv("REPRO_SIGNATURES", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_AUDIT_MAX_BYTES", raising=False)
    monkeypatch.delenv("REPRO_AUTOTUNE_AUDIT_KEEP", raising=False)

    def _reset():
        tuner_mod = sys.modules.get("repro.autotune.tuner")
        if tuner_mod is not None:
            tuner_mod.reset_tuner()
        # Ambient learned gates (global + per-machine-family) steer the
        # heuristic tree's gate resolution process-wide; drop any a test
        # installed so suites stay order-independent.
        gate_mod = sys.modules.get("repro.learn.gate")
        if gate_mod is not None:
            gate_mod.set_default_gate(None)
            gate_mod.clear_machine_gates()
        # In-process promoted kernel variants resolve ahead of persisted
        # artifacts and registry defaults; drop any a test promoted.
        tune_mod = sys.modules.get("repro.tune.registry")
        if tune_mod is not None:
            tune_mod.reset_variants()
        # Process-wide observability state (tracer / metric registry /
        # audit log) would otherwise leak spans and counts across tests.
        trace_mod = sys.modules.get("repro.obs.trace")
        if trace_mod is not None:
            trace_mod._TRACER = None
        metrics_mod = sys.modules.get("repro.obs.metrics")
        if metrics_mod is not None:
            metrics_mod.reset_metrics()
        audit_mod = sys.modules.get("repro.obs.audit")
        if audit_mod is not None:
            audit_mod.disable_audit()
        signature_mod = sys.modules.get("repro.obs.signature")
        if signature_mod is not None:
            signature_mod._STREAM = None

    _reset()
    yield
    _reset()
