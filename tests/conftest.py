import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running tests (subprocess drivers, sweeps)"
    )
    config.addinivalue_line(
        "markers",
        "multidev: multi-device subprocess tests (8 simulated devices); "
        "deselect with -m 'not multidev' for the fast tier-1 subset",
    )
