"""Shared GridResult bit-identity assertion (one site for the contract).

Importable both from pytest modules and from the subprocess drivers
(both have ``tests/`` on ``sys.path``: pytest inserts the test dir,
scripts get their own directory as ``sys.path[0]``).
"""

import numpy as np


def assert_grid_identical(got, want, ctx: str = "") -> None:
    """Every GridResult field equal bit for bit (NaN == NaN)."""
    for f in ("total", "comm_busy", "compute_busy", "exposed"):
        assert np.array_equal(
            getattr(got, f), getattr(want, f), equal_nan=True
        ), f"{ctx}{f}"
    assert np.array_equal(got.valid, want.valid), f"{ctx}valid"
    assert np.array_equal(got.steps, want.steps), f"{ctx}steps"
    assert np.array_equal(
        got.serial_comm, want.serial_comm
    ), f"{ctx}serial_comm"
    assert np.array_equal(
        got.serial_gemm, want.serial_gemm
    ), f"{ctx}serial_gemm"
