"""repro.autotune: jitted grid engine == NumPy engine, differentiable
TAU calibration, tiered tuner, persistent cache, serial gate.

Equivalence is randomized (seeded) over the scenario grid x machine grid
— all schedules, both topologies, group sizes 8/16, dtypes bf16/fp8/fp32
— asserting the jax engine matches ``repro.core.batch.evaluate_grid``
within 1e-5 relative (measured agreement is ~1e-15: the jitted scan
replays the NumPy accumulation order in float64).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    GRID_SCHEDULES,
    MI300X,
    TABLE_I,
    TPU_V5E,
    GemmShape,
    Schedule,
    ScenarioBatch,
    machine_grid,
    scenario_grid,
)
from repro.core.batch import evaluate_grid as np_evaluate_grid

pytestmark = pytest.mark.autotune

RTOL = 1e-5
_FIELDS = ("total", "comm_busy", "compute_busy", "exposed")


def _grid_slice(seed: int, count: int):
    scenarios = scenario_grid()
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(scenarios), size=count, replace=False)
    return [scenarios[i] for i in idx]


def _assert_engines_agree(scenarios, machines, **kw):
    from repro.autotune import evaluate_grid_jax
    from repro.core.batch import _as_batch

    sb = _as_batch(scenarios)
    ref = np_evaluate_grid(sb, machines, **kw)
    got = evaluate_grid_jax(sb, machines, **kw)
    assert (ref.valid == got.valid).all()
    assert (ref.steps == got.steps).all()
    for f in _FIELDS:
        a, b = getattr(ref, f), getattr(got, f)
        assert np.isnan(b[~ref.valid]).all(), f
        np.testing.assert_allclose(
            b[ref.valid], a[ref.valid], rtol=RTOL, err_msg=f
        )
    np.testing.assert_allclose(got.serial_comm, ref.serial_comm, rtol=RTOL)
    np.testing.assert_allclose(got.serial_gemm, ref.serial_gemm, rtol=RTOL)
    assert (ref.best_idx() == got.best_idx()).all()


class TestJaxNumpyEquivalence:
    def test_table_i_dma_on_off(self):
        for dma in (True, False):
            _assert_engines_agree(
                list(TABLE_I), (MI300X, TPU_V5E), dma=dma
            )

    def test_random_grid_slice_all_topologies(self):
        """Random grid slice x full machine grid (both topologies, mixed
        group sizes vmapped together through the padded scan)."""
        _assert_engines_agree(_grid_slice(seed=42, count=32), machine_grid())

    def test_full_acceptance_grid(self):
        """The acceptance criterion verbatim: the full 720-scenario x
        8-machine grid agrees within 1e-5 relative tolerance."""
        scenarios = scenario_grid()
        machines = machine_grid()
        assert len(scenarios) == 720 and len(machines) == 8
        _assert_engines_agree(scenarios, machines)

    def test_schedule_subsets(self):
        subset = (Schedule.SERIAL, Schedule.UNIFORM_FUSED_1D)
        _assert_engines_agree(
            list(TABLE_I)[:6], (MI300X,), schedules=subset
        )
        subset = (Schedule.SHARD_P2P, Schedule.HETERO_UNFUSED_1D)
        _assert_engines_agree(
            list(TABLE_I)[:6], (TPU_V5E,), schedules=subset
        )

    def test_extra_dtypes(self):
        """fp8 / bf16 / fp32 operand widths all agree."""
        gemms = [
            GemmShape(65536, 8192, 8192, b) for b in (1, 2, 4)
        ] + [GemmShape(131072, 4096, 16384, 4)]
        from repro.autotune import evaluate_grid_jax

        ref = np_evaluate_grid(gemms, (MI300X, TPU_V5E))
        got = evaluate_grid_jax(gemms, (MI300X, TPU_V5E))
        np.testing.assert_allclose(
            got.total[ref.valid], ref.total[ref.valid], rtol=RTOL
        )

    def test_dma_into_place(self):
        _assert_engines_agree(
            list(TABLE_I)[:8], (MI300X,), dma_into_place=True
        )

    def test_degenerate_and_indivisible_masked(self):
        """NaN/validity handling matches the NumPy engine exactly."""
        gemms = [
            GemmShape(1001, 4096, 4096),  # m not divisible by any group
            GemmShape(32, 4096, 4096),  # hetero chunk rows would be 0
            GemmShape(8192, 8192, 8191),  # k indivisible -> 2D masked
        ]
        _assert_engines_agree(gemms, (MI300X, TPU_V5E))

    def test_backend_switch(self):
        from repro.autotune import evaluate_grid

        a = evaluate_grid(list(TABLE_I)[:4], (MI300X,), backend="numpy")
        b = evaluate_grid(list(TABLE_I)[:4], (MI300X,), backend="jax")
        np.testing.assert_allclose(
            b.total[a.valid], a.total[a.valid], rtol=RTOL
        )
        with pytest.raises(ValueError):
            evaluate_grid(list(TABLE_I)[:4], (MI300X,), backend="torch")


class TestDifferentiability:
    def test_grad_total_wrt_tau_finite_nonzero(self):
        """d E[heuristic-picked time] / d tau exists and is informative."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.autotune import expected_heuristic_time

        with enable_x64():
            f = lambda t: expected_heuristic_time(t, TABLE_I, MI300X)
            g = jax.grad(f)(jnp.asarray(0.02, jnp.float64))
        assert np.isfinite(float(g))
        assert float(g) != 0.0

    def test_grad_wrt_machine_params_finite_nonzero(self):
        """The grid is differentiable through machine parameters: a
        faster HBM strictly reduces mean schedule time."""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.autotune import evaluate_grid_raw, machine_arrays

        with enable_x64():
            mp = machine_arrays((MI300X,))

            def mean_total(link_bw):
                out = evaluate_grid_raw(
                    list(TABLE_I)[:4],
                    mp._replace(link_bw=link_bw),
                    g_max=MI300X.group,
                )
                total, valid = out[0], out[5]
                return jnp.sum(jnp.where(valid, total, 0.0))

            g = jax.grad(mean_total)(mp.link_bw)
        assert np.isfinite(np.asarray(g)).all()
        assert float(np.asarray(g)[0]) < 0.0  # faster links -> less time

    def test_calibrate_tau_matches_bisection_within_5pct(self):
        """Acceptance: a few Adam steps reproduce the bisection TAU on
        MI300X within 5% (and land at a no-worse loss)."""
        from repro.autotune import (
            calibrate_tau,
            calibrate_tau_reference,
            expected_heuristic_time,
        )

        tau_ref = calibrate_tau_reference(MI300X, TABLE_I)
        tau_adam = calibrate_tau(MI300X, TABLE_I)
        assert abs(tau_adam - tau_ref) / tau_ref < 0.05
        l_ref = float(expected_heuristic_time(tau_ref, TABLE_I, MI300X))
        l_adam = float(expected_heuristic_time(tau_adam, TABLE_I, MI300X))
        assert l_adam <= l_ref * (1.0 + 1e-6)

    def test_calibrated_tau_no_worse_than_discrete_search(self):
        """Hard-decision accuracy with the gradient TAU is at least the
        discrete candidate search's (the engine it replaces)."""
        from repro.core.explorer import explore_grid
        from repro.core.heuristics import _TAU_OVERRIDES, calibrate_tau
        from repro.autotune import calibrate_tau as grad_tau

        saved = _TAU_OVERRIDES.pop(MI300X.name, None)
        try:
            disc = calibrate_tau(MI300X, TABLE_I)
            _TAU_OVERRIDES.pop(MI300X.name, None)
        finally:
            if saved is not None:
                _TAU_OVERRIDES[MI300X.name] = saved
        adam = grad_tau(MI300X, TABLE_I)
        acc_disc = explore_grid(
            TABLE_I, machines=(MI300X,), tau=disc
        ).accuracy(0.05)
        acc_adam = explore_grid(
            TABLE_I, machines=(MI300X,), tau=adam
        ).accuracy(0.05)
        assert acc_adam >= acc_disc - 1e-9


class TestSerialGate:
    def test_gridwide_within5_above_baseline(self):
        """Regression pin for the learned serial gate: grid-wide
        within-5% accuracy with the frozen gate clears 70%, against a
        gate-less baseline of ~30% (the PR-1 'serial tranche' finding).
        """
        from repro.core import explore_grid

        sb = ScenarioBatch.from_scenarios(scenario_grid())
        machines = machine_grid()
        gated = explore_grid(sb, machines=machines).accuracy(0.05)
        baseline = 0.31  # measured pre-gate (PR-1 engine, frozen pin)
        assert gated >= 0.70, f"gated accuracy regressed: {gated:.3f}"
        assert gated > baseline + 0.25

    def test_gate_disabled_reproduces_paper_tree(self):
        from repro.core import select_schedule

        gemm = GemmShape(65536, 2048, 8192)
        with_gate = select_schedule(gemm, TPU_V5E)
        without = select_schedule(gemm, TPU_V5E, serial_gate=np.inf)
        # This shape is comm-bound on the torus: gate says serial, the
        # paper tree decomposes.
        assert with_gate.schedule is Schedule.SERIAL
        assert without.schedule is not Schedule.SERIAL

    def test_batch_matches_scalar_with_gate(self):
        from repro.core import select_schedule, select_schedule_batch
        from repro.core.batch import GRID_SCHEDULES as GS

        scenarios = [*TABLE_I, *_grid_slice(seed=11, count=48)]
        sb = ScenarioBatch.from_scenarios(scenarios)
        for machine in (MI300X, TPU_V5E):
            picks = select_schedule_batch(
                sb.m, sb.n, sb.k, sb.dtype_bytes, machine
            )
            for i, sc in enumerate(scenarios):
                dec = select_schedule(sc.gemm, machine)
                assert GS[int(picks[i])] is dec.schedule, sc.name

    def test_calibrate_serial_gate(self):
        from repro.core.heuristics import (
            _SERIAL_GATE_OVERRIDES,
            calibrate_serial_gate,
        )

        cands = (0.5, 1.2, 5.0)
        got = calibrate_serial_gate(
            (MI300X,), _grid_slice(seed=3, count=64), candidates=cands
        )
        assert got in cands
        saved = dict(_SERIAL_GATE_OVERRIDES)
        try:
            calibrate_serial_gate(
                (MI300X,), _grid_slice(seed=3, count=64),
                candidates=cands, freeze=True,
            )
            assert MI300X.name in _SERIAL_GATE_OVERRIDES
        finally:
            _SERIAL_GATE_OVERRIDES.clear()
            _SERIAL_GATE_OVERRIDES.update(saved)


class TestTunerAndCache:
    def test_pick_analytic_then_cached(self):
        from repro.autotune import Autotuner

        t = Autotuner()
        gemm = GemmShape(65536, 8192, 8192)
        d1 = t.pick(gemm, MI300X)
        assert d1.source == "analytic"
        d2 = t.pick(gemm, MI300X)
        assert d2.source == "cache" and d2.schedule is d1.schedule
        assert t.hit_rate == pytest.approx(0.5)

    def test_analytic_pick_is_model_optimal(self):
        from repro.autotune import Autotuner

        t = Autotuner(backend="numpy")
        for sc in list(TABLE_I)[:6]:
            d = t.pick(sc.gemm, MI300X)
            grid = np_evaluate_grid([sc.gemm], (MI300X,))
            best = GRID_SCHEDULES[int(grid.best_idx()[0, 0])]
            assert d.schedule is best, sc.name

    def test_persisted_across_tuner_instances(self):
        from repro.autotune import Autotuner, default_cache_path

        gemm = GemmShape(131072, 16384, 16384)
        t1 = Autotuner()
        d1 = t1.pick(gemm, TPU_V5E, group=16)
        assert os.path.exists(default_cache_path())
        t2 = Autotuner()  # fresh instance, same backing file
        d2 = t2.pick(gemm, TPU_V5E, group=16)
        assert d2.source == "cache" and d2.schedule is d1.schedule

    def test_cache_corrupt_file_tolerated(self):
        from repro.autotune import AutotuneCache, default_cache_path

        path = default_cache_path()
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write("{not json")
        c = AutotuneCache()
        assert len(c) == 0
        c.put("k", {"schedule": "serial", "source": "analytic"})
        assert len(AutotuneCache()) == 1  # healthy again

    def test_pick_never_records_unexecutable_schedule(self):
        """The cost model's validity (M % g == 0) is weaker than the
        runtime chunking rule (M/g % g == 0 for 1D FiCCO): the recorded
        winner must be one ``ficco_linear`` will actually run."""
        from repro.autotune import Autotuner
        from repro.overlap.api import _divisible

        gemm = GemmShape(65544, 8192, 8192)  # m%8==0 but (m/8)%8 != 0
        t = Autotuner()
        d = t.pick(gemm, MI300X)
        assert d.source == "analytic"
        assert _divisible(gemm.m // 8, gemm.k, 8, d.schedule)
        assert d.schedule not in (
            Schedule.UNIFORM_FUSED_1D,
            Schedule.HETERO_FUSED_1D,
            Schedule.HETERO_UNFUSED_1D,
        )

    def test_resolve_auto_respects_group(self):
        """schedule="auto" evaluates the tree (incl. the group-sensitive
        serial gate) at the actual axis size, not the machine default."""
        from repro.core import machine_for_group, select_schedule
        from repro.overlap.api import resolve_schedule

        for group in (4, 8):
            for m, n, k in ((8192, 16384, 16384), (65536, 2048, 8192)):
                want = select_schedule(
                    GemmShape(m, n, k), machine_for_group(TPU_V5E, group)
                ).schedule
                got = resolve_schedule(
                    "auto", m=m, n=n, k=k, group=group
                )
                assert got is want, (group, m, n, k)

    def test_concurrent_caches_merge_on_save(self):
        """Two processes tuning disjoint keys must not clobber each
        other: save() folds in entries persisted since our load."""
        from repro.autotune import AutotuneCache

        a = AutotuneCache()
        b = AutotuneCache()
        a.put("key/a", {"schedule": "serial", "source": "analytic"})
        b.put("key/b", {"schedule": "serial", "source": "analytic"})
        fresh = AutotuneCache()
        assert "key/a" in fresh and "key/b" in fresh

    def test_cache_jax_version_mismatch_invalidates(self):
        from repro.autotune import AutotuneCache, default_cache_path

        c = AutotuneCache()
        c.put("k", {"schedule": "serial", "source": "analytic"})
        with open(default_cache_path()) as f:
            raw = json.load(f)
        raw["jax"] = "0.0.0-other"
        with open(default_cache_path(), "w") as f:
            json.dump(raw, f)
        assert len(AutotuneCache()) == 0

    def test_measured_tier_records_winner(self):
        import jax
        import jax.numpy as jnp

        from repro.autotune import Autotuner

        mesh = jax.make_mesh((1,), ("tp",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        t = Autotuner()
        d = t.measure(
            x, w, mesh=mesh, axis_name="tp", machine=TPU_V5E,
            schedules=[Schedule.SERIAL], iters=1,
        )
        assert d.source == "measured"
        assert d.schedule is Schedule.SERIAL
        assert d.measured_total_s is not None and d.measured_total_s > 0
        # tier-1 lookup now prefers the measured record
        gemm = GemmShape(64, 16, 32, x.dtype.itemsize)
        d2 = t.pick(gemm, TPU_V5E, group=1)
        assert d2.source == "cache" and d2.schedule is Schedule.SERIAL

    def test_resolve_schedule_autotune_and_fallback(self):
        from repro.overlap.api import resolve_schedule

        s = resolve_schedule(
            "autotune", m=65536, n=8192, k=8192, machine=MI300X, group=8
        )
        assert isinstance(s, Schedule)
        grid = np_evaluate_grid([GemmShape(65536, 8192, 8192)], (MI300X,))
        assert s is GRID_SCHEDULES[int(grid.best_idx()[0, 0])]


class TestCacheSchemaV2:
    """Schema v2: the ragged step-profile digest joined the key schema
    (ISSUE 3).  v1 stores written by PR 2 must be invalidated cleanly —
    no KeyError on old entries, no old decision surfacing under a new
    key — and the clear script must handle both file names."""

    def test_schema_and_default_path_bumped(self):
        from repro.autotune import SCHEMA_VERSION, default_cache_path

        assert SCHEMA_VERSION == 2
        assert default_cache_path().endswith("autotune-v2.json")

    def _write_v1_store(self, directory):
        """A realistic PR-2-era store: v1 schema, profile-less keys."""
        import jax

        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, "autotune-v1.json")
        with open(path, "w") as f:
            json.dump(
                {
                    "schema": 1,
                    "jax": jax.__version__,
                    "entries": {
                        "mi300x-8/g8/m65536/n8192/k8192/b2": {
                            "schedule": "hetero-fused-1d",
                            "source": "measured",
                            "model_total_s": None,
                            "measured_total_s": 1e-9,  # poisoned-fast
                        }
                    },
                },
                f,
            )
        return path

    def test_v1_store_invalidated_cleanly(self):
        """A v1 file on disk never feeds a v2 tuner: the tuner starts
        cold (no KeyError, no stale decision) and re-tunes under the
        profile-suffixed key."""
        from repro.autotune import Autotuner, AutotuneCache

        cache_dir = os.environ["REPRO_AUTOTUNE_CACHE_DIR"]
        self._write_v1_store(cache_dir)
        c = AutotuneCache()
        assert len(c) == 0  # old entries invisible, not an error
        t = Autotuner(cache=c)
        gemm = GemmShape(65536, 8192, 8192)
        d = t.pick(gemm, MI300X)  # same site the v1 store "measured"
        assert d.source == "analytic"  # re-tuned, not the stale winner
        assert all(key.endswith("/u8") for key in c.entries)

    def test_v1_payload_at_v2_path_treated_as_empty(self):
        """Even a v1-schema payload written AT the v2 file name is
        rejected wholesale by the schema stamp."""
        from repro.autotune import AutotuneCache, default_cache_path

        import jax

        path = default_cache_path()
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(
                {
                    "schema": 1,
                    "jax": jax.__version__,
                    "entries": {"old/key": {"schedule": "serial"}},
                },
                f,
            )
        assert len(AutotuneCache()) == 0

    def test_keys_carry_profile_digest(self):
        from repro.autotune import Autotuner, TuneKey
        from repro.core import StepProfile

        gemm = GemmShape(65536, 8192, 8192)
        assert str(TuneKey.for_gemm(gemm, MI300X)).endswith("/b2/u8")
        skew = StepProfile.skewed(8, 4.0)
        key = str(TuneKey.for_gemm(gemm, MI300X, profile=skew))
        assert key.endswith("/" + skew.digest())

        t = Autotuner(backend="numpy")
        d_uniform = t.pick(gemm, MI300X)
        d_skew = t.pick(gemm, MI300X, profile=skew)
        assert len(t.cache.entries) == 2  # distinct keys coexist
        assert d_uniform.source == "analytic"
        assert d_skew.source == "analytic"
        # both hit their own record on re-query
        assert t.pick(gemm, MI300X).source == "cache"
        assert t.pick(gemm, MI300X, profile=skew).source == "cache"

    def test_ragged_pick_not_filtered_by_uniform_runtime_rule(self):
        """Profile-keyed picks go to the ragged kernel path (arbitrary
        quantized chunk sizes), so ficco_linear's one-level-deeper
        divisibility filter must not apply: m=96, g=8 has m%g==0 but
        (m/g)%g!=0 — the uniform pick falls back to serial/p2p, while
        the ragged pick may keep the model's FiCCO winner."""
        from repro.autotune import Autotuner
        from repro.core import StepProfile
        from repro.core.batch import evaluate_ragged_grid, RaggedBatch
        from repro.core.workload import RaggedScenario

        gemm = GemmShape(65544, 8192, 8192)  # m%8==0 but (m/8)%8 != 0
        profile = StepProfile.skewed(8, 2.0)
        t = Autotuner(backend="numpy")
        d = t.pick(gemm, MI300X, profile=profile)
        rb = RaggedBatch.from_ragged_scenarios(
            [RaggedScenario("x", "EP", "t", gemm, profile)]
        )
        grid = evaluate_ragged_grid(rb, (MI300X,))
        best = GRID_SCHEDULES[int(grid.best_idx()[0, 0])]
        assert d.schedule is best  # the model optimum, unfiltered

    def test_padded_profile_shares_cache_key_with_trimmed(self):
        from repro.core import StepProfile

        p = StepProfile.skewed(5, 3.0)
        assert p.padded(9).digest() == p.digest()
        assert StepProfile.uniform(4).padded(8).digest() == "u4"

    def test_clear_script_handles_old_and_new_names(self, tmp_path):
        from repro.autotune import AutotuneCache

        cache_dir = str(tmp_path / "cc")
        v1 = self._write_v1_store(cache_dir)
        env = dict(os.environ, REPRO_AUTOTUNE_CACHE_DIR=cache_dir)
        c = AutotuneCache(path=os.path.join(cache_dir, "autotune-v2.json"))
        c.put("k/u8", {"schedule": "serial", "source": "analytic"})
        v2 = c.path
        assert os.path.exists(v1) and os.path.exists(v2)
        out = subprocess.run(
            [sys.executable, "scripts/clear_autotune_cache.py"],
            env=env, capture_output=True, text=True,
        )
        assert out.returncode == 0, out.stderr
        assert not os.path.exists(v1) and not os.path.exists(v2)


_ROUNDTRIP_SCRIPT = r"""
import functools, json, os, sys
import numpy as np
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.overlap import ficco_linear
from repro.autotune import get_tuner

mesh = jax.make_mesh((8,), ("tp",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
fn = jax.jit(
    shard_map(
        functools.partial(ficco_linear, axis_name="tp", schedule="autotune"),
        mesh=mesh,
        in_specs=(P("tp", None), P(None, "tp")),
        out_specs=P(None, "tp"),
        check_vma=False,
    )
)
out = np.asarray(fn(x, w))
ok = np.allclose(out, np.asarray(x) @ np.asarray(w), rtol=1e-3, atol=1e-3)
t = get_tuner()
print(json.dumps({
    "ok": bool(ok), "hits": t.hits, "misses": t.misses,
    "entries": sorted(t.cache.entries),
    "schedules": [t.cache.entries[k]["schedule"]
                  for k in sorted(t.cache.entries)],
    "sources": [t.cache.entries[k]["source"]
                for k in sorted(t.cache.entries)],
}))
"""


@pytest.mark.slow
class TestFreshProcessRoundtrip:
    def test_ficco_linear_autotune_roundtrips_cache(self, tmp_path):
        """Acceptance: ``ficco_linear(schedule="autotune")`` persists its
        tuned decision and a *fresh process* answers from the cache."""
        env = dict(
            os.environ,
            PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
            REPRO_AUTOTUNE_CACHE_DIR=str(tmp_path / "cache"),
        )

        def run():
            p = subprocess.run(
                [sys.executable, "-c", _ROUNDTRIP_SCRIPT],
                capture_output=True, text=True, env=env,
                cwd=os.path.dirname(os.path.dirname(__file__)),
                timeout=600,
            )
            assert p.returncode == 0, p.stderr[-2000:]
            return json.loads(p.stdout.strip().splitlines()[-1])

        first = run()
        assert first["ok"]
        assert first["misses"] >= 1 and first["hits"] == 0
        assert first["entries"], "no cache entry persisted"
        assert all(s == "analytic" for s in first["sources"])

        second = run()
        assert second["ok"]
        assert second["hits"] >= 1 and second["misses"] == 0
        assert second["entries"] == first["entries"]
        assert second["schedules"] == first["schedules"]
