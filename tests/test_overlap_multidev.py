"""Multi-device overlap tests (8 simulated CPU devices, subprocess-isolated).

The subprocess gets its own XLA_FLAGS so this pytest process keeps seeing a
single device (required by the smoke tests and benchmarks).
"""

import os
import pathlib
import subprocess
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(_ROOT / "tests" / name)],
        capture_output=True,
        text=True,
        timeout=1200,
        env=env,
    )
    if proc.returncode != 0 or "ALL-OK" not in proc.stdout:
        raise AssertionError(
            f"driver {name} failed\n--- stdout ---\n{proc.stdout[-8000:]}"
            f"\n--- stderr ---\n{proc.stderr[-8000:]}"
        )
    return proc.stdout


@pytest.mark.slow
@pytest.mark.multidev
def test_overlap_schedules_multidevice():
    out = _run_driver("multidev_driver.py")
    assert "ok schedules_allclose" in out
    assert "ok ficco_in_model_matches_gspmd" in out
    assert "ok moe_dispatch_equivalence" in out
    assert "ok hlo_uses_async_collectives" in out
