"""Engine facade tests (``repro.core.engine``).

The registry is the single routing point from ``backend=`` strings to
engines; these tests pin (a) the unknown-backend ValueError naming every
registered engine, (b) ScalarEngine/NumpyEngine bit-identity (the scalar
simulator is the ground truth the batched scan replicates exactly), and
(c) the capability flags downstream code keys off.
"""

import numpy as np
import pytest

from repro.core import (
    MI300X,
    TABLE_I,
    TPU_V5E,
    GemmShape,
    engine_names,
    explore_grid,
    get_engine,
    register_engine,
)
from repro.core.engine import Engine, NumpyEngine
from repro.core.workload import ragged_scenario_grid

from grid_asserts import assert_grid_identical

MACHINES = (MI300X, TPU_V5E)
# A small zoo including shapes the simulator rejects (indivisible /
# degenerate decompositions), so the valid-mask paths are exercised.
GEMMS = [
    GemmShape(8192, 57344, 8192),
    GemmShape(1001, 4096, 4096),  # m not divisible by any group
    GemmShape(32, 4096, 4096),  # hetero chunk rows would be 0
    GemmShape(8192, 8192, 8191),  # k indivisible -> 2D masked
]


class TestRegistry:
    def test_builtin_engines_registered(self):
        assert {"scalar", "numpy", "jax"} <= set(engine_names())

    def test_get_engine_singleton(self):
        assert get_engine("numpy") is get_engine("numpy")

    def test_unknown_backend_lists_registered(self):
        with pytest.raises(ValueError) as e:
            get_engine("torch")
        msg = str(e.value)
        for name in engine_names():
            assert name in msg
        assert "torch" in msg

    def test_explore_grid_unknown_backend(self):
        with pytest.raises(ValueError) as e:
            explore_grid(TABLE_I, machines=(MI300X,), backend="bogus")
        assert "numpy" in str(e.value) and "jax" in str(e.value)

    def test_engine_instance_passthrough(self):
        eng = NumpyEngine()
        assert get_engine(eng) is eng
        with pytest.raises(TypeError):
            get_engine(42)

    def test_register_custom_engine(self):
        class Fake(NumpyEngine):
            name = "fake-for-test"

        register_engine("fake-for-test", Fake)
        try:
            assert get_engine("fake-for-test").name == "fake-for-test"
            with pytest.raises(ValueError) as e:
                register_engine("fake-for-test", Fake)  # no silent clobber
            # The collision error lists every registered engine, like
            # get_engine's unknown-name diagnostic.
            for name in engine_names():
                assert name in str(e.value)
            register_engine("fake-for-test", Fake, overwrite=True)
        finally:
            from repro.core import engine as engine_mod

            engine_mod._REGISTRY.pop("fake-for-test", None)
            engine_mod._INSTANCES.pop("fake-for-test", None)

    def test_capability_flags(self):
        np_eng = get_engine("numpy")
        jx_eng = get_engine("jax")
        sc_eng = get_engine("scalar")
        for eng in (np_eng, jx_eng, sc_eng):
            assert isinstance(eng, Engine)
            assert eng.supports_ragged
        assert np_eng.trace_safe and sc_eng.trace_safe
        assert not jx_eng.trace_safe
        assert jx_eng.jit and jx_eng.differentiable
        assert not np_eng.jit and not sc_eng.jit


class TestScalarVsNumpy:
    def test_uniform_bit_identical(self):
        ref = get_engine("numpy").evaluate(GEMMS, MACHINES)
        got = get_engine("scalar").evaluate(GEMMS, MACHINES)
        assert_grid_identical(got, ref)

    def test_table_i_bit_identical(self):
        ref = get_engine("numpy").evaluate(list(TABLE_I), MACHINES)
        got = get_engine("scalar").evaluate(list(TABLE_I), MACHINES)
        assert_grid_identical(got, ref)

    def test_ragged_bit_identical(self):
        fam = ragged_scenario_grid(steps=8, skews=(1.0, 4.0))[:6]
        ref = get_engine("numpy").evaluate(fam, (MI300X,))
        got = get_engine("scalar").evaluate(fam, (MI300X,))
        assert_grid_identical(got, ref)

    def test_dma_into_place_bit_identical(self):
        ref = get_engine("numpy").evaluate(
            GEMMS, (MI300X,), dma_into_place=True
        )
        got = get_engine("scalar").evaluate(
            GEMMS, (MI300X,), dma_into_place=True
        )
        assert_grid_identical(got, ref)

    def test_serial_reference_on_all_invalid_subset(self):
        """serial_comm/serial_gemm are analytic metadata: present even
        when every requested schedule is indivisible for a scenario."""
        from repro.core import Schedule

        args = ([GemmShape(1001, 4096, 4096)], (MI300X,))
        kw = dict(schedules=(Schedule.UNIFORM_FUSED_2D,))
        ref = get_engine("numpy").evaluate(*args, **kw)
        got = get_engine("scalar").evaluate(*args, **kw)
        assert not ref.valid.any()
        assert np.array_equal(got.serial_comm, ref.serial_comm)
        assert np.array_equal(got.serial_gemm, ref.serial_gemm)
        assert (ref.serial_comm > 0).all()

    def test_generator_input_routes_ragged(self):
        """An iterator of RaggedScenario must not silently drop its
        profiles (engines materialize generic iterables first)."""
        from repro.core.batch import RaggedBatch

        fam = ragged_scenario_grid(steps=8, skews=(3.0,))[:4]
        ref = get_engine("numpy").evaluate(fam, (MI300X,))
        got = get_engine("numpy").evaluate(iter(fam), (MI300X,))
        assert isinstance(got.scenarios, RaggedBatch)
        assert np.array_equal(got.total, ref.total, equal_nan=True)


class TestExploreGridThroughRegistry:
    def test_scalar_backend_matches_numpy(self):
        ex_np = explore_grid(TABLE_I, machines=MACHINES, backend="numpy")
        ex_sc = explore_grid(TABLE_I, machines=MACHINES, backend="scalar")
        assert np.array_equal(
            ex_sc.grid.total, ex_np.grid.total, equal_nan=True
        )
        assert np.array_equal(ex_sc.heuristic_idx, ex_np.heuristic_idx)

    def test_engine_kwarg(self):
        ex = explore_grid(
            TABLE_I, machines=(MI300X,), engine=get_engine("numpy")
        )
        assert ex.exact.shape == (len(TABLE_I), 1)

    def test_from_grid_classmethod(self):
        from repro.core.explorer import GridExploration

        grid = get_engine("numpy").evaluate(list(TABLE_I), (MI300X,))
        ex = GridExploration.from_grid(grid)
        ex_ref = explore_grid(TABLE_I, machines=(MI300X,))
        assert np.array_equal(ex.heuristic_idx, ex_ref.heuristic_idx)


class TestCalibratorsThroughRegistry:
    def test_calibrate_tau_backend_param(self):
        from repro.core.heuristics import calibrate_tau

        a = calibrate_tau(MI300X, list(TABLE_I))
        b = calibrate_tau(MI300X, list(TABLE_I), backend="scalar")
        assert a == b

    def test_calibrate_serial_gate_backend_param(self):
        from repro.core.heuristics import calibrate_serial_gate

        a = calibrate_serial_gate((MI300X,), list(TABLE_I))
        b = calibrate_serial_gate(
            (MI300X,), list(TABLE_I), backend="scalar"
        )
        assert a == b

    def test_unknown_backend_raises(self):
        from repro.core.heuristics import calibrate_tau

        with pytest.raises(ValueError):
            calibrate_tau(MI300X, list(TABLE_I), backend="bogus")


class TestShortlist:
    def test_generic_shortlist_numpy(self):
        from repro.core.engine import shortlist

        out = shortlist(TABLE_I[0].gemm, MI300X, backend="numpy")
        assert 1 <= len(out) <= 3
        totals = [t for _, t in out]
        assert totals == sorted(totals)

    def test_shortlist_engine_instance(self):
        from repro.core.engine import shortlist

        out = shortlist(
            TABLE_I[0].gemm, MI300X, engine=get_engine("scalar")
        )
        ref = shortlist(TABLE_I[0].gemm, MI300X, backend="numpy")
        assert out == ref


@pytest.mark.autotune
class TestJaxEngineAgreement:
    def test_jax_matches_numpy_through_registry(self):
        ref = get_engine("numpy").evaluate(GEMMS, MACHINES)
        got = get_engine("jax").evaluate(GEMMS, MACHINES)
        assert np.array_equal(got.valid, ref.valid)
        np.testing.assert_allclose(
            got.total[ref.valid], ref.total[ref.valid], rtol=1e-9
        )

    def test_jaxgrid_shortlist_alias(self):
        from repro.autotune.jaxgrid import shortlist as jx_shortlist
        from repro.core.engine import shortlist as eng_shortlist

        a = jx_shortlist(TABLE_I[0].gemm, MI300X, backend="numpy")
        b = eng_shortlist(TABLE_I[0].gemm, MI300X, backend="numpy")
        assert a == b
