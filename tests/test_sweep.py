"""Sharded sweep subsystem tests (``repro.sweep``).

Edge cases the sharding must survive: scenario counts not divisible by
the shard count, single-shard/single-device degenerate plans, ragged
profiles traveling with their scenario shard, and — the acceptance bar —
sharded evaluation reproducing the unsharded GridResult bit for bit
(in-process over shards/hosts here; over >= 2 forced host devices in the
subprocess driver ``tests/sweep_driver.py``).
"""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import MI300X, TPU_V5E, get_engine
from repro.sweep import (
    ShardSummary,
    concat_batches,
    concat_grid_results,
    merge_summaries,
    owner_of,
    plan_shards,
    shard_batch,
    shards_for_host,
    sweep_grid,
    synthetic_batch,
    synthetic_ragged_batch,
)

from grid_asserts import assert_grid_identical

_ROOT = pathlib.Path(__file__).resolve().parent.parent
MACHINES = (MI300X, TPU_V5E)


class TestShardPlan:
    def test_divisible(self):
        p = plan_shards(12, 4)
        assert p.sizes == (3, 3, 3, 3)
        assert p.bounds[0] == (0, 3) and p.bounds[-1] == (9, 12)
        assert p.pad == 0

    def test_non_divisible_remainder_spread(self):
        p = plan_shards(7, 3)
        assert p.sizes == (3, 2, 2)
        assert sum(p.sizes) == 7
        # contiguous cover, no gaps or overlaps
        assert p.bounds == ((0, 3), (3, 5), (5, 7))

    def test_single_shard_degenerate(self):
        p = plan_shards(5, 1)
        assert p.bounds == ((0, 5),)

    def test_more_shards_than_scenarios(self):
        p = plan_shards(2, 4)
        assert p.sizes == (1, 1, 0, 0)

    def test_equalized_padding(self):
        p = plan_shards(7, 3, equalize=True)
        assert p.padded_size == 3
        assert p.pad == 2
        assert p.bounds == ((0, 3), (3, 6), (6, 7))

    def test_owner_map_deterministic_and_exhaustive(self):
        p = plan_shards(100, 7)
        owned = [shards_for_host(p, h, 3) for h in range(3)]
        flat = sorted(s for o in owned for s in o)
        assert flat == list(range(7))
        assert all(owner_of(s, 3) == h for h, o in enumerate(owned)
                   for s in o)

    def test_invalid_args(self):
        with pytest.raises(ValueError):
            plan_shards(10, 0)
        with pytest.raises(ValueError):
            shards_for_host(plan_shards(4, 2), 2, 2)


class TestSynthetic:
    def test_deterministic(self):
        a = synthetic_batch(100, seed=7)
        b = synthetic_batch(100, seed=7)
        assert np.array_equal(a.m, b.m) and np.array_equal(a.k, b.k)

    def test_ragged_rows_sum_to_one(self):
        rb = synthetic_ragged_batch(64, seed=3)
        np.testing.assert_allclose(rb.frac.sum(axis=1), 1.0, rtol=1e-12)
        assert (rb.frac >= 0).all()

    def test_ragged_single_step_degenerate(self):
        rb = synthetic_ragged_batch(4, steps=1)
        assert np.array_equal(rb.frac, np.ones((4, 1)))
        with pytest.raises(ValueError):
            synthetic_ragged_batch(4, steps=0)


class TestShardedEqualsUnsharded:
    def test_uniform_non_divisible(self):
        sb = synthetic_batch(101, seed=0)  # 101 over 4 shards
        ref = get_engine("numpy").evaluate(sb, MACHINES)
        res = sweep_grid(sb, MACHINES, num_shards=4, mode="gather")
        assert_grid_identical(res.grid, ref)

    def test_uniform_single_shard_degenerate(self):
        sb = synthetic_batch(17, seed=1)
        ref = get_engine("numpy").evaluate(sb, MACHINES)
        res = sweep_grid(sb, MACHINES, num_shards=1, mode="gather")
        assert_grid_identical(res.grid, ref)

    def test_ragged_profiles_travel_with_shards(self):
        rb = synthetic_ragged_batch(37, seed=5)
        ref = get_engine("numpy").evaluate(rb, MACHINES)
        res = sweep_grid(rb, MACHINES, num_shards=5, mode="gather")
        assert_grid_identical(res.grid, ref)
        # the reassembled batch carries the original frac rows exactly
        assert np.array_equal(res.grid.scenarios.frac, rb.frac)
        # and each shard's slice is the matching row block
        parts = shard_batch(rb, res.plan)
        for (start, stop), piece in zip(res.plan.bounds, parts):
            assert np.array_equal(piece.frac, rb.frac[start:stop])

    def test_two_hosts_disjoint_and_exhaustive(self):
        sb = synthetic_batch(41, seed=2)
        ref = get_engine("numpy").evaluate(sb, MACHINES)
        results = [
            sweep_grid(sb, MACHINES, num_shards=4, host_index=h,
                       host_count=2, mode="gather")
            for h in (0, 1)
        ]
        assert results[0].owned == (0, 2) and results[1].owned == (1, 3)
        # hosts cover disjoint scenario sets whose union is everything
        covered = sorted(
            i for res in results for s in res.owned
            for i in range(*res.plan.bounds[s])
        )
        assert covered == list(range(41))
        # reassemble in shard order -> bit-identical full grid
        from repro.sweep.runner import _slice_grid

        by_shard = {}
        for res in results:
            offset = 0
            for shard in res.owned:
                size = res.plan.sizes[shard]
                by_shard[shard] = _slice_grid(
                    res.grid, offset, offset + size
                )
                offset += size
        merged = concat_grid_results([by_shard[i] for i in range(4)])
        assert_grid_identical(merged, ref)

    def test_more_shards_than_scenarios(self):
        sb = synthetic_batch(3, seed=9)
        ref = get_engine("numpy").evaluate(sb, MACHINES)
        res = sweep_grid(sb, MACHINES, num_shards=8, mode="gather")
        assert_grid_identical(res.grid, ref)
        assert sum(s.n_scenarios == 0 for s in res.summaries) == 5

    def test_gather_with_all_empty_owned_shards(self):
        """A host whose round-robin shards are all empty still honors
        the gather contract: an S=0 GridResult, never None."""
        sb = synthetic_batch(1, seed=10)
        res = sweep_grid(
            sb, MACHINES, num_shards=4, host_index=1, host_count=2,
            mode="gather",
        )
        assert res.grid is not None
        assert res.grid.total.shape[1] == 0
        assert res.grid.machines == MACHINES

    def test_scalar_engine_shards_too(self):
        sb = synthetic_batch(6, seed=4)
        ref = get_engine("numpy").evaluate(sb, MACHINES)
        res = sweep_grid(sb, MACHINES, backend="scalar", num_shards=2)
        assert_grid_identical(res.grid, ref)


class TestReduceMode:
    def test_counts_match_gather(self):
        sb = synthetic_batch(60, seed=6)
        ref = get_engine("numpy").evaluate(sb, MACHINES)
        streamed: list[ShardSummary] = []
        res = sweep_grid(
            sb, MACHINES, num_shards=3, mode="reduce",
            on_shard=streamed.append,
        )
        assert res.grid is None
        assert len(streamed) == 3
        merged = merge_summaries(res.summaries)
        best = ref.best_idx()
        want = {
            s.value: int((best == l).sum())
            for l, s in enumerate(ref.schedules)
        }
        assert merged["best_counts"] == want
        assert merged["n_scenarios"] == 60
        assert merged["n_points"] == 60 * len(MACHINES)

    def test_summary_json_roundtrip(self):
        sb = synthetic_batch(10, seed=8)
        res = sweep_grid(sb, MACHINES, num_shards=2, mode="reduce")
        for s in res.summaries:
            assert json.loads(json.dumps(s.to_json()))["n_scenarios"] > 0

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError):
            sweep_grid(synthetic_batch(4), MACHINES, mode="scatter")


class TestConcat:
    def test_concat_batches_ragged_mixed_p(self):
        a = synthetic_ragged_batch(5, seed=1, steps=4)
        b = synthetic_ragged_batch(5, seed=2, steps=8)
        cat = concat_batches([a, b])
        assert cat.frac.shape == (10, 8)
        # zero-padded columns change nothing (masked-scan contract)
        assert np.array_equal(cat.frac[:5, :4], a.frac)
        assert (cat.frac[:5, 4:] == 0).all()

    def test_concat_mismatched_machines_rejected(self):
        sb = synthetic_batch(8, seed=1)
        g1 = get_engine("numpy").evaluate(sb, (MI300X,))
        g2 = get_engine("numpy").evaluate(sb, (TPU_V5E,))
        with pytest.raises(ValueError):
            concat_grid_results([g1, g2])


def test_sweep_cli_smoke(tmp_path):
    """scripts/sweep.py streams per-shard JSON lines + a host summary."""
    out = tmp_path / "sweep.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [
            sys.executable, str(_ROOT / "scripts" / "sweep.py"),
            "--scenarios", "300", "--shards", "4", "--mode", "reduce",
            "--out", str(out),
        ],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    lines = [json.loads(ln) for ln in out.read_text().splitlines()]
    shard_lines = [ln for ln in lines if "shard_summary" in ln]
    host_lines = [ln for ln in lines if "host_summary" in ln]
    assert len(shard_lines) == 4 and len(host_lines) == 1
    assert host_lines[0]["host_summary"]["n_scenarios"] == 300


@pytest.mark.slow
@pytest.mark.multidev
def test_device_sharded_sweep_multidevice():
    """Sharded sweep over 2 forced host devices == unsharded GridResult,
    bit for bit, uniform and ragged (subprocess driver)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(_ROOT / "tests" / "sweep_driver.py")],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    if proc.returncode != 0 or "ALL-OK" not in proc.stdout:
        raise AssertionError(
            f"sweep driver failed\n--- stdout ---\n{proc.stdout[-8000:]}"
            f"\n--- stderr ---\n{proc.stderr[-8000:]}"
        )
    assert "ok uniform_device_sharded_exact" in proc.stdout
    assert "ok ragged_device_sharded_exact" in proc.stdout
    assert "ok hosts_compose_with_devices" in proc.stdout
