"""Observability stack: tracer, metrics, timelines, decision provenance.

Three contracts under test:

* The tracer/metrics layer is schema-stable (exports load in Perfetto,
  snapshots validate) and strictly no-op when disabled.
* The schedule timeline is *exactly* what ``simulate()`` integrates —
  lane sums equal the SimResult busy times, and the inefficiency
  signature's splits close algebraically.
* Every :meth:`Autotuner.pick` tier (cache / analytic / measured /
  heuristic fallback) records provenance matching the tier that actually
  fired, and a recorded decision log replays offline to the same
  choices.
"""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.batch import evaluate_grid
from repro.core.machine import MI300X, TPU_V5E, machine_for_group
from repro.core.schedule_types import STUDIED, Schedule
from repro.core.simulator import schedule_steps, simulate
from repro.core.workload import GemmShape, StepProfile
from repro.obs import audit as obs_audit
from repro.obs import metrics as obs_metrics
from repro.obs import timeline as obs_timeline
from repro.obs import trace as obs_trace

GEMM = GemmShape(16384, 16384, 32768, 2)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cli(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "trace.py"), *argv],
        capture_output=True, text=True, env=env, cwd=REPO,
    )


class TestTracer:
    def test_disabled_is_shared_noop(self):
        assert not obs_trace.enabled()
        sp = obs_trace.span("x", "t")
        assert sp is obs_trace.NULL_SPAN
        with sp as s:
            s.set(anything="goes")  # must not raise, must not record
        assert obs_trace.get_tracer() is None

    def test_span_records_complete_event(self):
        tr = obs_trace.enable()
        with obs_trace.span("work", "cat", foo=1) as sp:
            sp.set(bar=2)
        obs_trace.disable()
        (ev,) = tr.events
        assert ev["name"] == "work" and ev["ph"] == "X"
        assert ev["dur"] >= 0
        assert ev["args"] == {"foo": 1, "bar": 2}
        assert obs_trace.validate_trace(tr.to_json()) == []

    def test_export_roundtrip(self, tmp_path):
        path = str(tmp_path / "t.json")
        tr = obs_trace.enable(path)
        with obs_trace.span("a"):
            pass
        obs_trace.instant("mark", note="here")
        obs_trace.counter("rate", 3.5)
        assert obs_trace.disable() == path
        with open(path) as f:
            obj = json.load(f)
        assert obs_trace.validate_trace(obj) == []
        assert {e["ph"] for e in obj["traceEvents"]} == {"X", "i", "C"}

    def test_validate_catches_violations(self):
        bad = {"traceEvents": [
            {"ph": "X", "ts": 0, "pid": 1, "tid": 0},        # no name
            {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 0},  # no dur
            {"name": "y", "ph": "i", "ts": "zero", "pid": 1, "tid": 0},
        ]}
        errors = obs_trace.validate_trace(bad)
        assert len(errors) >= 3
        joined = "\n".join(errors)
        assert "name" in joined and "dur" in joined and "ts" in joined
        assert obs_trace.validate_trace([]) != []
        assert obs_trace.validate_trace({}) != []


class TestMetrics:
    def test_counter_histogram_snapshot(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("a").inc()
        reg.counter("a").inc(4)
        for v in (1.0, 2.0, 3.0, 10.0):
            reg.histogram("h").observe(v)
        snap = reg.snapshot()
        assert snap["counters"]["a"] == 5
        h = snap["histograms"]["h"]
        assert h["count"] == 4 and h["max"] == 10.0
        assert h["p50"] == 2.0
        assert obs_metrics.validate_snapshot(snap) == []

    def test_export_jsonl_appends(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        reg = obs_metrics.MetricsRegistry()
        reg.counter("c").inc()
        reg.export_jsonl(path)
        reg.counter("c").inc()
        reg.export_jsonl(path)
        with open(path) as f:
            lines = [json.loads(ln) for ln in f]
        assert [ln["counters"]["c"] for ln in lines] == [1, 2]
        for ln in lines:
            assert obs_metrics.validate_snapshot(ln) == []

    def test_validate_snapshot_catches_violations(self):
        assert obs_metrics.validate_snapshot([]) != []
        assert obs_metrics.validate_snapshot({"counters": {}}) != []
        bad = {"ts": 0.0, "counters": {"x": "NaN-ish"}, "histograms": {}}
        assert obs_metrics.validate_snapshot(bad) != []

    def test_gate_agreement_rate(self):
        grid = evaluate_grid(
            [GemmShape(65536, 8192, 8192), GemmShape(512, 512, 512)],
            (MI300X,),
        )
        reg = obs_metrics.MetricsRegistry()
        rate = obs_metrics.observe_gate_agreement(grid, registry=reg)
        assert 0.0 <= rate <= 1.0
        snap = reg.snapshot()
        assert snap["counters"]["gate/points"] == 2
        assert snap["counters"]["gate/agree"] == round(rate * 2)


class TestHistogramReservoir:
    """The bounded-growth + thread-safety contract of Histogram."""

    def test_exact_below_reservoir_size(self):
        h = obs_metrics.Histogram()
        vals = [float(i) for i in range(1000)]
        for v in vals:
            h.observe(v)
        assert h.count == 1000
        assert h.total == sum(vals)
        assert sorted(h.values) == vals  # nothing sampled away yet
        assert h.percentile(0.5) == 499.0  # nearest-rank, exact

    def test_bounded_above_reservoir_size(self):
        r = obs_metrics.RESERVOIR_SIZE
        h = obs_metrics.Histogram(seed=1)
        n = 3 * r
        for i in range(n):
            h.observe(i / n)  # uniform on [0, 1)
        # count/sum/min/max exact, memory bounded.
        assert h.count == n
        assert len(h.values) == r
        assert abs(h.total - sum(i / n for i in range(n))) < 1e-6
        j = h.to_json()
        assert j["count"] == n
        assert j["min"] == 0.0 and j["max"] == (n - 1) / n
        # Percentiles carry the documented ~1/sqrt(K) sampling error;
        # 0.05 is ~6 sigma for K=4096 — loose enough to never flake,
        # tight enough to catch a broken reservoir (e.g. keeping only
        # the newest samples would push p50 toward the tail).
        assert abs(j["p50"] - 0.5) < 0.05
        assert abs(j["p95"] - 0.95) < 0.05

    def test_concurrent_observe_loses_nothing(self):
        import threading

        h = obs_metrics.Histogram()
        c = obs_metrics.Counter()
        n_threads, iters = 8, 20_000

        def worker():
            for _ in range(iters):
                c.inc()
                h.observe(1.0)

        threads = [
            threading.Thread(target=worker) for _ in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = n_threads * iters
        assert c.value == total          # the bare += would lose counts
        assert h.count == total
        assert h.total == float(total)   # every observation summed
        assert len(h.values) == obs_metrics.RESERVOIR_SIZE

    def test_empty_histogram_snapshot(self):
        h = obs_metrics.Histogram()
        assert h.percentile(0.5) == 0.0
        j = h.to_json()
        assert j == {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                     "p50": 0.0, "p95": 0.0}


class TestTimeline:
    @pytest.mark.parametrize("schedule", list(STUDIED))
    def test_lanes_integrate_to_simulate(self, schedule):
        steps = schedule_steps(GEMM, TPU_V5E, schedule, dma=True)
        res = simulate(GEMM, TPU_V5E, schedule, dma=True)
        lanes = obs_timeline.lane_intervals(steps)
        assert math.isclose(
            sum(d for _, d in lanes["comm"]), res.comm_busy, rel_tol=1e-12
        )
        assert math.isclose(
            sum(d for _, d in lanes["compute"]), res.compute_busy,
            rel_tol=1e-12,
        )
        assert math.isclose(
            sum(d for _, d in lanes["exposed"]) + 0.0, res.exposed_comm,
            rel_tol=1e-9, abs_tol=1e-15,
        )

    def test_signature_splits_close(self):
        steps = schedule_steps(
            GEMM, TPU_V5E, Schedule.UNIFORM_FUSED_1D, dma=True
        )
        sig = obs_timeline.inefficiency_signature(steps)
        res = steps.run()
        # contention + decomposition = total comm overhead over serial
        assert math.isclose(
            sig["comm_contention_s"] + sig["comm_decomposition_s"],
            res.comm_busy - res.serial_comm, rel_tol=1e-12, abs_tol=1e-15,
        )
        assert math.isclose(
            sig["gemm_contention_s"] + sig["gemm_decomposition_s"],
            res.compute_busy - res.serial_gemm, rel_tol=1e-12,
            abs_tol=1e-15,
        )
        assert sig["speedup"] == res.speedup
        assert sig["exposure_s"] == res.exposed_comm

    def test_ragged_signature_omits_cil_split(self):
        profile = StepProfile.from_weights((0.5, 0.3, 0.1, 0.1))
        steps = schedule_steps(
            GEMM, TPU_V5E, Schedule.HETERO_FUSED_1D, dma=True,
            profile=profile,
        )
        sig = obs_timeline.inefficiency_signature(steps)
        assert "comm_contention_s" not in sig
        assert sig["total_s"] == steps.run().total

    def test_schedule_timeline_exports_valid_trace(self):
        tr, sig = obs_timeline.schedule_timeline(
            GEMM, TPU_V5E, Schedule.UNIFORM_FUSED_1D
        )
        obj = tr.to_json()
        assert obs_trace.validate_trace(obj) == []
        names = {e["name"] for e in obj["traceEvents"]}
        assert {"a2a_chunk", "gemm_step", "inefficiency_signature"} <= names
        assert sig["schedule"] == "uniform-fused-1d"

    def test_grid_timeline_defaults_to_best(self):
        grid = evaluate_grid([GEMM], (TPU_V5E,))
        tr, sig = obs_timeline.grid_timeline(grid, 0)
        best = grid.schedules[int(grid.best_idx()[0, 0])]
        assert sig["schedule"] == best.value
        assert obs_trace.validate_trace(tr.to_json()) == []


@pytest.mark.autotune
class TestTunerProvenance:
    """One test per tier: the recorded provenance must match the tier
    that actually fired."""

    def _tuner(self, tmp_path, **kw):
        from repro.autotune import Autotuner

        log = obs_audit.AuditLog(str(tmp_path / "decisions.jsonl"))
        return Autotuner(backend="numpy", audit=log, **kw), log

    def _records(self, log):
        return obs_audit.read_audit(log.path)

    def test_analytic_tier(self, tmp_path):
        t, log = self._tuner(tmp_path)
        tr = obs_trace.enable()
        dec = t.pick(GEMM, TPU_V5E, group=8)
        obs_trace.disable()
        assert dec.source == "analytic"
        assert dec.key and dec.shortlist
        (rec,) = self._records(log)
        assert rec["source"] == "analytic"
        assert rec["schedule"] == dec.schedule.value
        assert rec["key"] == dec.key
        spans = [e for e in tr.events if e["name"] == "tuner/pick"]
        assert spans and spans[0]["args"]["tier"] == "analytic"
        assert spans[0]["args"]["cache"] == "miss"
        rates = obs_metrics.tuner_tier_rates()
        assert rates["analytic"] == 1.0
        assert rates.get("cache", 0.0) == 0.0

    def test_cache_tier(self, tmp_path):
        t, log = self._tuner(tmp_path)
        first = t.pick(GEMM, TPU_V5E, group=8)
        tr = obs_trace.enable()
        dec = t.pick(GEMM, TPU_V5E, group=8)
        obs_trace.disable()
        assert dec.source == "cache"
        assert dec.schedule is first.schedule
        recs = self._records(log)
        assert [r["source"] for r in recs] == ["analytic", "cache"]
        spans = [e for e in tr.events if e["name"] == "tuner/pick"]
        assert spans[0]["args"]["cache"] == "hit"
        rates = obs_metrics.tuner_tier_rates()
        assert rates["analytic"] == 0.5 and rates["cache"] == 0.5

    def test_measured_tier(self, tmp_path):
        import jax
        import jax.numpy as jnp

        mesh = jax.make_mesh((1,), ("tp",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        t, log = self._tuner(tmp_path)
        dec = t.measure(
            x, w, mesh=mesh, axis_name="tp", machine=TPU_V5E,
            schedules=[Schedule.SERIAL], iters=1,
        )
        assert dec.source == "measured"
        (rec,) = self._records(log)
        assert rec["kind"] == "measure" and rec["source"] == "measured"
        assert rec["measured_total_s"] > 0
        assert rec["schedule"] == dec.schedule.value

    def test_heuristic_tier_malformed_gate(self, tmp_path, monkeypatch):
        """A broken analytic backend plus a malformed learned gate must
        degrade to the scalar-gated tree — recorded as such."""

        class BrokenGate:
            def __call__(self, *a, **k):
                raise RuntimeError("malformed artifact")

        t, log = self._tuner(tmp_path, gate=BrokenGate())
        monkeypatch.setattr(
            type(t), "_shortlist",
            lambda *a, **k: (_ for _ in ()).throw(RuntimeError("backend")),
        )
        dec = t.pick(GEMM, TPU_V5E, group=8)
        assert dec.source == "heuristic"
        assert dec.gate is not None and dec.gate["kind"] is None
        (rec,) = self._records(log)
        assert rec["source"] == "heuristic"
        assert rec["gate"]["kind"] is None
        # heuristic decisions are never persisted
        assert t.cache.get(dec.key) is None


@pytest.mark.autotune
class TestAuditReplay:
    def test_replay_reproduces_every_pick(self, tmp_path):
        from repro.autotune import Autotuner

        log = obs_audit.AuditLog(str(tmp_path / "decisions.jsonl"))
        t = Autotuner(backend="numpy", audit=log)
        t.pick(GEMM, TPU_V5E, group=8)
        t.pick(GemmShape(512, 512, 512, 2), MI300X)
        t.pick(GEMM, TPU_V5E, group=8)  # cache hit
        records = obs_audit.read_audit(log.path)
        assert obs_audit.validate_audit(records) == []
        res = obs_audit.replay(records)
        assert res.ok
        assert res.replayed == 3 and res.matched == 3
        assert res.mismatches == []

    def test_replay_flags_tampered_log(self, tmp_path):
        from repro.autotune import Autotuner

        log = obs_audit.AuditLog(str(tmp_path / "decisions.jsonl"))
        Autotuner(backend="numpy", audit=log).pick(GEMM, TPU_V5E, group=8)
        records = obs_audit.read_audit(log.path)
        wrong = (
            Schedule.SERIAL.value
            if records[0]["schedule"] != Schedule.SERIAL.value
            else Schedule.UNIFORM_FUSED_1D.value
        )
        records[0]["schedule"] = wrong
        res = obs_audit.replay(records)
        assert not res.ok and res.mismatches

    def test_read_audit_raises_on_malformed(self, tmp_path):
        path = str(tmp_path / "bad.jsonl")
        with open(path, "w") as f:
            f.write('{"kind": "pick"}\n{oops\n')
        with pytest.raises(ValueError):
            obs_audit.read_audit(path)


class TestSweepInstrumentation:
    def test_sweep_grid_emits_spans_and_counters(self):
        from repro.sweep import sweep_grid, synthetic_batch

        sb = synthetic_batch(16, seed=0)
        machines = (TPU_V5E,)
        tr = obs_trace.enable()
        res = sweep_grid(sb, machines, backend="numpy", num_shards=3)
        obs_trace.disable()
        names = [e["name"] for e in tr.events]
        assert names.count("sweep/dispatch") == 3
        assert names.count("sweep/compute") == 3
        assert names.count("sweep/reduce") == 3
        assert names.count("sweep/run") == 1
        snap = obs_metrics.get_metrics().snapshot()
        assert snap["counters"]["sweep/shards"] == 3
        assert snap["counters"]["sweep/scenarios"] == 16
        assert snap["histograms"]["sweep/shard_seconds"]["count"] == 3
        assert len(res.summaries) == 3

    def test_merge_sweep_host_throughput_skew(self):
        sys.path.insert(0, os.path.join(REPO, "scripts"))
        try:
            from merge_sweep import merge_streams
        finally:
            sys.path.pop(0)
        from repro.sweep import ShardSummary

        def host(idx, shards, wall, n):
            return (
                [
                    ShardSummary(s, s * 8, s * 8 + 8, 8, 8, 0.1, 80.0,
                                 {}, 0.5, 1.2)
                    for s in shards
                ],
                [{
                    "host_index": idx, "wall_seconds": wall,
                    "n_scenarios": n, "owned_shards": list(shards),
                    "plan_shards": 4,
                }],
            )

        merged = merge_streams([host(0, (0, 1), 2.0, 16),
                                host(1, (2, 3), 8.0, 16)])
        assert merged["complete"]
        assert merged["host_throughput"] == {"0": 8.0, "1": 2.0}
        assert merged["host_throughput_skew"] == 4.0
        solo = merge_streams([host(0, (0, 1, 2, 3), 2.0, 32)])
        assert solo["host_throughput_skew"] is None


class TestCLI:
    def test_timeline_subcommand(self, tmp_path):
        out = str(tmp_path / "tl.json")
        r = _cli(
            "timeline", "--scenario", "g1", "--schedule",
            "uniform-fused-1d", "--out", out,
        )
        assert r.returncode == 0, r.stderr
        with open(out) as f:
            obj = json.load(f)
        assert obs_trace.validate_trace(obj) == []
        assert any(
            e["name"] == "inefficiency_signature"
            for e in obj["traceEvents"]
        )
        r2 = _cli("validate", out)
        assert r2.returncode == 0, r2.stderr

    def test_validate_rejects_garbage(self, tmp_path):
        bad = str(tmp_path / "bad.json")
        with open(bad, "w") as f:
            json.dump({"traceEvents": [{"ph": "X"}]}, f)
        assert _cli("validate", bad).returncode == 1

    def test_metrics_subcommand(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        reg = obs_metrics.MetricsRegistry()
        reg.counter("tuner/decisions").inc(4)
        reg.counter("tuner/pick.cache").inc(3)
        reg.counter("tuner/pick.analytic").inc()
        reg.export_jsonl(path)
        r = _cli("metrics", path)
        assert r.returncode == 0, r.stderr
        assert "tier rates" in r.stdout
        assert "cache=75.00%" in r.stdout


class TestEnvHooks:
    @pytest.mark.slow
    def test_repro_trace_env_exports_at_exit(self, tmp_path):
        path = str(tmp_path / "env.trace.json")
        env = dict(
            os.environ,
            PYTHONPATH=os.path.join(REPO, "src"),
            REPRO_TRACE=path,
        )
        code = (
            "from repro.core.simulator import simulate\n"
            "from repro.obs import trace\n"
            "assert trace.enabled()\n"
            "with trace.span('x'):\n"
            "    pass\n"
        )
        r = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True, text=True, env=env,
        )
        assert r.returncode == 0, r.stderr
        with open(path) as f:
            assert obs_trace.validate_trace(json.load(f)) == []
