"""Observability stack: tracer, metrics, timelines, decision provenance.

Three contracts under test:

* The tracer/metrics layer is schema-stable (exports load in Perfetto,
  snapshots validate) and strictly no-op when disabled.
* The schedule timeline is *exactly* what ``simulate()`` integrates —
  lane sums equal the SimResult busy times, and the inefficiency
  signature's splits close algebraically.
* Every :meth:`Autotuner.pick` tier (cache / analytic / measured /
  heuristic fallback) records provenance matching the tier that actually
  fired, and a recorded decision log replays offline to the same
  choices.
"""

import copy
import json
import math
import os
import subprocess
import sys
import threading
import types

import numpy as np
import pytest

from repro.core.batch import evaluate_grid
from repro.core.inefficiency import loss_components
from repro.core.machine import MI300X, TPU_V5E, machine_for_group
from repro.core.schedule_types import STUDIED, Schedule
from repro.core.simulator import schedule_steps, simulate
from repro.core.workload import GemmShape, StepProfile
from repro.obs import audit as obs_audit
from repro.obs import metrics as obs_metrics
from repro.obs import sentinel as obs_sentinel
from repro.obs import signature as obs_signature
from repro.obs import timeline as obs_timeline
from repro.obs import trace as obs_trace

GEMM = GemmShape(16384, 16384, 32768, 2)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _script(name, *argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", name), *argv],
        capture_output=True, text=True, env=env, cwd=REPO,
    )


def _cli(*argv):
    return _script("trace.py", *argv)


class TestTracer:
    def test_disabled_is_shared_noop(self):
        assert not obs_trace.enabled()
        sp = obs_trace.span("x", "t")
        assert sp is obs_trace.NULL_SPAN
        with sp as s:
            s.set(anything="goes")  # must not raise, must not record
        assert obs_trace.get_tracer() is None

    def test_span_records_complete_event(self):
        tr = obs_trace.enable()
        with obs_trace.span("work", "cat", foo=1) as sp:
            sp.set(bar=2)
        obs_trace.disable()
        (ev,) = tr.events
        assert ev["name"] == "work" and ev["ph"] == "X"
        assert ev["dur"] >= 0
        assert ev["args"] == {"foo": 1, "bar": 2}
        assert obs_trace.validate_trace(tr.to_json()) == []

    def test_export_roundtrip(self, tmp_path):
        path = str(tmp_path / "t.json")
        tr = obs_trace.enable(path)
        with obs_trace.span("a"):
            pass
        obs_trace.instant("mark", note="here")
        obs_trace.counter("rate", 3.5)
        assert obs_trace.disable() == path
        with open(path) as f:
            obj = json.load(f)
        assert obs_trace.validate_trace(obj) == []
        assert {e["ph"] for e in obj["traceEvents"]} == {"X", "i", "C"}

    def test_validate_catches_violations(self):
        bad = {"traceEvents": [
            {"ph": "X", "ts": 0, "pid": 1, "tid": 0},        # no name
            {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 0},  # no dur
            {"name": "y", "ph": "i", "ts": "zero", "pid": 1, "tid": 0},
        ]}
        errors = obs_trace.validate_trace(bad)
        assert len(errors) >= 3
        joined = "\n".join(errors)
        assert "name" in joined and "dur" in joined and "ts" in joined
        assert obs_trace.validate_trace([]) != []
        assert obs_trace.validate_trace({}) != []


class TestMetrics:
    def test_counter_histogram_snapshot(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("a").inc()
        reg.counter("a").inc(4)
        for v in (1.0, 2.0, 3.0, 10.0):
            reg.histogram("h").observe(v)
        snap = reg.snapshot()
        assert snap["counters"]["a"] == 5
        h = snap["histograms"]["h"]
        assert h["count"] == 4 and h["max"] == 10.0
        assert h["p50"] == 2.0
        assert obs_metrics.validate_snapshot(snap) == []

    def test_export_jsonl_appends(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        reg = obs_metrics.MetricsRegistry()
        reg.counter("c").inc()
        reg.export_jsonl(path)
        reg.counter("c").inc()
        reg.export_jsonl(path)
        with open(path) as f:
            lines = [json.loads(ln) for ln in f]
        assert [ln["counters"]["c"] for ln in lines] == [1, 2]
        for ln in lines:
            assert obs_metrics.validate_snapshot(ln) == []

    def test_validate_snapshot_catches_violations(self):
        assert obs_metrics.validate_snapshot([]) != []
        assert obs_metrics.validate_snapshot({"counters": {}}) != []
        bad = {"ts": 0.0, "counters": {"x": "NaN-ish"}, "histograms": {}}
        assert obs_metrics.validate_snapshot(bad) != []

    def test_gate_agreement_rate(self):
        grid = evaluate_grid(
            [GemmShape(65536, 8192, 8192), GemmShape(512, 512, 512)],
            (MI300X,),
        )
        reg = obs_metrics.MetricsRegistry()
        rate = obs_metrics.observe_gate_agreement(grid, registry=reg)
        assert 0.0 <= rate <= 1.0
        snap = reg.snapshot()
        assert snap["counters"]["gate/points"] == 2
        assert snap["counters"]["gate/agree"] == round(rate * 2)


class TestHistogramReservoir:
    """The bounded-growth + thread-safety contract of Histogram."""

    def test_exact_below_reservoir_size(self):
        h = obs_metrics.Histogram()
        vals = [float(i) for i in range(1000)]
        for v in vals:
            h.observe(v)
        assert h.count == 1000
        assert h.total == sum(vals)
        assert sorted(h.values) == vals  # nothing sampled away yet
        assert h.percentile(0.5) == 499.0  # nearest-rank, exact

    def test_bounded_above_reservoir_size(self):
        r = obs_metrics.RESERVOIR_SIZE
        h = obs_metrics.Histogram(seed=1)
        n = 3 * r
        for i in range(n):
            h.observe(i / n)  # uniform on [0, 1)
        # count/sum/min/max exact, memory bounded.
        assert h.count == n
        assert len(h.values) == r
        assert abs(h.total - sum(i / n for i in range(n))) < 1e-6
        j = h.to_json()
        assert j["count"] == n
        assert j["min"] == 0.0 and j["max"] == (n - 1) / n
        # Percentiles carry the documented ~1/sqrt(K) sampling error;
        # 0.05 is ~6 sigma for K=4096 — loose enough to never flake,
        # tight enough to catch a broken reservoir (e.g. keeping only
        # the newest samples would push p50 toward the tail).
        assert abs(j["p50"] - 0.5) < 0.05
        assert abs(j["p95"] - 0.95) < 0.05

    def test_concurrent_observe_loses_nothing(self):
        import threading

        h = obs_metrics.Histogram()
        c = obs_metrics.Counter()
        n_threads, iters = 8, 20_000

        def worker():
            for _ in range(iters):
                c.inc()
                h.observe(1.0)

        threads = [
            threading.Thread(target=worker) for _ in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = n_threads * iters
        assert c.value == total          # the bare += would lose counts
        assert h.count == total
        assert h.total == float(total)   # every observation summed
        assert len(h.values) == obs_metrics.RESERVOIR_SIZE

    def test_empty_histogram_snapshot(self):
        h = obs_metrics.Histogram()
        assert h.percentile(0.5) == 0.0
        j = h.to_json()
        assert j == {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                     "p50": 0.0, "p95": 0.0}


class TestTimeline:
    @pytest.mark.parametrize("schedule", list(STUDIED))
    def test_lanes_integrate_to_simulate(self, schedule):
        steps = schedule_steps(GEMM, TPU_V5E, schedule, dma=True)
        res = simulate(GEMM, TPU_V5E, schedule, dma=True)
        lanes = obs_timeline.lane_intervals(steps)
        assert math.isclose(
            sum(d for _, d in lanes["comm"]), res.comm_busy, rel_tol=1e-12
        )
        assert math.isclose(
            sum(d for _, d in lanes["compute"]), res.compute_busy,
            rel_tol=1e-12,
        )
        assert math.isclose(
            sum(d for _, d in lanes["exposed"]) + 0.0, res.exposed_comm,
            rel_tol=1e-9, abs_tol=1e-15,
        )

    def test_signature_splits_close(self):
        steps = schedule_steps(
            GEMM, TPU_V5E, Schedule.UNIFORM_FUSED_1D, dma=True
        )
        sig = obs_timeline.inefficiency_signature(steps)
        res = steps.run()
        # contention + decomposition = total comm overhead over serial
        assert math.isclose(
            sig["comm_contention_s"] + sig["comm_decomposition_s"],
            res.comm_busy - res.serial_comm, rel_tol=1e-12, abs_tol=1e-15,
        )
        assert math.isclose(
            sig["gemm_contention_s"] + sig["gemm_decomposition_s"],
            res.compute_busy - res.serial_gemm, rel_tol=1e-12,
            abs_tol=1e-15,
        )
        assert sig["speedup"] == res.speedup
        assert sig["exposure_s"] == res.exposed_comm

    def test_ragged_signature_omits_cil_split(self):
        profile = StepProfile.from_weights((0.5, 0.3, 0.1, 0.1))
        steps = schedule_steps(
            GEMM, TPU_V5E, Schedule.HETERO_FUSED_1D, dma=True,
            profile=profile,
        )
        sig = obs_timeline.inefficiency_signature(steps)
        assert "comm_contention_s" not in sig
        assert sig["total_s"] == steps.run().total

    def test_schedule_timeline_exports_valid_trace(self):
        tr, sig = obs_timeline.schedule_timeline(
            GEMM, TPU_V5E, Schedule.UNIFORM_FUSED_1D
        )
        obj = tr.to_json()
        assert obs_trace.validate_trace(obj) == []
        names = {e["name"] for e in obj["traceEvents"]}
        assert {"a2a_chunk", "gemm_step", "inefficiency_signature"} <= names
        assert sig["schedule"] == "uniform-fused-1d"

    def test_grid_timeline_defaults_to_best(self):
        grid = evaluate_grid([GEMM], (TPU_V5E,))
        tr, sig = obs_timeline.grid_timeline(grid, 0)
        best = grid.schedules[int(grid.best_idx()[0, 0])]
        assert sig["schedule"] == best.value
        assert obs_trace.validate_trace(tr.to_json()) == []


@pytest.mark.autotune
class TestTunerProvenance:
    """One test per tier: the recorded provenance must match the tier
    that actually fired."""

    def _tuner(self, tmp_path, **kw):
        from repro.autotune import Autotuner

        log = obs_audit.AuditLog(str(tmp_path / "decisions.jsonl"))
        return Autotuner(backend="numpy", audit=log, **kw), log

    def _records(self, log):
        return obs_audit.read_audit(log.path)

    def test_analytic_tier(self, tmp_path):
        t, log = self._tuner(tmp_path)
        tr = obs_trace.enable()
        dec = t.pick(GEMM, TPU_V5E, group=8)
        obs_trace.disable()
        assert dec.source == "analytic"
        assert dec.key and dec.shortlist
        (rec,) = self._records(log)
        assert rec["source"] == "analytic"
        assert rec["schedule"] == dec.schedule.value
        assert rec["key"] == dec.key
        spans = [e for e in tr.events if e["name"] == "tuner/pick"]
        assert spans and spans[0]["args"]["tier"] == "analytic"
        assert spans[0]["args"]["cache"] == "miss"
        rates = obs_metrics.tuner_tier_rates()
        assert rates["analytic"] == 1.0
        assert rates.get("cache", 0.0) == 0.0

    def test_cache_tier(self, tmp_path):
        t, log = self._tuner(tmp_path)
        first = t.pick(GEMM, TPU_V5E, group=8)
        tr = obs_trace.enable()
        dec = t.pick(GEMM, TPU_V5E, group=8)
        obs_trace.disable()
        assert dec.source == "cache"
        assert dec.schedule is first.schedule
        recs = self._records(log)
        assert [r["source"] for r in recs] == ["analytic", "cache"]
        spans = [e for e in tr.events if e["name"] == "tuner/pick"]
        assert spans[0]["args"]["cache"] == "hit"
        rates = obs_metrics.tuner_tier_rates()
        assert rates["analytic"] == 0.5 and rates["cache"] == 0.5

    def test_measured_tier(self, tmp_path):
        import jax
        import jax.numpy as jnp

        mesh = jax.make_mesh((1,), ("tp",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        t, log = self._tuner(tmp_path)
        dec = t.measure(
            x, w, mesh=mesh, axis_name="tp", machine=TPU_V5E,
            schedules=[Schedule.SERIAL], iters=1,
        )
        assert dec.source == "measured"
        (rec,) = self._records(log)
        assert rec["kind"] == "measure" and rec["source"] == "measured"
        assert rec["measured_total_s"] > 0
        assert rec["schedule"] == dec.schedule.value

    def test_heuristic_tier_malformed_gate(self, tmp_path, monkeypatch):
        """A broken analytic backend plus a malformed learned gate must
        degrade to the scalar-gated tree — recorded as such."""

        class BrokenGate:
            def __call__(self, *a, **k):
                raise RuntimeError("malformed artifact")

        t, log = self._tuner(tmp_path, gate=BrokenGate())
        monkeypatch.setattr(
            type(t), "_shortlist",
            lambda *a, **k: (_ for _ in ()).throw(RuntimeError("backend")),
        )
        dec = t.pick(GEMM, TPU_V5E, group=8)
        assert dec.source == "heuristic"
        assert dec.gate is not None and dec.gate["kind"] is None
        (rec,) = self._records(log)
        assert rec["source"] == "heuristic"
        assert rec["gate"]["kind"] is None
        # heuristic decisions are never persisted
        assert t.cache.get(dec.key) is None


@pytest.mark.autotune
class TestAuditReplay:
    def test_replay_reproduces_every_pick(self, tmp_path):
        from repro.autotune import Autotuner

        log = obs_audit.AuditLog(str(tmp_path / "decisions.jsonl"))
        t = Autotuner(backend="numpy", audit=log)
        t.pick(GEMM, TPU_V5E, group=8)
        t.pick(GemmShape(512, 512, 512, 2), MI300X)
        t.pick(GEMM, TPU_V5E, group=8)  # cache hit
        records = obs_audit.read_audit(log.path)
        assert obs_audit.validate_audit(records) == []
        res = obs_audit.replay(records)
        assert res.ok
        assert res.replayed == 3 and res.matched == 3
        assert res.mismatches == []

    def test_replay_flags_tampered_log(self, tmp_path):
        from repro.autotune import Autotuner

        log = obs_audit.AuditLog(str(tmp_path / "decisions.jsonl"))
        Autotuner(backend="numpy", audit=log).pick(GEMM, TPU_V5E, group=8)
        records = obs_audit.read_audit(log.path)
        wrong = (
            Schedule.SERIAL.value
            if records[0]["schedule"] != Schedule.SERIAL.value
            else Schedule.UNIFORM_FUSED_1D.value
        )
        records[0]["schedule"] = wrong
        res = obs_audit.replay(records)
        assert not res.ok and res.mismatches

    def test_read_audit_raises_on_malformed(self, tmp_path):
        path = str(tmp_path / "bad.jsonl")
        with open(path, "w") as f:
            f.write('{"kind": "pick"}\n{oops\n')
        with pytest.raises(ValueError):
            obs_audit.read_audit(path)


class TestSweepInstrumentation:
    def test_sweep_grid_emits_spans_and_counters(self):
        from repro.sweep import sweep_grid, synthetic_batch

        sb = synthetic_batch(16, seed=0)
        machines = (TPU_V5E,)
        tr = obs_trace.enable()
        res = sweep_grid(sb, machines, backend="numpy", num_shards=3)
        obs_trace.disable()
        names = [e["name"] for e in tr.events]
        assert names.count("sweep/dispatch") == 3
        assert names.count("sweep/compute") == 3
        assert names.count("sweep/reduce") == 3
        assert names.count("sweep/run") == 1
        snap = obs_metrics.get_metrics().snapshot()
        assert snap["counters"]["sweep/shards"] == 3
        assert snap["counters"]["sweep/scenarios"] == 16
        assert snap["histograms"]["sweep/shard_seconds"]["count"] == 3
        assert len(res.summaries) == 3

    def test_merge_sweep_host_throughput_skew(self):
        sys.path.insert(0, os.path.join(REPO, "scripts"))
        try:
            from merge_sweep import merge_streams
        finally:
            sys.path.pop(0)
        from repro.sweep import ShardSummary

        def host(idx, shards, wall, n):
            return (
                [
                    ShardSummary(s, s * 8, s * 8 + 8, 8, 8, 0.1, 80.0,
                                 {}, 0.5, 1.2)
                    for s in shards
                ],
                [{
                    "host_index": idx, "wall_seconds": wall,
                    "n_scenarios": n, "owned_shards": list(shards),
                    "plan_shards": 4,
                }],
            )

        merged = merge_streams([host(0, (0, 1), 2.0, 16),
                                host(1, (2, 3), 8.0, 16)])
        assert merged["complete"]
        assert merged["host_throughput"] == {"0": 8.0, "1": 2.0}
        assert merged["host_throughput_skew"] == 4.0
        solo = merge_streams([host(0, (0, 1, 2, 3), 2.0, 32)])
        assert solo["host_throughput_skew"] is None


class TestCLI:
    def test_timeline_subcommand(self, tmp_path):
        out = str(tmp_path / "tl.json")
        r = _cli(
            "timeline", "--scenario", "g1", "--schedule",
            "uniform-fused-1d", "--out", out,
        )
        assert r.returncode == 0, r.stderr
        with open(out) as f:
            obj = json.load(f)
        assert obs_trace.validate_trace(obj) == []
        assert any(
            e["name"] == "inefficiency_signature"
            for e in obj["traceEvents"]
        )
        r2 = _cli("validate", out)
        assert r2.returncode == 0, r2.stderr

    def test_validate_rejects_garbage(self, tmp_path):
        bad = str(tmp_path / "bad.json")
        with open(bad, "w") as f:
            json.dump({"traceEvents": [{"ph": "X"}]}, f)
        assert _cli("validate", bad).returncode == 1

    def test_metrics_subcommand(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        reg = obs_metrics.MetricsRegistry()
        reg.counter("tuner/decisions").inc(4)
        reg.counter("tuner/pick.cache").inc(3)
        reg.counter("tuner/pick.analytic").inc()
        reg.export_jsonl(path)
        r = _cli("metrics", path)
        assert r.returncode == 0, r.stderr
        assert "tier rates" in r.stdout
        assert "cache=75.00%" in r.stdout


class TestEnvHooks:
    @pytest.mark.slow
    def test_repro_trace_env_exports_at_exit(self, tmp_path):
        path = str(tmp_path / "env.trace.json")
        env = dict(
            os.environ,
            PYTHONPATH=os.path.join(REPO, "src"),
            REPRO_TRACE=path,
        )
        code = (
            "from repro.core.simulator import simulate\n"
            "from repro.obs import trace\n"
            "assert trace.enabled()\n"
            "with trace.span('x'):\n"
            "    pass\n"
        )
        r = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True, text=True, env=env,
        )
        assert r.returncode == 0, r.stderr
        with open(path) as f:
            assert obs_trace.validate_trace(json.load(f)) == []


class TestLossComponents:
    """The streaming attribution's algebra: components sum back to the
    analytic schedule time, exactly."""

    @pytest.mark.parametrize("schedule", list(STUDIED))
    def test_uniform_lowerings_integrate_exactly(self, schedule):
        steps = schedule_steps(GEMM, TPU_V5E, schedule, dma=True)
        res = steps.run()
        comps = loss_components(
            res, comm_cil=steps.comm_cil, gemm_cil=steps.gemm_cil
        )
        expected = (
            obs_signature.RAGGED_COMPONENTS if steps.gemm_cil is None
            else obs_signature.UNIFORM_COMPONENTS
        )
        assert set(comps) == set(expected)
        assert math.isclose(
            sum(comps.values()), res.total, rel_tol=1e-12
        )

    def test_ragged_lowering_integrates_exactly(self):
        profile = StepProfile.from_weights((0.5, 0.25, 0.15, 0.1))
        steps = schedule_steps(
            GEMM, TPU_V5E, Schedule.HETERO_FUSED_1D, dma=True,
            profile=profile,
        )
        res = steps.run()
        comps = loss_components(res)
        assert set(comps) == set(obs_signature.RAGGED_COMPONENTS)
        assert math.isclose(
            sum(comps.values()), res.total, rel_tol=1e-12
        )

    def test_pinned_busies_close_algebraically(self):
        """Hand-pinned busy times: every split term is exactly the
        documented formula, and both variants sum to the pinned total."""
        res = types.SimpleNamespace(
            total=10.0, compute_busy=6.0, exposed_comm=1.5,
            serial_gemm=4.0,
        )
        comps = loss_components(res, comm_cil=1.2, gemm_cil=1.5)
        assert comps["serial_gemm_s"] == 4.0
        assert comps["gemm_decomposition_s"] == 6.0 / 1.5 - 4.0
        assert comps["gemm_contention_s"] == 6.0 * (1.0 - 1.0 / 1.5)
        assert comps["exposed_comm_s"] == 1.5
        assert comps["comm_tail_s"] == 10.0 - 6.0 - 1.5
        assert math.isclose(sum(comps.values()), 10.0, rel_tol=1e-12)
        ragged = loss_components(res)
        assert set(ragged) == set(obs_signature.RAGGED_COMPONENTS)
        assert math.isclose(sum(ragged.values()), 10.0, rel_tol=1e-12)


class TestSignature:
    SCHED = Schedule.UNIFORM_FUSED_1D

    @pytest.mark.parametrize("schedule", list(STUDIED))
    def test_decision_signature_integrates(self, schedule):
        sig = obs_signature.decision_signature(
            GEMM, TPU_V5E, schedule, group=8
        )
        expected = (
            obs_signature.RAGGED_COMPONENTS if sig["ragged"]
            else obs_signature.UNIFORM_COMPONENTS
        )
        assert set(sig["components"]) == set(expected)
        assert math.isclose(
            sum(sig["components"].values()), sig["total_s"],
            rel_tol=1e-12,
        )
        assert sig["schedule"] == schedule.value

    def test_family_and_scenario_class(self):
        assert obs_signature.machine_family("tpu_v5e/dma") == "tpu_v5e"
        flops = 2.0 * GEMM.m * GEMM.n * GEMM.k
        assert obs_signature.scenario_class(GEMM) == (
            f"uniform/f{int(math.log2(flops))}"
        )
        prof = StepProfile.from_weights((0.6, 0.4), name="skew")
        assert obs_signature.scenario_class(GEMM, prof).startswith("skew/")

    def test_stream_memoizes_and_defers_flush(self):
        stream = obs_signature.SignatureStream()
        for _ in range(5):
            stream.observe_decision(
                GEMM, TPU_V5E, self.SCHED, group=8, source="analytic"
            )
        assert stream.errors == 0
        assert stream.observed == 0      # deferred: nothing folded yet
        assert len(stream.acc) == 0
        snap = stream.snapshot()         # flush + read
        assert stream.observed == 5
        (cell,) = snap["cells"]
        assert cell["count"] == 5
        assert cell["sources"] == {"analytic": 5}
        sig = obs_signature.decision_signature(
            GEMM, TPU_V5E, self.SCHED, group=8
        )
        assert math.isclose(
            cell["total_s"]["sum"], 5 * sig["total_s"], rel_tol=1e-12
        )
        # The deferred fold preserves the integration identity.
        comp_sum = sum(s["sum"] for s in cell["components"].values())
        assert math.isclose(
            comp_sum, cell["total_s"]["sum"], rel_tol=1e-12
        )
        assert obs_signature.validate_signature(snap) == []

    def test_measured_residual_accumulates(self):
        stream = obs_signature.SignatureStream()
        sig = obs_signature.decision_signature(
            GEMM, TPU_V5E, self.SCHED, group=8
        )
        model = sig["total_s"]
        for _ in range(3):
            stream.observe_decision(
                GEMM, TPU_V5E, self.SCHED, group=8, source="measured",
                model_total_s=model, measured_total_s=model * 1.25,
            )
        (cell,) = stream.snapshot()["cells"]
        assert cell["residual"]["count"] == 3
        assert math.isclose(
            cell["residual"]["mean"], math.log(1.25), rel_tol=1e-12
        )
        assert cell["sources"] == {"measured": 3}

    def test_roll_starts_fresh_window(self):
        stream = obs_signature.SignatureStream()
        stream.observe_decision(GEMM, TPU_V5E, self.SCHED, group=8)
        first = stream.roll()
        assert len(first["cells"]) == 1
        assert stream.snapshot()["cells"] == []
        # The memo survives a roll; new observations land in the new
        # window without re-lowering.
        stream.observe_decision(GEMM, TPU_V5E, self.SCHED, group=8)
        assert len(stream.snapshot()["cells"]) == 1

    def test_unlowerable_decision_remembered_not_raised(self):
        bad = GemmShape(4, 4, 4, 2)  # M=4 not divisible 8 ways
        with pytest.raises(ValueError):
            obs_signature.decision_signature(
                bad, TPU_V5E, self.SCHED, group=8
            )
        stream = obs_signature.SignatureStream()
        for _ in range(3):
            stream.observe_decision(bad, TPU_V5E, self.SCHED, group=8)
        assert stream.errors == 1  # lowered once, miss remembered
        assert stream.snapshot()["cells"] == []

    def test_accumulator_bounds_cells(self):
        acc = obs_signature.SignatureAccumulator(max_cells=2)
        for i in range(3):
            acc.observe(
                "fam", f"s{i}", "serial", {"compute_busy_s": 1.0}, 1.0,
                ragged=True,
            )
        assert len(acc) == 2
        assert acc.evicted == 1

    def test_validate_signature_catches_violations(self):
        assert obs_signature.validate_signature([]) != []
        assert obs_signature.validate_signature({"ts": 0.0}) != []
        stream = obs_signature.SignatureStream()
        stream.observe_decision(GEMM, TPU_V5E, self.SCHED, group=8)
        snap = stream.snapshot()
        del snap["cells"][0]["components"]["exposed_comm_s"]
        errs = obs_signature.validate_signature(snap)
        assert any("exposed_comm_s" in e for e in errs)

    def test_overlay_grid(self):
        stream = obs_signature.SignatureStream()
        for sched in (Schedule.SERIAL, self.SCHED):
            for _ in range(2):
                stream.observe_decision(GEMM, TPU_V5E, sched, group=8)
        grid = obs_signature.overlay([stream.snapshot()])
        key = (
            obs_signature.machine_family(TPU_V5E.name),
            obs_signature.scenario_class(GEMM),
        )
        assert key in grid
        row = grid[key]
        assert set(row) == {"serial", self.SCHED.value}
        for agg in row.values():
            assert agg["count"] == 2
            assert agg["mean_total_s"] > 0.0
            # Dominant is a LOSS category, never the work itself.
            assert agg["dominant"] in obs_signature.UNIFORM_COMPONENTS
            assert agg["dominant"] != "serial_gemm_s"
            for f in agg["loss_fractions"].values():
                assert -1e-9 <= f <= 1.0
        # Fully serial: the entire loss is exposed communication.
        assert row["serial"]["dominant"] == "exposed_comm_s"

    def test_enable_disable_roundtrip(self, tmp_path):
        path = str(tmp_path / "sig.jsonl")
        stream = obs_signature.enable_signatures(path)
        assert obs_signature.get_signatures() is stream
        stream.observe_decision(GEMM, TPU_V5E, self.SCHED, group=8)
        snap = obs_signature.disable_signatures()
        assert obs_signature.get_signatures() is None
        assert len(snap["cells"]) == 1
        with open(path) as f:
            lines = [json.loads(ln) for ln in f]
        assert len(lines) == 1
        assert obs_signature.validate_signature(lines[0]) == []

    def test_committed_overhead_within_serve_budget(self):
        """The ISSUE's acceptance bound, deterministically: the
        committed per-observe signature cost is <= 5% of the committed
        per-decision serve cost (both us_per_call in BENCH_sweep.json,
        both gated against regression by benchmarks/run.py)."""
        with open(os.path.join(REPO, "BENCH_sweep.json")) as f:
            bench = json.load(f)
        assert "obs/signature_overhead" in bench
        assert "obs/sentinel_step" in bench
        from benchmarks.run import THROUGHPUT_KEYS

        assert "obs/signature_overhead" in THROUGHPUT_KEYS
        assert "obs/sentinel_step" in THROUGHPUT_KEYS
        assert bench["obs/signature_overhead"] <= (
            0.05 * bench["serve/decisions_per_s"]
        )


@pytest.mark.autotune
class TestSignatureTunerFeed:
    def test_autotuner_pick_feeds_stream_per_tier(self, tmp_path):
        from repro.autotune import Autotuner

        stream = obs_signature.enable_signatures(None)
        t = Autotuner(backend="numpy")
        dec = t.pick(GEMM, TPU_V5E, group=8)
        t.pick(GEMM, TPU_V5E, group=8)  # cache tier feeds too
        snap = stream.snapshot()
        (cell,) = [
            c for c in snap["cells"]
            if c["schedule"] == dec.schedule.value
        ]
        assert cell["sources"].get("analytic") == 1
        assert cell["sources"].get("cache") == 1
        assert cell["count"] == 2
        assert math.isclose(
            sum(s["sum"] for s in cell["components"].values()),
            cell["total_s"]["sum"], rel_tol=1e-12,
        )


class TestSentinel:
    def _sentinel(self, **kw):
        kw.setdefault("min_samples", 4)
        return obs_sentinel.Sentinel(obs_sentinel.SentinelConfig(**kw))

    def test_biased_residuals_trip_and_latch(self):
        s = self._sentinel()
        fired = [
            s.observe_residual(1.0e-3, 2.0e-3, key="k") for _ in range(12)
        ]
        assert any(fired)
        assert s.should_refit()
        assert s.alarms == 1  # latched: exactly one alarm for the episode
        (ev,) = [e for e in s.events if e["kind"] == "sentinel_alarm"]
        assert ev["channel"] == "residual"
        assert ev["n"] >= 4
        assert ev["ewma"] > 0.0  # measured slower than predicted

    def test_unbiased_residuals_stay_quiet(self):
        s = self._sentinel()
        for i in range(200):
            measured = 1.0e-3 * math.exp(0.05 if i % 2 else -0.05)
            s.observe_residual(1.0e-3, measured)
        assert not s.should_refit()
        assert s.alarms == 0

    def test_agreement_channel_alarms_below_floor(self):
        s = self._sentinel()
        assert not s.observe_agreement(0.9)
        fired = [s.observe_agreement(0.1) for _ in range(6)]
        assert any(fired)
        (ev,) = [e for e in s.events if e["kind"] == "sentinel_alarm"]
        assert ev["channel"] == "agreement"

    def test_refit_resets_and_recovery_summarizes(self):
        s = self._sentinel()
        for _ in range(8):
            s.observe_residual(1.0e-3, 2.0e-3)
        assert s.should_refit()
        ev = s.record_refit(
            {"fit_sigma": 0.2, "shortlist": [1, 2]}, trigger="drift"
        )
        assert ev["kind"] == "sentinel_refit"
        assert ev["trigger"] == "drift"
        assert ev["channel"] == "residual"
        assert ev["report"]["fit_sigma"] == 0.2
        assert "shortlist" not in ev["report"]  # non-scalars dropped
        assert not s.should_refit()  # latch cleared
        assert s.state()["cusum_pos"] == 0.0
        for _ in range(4):  # healthy post-refit residuals
            s.observe_residual(1.0e-3, 1.0e-3)
        (rec,) = [
            e for e in s.events if e["kind"] == "sentinel_recovery"
        ]
        assert rec["samples"] == 4
        assert rec["post_mean"] == 0.0
        assert abs(rec["pre_refit_ewma"]) > 0.1  # the drift it recovered from
        assert not s.state()["recovering"]

    def test_degenerate_inputs_skipped(self):
        s = self._sentinel()
        assert not s.observe_residual(0.0, 1.0)
        assert not s.observe_residual(1.0, -1.0)
        assert not s.observe_residual("x", 1.0)
        assert not s.observe_agreement(1.5)
        assert s.state()["n"] == 0

    def test_on_alarm_hook_fires_once_per_episode(self):
        s = self._sentinel()
        kicks = []
        s.on_alarm = lambda: kicks.append(1)
        for _ in range(12):
            s.observe_residual(1.0e-3, 3.0e-3)
        assert kicks == [1]

    def test_validate_export_and_cli(self, tmp_path):
        s = self._sentinel()
        for _ in range(8):
            s.observe_residual(1.0e-3, 2.0e-3)
        s.record_refit({}, trigger="drift")
        for _ in range(4):
            s.observe_residual(1.0e-3, 1.0e-3)
        assert obs_sentinel.validate_sentinel(s.events) == []
        path = str(tmp_path / "sentinel.jsonl")
        n = s.export_jsonl(path)
        with open(path) as f:
            lines = [json.loads(ln) for ln in f]
        assert len(lines) == n == len(s.events)
        kinds = [ln["kind"] for ln in lines]
        assert kinds[0] == "sentinel_alarm"
        assert "sentinel_refit" in kinds and "sentinel_recovery" in kinds
        r = _cli("validate", "--kind", "sentinel", path)
        assert r.returncode == 0, r.stderr

    def test_validate_catches_violations(self):
        errs = obs_sentinel.validate_sentinel([
            {"kind": "nope"},
            {"kind": "sentinel_alarm", "channel": "psychic"},
            "not-an-object",
            {"kind": "sentinel_refit", "ts": 0.0, "n": 0,
             "cusum_pos": 0.0, "cusum_neg": 0.0, "sigma": 0.1},
        ])
        assert len(errs) >= 4
        assert any("trigger" in e for e in errs)


class TestAuditRotation:
    def _fill(self, log, n):
        for i in range(n):
            log.record({
                "kind": "pick", "schedule": "serial",
                "source": "analytic", "machine": "tpu-v5e-axis16",
                "group": 8, "m": 64 + i, "n": 64, "k": 64,
                "dtype_bytes": 2, "key": f"k{i}",
            })

    def test_rotation_bounds_disk_keeps_newest(self, tmp_path):
        path = str(tmp_path / "audit.jsonl")
        log = obs_audit.AuditLog(path, max_bytes=600, keep=2)
        self._fill(log, 40)
        assert log.rotations > 2
        assert os.path.exists(path + ".1")
        assert os.path.exists(path + ".2")
        assert not os.path.exists(path + ".3")  # keep bound enforced
        assert obs_audit.audit_segments(path) == [
            path + ".2", path + ".1", path
        ]
        recs = obs_audit.read_audit_segments(path)
        assert obs_audit.validate_audit(recs) == []
        # Oldest-beyond-keep dropped; what remains is the NEWEST
        # contiguous run, in append order across segments.
        ms = [r["m"] for r in recs]
        assert 0 < len(ms) < 40
        assert ms == list(range(64 + 40 - len(ms), 64 + 40))

    def test_unbounded_by_default_never_rotates(self, tmp_path):
        path = str(tmp_path / "audit.jsonl")
        log = obs_audit.AuditLog(path)  # env unset under conftest
        assert log.max_bytes == 0
        self._fill(log, 20)
        assert log.rotations == 0
        assert obs_audit.audit_segments(path) == [path]
        assert len(obs_audit.read_audit_segments(path)) == 20

    def test_env_defaults(self, tmp_path, monkeypatch):
        monkeypatch.setenv(obs_audit.ENV_MAX_BYTES, "123")
        monkeypatch.setenv(obs_audit.ENV_KEEP, "5")
        log = obs_audit.AuditLog(str(tmp_path / "a.jsonl"))
        assert log.max_bytes == 123 and log.keep == 5
        # Explicit args beat the environment.
        log2 = obs_audit.AuditLog(
            str(tmp_path / "b.jsonl"), max_bytes=0, keep=1
        )
        assert log2.max_bytes == 0 and log2.keep == 1

    def test_aux_kinds_share_the_stream(self):
        recs = [
            {"kind": "adapt_measure", "ts": 1.0},
            {"kind": "sentinel_alarm", "ts": 2.0, "channel": "residual"},
            {"kind": "sentinel_refit", "ts": 3.0, "trigger": "drift"},
        ]
        assert obs_audit.validate_audit(recs) == []
        assert obs_audit.validate_audit([{"kind": "adapt_measure"}]) != []
        res = obs_audit.replay(recs)
        assert res.total == 3 and res.replayed == 0
        assert len(res.skipped) == 3


@pytest.mark.autotune
class TestAuditRotatedReplay:
    def test_replay_spans_segments(self, tmp_path):
        from repro.autotune import Autotuner

        path = str(tmp_path / "decisions.jsonl")
        log = obs_audit.AuditLog(path, max_bytes=300, keep=4)
        t = Autotuner(backend="numpy", audit=log)
        t.pick(GEMM, TPU_V5E, group=8)
        t.pick(GemmShape(512, 512, 512, 2), MI300X)
        t.pick(GEMM, TPU_V5E, group=8)  # cache hit
        assert log.rotations >= 1  # the log actually rolled mid-run
        res = obs_audit.replay(path)  # path form walks all segments
        assert res.ok
        assert res.replayed == 3 and res.matched == 3


class TestSnapshotAtomicity:
    def test_tier_counters_never_tear_under_writer(self):
        """Regression: snapshot() must hold one lock across the whole
        read.  The writer bumps tuner/decisions BEFORE tuner/pick.*, so
        any snapshot where sum(pick.*) exceeds decisions observed a torn
        cut (the bug that made tuner_tier_rates deltas go negative)."""
        reg = obs_metrics.MetricsRegistry()
        stop = threading.Event()

        def writer():
            decisions = reg.counter("tuner/decisions")
            pick = reg.counter("tuner/pick.cache")
            while not stop.is_set():
                decisions.inc()
                pick.inc()

        t = threading.Thread(target=writer)
        t.start()
        try:
            prev = -1
            for _ in range(500):
                c = reg.snapshot()["counters"]
                picks = sum(
                    v for k, v in c.items()
                    if k.startswith("tuner/pick.")
                )
                decisions = c.get("tuner/decisions", 0)
                assert picks <= decisions
                assert decisions >= prev  # snapshots are monotone too
                prev = decisions
        finally:
            stop.set()
            t.join()
        assert prev > 0  # the writer actually ran against the reads


class TestFleetMerge:
    def _host_snap(self, idx, values, shards=10):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("sweep/shards").inc(shards)
        h = reg.histogram("sweep/shard_seconds")
        for v in values:
            h.observe(v)
        return reg.snapshot(
            reservoir=True,
            host={
                "hostname": f"host{idx}", "pid": 100 + idx,
                "host_index": idx,
            },
        )

    def test_counters_bit_exact_percentiles_from_union(self):
        a = self._host_snap(0, [1.0, 2.0, 3.0], shards=7)
        b = self._host_snap(1, [10.0, 20.0, 30.0], shards=5)
        m = obs_metrics.merge_snapshots([a, b])
        assert obs_metrics.validate_merged_snapshot(m) == []
        assert m["hosts"] == 2
        assert m["counters"]["sweep/shards"] == 12
        h = m["histograms"]["sweep/shard_seconds"]
        assert h["count"] == 6
        assert h["sum"] == 66.0
        assert h["min"] == 1.0 and h["max"] == 30.0
        # Union-reservoir nearest-rank percentiles, exact while the
        # per-host reservoirs were exact.
        union = sorted([1.0, 2.0, 3.0, 10.0, 20.0, 30.0])
        assert h["p50"] == union[2]
        assert h["p95"] == union[5]
        assert h["reservoir_n"] == 6
        assert "approx" not in h

    def test_same_host_dedupes_latest_wins(self):
        a = self._host_snap(0, [1.0], shards=3)
        b = dict(a, ts=a["ts"] + 5.0, counters={"sweep/shards": 9})
        m = obs_metrics.merge_snapshots([a, b, a])
        assert m["hosts"] == 1
        assert m["counters"]["sweep/shards"] == 9  # cumulative: latest
        # Idempotent: re-feeding the same stream changes nothing.
        again = obs_metrics.merge_snapshots([a, b, b, a])
        assert again["counters"] == m["counters"]
        assert again["histograms"] == m["histograms"]

    def test_missing_reservoir_falls_back_to_approx(self):
        reg = obs_metrics.MetricsRegistry()
        reg.histogram("sweep/shard_seconds").observe(2.0)
        old = reg.snapshot(host={"hostname": "old", "pid": 1})
        new = self._host_snap(1, [4.0])
        m = obs_metrics.merge_snapshots([old, new])
        h = m["histograms"]["sweep/shard_seconds"]
        assert h["count"] == 2
        assert h["approx"] is True  # flagged, not silently exact-looking
        assert obs_metrics.validate_merged_snapshot(m) == []

    def test_empty_inputs_raise(self):
        with pytest.raises(ValueError):
            obs_metrics.merge_snapshots([])
        with pytest.raises(ValueError):
            obs_trace.merge_traces([])

    def test_schema_forward_backward(self):
        # Backward: a pre-fleet-merge snapshot (no host/clock/reservoir)
        # still validates.
        old = {"ts": 1.0, "counters": {"c": 1}, "histograms": {}}
        assert obs_metrics.validate_snapshot(old) == []
        # Forward: the new identity-stamped reservoir snapshot validates
        # and carries the fields the merge needs.
        reg = obs_metrics.MetricsRegistry()
        reg.histogram("h").observe(1.0)
        new = reg.snapshot(reservoir=True)
        assert obs_metrics.validate_snapshot(new) == []
        assert isinstance(new["host"]["hostname"], str)
        assert isinstance(new["clock"]["epoch_s"], (int, float))
        assert new["histograms"]["h"]["reservoir"] == [1.0]
        # New fields are validated when present.
        assert obs_metrics.validate_snapshot(
            dict(new, host={"hostname": 7})
        ) != []
        bad_h = dict(new["histograms"]["h"], reservoir="x")
        assert obs_metrics.validate_snapshot(
            dict(new, histograms={"h": bad_h})
        ) != []
        # Merged schema: fleet fields required on top of the base.
        merged = obs_metrics.merge_snapshots([new])
        assert obs_metrics.validate_merged_snapshot(merged) == []
        assert obs_metrics.validate_merged_snapshot(old) != []

    def test_merge_traces_offsets_and_pid_namespace(self):
        tr = obs_trace.enable()
        with obs_trace.span("a", "cat"):
            pass
        obs_trace.disable()
        t0 = tr.to_json()
        assert obs_trace.validate_trace(t0) == []
        assert isinstance(t0["host"]["hostname"], str)
        t1 = copy.deepcopy(t0)
        t1["clock"]["epoch0_s"] = t0["clock"]["epoch0_s"] + 1.0
        t1["host"] = dict(t1["host"], host_index=1)
        m = obs_trace.merge_traces([t0, t1])
        assert obs_trace.validate_trace(m) == []
        assert len(m["merged_from"]) == 2
        spans = [e for e in m["traceEvents"] if e.get("ph") == "X"]
        stride = obs_trace._MERGE_PID_STRIDE
        a = [e for e in spans if e["pid"] < stride]
        b = [e for e in spans if e["pid"] >= stride]
        assert len(a) == 1 and len(b) == 1  # per-host pid namespaces
        # The 1s epoch skew lands as exactly 1e6 us of timeline offset.
        assert math.isclose(
            b[0]["ts"] - a[0]["ts"], 1e6, rel_tol=1e-9
        )
        labels = [
            e for e in m["traceEvents"]
            if e.get("ph") == "M" and e.get("name") == "process_name"
        ]
        assert len(labels) >= 2


class TestObsMergeCLI:
    def test_metrics_merge_roundtrip(self, tmp_path):
        paths = []
        for i in range(2):
            p = str(tmp_path / f"m{i}.jsonl")
            reg = obs_metrics.MetricsRegistry()
            reg.counter("sweep/shards").inc(i + 1)
            reg.histogram("sweep/shard_seconds").observe(float(i + 1))
            reg.export_jsonl(
                p, reservoir=True,
                host={"host_index": i, "pid": 100 + i},
            )
            paths.append(p)
        out = str(tmp_path / "merged.json")
        r = _script("obs_merge.py", "metrics", *paths, "--out", out)
        assert r.returncode == 0, r.stderr
        with open(out) as f:
            merged = json.load(f)
        assert obs_metrics.validate_merged_snapshot(merged) == []
        assert merged["hosts"] == 2
        assert merged["counters"]["sweep/shards"] == 3
        r2 = _cli("validate", "--kind", "merged", out)
        assert r2.returncode == 0, r2.stderr

    def test_traces_merge_roundtrip(self, tmp_path):
        tp = str(tmp_path / "t.json")
        tr = obs_trace.enable(tp)
        with obs_trace.span("x"):
            pass
        obs_trace.disable()
        out = str(tmp_path / "merged_trace.json")
        r = _script("obs_merge.py", "traces", tp, tp, "--out", out)
        assert r.returncode == 0, r.stderr
        with open(out) as f:
            merged = json.load(f)
        assert obs_trace.validate_trace(merged) == []
        r2 = _cli("validate", out)
        assert r2.returncode == 0, r2.stderr


class TestSignatureCLI:
    def test_signature_subcommand_renders_overlay(self, tmp_path):
        path = str(tmp_path / "sig.jsonl")
        stream = obs_signature.SignatureStream(path)
        for sched in (Schedule.SERIAL, Schedule.UNIFORM_FUSED_1D):
            stream.observe_decision(GEMM, TPU_V5E, sched, group=8,
                                    source="analytic")
        stream.export_jsonl()
        r = _cli("validate", "--kind", "signature", path)
        assert r.returncode == 0, r.stderr
        r2 = _cli("signature", path)
        assert r2.returncode == 0, r2.stderr
        assert "uniform-fused-1d" in r2.stdout
        assert obs_signature.machine_family(TPU_V5E.name) in r2.stdout
        assert "exposed_comm_s" in r2.stdout

    def test_validate_rejects_malformed_signature(self, tmp_path):
        path = str(tmp_path / "bad.jsonl")
        with open(path, "w") as f:
            f.write(json.dumps({"ts": 0.0}) + "\n")
        assert _cli("validate", "--kind", "signature", path).returncode == 1
