"""repro.learn: features, sufficient statistics, the learned gate,
machine fitting, the measured engine and the sweep aggregator.

Key contracts locked here:

  * gate training from per-shard sufficient statistics (reduce-mode
    sweep, never gathering a grid) is **bit-identical** to training on
    the gathered grid;
  * the learned gate lifts skewed-grid within-5% to >= 75% without
    regressing the uniform grid's ~84%;
  * the LearnedGate artifact JSON round-trips bit-stably and a schema
    bump invalidates cleanly (mirroring the autotune cache v1->v2
    regression tests);
  * ``select_schedule(gate=...)`` == ``select_schedule_batch(gate=...)``
    on a randomized grid;
  * ``fit_machine`` recovers perturbed ``link_bw``/``s_half`` within 5%
    from synthetic measured times;
  * ``get_engine("measured")`` resolves through the registry with the
    right capability flags and does shortlist-only measured evaluation.
"""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import TABLE_I, synthetic_scenarios
from repro.core.batch import RaggedBatch, ScenarioBatch
from repro.core.engine import get_engine
from repro.core.heuristics import (
    select_schedule,
    select_schedule_batch,
    serial_gate_terms_batch,
)
from repro.core.machine import MI300X, TPU_V5E
from repro.core.schedule_types import Schedule
from repro.core.workload import (
    GemmShape,
    StepProfile,
    machine_grid,
    ragged_scenario_grid,
    scenario_grid,
)
from repro.learn import (
    FEATURE_INDEX,
    FEATURE_NAMES,
    GATE_SCHEMA_VERSION,
    GateStats,
    LearnedGate,
    MeasuredRecord,
    clear_machine_gates,
    fit_machine,
    gate_accuracy,
    get_machine_gate,
    grid_features,
    load_gate,
    load_machine_gate,
    machine_family,
    records_from_cache,
    save_gate,
    save_machine_gates,
    scenario_features,
    set_default_gate,
    set_machine_gate,
    sweep_stats,
    synthesize_records,
    train_gate,
    train_gate_from_stats,
    train_machine_gates,
)
from repro.sweep import synthetic_batch, synthetic_ragged_batch

_ROOT = pathlib.Path(__file__).resolve().parent.parent

MACHINES = machine_grid()


@pytest.fixture(autouse=True)
def _no_ambient_state():
    """Deterministic heuristic state: no leaked process-wide default
    gate, and the frozen default TAU / serial-gate thresholds (other
    suites freeze per-machine TAU overrides via ``calibrate_tau``,
    which would make the accuracy assertions order-dependent)."""
    from repro.core import heuristics as _h

    tau = dict(_h._TAU_OVERRIDES)
    sg = dict(_h._SERIAL_GATE_OVERRIDES)
    _h._TAU_OVERRIDES.clear()
    _h._SERIAL_GATE_OVERRIDES.clear()
    set_default_gate(None)
    clear_machine_gates()
    yield
    set_default_gate(None)
    clear_machine_gates()
    _h._TAU_OVERRIDES.clear()
    _h._TAU_OVERRIDES.update(tau)
    _h._SERIAL_GATE_OVERRIDES.clear()
    _h._SERIAL_GATE_OVERRIDES.update(sg)


def _always_serial_gate() -> LearnedGate:
    return LearnedGate(
        tree={"leaf": True, "gate": float("-inf"), "n": 0, "win5": 0,
              "regret_q": 0}
    )


def _trained_gate():
    """The bench training recipe, shrunk: Dirichlet ragged + uniform
    synthetic sweeps, stats-only (reduce mode), greedy tree."""
    stats_r, _ = sweep_stats(
        synthetic_ragged_batch(2000, seed=7), MACHINES, num_shards=8
    )
    stats_u, _ = sweep_stats(
        synthetic_batch(2000, seed=8), MACHINES, num_shards=8
    )
    return train_gate_from_stats(stats_r + stats_u)


# ---------------------------------------------------------------------------
# Features.
# ---------------------------------------------------------------------------


class TestFeatures:
    def test_shapes_and_defaults(self):
        sb = synthetic_batch(32, seed=0)
        X = scenario_features(sb, MI300X)
        assert X.shape == (32, len(FEATURE_NAMES))
        assert np.isfinite(X).all()
        # Uniform batches: imbalance 1, active steps == group.
        assert (X[:, FEATURE_INDEX["imbalance"]] == 1.0).all()
        assert (X[:, FEATURE_INDEX["active_steps"]] == MI300X.group).all()
        assert (X[:, FEATURE_INDEX["group"]] == MI300X.group).all()

    def test_ragged_profile_features(self):
        rb = synthetic_ragged_batch(64, seed=3)
        X = scenario_features(rb, TPU_V5E)
        active = (rb.frac > 0).sum(axis=1)
        assert np.array_equal(X[:, FEATURE_INDEX["active_steps"]], active)
        assert np.allclose(X[:, FEATURE_INDEX["imbalance"]], rb.imbalance)

    def test_matches_heuristic_gate_terms(self):
        """The learner's r/inflate are literally the gate's terms."""
        sb = synthetic_batch(16, seed=1)
        X = scenario_features(sb, MI300X)
        r, inflate = serial_gate_terms_batch(
            sb.m, sb.n, sb.k, sb.dtype_bytes, MI300X
        )
        assert np.array_equal(X[:, FEATURE_INDEX["r"]], r)
        assert np.array_equal(X[:, FEATURE_INDEX["inflate"]], inflate)

    def test_grid_features(self):
        sb = synthetic_batch(12, seed=2)
        grid = get_engine("numpy").evaluate(sb, MACHINES[:3])
        F = grid_features(grid)
        assert F.shape == (12, 3, len(FEATURE_NAMES))
        for j, mach in enumerate(grid.machines):
            assert np.array_equal(F[:, j], scenario_features(sb, mach))


# ---------------------------------------------------------------------------
# Sufficient statistics.
# ---------------------------------------------------------------------------


class TestGateStats:
    def test_sharded_equals_gathered_exactly(self):
        """The tentpole contract: reduce-mode per-shard statistics sum
        to exactly the gathered-grid statistics (integer histograms)."""
        rb = synthetic_ragged_batch(400, seed=11)
        machines = MACHINES[:2]
        sharded, res = sweep_stats(rb, machines, num_shards=7)
        assert res.grid is None  # reduce mode never gathered
        gathered = GateStats.from_grid(
            get_engine("numpy").evaluate(rb, machines)
        )
        assert np.array_equal(sharded.hist, gathered.hist)
        assert sharded.n_points == gathered.n_points
        assert sharded.best_counts == gathered.best_counts

    def test_merge_is_addition(self):
        a = GateStats.from_grid(
            get_engine("numpy").evaluate(
                synthetic_batch(50, seed=1), (MI300X,)
            )
        )
        b = GateStats.from_grid(
            get_engine("numpy").evaluate(
                synthetic_batch(60, seed=2), (MI300X,)
            )
        )
        m = a + b
        assert np.array_equal(m.hist, a.hist + b.hist)
        assert m.n_points == a.n_points + b.n_points

    def test_json_roundtrip(self):
        stats, _ = sweep_stats(
            synthetic_ragged_batch(80, seed=5), MACHINES[:2], num_shards=2
        )
        back = GateStats.from_json(stats.to_json())
        assert np.array_equal(back.hist, stats.hist)
        assert back.to_json() == stats.to_json()

    def test_schema_mismatch_rejected(self):
        stats = GateStats.empty()
        raw = json.loads(stats.to_json())
        raw["schema"] = 999
        with pytest.raises(ValueError):
            GateStats.from_json(json.dumps(raw))

    def test_edge_mismatch_rejected(self):
        """Streams binned on different edges (same shape!) never merge."""
        stats = GateStats.empty()
        raw = json.loads(stats.to_json())
        raw["score_edges"][0] *= 2.0
        with pytest.raises(ValueError):
            GateStats.from_json(json.dumps(raw))
        raw = json.loads(stats.to_json())
        raw["feature_edges"]["otb"][0] *= 2.0
        with pytest.raises(ValueError):
            GateStats.from_json(json.dumps(raw))

    def test_schedule_subset_grid_rejected(self):
        """A grid evaluated on a schedule subset would be misread
        (SCHEDULE_INDEX positions) — refuse it loudly."""
        sub = get_engine("numpy").evaluate(
            synthetic_batch(8, seed=0), (MI300X,),
            schedules=(Schedule.SERIAL, Schedule.UNIFORM_FUSED_1D),
        )
        with pytest.raises(ValueError, match="GRID_SCHEDULES"):
            GateStats.from_grid(sub)

    def test_feature_summary_reports_all(self):
        stats = GateStats.from_grid(
            get_engine("numpy").evaluate(
                synthetic_batch(30, seed=3), (MI300X,)
            )
        )
        summ = stats.feature_summary()
        assert set(summ) == set(FEATURE_NAMES)
        assert summ["imbalance"]["mean"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# The learned gate.
# ---------------------------------------------------------------------------


class TestLearnedGate:
    def test_headline_accuracy(self):
        """Skewed within-5% >= 75% with the learned gate, beating the
        scalar gate, while the uniform grid does not regress."""
        gate = _trained_gate()

        # Held-out capacity-skewed EP family (the bench_ragged grid).
        fam = ragged_scenario_grid(
            steps=8, skews=(1.0, 2.0, 4.0), zipf_alphas=(1.0,),
            top_k=((2, 0.6),),
            scenarios=[s for s in TABLE_I if s.parallelism == "EP"]
            + synthetic_scenarios(12),
        )
        grid_skew = get_engine("numpy").evaluate(
            RaggedBatch.from_ragged_scenarios(fam), MACHINES
        )
        acc_scalar = gate_accuracy(grid_skew)
        acc_learned = gate_accuracy(grid_skew, gate)
        assert acc_learned >= 0.75
        assert acc_learned >= acc_scalar

        # Held-out Dirichlet skew (disjoint seed from training).
        grid_ho = get_engine("numpy").evaluate(
            synthetic_ragged_batch(1500, seed=99), MACHINES
        )
        assert gate_accuracy(grid_ho, gate) >= 0.75
        assert gate_accuracy(grid_ho, gate) > gate_accuracy(grid_ho)

        # Uniform design-space grid: do no harm (~84% scalar baseline).
        grid_unif = get_engine("numpy").evaluate(
            ScenarioBatch.from_scenarios(scenario_grid()), MACHINES
        )
        unif_scalar = gate_accuracy(grid_unif)
        unif_learned = gate_accuracy(grid_unif, gate)
        assert unif_scalar >= 0.82  # the established ~84% baseline
        assert unif_learned >= unif_scalar - 0.005

    def test_stats_trained_equals_grid_trained(self):
        """A gate trained purely from per-shard sufficient statistics
        matches one trained on the gathered grid, bit for bit."""
        rb = synthetic_ragged_batch(500, seed=21)
        machines = MACHINES[:3]
        stats, _ = sweep_stats(rb, machines, num_shards=9)
        g_stats = train_gate_from_stats(stats)
        g_grid = train_gate(get_engine("numpy").evaluate(rb, machines))
        assert g_stats.to_json() == g_grid.to_json()
        assert g_stats == g_grid

    def test_single_leaf_generalizes_scalar_gate(self):
        """max_leaves=1 degenerates to one global threshold — the
        calibrate_serial_gate family."""
        stats, _ = sweep_stats(
            synthetic_batch(300, seed=4), MACHINES[:2], num_shards=3
        )
        gate = train_gate_from_stats(stats, max_leaves=1)
        assert gate.n_leaves == 1
        sb = synthetic_batch(40, seed=5)
        thr = gate.thresholds_batch(
            sb.m, sb.n, sb.k, sb.dtype_bytes, MI300X
        )
        assert np.unique(thr).size == 1

    def test_json_roundtrip_bit_stable(self):
        gate = _trained_gate()
        text = gate.to_json()
        back = LearnedGate.from_json(text)
        assert back.to_json() == text  # bit-stable
        assert back == gate
        # Non-finite thresholds survive the trip too.
        g2 = _always_serial_gate()
        assert LearnedGate.from_json(g2.to_json()).tree["gate"] == float(
            "-inf"
        )

    def test_schema_bump_invalidates_cleanly(self, tmp_path):
        """Mirror of the autotune cache v1->v2 tests: a bumped-schema
        artifact never feeds picks — from_json raises, load_gate yields
        None."""
        from repro.autotune.cache import AutotuneCache

        gate = _always_serial_gate()
        raw = json.loads(gate.to_json())
        raw["version"] = GATE_SCHEMA_VERSION + 1
        with pytest.raises(ValueError):
            LearnedGate.from_json(json.dumps(raw))

        cache = AutotuneCache(path=str(tmp_path / "store.json"))
        cache.put_artifact("gate", "default", raw)
        assert load_gate(cache=cache) is None
        # A current-schema artifact loads fine from the same store.
        save_gate(gate, cache=cache)
        assert load_gate(cache=cache) == gate

    def test_scalar_equals_batch_on_randomized_grid(self):
        """select_schedule(gate=) == select_schedule_batch(gate=) over
        random shapes x machines x profiles."""
        from repro.core.batch import SCHEDULE_INDEX, GRID_SCHEDULES

        gate = _trained_gate()
        rng = np.random.default_rng(17)
        S = 48
        m = 1024 * rng.integers(1, 512, S)
        n = 128 * rng.integers(1, 256, S)
        k = 128 * rng.integers(1, 256, S)
        b = rng.choice([1, 2], size=S)
        profiles = []
        for i in range(S):
            if i % 3 == 0:
                profiles.append(None)  # uniform path
            else:
                steps = int(rng.integers(2, 9))
                w = rng.random(steps) + 0.05
                if i % 3 == 2 and steps > 2:
                    w[-(steps // 3):] = 0.0  # masked tail
                profiles.append(StepProfile.from_weights(w))
        for machine in (MI300X, TPU_V5E, MACHINES[3]):
            imb = np.array(
                [1.0 if p is None else p.imbalance for p in profiles]
            )
            act = np.array(
                [
                    float(machine.group) if p is None else p.active_steps
                    for p in profiles
                ]
            )
            batch = select_schedule_batch(
                m, n, k, b, machine, gate=gate, imbalance=imb,
                active_steps=act,
            )
            for i in range(S):
                dec = select_schedule(
                    GemmShape(int(m[i]), int(n[i]), int(k[i]), int(b[i])),
                    machine, gate=gate, profile=profiles[i],
                )
                assert batch[i] == SCHEDULE_INDEX[dec.schedule], (
                    f"lane {i} on {machine.name}: scalar "
                    f"{dec.schedule} != batch {GRID_SCHEDULES[batch[i]]}"
                )

    def test_autotuner_consults_learned_gate(self, monkeypatch, tmp_path):
        """The tuner's heuristic fallback applies the learned family
        ahead of the hand-tuned scalar gate — explicitly, via the
        process default, and via the cache artifact segment."""
        from repro.autotune.cache import AutotuneCache
        from repro.autotune.tuner import Autotuner

        def fresh_cache(tag):
            return AutotuneCache(path=str(tmp_path / f"{tag}.json"))

        gemm = TABLE_I[1].gemm  # overlap-friendly: scalar gate says FiCCO
        baseline = select_schedule(gemm, MI300X).schedule
        assert baseline is not Schedule.SERIAL

        def boom(self, *a, **kw):
            raise RuntimeError("force the heuristic fallback")

        monkeypatch.setattr(Autotuner, "_shortlist", boom)
        serial_gate = _always_serial_gate()

        # (a) explicit constructor gate
        t = Autotuner(fresh_cache("a"), backend="numpy", gate=serial_gate)
        assert t.pick(gemm, MI300X).schedule is Schedule.SERIAL

        # (b) process-wide default — including one installed only AFTER
        # the tuner already fell back once (the default is re-checked
        # per call, not latched on first resolution).
        t2 = Autotuner(fresh_cache("b"), backend="numpy")
        assert t2.pick(gemm, MI300X).schedule is baseline
        set_default_gate(serial_gate)
        assert t2.pick(gemm, MI300X).schedule is Schedule.SERIAL
        set_default_gate(None)
        assert t2.pick(gemm, MI300X).schedule is baseline

        # (c) persisted artifact in the tuner's cache
        cache = fresh_cache("c")
        save_gate(serial_gate, cache=cache)
        t3 = Autotuner(cache, backend="numpy")
        assert t3.pick(gemm, MI300X).schedule is Schedule.SERIAL

        # without any learned gate the scalar-gate pick returns
        t4 = Autotuner(fresh_cache("d"), backend="numpy")
        assert t4.pick(gemm, MI300X).schedule is baseline

        # a malformed persisted gate must not break pick()'s never-raise
        # contract: it degrades to the scalar-gated tree.
        broken = LearnedGate(
            tree={"feature": "no-such-feature", "edge": 1.0,
                  "lo": {"leaf": True, "gate": 0.0},
                  "hi": {"leaf": True, "gate": 0.0}},
        )
        cache5 = fresh_cache("e")
        save_gate(broken, cache=cache5)
        t5 = Autotuner(cache5, backend="numpy")
        assert t5.pick(gemm, MI300X).schedule is baseline


# ---------------------------------------------------------------------------
# Sim-to-real machine fitting (jitted engine -> marked autotune).
# ---------------------------------------------------------------------------


@pytest.mark.autotune
class TestFit:
    def test_recovers_perturbed_machine_within_5pct(self):
        gemms = [s.gemm for s in synthetic_scenarios(12)]
        true = {"link_bw": MI300X.link_bw * 0.8, "s_half": 3.2e6}
        records = synthesize_records(
            MI300X, gemms,
            (
                Schedule.SERIAL,
                Schedule.UNIFORM_FUSED_1D,
                Schedule.HETERO_UNFUSED_1D,
            ),
            overrides=true,
        )
        fit = fit_machine(
            MI300X, records, params=("link_bw", "s_half"), steps=300
        )
        assert fit.loss < fit.loss0
        for name, target in true.items():
            assert abs(fit.fitted[name] / target - 1.0) < 0.05, (
                name, fit.fitted[name], target,
            )

    def test_fit_roundtrip_and_noise_tolerance(self, tmp_path):
        from repro.autotune.cache import AutotuneCache
        from repro.learn import FitResult, load_fit, save_fit

        gemms = [s.gemm for s in synthetic_scenarios(10)]
        true = {"link_bw": MI300X.link_bw * 1.3}
        records = synthesize_records(
            MI300X, gemms,
            (Schedule.SERIAL, Schedule.UNIFORM_FUSED_1D),
            overrides=true, noise=0.01, seed=3,
        )
        fit = fit_machine(MI300X, records, params=("link_bw",), steps=200)
        assert abs(fit.fitted["link_bw"] / true["link_bw"] - 1.0) < 0.05

        cache = AutotuneCache(path=str(tmp_path / "store.json"))
        save_fit(fit, cache=cache)
        back = load_fit(f"{fit.machine}/g{fit.group}", cache=cache)
        assert back == fit
        # Schema bump invalidates cleanly, like the gate artifact.
        raw = fit.to_payload()
        raw["version"] += 1
        with pytest.raises(ValueError):
            FitResult.from_payload(raw)

    def test_fit_preserves_machine_grid_variant_spec(self):
        """A fit against a machine-grid variant keeps the variant's
        topology/link counts through persistence — rebuilding from the
        base registry machine would swap the comm model."""
        from repro.core.machine import Topology
        from repro.learn import FitResult

        variant = next(
            m for m in MACHINES if m.topology is Topology.TORUS_RING
        )
        gemms = [s.gemm for s in synthetic_scenarios(4)]
        records = synthesize_records(
            variant, gemms, (Schedule.SERIAL,)
        )
        fit = fit_machine(variant, records, params=("link_bw",), steps=5)
        back = FitResult.from_payload(fit.to_payload())
        spec = back.spec()
        assert spec == variant
        assert spec.topology is Topology.TORUS_RING
        assert spec.a2a_links == variant.a2a_links
        mp = back.machine_arrays()
        assert not bool(mp.is_mesh[0])
        assert int(mp.a2a_links[0]) == variant.a2a_links

    def test_records_from_cache_parses_tunekeys(self):
        from repro.autotune.cache import AutotuneCache
        from repro.autotune.tuner import TuneKey

        cache = AutotuneCache()
        mach = MACHINES[0]  # name contains '/' — the parsing edge case
        gemm = GemmShape(8192, 4096, 2048, 2)
        key = str(TuneKey.for_gemm(gemm, mach))
        cache.put(
            key,
            {
                "schedule": "serial",
                "source": "measured",
                "model_total_s": None,
                "measured_total_s": 1.25e-3,
            },
            persist=False,
        )
        cache.put(  # analytic entries don't qualify
            str(TuneKey.for_gemm(GemmShape(1024, 1024, 1024), mach)),
            {"schedule": "serial", "source": "analytic",
             "model_total_s": 1e-3, "measured_total_s": None},
            persist=False,
        )
        # A *named* skewed profile starting with 'u' is not uniform.
        skewed = StepProfile.from_weights(
            [3.0, 1.0, 1.0, 1.0], name="uneven"
        )
        cache.put(
            str(TuneKey.for_gemm(gemm, mach, profile=skewed)),
            {"schedule": "serial", "source": "measured",
             "model_total_s": None, "measured_total_s": 9e-4},
            persist=False,
        )
        recs = records_from_cache(cache, mach.name)
        assert recs == [
            MeasuredRecord(gemm, Schedule.SERIAL, 1.25e-3, mach.group)
        ]


# ---------------------------------------------------------------------------
# The measured engine (registry extension).
# ---------------------------------------------------------------------------


class TestMeasuredEngine:
    def test_registry_resolution_and_flags(self):
        from repro.core.engine import Engine, engine_names

        assert "measured" in engine_names()
        eng = get_engine("measured")
        assert isinstance(eng, Engine)
        assert eng.name == "measured"
        assert eng.supports_ragged
        assert not eng.jit
        assert not eng.differentiable
        assert eng.trace_safe

    def test_shortlist_only_with_measured_override(self):
        from repro.autotune.cache import AutotuneCache
        from repro.autotune.tuner import TuneKey
        from repro.learn.measured import MeasuredEngine

        sb = ScenarioBatch.from_scenarios(synthetic_scenarios(6))
        base = get_engine("numpy").evaluate(sb, (MI300X,))

        cache = AutotuneCache()
        # Persist a "measured" time for scenario 0's analytic winner.
        l0 = int(base.best_idx()[0, 0])
        sched0 = base.schedules[l0]
        t_meas = 0.5 * float(base.total[l0, 0, 0])
        cache.put(
            str(TuneKey.for_gemm(sb.gemm(0), MI300X)),
            {"schedule": sched0.value, "source": "measured",
             "model_total_s": None, "measured_total_s": t_meas},
            persist=False,
        )
        eng = MeasuredEngine(cache, top=3)
        grid = eng.evaluate(sb, (MI300X,))

        # Shortlist-only: at most top+serial schedules stay valid.
        assert (grid.valid.sum(axis=0) <= 4).all()
        serial_l = grid.schedules.index(Schedule.SERIAL)
        assert grid.valid[serial_l].all()
        # The measured record overrides the model time.
        assert grid.total[l0, 0, 0] == t_meas
        # Unmeasured shortlisted entries keep analytic times.
        l1 = int(base.best_idx()[1, 0])
        assert grid.total[l1, 1, 0] == base.total[l1, 1, 0]
        # Invalidated entries are NaN.
        assert np.isnan(grid.total[~grid.valid]).all()

    def test_ragged_shortlist_with_profile_keyed_override(self):
        from repro.autotune.cache import AutotuneCache
        from repro.autotune.tuner import TuneKey
        from repro.learn.measured import MeasuredEngine

        rb = synthetic_ragged_batch(4, seed=0)
        base = get_engine("numpy").evaluate(rb, (MI300X,))
        l0 = int(base.best_idx()[0, 0])
        t_meas = 0.5 * float(base.total[l0, 0, 0])
        cache = AutotuneCache()
        cache.put(
            str(
                TuneKey.for_gemm(
                    rb.gemm(0), MI300X, profile=rb.profile(0)
                )
            ),
            {"schedule": base.schedules[l0].value, "source": "measured",
             "model_total_s": None, "measured_total_s": t_meas},
            persist=False,
        )
        grid = MeasuredEngine(cache, top=3).evaluate(rb, (MI300X,))
        # Profile-keyed measured record overrides the model time.
        assert grid.total[l0, 0, 0] == t_meas
        # Shortlist semantics carry over to ragged grids.
        assert (grid.valid.sum(axis=0) <= 4).all()

    def test_no_reregistration_on_reimport(self):
        import importlib

        import repro.learn

        importlib.reload(repro.learn)  # must not trip the collision guard
        assert get_engine("measured").name == "measured"


# ---------------------------------------------------------------------------
# merge_sweep.py (gather-side aggregator).
# ---------------------------------------------------------------------------


def _run_script(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    return subprocess.run(
        [sys.executable, *args],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def test_merge_sweep_cli_smoke(tmp_path):
    """Two-host sweep streams merge into one complete summary; a
    missing host is detected (and fails under --strict)."""
    outs = []
    for host in (0, 1):
        out = tmp_path / f"sweep_host{host}.jsonl"
        outs.append(out)
        proc = _run_script(
            [
                str(_ROOT / "scripts" / "sweep.py"),
                "--scenarios", "300", "--shards", "6", "--mode", "reduce",
                "--host-index", str(host), "--host-count", "2",
                "--out", str(out),
            ]
        )
        assert proc.returncode == 0, proc.stderr[-4000:]

    merged_path = tmp_path / "merged.json"
    proc = _run_script(
        [
            str(_ROOT / "scripts" / "merge_sweep.py"),
            *map(str, outs), "--out", str(merged_path),
        ]
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    merged = json.loads(merged_path.read_text())
    assert merged["complete"] is True
    assert merged["n_shards"] == 6
    assert merged["n_scenarios"] == 300
    assert merged["missing_shards"] == []
    assert merged["hosts_reporting"] == 2

    # Duplicate streams dedupe by shard id.
    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"),
         str(outs[0]), str(outs[0]), str(outs[1])]
    )
    assert proc.returncode == 0
    dup = json.loads(proc.stdout)
    assert dup["n_scenarios"] == 300
    assert dup["duplicate_shard_reports"] > 0

    # One host missing: incomplete, and --strict exits 3.  The plan
    # shard count comes from the surviving host's summary line, so no
    # --expect-shards is needed to see the gap.
    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"), str(outs[0])]
    )
    assert proc.returncode == 0
    partial = json.loads(proc.stdout)
    assert partial["complete"] is False
    assert partial["expected_shards"] == 6
    assert partial["expected_shards_known"] is True
    assert len(partial["missing_shards"]) == 3
    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"), str(outs[0]),
         "--expect-shards", "6", "--strict"]
    )
    assert proc.returncode == 3

    # Host died before its summary line: trailing losses are
    # undetectable, so the merge refuses to claim completeness.
    torn = tmp_path / "torn.jsonl"
    torn.write_text(
        "\n".join(
            ln for ln in outs[0].read_text().splitlines()
            if "host_summary" not in ln
        )
    )
    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"), str(torn)]
    )
    assert proc.returncode == 0
    t = json.loads(proc.stdout)
    assert t["expected_shards_known"] is False
    assert t["complete"] is False
    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"), str(torn), "--strict"]
    )
    assert proc.returncode == 3


# ---------------------------------------------------------------------------
# Per-machine-family gates.
# ---------------------------------------------------------------------------


class TestMachineFamilyGates:
    def test_family_key_convention(self):
        assert machine_family("mi300x-8/bw0.7") == "mi300x-8"
        assert machine_family("tpu-v5e-axis16") == "tpu-v5e-axis16"
        assert machine_family(MI300X) == MI300X.name.split("/", 1)[0]
        fams = {machine_family(m) for m in machine_grid(groups=(8,))}
        assert fams == {"mi300x-8", "tpu-v5e-axis16"}

    def test_registry_routes_heuristic_tree(self):
        """A registered family gate outranks the scalar machine gate in
        both the scalar and the batched decision tree; explicit
        ``gate=`` / ``serial_gate=`` arguments still win."""
        from repro.core.batch import GRID_SCHEDULES, SCHEDULE_INDEX

        gemm = TABLE_I[1].gemm
        base = select_schedule(gemm, MI300X).schedule
        assert base is not Schedule.SERIAL

        set_machine_gate(MI300X, _always_serial_gate())
        assert get_machine_gate(MI300X) is not None
        assert select_schedule(gemm, MI300X).schedule is Schedule.SERIAL
        arr = lambda v: np.asarray([v])  # noqa: E731
        b = select_schedule_batch(
            arr(gemm.m), arr(gemm.n), arr(gemm.k), arr(gemm.dtype_bytes),
            MI300X,
        )
        assert GRID_SCHEDULES[b[0]] is Schedule.SERIAL
        # scalar-vs-batch agreement holds under ambient family gates
        assert b[0] == SCHEDULE_INDEX[Schedule.SERIAL]

        # Explicit arguments outrank the ambient family gate.
        never = LearnedGate(tree={"leaf": True, "gate": float("inf")})
        assert select_schedule(gemm, MI300X, gate=never).schedule is base
        assert (
            select_schedule(gemm, MI300X, serial_gate=float("inf")).schedule
            is base
        )
        b2 = select_schedule_batch(
            arr(gemm.m), arr(gemm.n), arr(gemm.k), arr(gemm.dtype_bytes),
            MI300X, serial_gate=float("inf"),
        )
        assert GRID_SCHEDULES[b2[0]] is base

        # A family gate for one machine never leaks onto another family.
        assert get_machine_gate(TPU_V5E) is None
        clear_machine_gates()
        assert select_schedule(gemm, MI300X).schedule is base

    def test_per_family_stats_sum_to_global(self):
        """Folding a grid per machine family partitions the global
        statistics exactly (integer histogram, counts, best tallies)."""
        machines = machine_grid(groups=(8,))
        grid = get_engine("numpy").evaluate(
            synthetic_batch(500, seed=11), machines
        )
        full = GateStats.from_grid(grid)
        parts = {}
        for fam in dict.fromkeys(machine_family(m) for m in machines):
            idx = [
                j for j, m in enumerate(machines)
                if machine_family(m) == fam
            ]
            st = GateStats.empty()
            st.update_from_grid(grid, machine_indices=idx)
            parts[fam] = st
        assert len(parts) == 2
        summed = None
        for st in parts.values():
            summed = st if summed is None else summed + st
        assert np.array_equal(summed.hist, full.hist)
        assert summed.n_points == full.n_points
        assert summed.best_counts == full.best_counts

    def test_train_install_persist_roundtrip(self, tmp_path):
        """train_machine_gates records the family in meta, installs on
        request, and persists under namespaced artifact names."""
        from repro.autotune.cache import AutotuneCache

        machines = machine_grid(groups=(8,))
        grid = get_engine("numpy").evaluate(
            synthetic_batch(500, seed=12), machines
        )
        parts = {}
        for fam in dict.fromkeys(machine_family(m) for m in machines):
            idx = [
                j for j, m in enumerate(machines)
                if machine_family(m) == fam
            ]
            st = GateStats.empty()
            st.update_from_grid(grid, machine_indices=idx)
            parts[fam] = st
        gates = train_machine_gates(parts, install=True)
        for fam, g in gates.items():
            assert g.meta["family"] == fam
            assert get_machine_gate(fam) is g

        cache = AutotuneCache(path=str(tmp_path / "c.json"))
        save_machine_gates(gates, cache=cache)
        for fam, g in gates.items():
            loaded = load_machine_gate(fam, cache=cache)
            assert loaded is not None
            assert loaded.to_json() == g.to_json()
        # Perturbed machine names resolve to their family's artifact.
        loaded = load_machine_gate("mi300x-8/bw0.7", cache=cache)
        assert loaded is not None
        assert loaded.to_json() == gates["mi300x-8"].to_json()
        # The namespaced slots never shadow the global "default" gate.
        assert load_gate(cache=cache) is None
        clear_machine_gates()

    def test_tuner_resolves_family_before_default(self, tmp_path,
                                                  monkeypatch):
        """Autotuner.learned_gate(machine): ambient family > ambient
        default > family artifact > default artifact."""
        from repro.autotune.cache import AutotuneCache
        from repro.autotune.tuner import Autotuner

        fam_gate = _always_serial_gate()
        default_gate = LearnedGate(tree={"leaf": True, "gate": 99.0})

        cache = AutotuneCache(path=str(tmp_path / "c.json"))
        save_machine_gates({machine_family(MI300X): fam_gate}, cache=cache)
        save_gate(default_gate, cache=cache)
        t = Autotuner(cache, backend="numpy")
        assert t.learned_gate(MI300X).to_json() == fam_gate.to_json()
        # No machine context -> the default artifact.
        assert t.learned_gate().to_json() == default_gate.to_json()
        # Other families skip the mi300x slot and fall to the default.
        assert t.learned_gate(TPU_V5E).to_json() == default_gate.to_json()

        # Ambient registrations outrank artifacts and are re-checked
        # per call.
        ambient = LearnedGate(tree={"leaf": True, "gate": 7.0})
        set_machine_gate(MI300X, ambient)
        assert t.learned_gate(MI300X).to_json() == ambient.to_json()
        clear_machine_gates()
        assert t.learned_gate(MI300X).to_json() == fam_gate.to_json()

    def test_tuner_fallback_applies_family_gate(self, tmp_path,
                                                monkeypatch):
        """The heuristic fallback picks serial for a machine whose
        family gate says always-serial, and stays unchanged for other
        machines."""
        from repro.autotune.cache import AutotuneCache
        from repro.autotune.tuner import Autotuner

        gemm = TABLE_I[1].gemm
        baseline = select_schedule(gemm, MI300X).schedule
        assert baseline is not Schedule.SERIAL

        def boom(self, *a, **kw):
            raise RuntimeError("force the heuristic fallback")

        monkeypatch.setattr(Autotuner, "_shortlist", boom)
        set_machine_gate(MI300X, _always_serial_gate())
        t = Autotuner(
            AutotuneCache(path=str(tmp_path / "c.json")), backend="numpy"
        )
        assert t.pick(gemm, MI300X).schedule is Schedule.SERIAL
        assert t.pick(gemm, TPU_V5E).schedule is not Schedule.SERIAL
        clear_machine_gates()
        assert t.pick(gemm, MI300X).schedule is baseline


def test_merge_sweep_refuses_mixed_dtypes(tmp_path):
    """Streams recorded at different evaluation dtypes never merge:
    merge_streams raises and the CLI exits 4."""
    sys.path.insert(0, str(_ROOT / "scripts"))
    try:
        import merge_sweep
    finally:
        sys.path.pop(0)
    from repro.sweep import ShardSummary

    def stream(host, dtype, shard):
        summ = ShardSummary(
            shard=shard, start=shard * 10, stop=shard * 10 + 10,
            n_scenarios=10, n_points=20, seconds=0.1,
            scenarios_per_sec=100.0, best_counts={"serial": 20},
            frac_overlap_profitable=0.0, mean_best_speedup=0.0,
        )
        host_summary = {
            "dtype": dtype, "owned_shards": [shard], "plan_shards": 2,
            "n_shards": 1, "n_scenarios": 10, "n_points": 20,
        }
        path = tmp_path / f"host{host}.jsonl"
        path.write_text(
            json.dumps({"shard_summary": summ.to_json()}) + "\n"
            + json.dumps({"host_summary": host_summary}) + "\n"
        )
        return path

    p64 = stream(0, "float64", 0)
    p32 = stream(1, "float32", 1)

    streams = []
    for p in (p64, p32):
        with open(p) as f:
            streams.append(merge_sweep.parse_stream(f))
    with pytest.raises(ValueError, match="mismatched dtypes"):
        merge_sweep.merge_streams(streams)

    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"), str(p64), str(p32)]
    )
    assert proc.returncode == 4
    assert "REFUSED" in proc.stderr
    assert "mismatched dtypes" in proc.stderr

    # Same-dtype streams still merge, recording the dtype; a stream
    # written before dtype recording existed counts as float64.
    proc = _run_script(
        [str(_ROOT / "scripts" / "merge_sweep.py"), str(p64), str(p64)]
    )
    assert proc.returncode == 0
    merged = json.loads(proc.stdout)
    assert merged["dtype"] == "float64"

    legacy = tmp_path / "legacy.jsonl"
    text = p64.read_text().replace('"dtype": "float64", ', "")
    legacy.write_text(text)
    streams = []
    for p in (legacy, p64):
        with open(p) as f:
            streams.append(merge_sweep.parse_stream(f))
    merged = merge_sweep.merge_streams(streams)
    assert merged["dtype"] == "float64"


def test_check_regression_skips_zero_baselines(capsys):
    """A 0.0 baseline value is a placeholder, not a target: the key is
    skipped with a warning instead of gating the run."""
    sys.path.insert(0, str(_ROOT))
    try:
        from benchmarks.run import check_regression
    finally:
        sys.path.pop(0)

    warns = []
    bad = check_regression(
        {"sweepshard/reduce": 123.0, "learn/within5_skewed": 1.0},
        {"sweepshard/reduce": 0.0, "learn/within5_skewed": 0.0},
        warn=warns.append,
    )
    assert bad == []
    assert len(warns) == 2
    assert all("0.0" in w and "skipping" in w for w in warns)

    # Non-zero baselines still gate as before.
    bad = check_regression(
        {"sweepshard/reduce": 123.0},
        {"sweepshard/reduce": 5.0},
        warn=warns.append,
    )
    assert len(bad) == 1 and "sweepshard/reduce" in bad[0]
    # Default warn goes to stderr and must not raise.
    bad = check_regression(
        {"sweepshard/reduce": 1.0}, {"sweepshard/reduce": 0.0}
    )
    assert bad == []
    assert "skipping" in capsys.readouterr().err


class TestRefineGate:
    """refine_gate: per-leaf sub-bin threshold refinement on a grid."""

    def test_never_worse_on_refit_grid(self):
        """Refinement strictly reduces (or preserves) quantized regret
        and never loses within-5% accuracy on the grid it refits to —
        the current threshold is always a candidate."""
        from repro.learn import refine_gate

        rb = synthetic_ragged_batch(400, seed=31)
        machines = MACHINES[:3]
        stats, _ = sweep_stats(rb, machines, num_shards=4)
        gate = train_gate_from_stats(stats)
        grid = get_engine("numpy").evaluate(rb, machines)

        refined = refine_gate(gate, grid)
        info = refined.meta["refine"]
        assert info["regret_q_after"] <= info["regret_q_before"]
        assert info["win5_after"] >= info["win5_before"]
        assert info["n_rows"] == 400 * len(machines)
        assert gate_accuracy(grid, refined) >= gate_accuracy(grid, gate)
        # Same tree structure, only leaf thresholds moved.
        assert refined.n_leaves == gate.n_leaves
        assert refined.features == gate.features

    def test_roundtrip_and_input_untouched(self):
        from repro.learn import refine_gate

        rb = synthetic_ragged_batch(200, seed=32)
        machines = MACHINES[:2]
        stats, _ = sweep_stats(rb, machines, num_shards=2)
        gate = train_gate_from_stats(stats)
        before = gate.to_json()
        grid = get_engine("numpy").evaluate(rb, machines)
        refined = refine_gate(gate, grid, sub_bins=4)
        # The input gate is deep-copied, never mutated.
        assert gate.to_json() == before
        back = LearnedGate.from_json(refined.to_json())
        assert back.to_json() == refined.to_json()

    def test_sub_bins_validated(self):
        from repro.learn import refine_gate

        gate = train_gate_from_stats(
            sweep_stats(
                synthetic_batch(100, seed=33), MACHINES[:2], num_shards=2
            )[0]
        )
        grid = get_engine("numpy").evaluate(
            synthetic_batch(100, seed=33), MACHINES[:2]
        )
        with pytest.raises(ValueError, match="sub_bins"):
            refine_gate(gate, grid, sub_bins=0)
