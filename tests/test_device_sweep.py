"""Accelerator-resident mixed-precision sweep (``repro.sweep.device``).

Contracts locked here:

  * the ``"mixed"`` engine registers with honest capability flags and a
    float64 mode that is **bit-identical** to the jitted jax engine
    (same kernel, same accumulator);
  * float32/bfloat16 evaluation tracks the float64 engine within a
    documented tolerance on the degenerate zoo + Table I + ragged
    profiles, with exactly equal validity masks (masking is integer
    logic, never dtype-dependent);
  * on-device counter-based synthesis is bitwise-identical to its host
    numpy twin (integers exact, Dirichlet fractions to f64 rounding)
    and shard-composable (``start`` slices the global lane stream);
  * the fused synth+eval+stats program reproduces host-side
    ``sweep_stats`` bit-for-bit at float64, and at float32 is exactly
    the statistics of its own materialized grid (the "same-dtype twin"
    — the histogram's feature/score axes are f64 on both sides, so
    count columns never move with the evaluation dtype);
  * a gate trained from mixed-precision device statistics reproduces
    the float64-trained gate: identical tree structure and split edges,
    leaf thresholds within one score-bin quantum;
  * double-buffered dispatch (runner ``overlap_dispatch`` and the fused
    sweep's default) changes throughput, never results;
  * the closed-form uniform pipeline used by the fused path matches the
    scan to float64 rounding and never flips an argmin at grid scale;
  * ``_floor_div`` (vectorizable f64 floor-division) is exact over the
    synthesizable shape range, including the negated-ceil pattern.
"""

import numpy as np
import pytest

from repro.core import TABLE_I, engine_names, get_engine
from repro.core.machine import MI300X, TPU_V5E
from repro.core.batch import ScenarioBatch
from repro.core.workload import GemmShape, machine_grid

from grid_asserts import assert_grid_identical

pytestmark = pytest.mark.autotune

MACHINES = machine_grid(groups=(8,))

# The engine-suite degenerate zoo (indivisible / zero-row shapes) as a
# batch, plus Table I.
ZOO = [
    GemmShape(8192, 57344, 8192),
    GemmShape(1001, 4096, 4096),  # m not divisible by any group
    GemmShape(32, 4096, 4096),  # hetero chunk rows would be 0
    GemmShape(8192, 8192, 8191),  # k indivisible -> 2D masked
]
# Documented differential tolerances vs the float64 engine.  Observed
# worst relative cases are ~3e-7 (f32) and ~2e-2 (bf16 p99); the bounds
# leave room for platform-dependent fma/rounding without masking real
# regressions.  bf16 additionally gets an absolute floor: on
# sub-millisecond ragged totals its ~2^-8 step eps can compound to
# ~17% relative while staying below 0.1 ms absolute.
RTOL = {"float32": 1e-4, "bfloat16": 5e-2}
ATOL = {"float32": 0.0, "bfloat16": 1e-4}


def _zoo_batch() -> ScenarioBatch:
    gemms = ZOO + [s.gemm for s in TABLE_I]
    return ScenarioBatch(
        m=np.asarray([g.m for g in gemms]),
        n=np.asarray([g.n for g in gemms]),
        k=np.asarray([g.k for g in gemms]),
        dtype_bytes=np.asarray([g.dtype_bytes for g in gemms]),
    )


class TestMixedEngineRegistry:
    def test_registered_with_capability_flags(self):
        assert "mixed" in engine_names()
        eng = get_engine("mixed")
        assert eng.name == "mixed"
        assert eng.supports_ragged is True
        assert eng.jit is True
        # Honest flags: reduced-precision totals are not differentiable
        # calibration targets, and the engine manages its own x64 scope.
        assert eng.differentiable is False
        assert eng.trace_safe is False

    def test_dtype_validated(self):
        from repro.core.engine import MixedEngine

        with pytest.raises(ValueError, match="float16"):
            MixedEngine(dtype="float16")


class TestMixedDifferential:
    def test_float64_bit_identical_to_jax_engine(self):
        from repro.core.engine import MixedEngine

        sb = _zoo_batch()
        ref = get_engine("jax").evaluate(sb, MACHINES)
        got = MixedEngine(dtype="float64").evaluate(sb, MACHINES)
        assert_grid_identical(got, ref)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_uniform_zoo_within_tolerance(self, dtype):
        from repro.core.engine import MixedEngine

        sb = _zoo_batch()
        ref = get_engine("jax").evaluate(sb, MACHINES)
        got = MixedEngine(dtype=dtype).evaluate(sb, MACHINES)
        # Valid masks are integer logic: exactly equal at any dtype.
        assert np.array_equal(got.valid, ref.valid)
        a = got.total[got.valid]
        b = ref.total[ref.valid]
        assert np.allclose(a, b, rtol=RTOL[dtype], atol=0.0)
        # Exposed-comm decomposition tracks too (atol guards the
        # fully-hidden entries where exposed == 0).
        ea, eb = got.exposed[got.valid], ref.exposed[ref.valid]
        assert np.allclose(
            ea, eb, rtol=RTOL[dtype], atol=RTOL[dtype] * np.abs(b).max()
        )

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_ragged_within_tolerance(self, dtype):
        from repro.core.engine import MixedEngine
        from repro.sweep import device_ragged_batch

        rb = device_ragged_batch(48, seed=5)
        ref = get_engine("jax").evaluate(rb, MACHINES)
        got = MixedEngine(dtype=dtype).evaluate(rb, MACHINES)
        assert np.array_equal(got.valid, ref.valid)
        a, b = got.total[got.valid], ref.total[ref.valid]
        assert np.allclose(a, b, rtol=RTOL[dtype], atol=ATOL[dtype])


class TestDeviceSynthParity:
    def test_uniform_host_equals_device(self):
        from repro.sweep import device_batch, host_batch

        hb = host_batch(512, seed=9)
        db = device_batch(512, seed=9)
        for f in ("m", "n", "k", "dtype_bytes"):
            assert np.array_equal(getattr(hb, f), getattr(db, f)), f

    def test_ragged_host_equals_device(self):
        from repro.sweep import device_ragged_batch, host_ragged_batch

        hb = host_ragged_batch(256, seed=4)
        db = device_ragged_batch(256, seed=4)
        for f in ("m", "n", "k", "dtype_bytes"):
            assert np.array_equal(getattr(hb, f), getattr(db, f)), f
        # Masked tails are exact; interior fractions agree to f64
        # rounding (host and device sum/normalize in different orders).
        assert np.array_equal(hb.frac == 0.0, db.frac == 0.0)
        assert np.allclose(hb.frac, db.frac, rtol=0, atol=1e-14)

    def test_shard_composability(self):
        """host_batch(k, start=s) is rows [s, s+k) of host_batch(s+k) —
        the property that lets every shard regenerate its own lanes."""
        from repro.sweep import host_batch, host_ragged_batch

        full = host_batch(96, seed=2)
        part = host_batch(32, seed=2, start=48)
        for f in ("m", "n", "k", "dtype_bytes"):
            assert np.array_equal(
                getattr(full, f)[48:80], getattr(part, f)
            ), f
        rfull = host_ragged_batch(64, seed=2)
        rpart = host_ragged_batch(16, seed=2, start=24)
        assert np.array_equal(rfull.frac[24:40], rpart.frac)

    def test_seed_and_field_decorrelation(self):
        from repro.sweep import host_batch

        a = host_batch(256, seed=0)
        b = host_batch(256, seed=1)
        assert not np.array_equal(a.m, b.m)
        assert not np.array_equal(a.m, a.k)


class TestFusedStats:
    def test_float64_fused_equals_host_sweep_stats(self):
        """The tentpole parity: on-device synth + eval + stats at
        float64 is bit-identical to the host reduce-mode pipeline on
        the same lanes."""
        from repro.learn.stats import sweep_stats
        from repro.sweep import host_batch
        from repro.sweep.device import sweep_device_stats

        S = 1024
        dev, dres = sweep_device_stats(
            S, MACHINES, seed=3, dtype="float64", num_shards=2
        )
        host, hres = sweep_stats(
            host_batch(S, seed=3), MACHINES, backend="jax", num_shards=2
        )
        assert np.array_equal(dev.hist, host.hist)
        assert dev.n_points == host.n_points
        assert dev.best_counts == host.best_counts
        # Shard summaries carry the same tallies.
        assert [s.best_counts for s in dres.summaries] == [
            s.best_counts for s in hres.summaries
        ]

    def test_float64_fused_equals_host_sweep_stats_ragged(self):
        from repro.learn.stats import sweep_stats
        from repro.sweep import host_ragged_batch
        from repro.sweep.device import sweep_device_stats

        S = 512
        dev, _ = sweep_device_stats(
            S, MACHINES, seed=6, dtype="float64", ragged=True,
            num_shards=2,
        )
        host, _ = sweep_stats(
            host_ragged_batch(S, seed=6), MACHINES, backend="jax",
            num_shards=2,
        )
        assert np.array_equal(dev.hist, host.hist)
        assert dev.best_counts == host.best_counts

    def test_float32_fused_equals_own_grid_stats(self):
        """Same-dtype twin: the fused f32 statistics are exactly the
        statistics of the f32 grid the mixed engine materializes — the
        histogram's feature/score binning is f64 on both sides, so
        reduced precision moves regret columns only through the times,
        never through the binning."""
        from repro.core.engine import MixedEngine
        from repro.learn.stats import GateStats
        from repro.sweep import device_batch
        from repro.sweep.device import sweep_device_stats

        S = 1024
        dev, _ = sweep_device_stats(S, MACHINES, seed=3, dtype="float32")
        grid = MixedEngine(dtype="float32").evaluate(
            device_batch(S, seed=3), MACHINES
        )
        host = GateStats.from_grid(grid)
        assert np.array_equal(dev.hist, host.hist)
        assert dev.best_counts == host.best_counts

    def test_per_family_partitions_global(self):
        from repro.sweep.device import sweep_device_stats

        S = 1024
        fams, _ = sweep_device_stats(
            S, MACHINES, seed=3, dtype="float32", per_family=True
        )
        glob, _ = sweep_device_stats(S, MACHINES, seed=3, dtype="float32")
        assert set(fams) == {"mi300x-8", "tpu-v5e-axis16"}
        summed = None
        for st in fams.values():
            summed = st if summed is None else summed + st
        assert np.array_equal(summed.hist, glob.hist)
        assert summed.n_points == glob.n_points
        assert summed.best_counts == glob.best_counts

    def test_overlap_dispatch_changes_nothing(self):
        from repro.sweep.device import sweep_device_stats

        S = 1024
        on, ron = sweep_device_stats(
            S, MACHINES, seed=3, dtype="float32", num_shards=4,
            overlap_dispatch=True,
        )
        off, roff = sweep_device_stats(
            S, MACHINES, seed=3, dtype="float32", num_shards=4,
            overlap_dispatch=False,
        )
        assert np.array_equal(on.hist, off.hist)
        assert on.best_counts == off.best_counts
        assert [s.shard for s in ron.summaries] == [
            s.shard for s in roff.summaries
        ]
        assert [s.best_counts for s in ron.summaries] == [
            s.best_counts for s in roff.summaries
        ]

    def test_collect_stats_off_returns_none(self):
        from repro.sweep.device import sweep_device_stats

        stats, res = sweep_device_stats(
            1024, MACHINES, seed=3, dtype="float32", collect_stats=False
        )
        assert stats is None
        assert sum(s.n_scenarios for s in res.summaries) == 1024


class TestGateStability:
    def test_mixed_trained_gate_reproduces_float64(self):
        """Acceptance contract: training from float32 device statistics
        yields the float64 gate's tree — identical structure and split
        edges, leaf thresholds within one score-bin quantum (equal in
        practice; counts are exactly equal because binning is f64 on
        both sides)."""
        from repro.learn.gate import _THRESHOLDS, train_gate_from_stats
        from repro.sweep.device import sweep_device_stats

        S = 32768
        s32, _ = sweep_device_stats(S, MACHINES, dtype="float32")
        s64, _ = sweep_device_stats(S, MACHINES, dtype="float64")
        g32 = train_gate_from_stats(s32)
        g64 = train_gate_from_stats(s64)

        def walk(a, b):
            assert a.get("leaf") == b.get("leaf")
            if a.get("leaf"):
                assert a["n"] == b["n"]
                ia = _THRESHOLDS.index(a["gate"])
                ib = _THRESHOLDS.index(b["gate"])
                assert abs(ia - ib) <= 1, (a["gate"], b["gate"])
                return
            assert a["feature"] == b["feature"]
            assert a["edge"] == b["edge"]
            walk(a["lo"], b["lo"])
            walk(a["hi"], b["hi"])

        assert g32.n_leaves == g64.n_leaves
        walk(g32.tree, g64.tree)


class TestRunnerOverlap:
    def test_numpy_engine_flag_is_inert(self):
        """overlap_dispatch on a single-phase engine falls back to the
        eager path bit-for-bit (gather mode compares full grids)."""
        from repro.sweep import sweep_grid, synthetic_batch

        sb = synthetic_batch(300, seed=1)
        on = sweep_grid(
            sb, MACHINES, num_shards=5, mode="gather",
            overlap_dispatch=True,
        )
        off = sweep_grid(sb, MACHINES, num_shards=5, mode="gather")
        assert_grid_identical(on.grid, off.grid)

        def stable(s):
            # Everything but the wall-clock fields is deterministic.
            d = s.to_json()
            d.pop("seconds"), d.pop("scenarios_per_sec")
            return d

        assert [stable(s) for s in on.summaries] == [
            stable(s) for s in off.summaries
        ]

    def test_mixed_engine_two_phase_identical(self):
        from repro.core.engine import MixedEngine
        from repro.sweep import device_batch, sweep_grid

        sb = device_batch(512, seed=7)
        eng = MixedEngine(dtype="float32")
        on = sweep_grid(
            sb, MACHINES, engine=eng, num_shards=4, mode="gather",
            overlap_dispatch=True,
        )
        off = sweep_grid(sb, MACHINES, engine=eng, num_shards=4,
                         mode="gather")
        assert_grid_identical(on.grid, off.grid)

    def test_empty_shards_keep_summary_order(self):
        from repro.sweep import device_batch, sweep_grid

        sb = device_batch(3, seed=0)
        res = sweep_grid(
            sb, MACHINES, num_shards=6, mode="reduce",
            overlap_dispatch=True,
        )
        assert [s.shard for s in res.summaries] == list(range(6))
        assert sum(s.n_scenarios for s in res.summaries) == 3


class TestClosedFormPipeline:
    def test_matches_scan_and_never_flips_argmin(self):
        from repro.autotune import jaxgrid
        from repro.sweep import host_batch

        sb = host_batch(2048, seed=13)
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            mp = jaxgrid.machine_arrays(MACHINES)
            g_max = max(m.group for m in MACHINES)
            scan = jaxgrid.evaluate_grid_raw(sb, mp, g_max=g_max)
            closed = jaxgrid.evaluate_grid_raw(
                sb, mp, g_max=g_max, closed_form=True
            )
        # Raw layout: (total, comm_busy, compute_busy, exposed, steps,
        # valid, ...), machine-major (M, L, S).
        t_s, t_c = np.asarray(scan[0]), np.asarray(closed[0])
        v_s, v_c = np.asarray(scan[5]), np.asarray(closed[5])
        assert np.array_equal(v_s, v_c)
        a, b = t_c[v_c], t_s[v_s]
        denom = np.where(b == 0.0, 1.0, np.abs(b))
        assert np.nanmax(np.abs(a - b) / denom) < 1e-12
        # Ranking is untouched: same argmin on every (machine, lane).
        ts = np.where(v_s, t_s, np.inf)
        tc = np.where(v_c, t_c, np.inf)
        assert np.array_equal(
            np.argmin(ts, axis=1), np.argmin(tc, axis=1)
        )

    def test_floor_div_exact(self):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.autotune.jaxgrid import _floor_div

        rng = np.random.default_rng(0)
        a = rng.integers(0, 1 << 26, size=4096).astype(np.int64)
        b = rng.integers(1, 1 << 20, size=4096).astype(np.int64)
        with enable_x64():
            got = np.asarray(_floor_div(jnp.asarray(a), jnp.asarray(b)))
            assert np.array_equal(got, a // b)
            # The negated-ceil pattern: -_floor_div(-a, b) == ceil(a/b).
            ceil = np.asarray(
                -_floor_div(jnp.asarray(-a), jnp.asarray(b))
            )
            assert np.array_equal(ceil, -((-a) // b))


def test_sweep_cli_mixed_dtype_and_synth_device(tmp_path):
    """scripts/sweep.py drives the mixed engine end-to-end: --dtype
    rides --backend mixed (and is rejected otherwise), --synth-device
    swaps in the counter-based stream, and the host summary records
    both so merge_sweep.py can enforce no-silent-mixing."""
    import json
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )

    def run(*args):
        return subprocess.run(
            [sys.executable, str(root / "scripts" / "sweep.py"), *args],
            capture_output=True, text=True, timeout=600, env=env,
        )

    out = tmp_path / "sweep.jsonl"
    proc = run(
        "--scenarios", "64", "--shards", "2", "--mode", "reduce",
        "--backend", "mixed", "--dtype", "float32", "--synth-device",
        "--overlap-dispatch", "--out", str(out),
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    host = [
        json.loads(ln)["host_summary"]
        for ln in out.read_text().splitlines()
        if "host_summary" in ln
    ]
    assert len(host) == 1
    assert host[0]["dtype"] == "float32"
    assert host[0]["synth"] == "device"
    assert host[0]["n_scenarios"] == 64

    # Reduced precision without the mixed engine is a usage error.
    proc = run("--scenarios", "8", "--dtype", "bfloat16")
    assert proc.returncode == 2
    assert "requires --backend mixed" in proc.stderr


class TestDeviceMergeStats:
    """device_merge_stats: on-device multi-host GateStats reduction."""

    def _stats_list(self, n=3):
        from repro.learn import sweep_stats
        from repro.sweep import synthetic_ragged_batch

        return [
            sweep_stats(
                synthetic_ragged_batch(60, seed=40 + i),
                MACHINES[:2],
                num_shards=2,
            )[0]
            for i in range(n)
        ]

    def test_bit_identical_to_host_fold(self):
        import functools

        from repro.learn import GateStats
        from repro.sweep import device_merge_stats

        stats = self._stats_list(3)
        got = device_merge_stats(stats)
        want = functools.reduce(GateStats.merge, stats)
        assert np.array_equal(got.hist, want.hist)
        assert np.array_equal(got.moments, want.moments)
        assert got.best_counts == want.best_counts
        assert got.n_points == want.n_points
        assert got.schema == want.schema

    def test_single_and_empty_inputs(self):
        from repro.learn import GateStats
        from repro.sweep import device_merge_stats

        (only,) = self._stats_list(1)
        got = device_merge_stats([only])  # pmap path on 1 device
        assert np.array_equal(got.hist, only.hist)
        assert got.n_points == only.n_points
        empty = device_merge_stats([])
        assert empty.n_points == 0
        assert np.array_equal(empty.hist, GateStats.empty().hist)

    def test_schema_mismatch_rejected(self):
        import dataclasses

        from repro.sweep import device_merge_stats

        a, b, _ = self._stats_list(3)
        bad = dataclasses.replace(b, schema=b.schema + 1)
        with pytest.raises(ValueError, match="schema"):
            device_merge_stats([a, bad])
