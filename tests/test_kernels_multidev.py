"""Remote-DMA kernel tests (subprocess, 8 simulated devices)."""

import pytest

from tests.test_overlap_multidev import _run_driver


@pytest.mark.slow
@pytest.mark.multidev
def test_dma_kernels_multidevice():
    out = _run_driver("multidev_kernels_driver.py")
    assert "ok exchange_matches_all_gather" in out
    assert "ok dma_schedule_matches_serial" in out
    assert "ok fused_kernel_matches_serial" in out
    assert "ok ag_fused_variants_bit_identical" in out
    assert "ok dma_schedule_variants_match" in out
    assert "ok a2a_ffn_variants_bit_identical" in out
