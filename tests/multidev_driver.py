"""Multi-device correctness driver (run in a subprocess with 8 host devices).

The main pytest process must keep seeing ONE device (smoke tests / benches),
so everything that needs a real mesh runs here, spawned by
``tests/test_overlap_multidev.py``.  Prints one line per check and a final
``ALL-OK`` sentinel on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import functools  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh, shard_map  # noqa: E402
from repro.core.schedule_types import Schedule  # noqa: E402
from repro.overlap import (  # noqa: E402
    ficco_a2a_ffn,
    ficco_linear,
    run_schedule,
    serial_a2a_ffn,
)

G = 8
AXIS = "tp"

failures: list[str] = []


def check(name: str, fn):
    try:
        fn()
        print(f"ok {name}")
    except Exception:
        failures.append(name)
        print(f"FAIL {name}")
        traceback.print_exc()


def make_mesh():
    return jax.make_mesh((G,), (AXIS,))


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


def run_sharded(fn, mesh, x, w):
    wrapped = jax.jit(
        shard_map(
            fn,
            mesh=mesh,
            in_specs=(P(AXIS, None), P(None, AXIS)),
            out_specs=P(None, AXIS),
            check_vma=False,
        )
    )
    return wrapped(x, w)


def schedules_allclose():
    mesh = make_mesh()
    rng = np.random.default_rng(0)
    for m, n, k in [(128, 64, 64), (256, 128, 128), (512, 256, 64)]:
        for dtype in (jnp.float32, jnp.bfloat16):
            x = jnp.asarray(
                rng.standard_normal((m, k)), dtype=dtype
            )
            w = jnp.asarray(rng.standard_normal((k, n)), dtype=dtype)
            ref = np.asarray(
                (x.astype(jnp.float32) @ w.astype(jnp.float32))
            )
            for sched in Schedule:
                if sched is Schedule.UNIFORM_FUSED_2D and k % G:
                    continue
                fn = functools.partial(
                    run_schedule, sched, axis_name=AXIS
                )
                got = np.asarray(
                    run_sharded(fn, mesh, x, w)
                ).astype(np.float32)
                np.testing.assert_allclose(
                    got,
                    ref,
                    err_msg=f"{sched} {m}x{n}x{k} {dtype}",
                    **tol(dtype),
                )


def ficco_linear_auto():
    mesh = make_mesh()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    ref = np.asarray(x @ w)
    for schedule in ("auto", "serial", "uniform-fused-1d", "hetero-fused-1d"):
        fn = functools.partial(
            ficco_linear, axis_name=AXIS, schedule=schedule
        )
        got = np.asarray(run_sharded(fn, mesh, x, w))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def ficco_linear_indivisible_falls_back():
    """M/g not divisible by g again -> serial fallback, still correct."""
    mesh = make_mesh()
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8 * 9, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    ref = np.asarray(x @ w)
    fn = functools.partial(
        ficco_linear, axis_name=AXIS, schedule="uniform-fused-1d"
    )
    got = np.asarray(run_sharded(fn, mesh, x, w))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def moe_dispatch_equivalence():
    mesh = make_mesh()
    rng = np.random.default_rng(3)
    e, c, d, f = 16, 32, 64, 128  # 16 experts over 8 devices
    e_local = e // G
    x = jnp.asarray(rng.standard_normal((G * e, c, d)), jnp.float32)
    w_up = jnp.asarray(
        rng.standard_normal((e, d, f)) / np.sqrt(d), jnp.float32
    )
    w_down = jnp.asarray(
        rng.standard_normal((e, f, d)) / np.sqrt(f), jnp.float32
    )

    def run(fn):
        wrapped = jax.jit(
            shard_map(
                fn,
                mesh=mesh,
                in_specs=(P(AXIS, None, None), P(AXIS, None, None),
                          P(AXIS, None, None)),
                out_specs=P(AXIS, None, None),
                check_vma=False,
            )
        )
        return np.asarray(wrapped(x, w_up, w_down))

    serial = run(functools.partial(serial_a2a_ffn, axis_name=AXIS))
    ficco = run(functools.partial(ficco_a2a_ffn, axis_name=AXIS))
    np.testing.assert_allclose(ficco, serial, rtol=1e-5, atol=1e-5)
    ficco2 = run(
        functools.partial(ficco_a2a_ffn, axis_name=AXIS, chunks=4)
    )
    np.testing.assert_allclose(ficco2, serial, rtol=1e-5, atol=1e-5)


def hlo_uses_async_collectives():
    """The FiCCO schedules must lower to one chunk collective per step so
    XLA's scheduler can pipeline them (the DMA-offload story)."""
    mesh = make_mesh()
    x = jnp.zeros((256, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    fn = functools.partial(
        run_schedule, Schedule.UNIFORM_FUSED_1D, axis_name=AXIS
    )
    wrapped = jax.jit(
        shard_map(
            fn,
            mesh=mesh,
            in_specs=(P(AXIS, None), P(None, AXIS)),
            out_specs=P(None, AXIS),
            check_vma=False,
        )
    )
    txt = wrapped.lower(x, w).compile().as_text()
    n_ag = txt.count("all-gather-start") or txt.count("all-gather(")
    assert n_ag >= G, f"expected >= {G} chunk all-gathers, found {n_ag}"


def ficco_in_model_matches_gspmd():
    """A reduced dense model under mesh: overlap ficco_auto forward must
    equal the gspmd_serial forward (the production integration path)."""
    import dataclasses

    from repro.configs import get_config
    from repro.configs.base import OverlapConfig
    from repro.models.model import build_model
    from repro.parallel.context import overlap_context

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(
        cfg, num_heads=4, num_kv_heads=4, d_ff=512, d_model=256
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 32)),
        jnp.int32,
    )

    def fwd(params, toks):
        logits, _ = model.forward(params, {"tokens": toks})
        return logits

    with set_mesh(mesh):
        base = np.asarray(jax.jit(fwd)(params, toks), np.float32)
        ov = OverlapConfig(mode="ficco_auto")

        def fwd_ficco(params, toks):
            with overlap_context(ov):
                logits, _ = model.forward(params, {"tokens": toks})
            return logits

        got = np.asarray(jax.jit(fwd_ficco)(params, toks), np.float32)
        ov2 = OverlapConfig(mode="uniform-fused-1d")

        def fwd_uf(params, toks):
            with overlap_context(ov2):
                logits, _ = model.forward(params, {"tokens": toks})
            return logits

        got2 = np.asarray(jax.jit(fwd_uf)(params, toks), np.float32)
    np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(got2, base, rtol=2e-3, atol=2e-3)


def shard_map_decode_attn_matches_reference():
    """Explicit flash-decode == cache_attention reference."""
    from repro.parallel import decode_attn
    from repro.models.layers import cache_attention

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(7)
    b, s, h, kv, d = 4, 4096, 8, 4, 32
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    k_new = jnp.asarray(rng.standard_normal((b, 1, kv, d)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((b, 1, kv, d)), jnp.float32)
    k_c = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v_c = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    pos = jnp.int32(2500)

    with set_mesh(mesh):
        out, k2, v2 = jax.jit(decode_attn.shard_map_attn_decode)(
            q, k_new, v_new, k_c, v_c, pos
        )
    # reference: dense update + cache_attention
    k_ref = k_c.at[:, 2500].set(k_new[:, 0])
    v_ref = v_c.at[:, 2500].set(v_new[:, 0])
    want = cache_attention(q, k_ref, v_ref, valid_len=pos + 1)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k_ref))
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v_ref))


def pallas_dma_backend_in_model():
    """overlap.backend=pallas_dma routes the TP MLP up-projections through
    the Pallas ICI-DMA kernel (interpret mode) — must match gspmd."""
    import dataclasses

    from repro.configs import get_config
    from repro.configs.base import OverlapConfig
    from repro.models.model import build_model
    from repro.parallel.context import overlap_context

    mesh = jax.make_mesh((8,), ("model",))
    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(
        cfg, num_layers=1, num_heads=4, num_kv_heads=4, d_ff=512,
        d_model=256,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 64)),
        jnp.int32,
    )

    def fwd(params, toks):
        logits, _ = model.forward(params, {"tokens": toks})
        return logits

    with set_mesh(mesh):
        base = np.asarray(jax.jit(fwd)(params, toks), np.float32)
        ov = OverlapConfig(mode="uniform-fused-1d", backend="pallas_dma")

        def fwd_pallas(params, toks):
            with overlap_context(ov):
                logits, _ = model.forward(params, {"tokens": toks})
            return logits

        got = np.asarray(jax.jit(fwd_pallas)(params, toks), np.float32)
    np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3)


def main():
    assert len(jax.devices()) == G, jax.devices()
    check("schedules_allclose", schedules_allclose)
    check("ficco_in_model_matches_gspmd", ficco_in_model_matches_gspmd)
    check("pallas_dma_backend_in_model", pallas_dma_backend_in_model)
    check("shard_map_decode_attn_matches_reference",
          shard_map_decode_attn_matches_reference)
    check("ficco_linear_auto", ficco_linear_auto)
    check("ficco_linear_indivisible_falls_back",
          ficco_linear_indivisible_falls_back)
    check("moe_dispatch_equivalence", moe_dispatch_equivalence)
    check("hlo_uses_async_collectives", hlo_uses_async_collectives)
    if failures:
        print("FAILED:", failures)
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
