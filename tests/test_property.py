"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an optional test extra (see requirements-test.txt); the
module skips cleanly when it is absent so plain ``pytest -x`` still runs
the rest of the suite.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import (
    MI300X,
    TPU_V5E,
    GemmShape,
    Schedule,
    gemm_dil,
    gemm_exec,
    select_schedule,
    simulate,
)
from repro.core.workload import geomean
from repro.kernels.chunked_gemm import chunked_matmul
from repro.models.layers import blockwise_attention

dims = st.sampled_from([1024, 2048, 4096, 8192, 16384, 65536, 131072])


class TestCostModelProperties:
    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=40, deadline=None)
    def test_gemm_time_positive_and_monotone_in_flops(self, m, n, k):
        t1 = gemm_exec(GemmShape(m, n, k), MI300X).time
        t2 = gemm_exec(GemmShape(2 * m, n, k), MI300X).time
        assert 0 < t1 < t2 * 1.001

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=30, deadline=None)
    def test_dil_at_least_one(self, m, n, k):
        g = GemmShape(m, n, k)
        for axis in ("m", "k"):
            assert gemm_dil(g, MI300X, 8, axis) >= 0.999

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=30, deadline=None)
    def test_schedules_never_beat_ideal(self, m, n, k):
        g = GemmShape(m, n, k)
        for sched in Schedule:
            r = simulate(g, MI300X, sched)
            assert r.total >= r.ideal_total * 0.999

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=30, deadline=None)
    def test_heuristic_total_function(self, m, n, k):
        """The heuristic returns a valid schedule for ANY shape, on both
        machines (frameworks can call it blindly)."""
        g = GemmShape(m, n, k)
        for machine in (MI300X, TPU_V5E):
            dec = select_schedule(g, machine)
            assert isinstance(dec.schedule, Schedule)
            if g.flops >= 1e9:
                if g.m < g.k:
                    assert dec.schedule is Schedule.UNIFORM_FUSED_2D

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=20, deadline=None)
    def test_serial_equals_parts(self, m, n, k):
        r = simulate(GemmShape(m, n, k), MI300X, Schedule.SERIAL)
        assert abs(r.total - (r.serial_comm + r.serial_gemm)) < 1e-12


class TestKernelProperties:
    @given(
        mb=st.integers(1, 3),
        nb=st.integers(1, 3),
        kb=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=8, deadline=None)
    def test_chunked_matmul_any_grid(self, mb, nb, kb, seed):
        rng = np.random.default_rng(seed)
        m, n, k = 128 * mb, 128 * nb, 128 * kb
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        got = chunked_matmul(x, w, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(x @ w), rtol=1e-4, atol=1e-4
        )


class TestAttentionProperties:
    @given(
        s=st.sampled_from([16, 48, 64, 100]),
        h=st.sampled_from([2, 4]),
        window=st.sampled_from([None, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=12, deadline=None)
    def test_blockwise_matches_dense_reference(self, s, h, window, seed):
        """Blockwise online-softmax == dense masked softmax attention."""
        rng = np.random.default_rng(seed)
        b, d = 2, 16
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        got = blockwise_attention(
            q, k, v, causal=True, window=window, block_q=32, block_k=32
        )
        # dense reference
        scores = np.einsum(
            "bqhd,bkhd->bhqk", np.asarray(q), np.asarray(k)
        ) / np.sqrt(d)
        qpos = np.arange(s)[:, None]
        kpos = np.arange(s)[None, :]
        mask = qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        scores = np.where(mask[None, None], scores, -1e30)
        p = np.exp(scores - scores.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        want = np.einsum("bhqk,bkhd->bqhd", p, np.asarray(v))
        np.testing.assert_allclose(
            np.asarray(got), want, rtol=2e-4, atol=2e-4
        )
