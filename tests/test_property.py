"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an optional test extra (see requirements-test.txt); the
module skips cleanly when it is absent so plain ``pytest -x`` still runs
the rest of the suite.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import (
    MI300X,
    TPU_V5E,
    GemmShape,
    Schedule,
    StepProfile,
    gemm_dil,
    gemm_exec,
    select_schedule,
    simulate,
)
from repro.core.simulator import _pipeline_masked
from repro.core.workload import geomean
from repro.kernels.chunked_gemm import chunked_matmul
from repro.models.layers import blockwise_attention

dims = st.sampled_from([1024, 2048, 4096, 8192, 16384, 65536, 131072])

# Ragged step profiles: raw per-step weights (zeros allowed — masked
# steps), normalized by StepProfile.from_weights.
ragged_weights = st.lists(
    st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    min_size=1,
    max_size=8,
).filter(lambda ws: sum(ws) > 1e-6)
step_times = st.floats(min_value=1e-6, max_value=10.0, allow_nan=False)


class TestCostModelProperties:
    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=40, deadline=None)
    def test_gemm_time_positive_and_monotone_in_flops(self, m, n, k):
        t1 = gemm_exec(GemmShape(m, n, k), MI300X).time
        t2 = gemm_exec(GemmShape(2 * m, n, k), MI300X).time
        assert 0 < t1 < t2 * 1.001

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=30, deadline=None)
    def test_dil_at_least_one(self, m, n, k):
        g = GemmShape(m, n, k)
        for axis in ("m", "k"):
            assert gemm_dil(g, MI300X, 8, axis) >= 0.999

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=30, deadline=None)
    def test_schedules_never_beat_ideal(self, m, n, k):
        g = GemmShape(m, n, k)
        for sched in Schedule:
            r = simulate(g, MI300X, sched)
            assert r.total >= r.ideal_total * 0.999

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=30, deadline=None)
    def test_heuristic_total_function(self, m, n, k):
        """The heuristic returns a valid schedule for ANY shape, on both
        machines (frameworks can call it blindly)."""
        g = GemmShape(m, n, k)
        for machine in (MI300X, TPU_V5E):
            dec = select_schedule(g, machine)
            assert isinstance(dec.schedule, Schedule)
            if g.flops >= 1e9:
                if g.m < g.k:
                    assert dec.schedule is Schedule.UNIFORM_FUSED_2D

    @given(m=dims, n=dims, k=dims)
    @settings(max_examples=20, deadline=None)
    def test_serial_equals_parts(self, m, n, k):
        r = simulate(GemmShape(m, n, k), MI300X, Schedule.SERIAL)
        assert abs(r.total - (r.serial_comm + r.serial_gemm)) < 1e-12


def _coherent_steps(profile: StepProfile, c: float, w: float):
    """Step time lists where comm AND compute scale with each step's
    share (total comm time == n*c*sum(f) == c*n/n... fixed totals)."""
    n = profile.steps
    comm = [n * f * c for f in profile.fractions]
    compute = [n * f * w for f in profile.fractions]
    active = [f > 0.0 for f in profile.fractions]
    return comm, compute, active


class TestRaggedPipelineProperties:
    """Invariants of the masked ragged pipeline (ISSUE 3 satellite).

    All at the pipeline-recurrence level, where totals are linear in the
    step times and the math is exact: fixed total work == fixed channel
    sums whatever the profile.
    """

    @given(weights=ragged_weights, c=step_times, w=step_times)
    @settings(max_examples=50, deadline=None)
    def test_total_bounded_by_channel_sums(self, weights, c, w):
        """max(comm, compute) <= total <= comm + compute, any skew."""
        p = StepProfile.from_weights(weights)
        comm, compute, active = _coherent_steps(p, c, w)
        deps = list(range(p.steps))
        total, exposed, cs, ws = _pipeline_masked(
            comm, compute, deps, active, active
        )
        slack = 1e-9 * (cs + ws)
        assert max(cs, ws) - slack <= total <= cs + ws + slack
        assert 0.0 <= exposed <= cs + slack

    @given(weights=ragged_weights, c=step_times, w=step_times)
    @settings(max_examples=50, deadline=None)
    def test_dependency_free_totals_permutation_invariant(self, weights, c, w):
        """With no cross-channel deps the total is max of the channel
        sums — invariant under any permutation of the step lists."""
        p = StepProfile.from_weights(weights)
        comm, compute, active = _coherent_steps(p, c, w)
        deps = [None] * p.steps
        total, _, cs, ws = _pipeline_masked(
            comm, compute, deps, active, active
        )
        assert total == pytest.approx(max(cs, ws), rel=1e-12)
        rev = _pipeline_masked(
            comm[::-1], compute[::-1], deps, active[::-1], active[::-1]
        )
        assert rev[0] == pytest.approx(total, rel=1e-12)

    @given(weights=ragged_weights, c=step_times, w=step_times)
    @settings(max_examples=50, deadline=None)
    def test_one_chunk_concentration_is_serialization_upper_bound(
        self, weights, c, w
    ):
        """Concentrating ALL work into a single chunk fully serializes
        the pipeline (total == comm + compute); every other profile at
        the same channel sums does no worse."""
        p = StepProfile.from_weights(weights)
        comm, compute, active = _coherent_steps(p, c, w)
        deps = list(range(p.steps))
        total, _, cs, ws = _pipeline_masked(
            comm, compute, deps, active, active
        )
        one = StepProfile((0.0,) * (p.steps - 1) + (1.0,))
        comm1, compute1, active1 = _coherent_steps(one, c, w)
        total1 = _pipeline_masked(
            comm1, compute1, deps, active1, active1
        )[0]
        assert total1 == pytest.approx(cs + ws, rel=1e-12)
        assert total <= total1 * (1.0 + 1e-12)

    @given(weights=ragged_weights, c=step_times, w=step_times)
    @settings(max_examples=30, deadline=None)
    def test_zero_padding_never_changes_anything(self, weights, c, w):
        p = StepProfile.from_weights(weights)
        comm, compute, active = _coherent_steps(p, c, w)
        deps = list(range(p.steps))
        base = _pipeline_masked(comm, compute, deps, active, active)
        padded = _pipeline_masked(
            comm + [123.0, 456.0],
            compute + [7.0, 8.0],
            deps + [p.steps, p.steps + 1],
            active + [False, False],
            active + [False, False],
        )
        assert base == padded


class TestRaggedModelProperties:
    @given(
        m=dims, n=dims, k=dims,
        skew=st.floats(min_value=0.25, max_value=8.0, allow_nan=False),
    )
    @settings(max_examples=25, deadline=None)
    def test_serial_exposed_comm_invariant_under_skew(self, m, n, k, skew):
        """Adding skew at fixed total work never decreases the serial
        schedule's modeled exposed comm — SERIAL moves the same
        aggregate bytes whatever the profile, so it stays constant."""
        g = GemmShape(m, n, k)
        base = simulate(g, MI300X, Schedule.SERIAL)
        skewed = simulate(
            g, MI300X, Schedule.SERIAL,
            profile=StepProfile.skewed(8, skew),
        )
        assert skewed.exposed_comm >= base.exposed_comm * (1.0 - 1e-12)
        assert skewed.total == base.total

    @given(
        m=dims, n=dims, k=dims,
        skew=st.floats(min_value=0.25, max_value=8.0, allow_nan=False),
    )
    @settings(max_examples=20, deadline=None)
    def test_ragged_engines_agree_for_any_shape(self, m, n, k, skew):
        """The scalar and batched ragged engines agree (or both reject)
        for ANY shape x geometric skew."""
        from repro.core.batch import RaggedBatch, evaluate_ragged_grid
        from repro.core.workload import RaggedScenario

        gemm = GemmShape(m, n, k)
        profile = StepProfile.skewed(8, skew)
        rb = RaggedBatch.from_ragged_scenarios(
            [RaggedScenario("x", "EP", "t", gemm, profile)]
        )
        grid = evaluate_ragged_grid(rb, (MI300X,))
        for sched in (
            Schedule.UNIFORM_FUSED_1D, Schedule.HETERO_UNFUSED_1D
        ):
            l = grid.schedule_idx(sched)
            try:
                want = simulate(gemm, MI300X, sched, profile=profile)
            except ValueError:
                assert not grid.valid[l, 0, 0]
                continue
            assert grid.total[l, 0, 0] == pytest.approx(
                want.total, rel=1e-12
            )


class TestKernelProperties:
    @given(
        mb=st.integers(1, 3),
        nb=st.integers(1, 3),
        kb=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=8, deadline=None)
    def test_chunked_matmul_any_grid(self, mb, nb, kb, seed):
        rng = np.random.default_rng(seed)
        m, n, k = 128 * mb, 128 * nb, 128 * kb
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        got = chunked_matmul(x, w, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(x @ w), rtol=1e-4, atol=1e-4
        )


class TestAttentionProperties:
    @given(
        s=st.sampled_from([16, 48, 64, 100]),
        h=st.sampled_from([2, 4]),
        window=st.sampled_from([None, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=12, deadline=None)
    def test_blockwise_matches_dense_reference(self, s, h, window, seed):
        """Blockwise online-softmax == dense masked softmax attention."""
        rng = np.random.default_rng(seed)
        b, d = 2, 16
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        got = blockwise_attention(
            q, k, v, causal=True, window=window, block_q=32, block_k=32
        )
        # dense reference
        scores = np.einsum(
            "bqhd,bkhd->bhqk", np.asarray(q), np.asarray(k)
        ) / np.sqrt(d)
        qpos = np.arange(s)[:, None]
        kpos = np.arange(s)[None, :]
        mask = qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        scores = np.where(mask[None, None], scores, -1e30)
        p = np.exp(scores - scores.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        want = np.einsum("bhqk,bkhd->bqhd", p, np.asarray(v))
        np.testing.assert_allclose(
            np.asarray(got), want, rtol=2e-4, atol=2e-4
        )
