"""Differential test harness for the ragged-step (non-uniform) engines.

The tentpole contract: for ANY ragged step list, the three engines —
the scalar simulator (``simulate(..., profile=...)`` + the pure-Python
masked pipeline), the NumPy masked-scan engine
(``batch.evaluate_ragged_grid``) and the jitted engine
(``jaxgrid.evaluate_ragged_grid``) — agree on totals, busy times and
exposed comm to within 1e-12 relative (scalar vs NumPy are held to
1e-15: they share the per-step time model and differ only in their
pipeline scans).  Degenerate profiles are first-class: a single-step
profile (fully serialized), an all-masked tail (zero padding), extreme
skew (all mass in one chunk), and mixed-length batches.

The uniform path must be untouched: the uniform-schedule grid is pinned
bit-identical to pre-PR golden values, and a uniform profile pushed
through the ragged engines reproduces the uniform engine bit-for-bit.
"""

import numpy as np
import pytest

from repro.core import (
    GRID_SCHEDULES,
    MI300X,
    TABLE_I,
    TPU_V5E,
    GemmShape,
    RaggedBatch,
    RaggedScenario,
    ScenarioBatch,
    Schedule,
    StepProfile,
    evaluate_grid,
    machine_grid,
    ragged_scenario_grid,
    simulate,
)
from repro.core import batch as core_batch
from repro.core.simulator import _pipeline_masked

# Acceptance tolerance for the three-way engine agreement (the jitted
# engine recomputes every model in XLA; 1e-12 is the ISSUE's bar, actual
# agreement is ~1e-15).
RTOL = 1e-12
# Scalar and NumPy share the per-step time model; only their pipeline
# scans differ, and those replay each other's accumulation order.
RTOL_SCALAR_NP = 1e-15

FICCO = tuple(
    s for s in GRID_SCHEDULES
    if s not in (Schedule.SERIAL, Schedule.SHARD_P2P)
)

_FIELDS = {
    "total": "total",
    "comm_busy": "comm_busy",
    "compute_busy": "compute_busy",
    "exposed": "exposed_comm",
}


def _profiles():
    """The profile zoo: every degenerate the harness must pin down."""
    return [
        StepProfile.uniform(8),
        StepProfile.skewed(8, 2.0),
        StepProfile.skewed(8, 0.25),            # front-loaded
        StepProfile.skewed(16, 8.0),            # extreme geometric skew
        StepProfile.zipf(8, 1.0),
        StepProfile.top_k_hot(8, 2, 0.6),
        StepProfile((1.0,)),                    # S=1: fully serialized
        StepProfile((1.0, 0.0, 0.0, 0.0)),      # all mass in chunk 0
        StepProfile((0.0, 0.0, 0.0, 1.0)),      # all mass in the tail
        StepProfile.skewed(5, 0.5).padded(9),   # masked tail padding
    ]


def _ragged_set(seed=0, count=6):
    rng = np.random.default_rng(seed)
    ms = [8192, 65536, 131072, 262144, 1048576]
    ks = [4096, 8192, 16384]
    ns = [8192, 28672, 57344]
    out = []
    profiles = _profiles()
    for i in range(count):
        gemm = GemmShape(
            int(rng.choice(ms)), int(rng.choice(ns)), int(rng.choice(ks))
        )
        for p in profiles:
            out.append(RaggedScenario(f"r{i}/{p.name}", "EP", "t", gemm, p))
    return out


def _assert_three_way(scenarios, machines, *, dma=True, dma_into_place=False):
    from repro.autotune import jaxgrid

    rb = RaggedBatch.from_ragged_scenarios(scenarios)
    grid_np = core_batch.evaluate_ragged_grid(
        rb, machines, dma=dma, dma_into_place=dma_into_place
    )
    grid_jx = jaxgrid.evaluate_ragged_grid(
        rb, machines, dma=dma, dma_into_place=dma_into_place
    )
    for j, machine in enumerate(machines):
        for i, sc in enumerate(scenarios):
            for l, sched in enumerate(GRID_SCHEDULES):
                try:
                    want = simulate(
                        sc.gemm, machine, sched, profile=sc.profile,
                        dma=dma, dma_into_place=dma_into_place,
                    )
                except ValueError:
                    assert not grid_np.valid[l, i, j]
                    assert not grid_jx.valid[l, i, j]
                    assert np.isnan(grid_np.total[l, i, j])
                    continue
                assert grid_np.valid[l, i, j], (sched, sc.name, machine.name)
                assert grid_jx.valid[l, i, j], (sched, sc.name, machine.name)
                for fname, attr in _FIELDS.items():
                    ref = getattr(want, attr)
                    got_np = getattr(grid_np, fname)[l, i, j]
                    got_jx = getattr(grid_jx, fname)[l, i, j]
                    assert got_np == pytest.approx(
                        ref, rel=RTOL_SCALAR_NP, abs=1e-18
                    ), (fname, sched, sc.name, machine.name)
                    assert got_jx == pytest.approx(
                        ref, rel=RTOL, abs=1e-15
                    ), (fname, sched, sc.name, machine.name)


# ---------------------------------------------------------------------------
# Pipeline primitive: the masked ragged scan in all three engines.
# ---------------------------------------------------------------------------


class TestMaskedPipelinePrimitive:
    def _random_case(self, rng, n_steps, batch):
        comm = [np.abs(rng.standard_normal(batch)) for _ in range(n_steps)]
        compute = [np.abs(rng.standard_normal(batch)) for _ in range(n_steps)]
        comm_act = [rng.random(batch) > 0.3 for _ in range(n_steps)]
        comp_act = [rng.random(batch) > 0.3 for _ in range(n_steps)]
        deps = list(range(n_steps))
        return comm, compute, deps, comm_act, comp_act

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_three_way_random(self, seed):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.autotune.jaxgrid import pipeline_jax

        rng = np.random.default_rng(seed)
        for n_steps in (1, 2, 5):
            for deps_kind in ("chain", "local", "free"):
                comm, compute, deps, c_act, w_act = self._random_case(
                    rng, n_steps, batch=7
                )
                if deps_kind == "local":
                    compute = [np.abs(rng.standard_normal(7))] + compute
                    w_act = [np.ones(7, dtype=bool)] + w_act
                    deps = [None] + deps
                elif deps_kind == "free":
                    deps = [None] * n_steps
                got_np = core_batch.pipeline_vec(
                    comm, compute, deps, c_act, w_act
                )
                with enable_x64():
                    got_jx = pipeline_jax(
                        [jnp.asarray(c) for c in comm],
                        [jnp.asarray(w) for w in compute],
                        deps,
                        [jnp.asarray(a) for a in c_act],
                        [jnp.asarray(a) for a in w_act],
                    )
                for b in range(7):
                    want = _pipeline_masked(
                        [float(c[b]) for c in comm],
                        [float(w[b]) for w in compute],
                        deps,
                        [bool(a[b]) for a in c_act],
                        [bool(a[b]) for a in w_act],
                    )
                    # (total, exposed, comm_busy, compute_busy)
                    for x, (w_np, w_jx) in zip(
                        want, zip(got_np, got_jx)
                    ):
                        assert float(w_np[b]) == pytest.approx(
                            x, rel=RTOL_SCALAR_NP, abs=1e-18
                        )
                        assert float(w_jx[b]) == pytest.approx(
                            x, rel=RTOL, abs=1e-15
                        )

    def test_masks_default_to_uniform_path(self):
        """pipeline_vec without masks == with all-True masks, bit-exact."""
        rng = np.random.default_rng(42)
        comm = [np.abs(rng.standard_normal(5)) for _ in range(4)]
        compute = [np.abs(rng.standard_normal(5)) for _ in range(4)]
        deps = list(range(4))
        ones = [np.ones(5, dtype=bool)] * 4
        a = core_batch.pipeline_vec(comm, compute, deps)
        b = core_batch.pipeline_vec(comm, compute, deps, ones, ones)
        for x, y in zip(a, b):
            assert (x == y).all()

    def test_inactive_steps_never_stall(self):
        """A masked compute step must not accrue exposed time even when
        its comm dependency would be 'late'."""
        comm = [np.array([10.0]), np.array([10.0])]
        compute = [np.array([1.0]), np.array([1.0])]
        deps = [0, 1]
        c_act = [np.array([True]), np.array([False])]
        w_act = [np.array([True]), np.array([False])]
        total, exposed, comm_sum, comp_sum = core_batch.pipeline_vec(
            comm, compute, deps, c_act, w_act
        )
        assert float(comm_sum[0]) == 10.0  # second comm masked
        assert float(exposed[0]) == 10.0  # only the first stall counts
        assert float(total[0]) == 11.0


# ---------------------------------------------------------------------------
# Scenario-level three-way differential.
# ---------------------------------------------------------------------------


class TestRaggedEngineEquivalence:
    def test_randomized_profile_zoo_both_machines(self):
        _assert_three_way(_ragged_set(seed=0, count=4), (MI300X, TPU_V5E))

    def test_all_topologies_group_sizes(self):
        machines = machine_grid()
        topos = {m.topology for m in machines}
        assert len(topos) == 2
        _assert_three_way(_ragged_set(seed=1, count=2), machines[:4])

    def test_rccl_and_dma_into_place(self):
        scenarios = _ragged_set(seed=2, count=2)
        _assert_three_way(scenarios, (MI300X,), dma=False)
        _assert_three_way(scenarios, (TPU_V5E,), dma_into_place=True)

    def test_indivisible_m_masked_and_raises(self):
        gemm = GemmShape(1001, 8192, 8192)
        sc = RaggedScenario("bad", "EP", "t", gemm, StepProfile.uniform(4))
        rb = RaggedBatch.from_ragged_scenarios([sc])
        grid = core_batch.evaluate_ragged_grid(rb, (MI300X,))
        for sched in FICCO:
            l = grid.schedule_idx(sched)
            assert not grid.valid[l, 0, 0]
            with pytest.raises(ValueError):
                simulate(gemm, MI300X, sched, profile=sc.profile)
        assert grid.valid[grid.schedule_idx(Schedule.SERIAL), 0, 0]

    def test_serial_and_p2p_ignore_profile(self):
        gemm = GemmShape(65536, 28672, 8192)
        for sched in (Schedule.SERIAL, Schedule.SHARD_P2P):
            a = simulate(gemm, MI300X, sched)
            b = simulate(
                gemm, MI300X, sched, profile=StepProfile.skewed(8, 4.0)
            )
            assert a.total == b.total and a.exposed_comm == b.exposed_comm


# ---------------------------------------------------------------------------
# Uniform path: bit-identity with the pre-PR engine.
# ---------------------------------------------------------------------------


class TestUniformPathUntouched:
    # Golden totals captured from the uniform engine at the PR-2 commit
    # (a92a83f), full float64 repr: (schedule_idx, scenario_idx in
    # TABLE_I, machine_idx in (MI300X, TPU_V5E)) -> total seconds.
    GOLDEN = {
        (0, 0, 0): 0.015746150880499563,
        (0, 5, 1): 0.051622680085611765,
        (1, 12, 0): 0.3924524961719757,
        (2, 0, 1): 0.04665035948169961,
        (2, 5, 0): 0.009574582152165011,
        (3, 12, 1): 0.5316026195958189,
        (4, 0, 0): 0.1172605248278478,
        (4, 12, 1): 0.5061417773647158,
        (5, 5, 0): 0.009650411517192505,
        (5, 12, 0): 0.23844371907157316,
    }

    def test_uniform_grid_bit_identical_to_pre_pr(self):
        sb = ScenarioBatch.from_scenarios(TABLE_I)
        grid = evaluate_grid(sb, (MI300X, TPU_V5E))
        for (l, i, j), want in self.GOLDEN.items():
            assert grid.total[l, i, j] == want, (l, i, j)

    def test_uniform_profile_reproduces_uniform_engine(self):
        """A 1/g x g profile through the ragged engine == the uniform
        engine, bit-for-bit (M divisible by g^2, K by g)."""
        scen = [
            s for s in TABLE_I
            if s.gemm.m % (16 * 16) == 0 and s.gemm.k % 16 == 0
        ]
        assert len(scen) >= 8
        for machine in (MI300X, TPU_V5E):
            g = machine.group
            rs = [
                RaggedScenario.from_scenario(s, StepProfile.uniform(g))
                for s in scen
            ]
            rg = core_batch.evaluate_ragged_grid(rs, (machine,))
            ug = evaluate_grid(
                ScenarioBatch.from_scenarios(scen), (machine,)
            )
            for sched in GRID_SCHEDULES:
                if sched is Schedule.UNIFORM_FUSED_2D:
                    # ragged 2D cuts K fractionally (no k%g validity bit)
                    continue
                l = ug.schedule_idx(sched)
                both = ug.valid[l, :, 0] & rg.valid[l, :, 0]
                assert (
                    rg.total[l, both, 0] == ug.total[l, both, 0]
                ).all(), sched
                assert (
                    rg.exposed[l, both, 0] == ug.exposed[l, both, 0]
                ).all(), sched

    def test_padding_invariance(self):
        """Zero-padding a profile never changes any engine figure."""
        gemm = GemmShape(131072, 28672, 8192)
        p = StepProfile.skewed(6, 3.0)
        for sched in FICCO:
            a = simulate(gemm, MI300X, sched, profile=p)
            b = simulate(gemm, MI300X, sched, profile=p.padded(11))
            assert a.total == b.total
            assert a.exposed_comm == b.exposed_comm
            assert a.comm_busy == b.comm_busy


# ---------------------------------------------------------------------------
# Step profiles.
# ---------------------------------------------------------------------------


class TestStepProfile:
    def test_validation(self):
        with pytest.raises(ValueError):
            StepProfile(())
        with pytest.raises(ValueError):
            StepProfile((0.5, 0.6))
        with pytest.raises(ValueError):
            StepProfile((-0.1, 1.1))
        with pytest.raises(ValueError):
            StepProfile.skewed(4, 0.0)

    def test_quantize_sums_and_determinism(self):
        for total in (7, 64, 1000, 12345):
            for p in _profiles():
                sizes = p.quantize(total)
                assert sum(sizes) == total
                assert len(sizes) == p.steps
                assert all(s >= 0 for s in sizes)
                assert sizes == p.quantize(total)  # deterministic

    def test_uniform_quantize_exact(self):
        assert StepProfile.uniform(8).quantize(64) == (8,) * 8

    def test_imbalance(self):
        assert StepProfile.uniform(8).imbalance == pytest.approx(1.0)
        assert StepProfile((1.0, 0.0)).imbalance == pytest.approx(1.0)
        assert StepProfile.skewed(8, 4.0).imbalance > 3.0
        # padding must not dilute imbalance (active steps only)
        p = StepProfile.skewed(4, 2.0)
        assert p.padded(9).imbalance == pytest.approx(p.imbalance)

    def test_padded_trimmed_roundtrip(self):
        p = StepProfile.zipf(5, 1.0)
        assert p.padded(9).trimmed() == p
        with pytest.raises(ValueError):
            p.padded(3)

    def test_digest_stable_and_uniform_short(self):
        assert StepProfile.uniform(16).digest() == "u16"
        a = StepProfile.skewed(8, 2.0).digest()
        assert a == StepProfile.skewed(8, 2.0).digest()
        assert a != StepProfile.skewed(8, 4.0).digest()

    def test_ragged_scenario_grid_families(self):
        fam = ragged_scenario_grid(skews=(1.0, 2.0, 4.0))
        assert len({s.profile.name for s in fam}) >= 5  # 3 skews+zipf+topk
        assert all(s.parallelism == "EP" for s in fam)
        skew_levels = {
            s.profile.name for s in fam if s.profile.name.startswith("skew")
        }
        assert len(skew_levels) >= 3


# ---------------------------------------------------------------------------
# explore_grid over the skewed EP family (acceptance criterion).
# ---------------------------------------------------------------------------


class TestExploreRaggedGrid:
    def test_skewed_ep_family_both_backends(self):
        from repro.core import explore_grid

        fam = ragged_scenario_grid(steps=8, skews=(1.0, 2.0, 4.0))
        machines = (MI300X, TPU_V5E)
        ex_np = explore_grid(fam, machines=machines, backend="numpy")
        ex_jx = explore_grid(fam, machines=machines, backend="jax")
        assert ex_np.exact.shape == (len(fam), len(machines))
        np.testing.assert_allclose(
            ex_np.grid.total, ex_jx.grid.total, rtol=RTOL, equal_nan=True
        )
        assert (ex_np.heuristic_idx == ex_jx.heuristic_idx).all()
        s = ex_np.summary()
        assert "within5%" in s

    def test_skew_aware_gate_consistent_scalar_vs_batch(self):
        from repro.core import select_schedule, select_schedule_batch

        fam = ragged_scenario_grid(steps=8, skews=(1.0, 4.0))
        rb = RaggedBatch.from_ragged_scenarios(fam)
        for machine in (MI300X, TPU_V5E):
            picks = select_schedule_batch(
                rb.m, rb.n, rb.k, rb.dtype_bytes, machine,
                imbalance=rb.imbalance,
            )
            for i, sc in enumerate(fam):
                dec = select_schedule(sc.gemm, machine, profile=sc.profile)
                assert GRID_SCHEDULES[int(picks[i])] is dec.schedule, sc.name


# ---------------------------------------------------------------------------
# Kernel layer: skew-aware chunked A2A dispatch.
# ---------------------------------------------------------------------------


class TestSkewAwareMoeKernel:
    def test_skewed_chunks_match_serial_reference(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.overlap.moe import ficco_a2a_ffn, serial_a2a_ffn

        mesh = Mesh(np.array(jax.devices()[:1]), ("ep",))
        e, c, d, f = 4, 12, 8, 16
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
        w_up = jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32)
        w_dn = jnp.asarray(rng.standard_normal((e, f, d)), jnp.float32)
        profile = StepProfile.from_weights([6, 3, 2, 1])

        def run(fn, **kw):
            wrapped = shard_map(
                lambda a, b, c_: fn(a, b, c_, axis_name="ep", **kw),
                mesh=mesh,
                in_specs=(P(), P(), P()),
                out_specs=P(),
                check_vma=False,
            )
            return np.asarray(wrapped(x, w_up, w_dn))

        want = run(serial_a2a_ffn)
        got = run(ficco_a2a_ffn, profile=profile)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        # explicit sizes incl. an empty chunk
        got2 = run(ficco_a2a_ffn, chunk_sizes=(5, 0, 4, 3))
        np.testing.assert_allclose(got2, want, rtol=2e-5, atol=2e-5)

    def test_chunk_sizes_validated(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.overlap.moe import ficco_a2a_ffn

        mesh = Mesh(np.array(jax.devices()[:1]), ("ep",))
        x = jnp.zeros((2, 8, 4), jnp.float32)
        w_up = jnp.zeros((2, 4, 8), jnp.float32)
        w_dn = jnp.zeros((2, 8, 4), jnp.float32)
        with pytest.raises(ValueError):
            shard_map(
                lambda a, b, c_: ficco_a2a_ffn(
                    a, b, c_, axis_name="ep", chunk_sizes=(3, 3)
                ),
                mesh=mesh,
                in_specs=(P(), P(), P()),
                out_specs=P(),
                check_vma=False,
            )(x, w_up, w_dn)

    def test_skewed_chunk_sizes_helper(self):
        from repro.overlap.moe import skewed_chunk_sizes

        sizes = skewed_chunk_sizes(64, StepProfile.skewed(4, 2.0))
        assert sum(sizes) == 64 and len(sizes) == 4
        assert sizes[-1] > sizes[0]
