"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Shape/dtype sweeps for the single-device kernels; the remote-DMA kernels
are swept in tests/multidev_kernels_driver.py (8 simulated devices).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chunked_gemm import accumulate_matmul, chunked_matmul

SHAPES = [
    (128, 128, 128),
    (256, 128, 384),
    (384, 256, 128),
    (128, 384, 256),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        # fp32 dots reassociate across K blocks -> not bit-equal to jnp
        else dict(rtol=1e-4, atol=1e-4)
    )


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_chunked_matmul_matches_ref(m, n, k, dtype):
    rng = np.random.default_rng(m + n + k)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = chunked_matmul(x, w, interpret=True)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_accumulate_matmul_matches_ref(m, n, k, dtype):
    rng = np.random.default_rng(7 * m + n + k)
    c = jnp.asarray(rng.standard_normal((m, n)), dtype)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = accumulate_matmul(c, x, w, interpret=True)
    want = ref.accumulate_matmul_ref(c, x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        **_tol(dtype),
    )


def test_block_shape_sweep():
    """BlockSpec tiling must not change results."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    want = np.asarray(ref.matmul_ref(x, w))
    for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 256, 256)]:
        got = chunked_matmul(
            x, w, block_m=bm, block_n=bn, block_k=bk, interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(got), want, rtol=1e-4, atol=1e-4,
            err_msg=f"blocks {bm},{bn},{bk}",
        )


def test_indivisible_raises():
    x = jnp.zeros((100, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(ValueError):
        chunked_matmul(x, w, interpret=True)


def test_accumulate_fallback_for_odd_shapes():
    """accumulate_matmul degrades to jnp for non-tileable shapes."""
    rng = np.random.default_rng(4)
    c = jnp.asarray(rng.standard_normal((100, 60)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((100, 30)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((30, 60)), jnp.float32)
    got = accumulate_matmul(c, x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.accumulate_matmul_ref(c, x, w)),
        rtol=1e-5, atol=1e-5,
    )


def test_ops_wrappers_interpret_on_cpu():
    assert jax.default_backend() == "cpu"
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    got = ops.matmul(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(x, w)),
        rtol=1e-5, atol=1e-5,
    )
