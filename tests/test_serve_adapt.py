"""Online-adaptation serving tier (repro.serve.adapt) + the
concurrency/growth fixes it exposed.

Contracts under test:

* :class:`DecisionCache` — LRU+TTL semantics under an injected clock:
  expiry forces a re-rank, the size bound actually bounds, recency is
  refreshed on hit.
* :class:`ExplorationPolicy` / :class:`TokenBucket` — the measured tier
  fires only when the analytic top-2 gap is inside the model's error
  bar AND the budget allows; the grant count is bounded by
  burst + rate * time no matter the traffic.
* :class:`AdaptiveTier` — tier routing (memory / analytic / measured /
  heuristic-never-raise), TTL-driven adaptation, persistent warm-start,
  write-behind persistence, and gate re-fit from live traffic.
* The threaded stress contract: request threads hammering
  ``AdaptiveTier.pick`` + ``Autotuner.pick`` + metrics while the
  background re-fit thread swaps gates and flushes the cache must lose
  no counter increments, no cache entries, and raise nothing.
* ``DecodeEngine`` — the zero-token early return executes zero jitted
  steps, and the adapt hook records a per-batch decision.
* ``drifting_request_stream`` — deterministic, quantized, phase-rotating.
"""

import os
import threading

import numpy as np
import pytest

from repro.autotune.cache import AutotuneCache
from repro.autotune.tuner import Autotuner, TuneKey
from repro.core.machine import TPU_V5E
from repro.core.workload import GemmShape, StepProfile
from repro.obs import metrics as obs_metrics
from repro.serve.adapt import (
    AdaptConfig,
    AdaptiveTier,
    DecisionCache,
    ExplorationPolicy,
    TokenBucket,
    simulated_measure_fn,
)
from repro.sweep.synth import ServeRequest, drifting_request_stream

GEMM = GemmShape(16384, 16384, 32768, 2)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _tier(tmp_path, name="adapt.json", *, clock=None, config=None,
          measure_fn=None):
    tuner = Autotuner(
        cache=AutotuneCache(path=str(tmp_path / name)),
        backend="numpy",
        persist="defer",
    )
    kw = {} if clock is None else {"clock": clock}
    return AdaptiveTier(
        tuner, machine=TPU_V5E, config=config or AdaptConfig(),
        measure_fn=measure_fn, **kw,
    )


class TestDecisionCache:
    def test_ttl_expiry_forces_miss(self):
        clk = FakeClock()
        c = DecisionCache(8, ttl_s=10.0, clock=clk)
        c.put("k", "decision")
        assert c.get("k") == "decision"
        clk.advance(9.99)
        assert c.get("k") == "decision"
        clk.advance(0.02)
        assert c.get("k") is None
        assert c.expired == 1
        assert len(c) == 0

    def test_lru_bound_and_recency(self):
        clk = FakeClock()
        c = DecisionCache(3, ttl_s=100.0, clock=clk)
        for k in "abc":
            c.put(k, k.upper())
        assert c.get("a") == "A"  # refresh a's recency
        c.put("d", "D")           # evicts b, the least recent
        assert c.evicted == 1
        assert c.get("b") is None
        assert all(c.get(k) for k in "acd")
        assert len(c) == 3

    def test_hit_refreshes_recency_not_freshness(self):
        clk = FakeClock()
        c = DecisionCache(8, ttl_s=10.0, clock=clk)
        c.put("k", "v")
        clk.advance(6.0)
        assert c.get("k") == "v"   # hit at t=6 does NOT reset the TTL
        clk.advance(6.0)
        assert c.get("k") is None  # dead at t=12 regardless of the hit


class TestTokenBucket:
    def test_burst_then_refill(self):
        clk = FakeClock()
        b = TokenBucket(rate=1.0, burst=2.0, clock=clk)
        assert b.try_take() and b.try_take()
        assert not b.try_take()  # burst exhausted, clock frozen
        clk.advance(1.0)
        assert b.try_take()
        assert not b.try_take()

    def test_refill_caps_at_burst(self):
        clk = FakeClock()
        b = TokenBucket(rate=100.0, burst=3.0, clock=clk)
        clk.advance(60.0)
        granted = sum(b.try_take() for _ in range(10))
        assert granted == 3


class TestExplorationPolicy:
    def _policy(self, clk, **kw):
        cfg = AdaptConfig(
            explore_rate=kw.pop("rate", 0.0),
            explore_burst=kw.pop("burst", 2.0),
            default_sigma=kw.pop("sigma", 0.10),
            **kw,
        )
        return ExplorationPolicy(cfg, clock=clk)

    def test_confident_gap_never_measures(self):
        from repro.core.schedule_types import Schedule

        p = self._policy(FakeClock())
        ranked = [(Schedule.SERIAL, 1.0), (Schedule.UNIFORM_FUSED_1D, 2.0)]
        assert not p.should_measure(ranked)
        assert p.ambiguous == 0

    def test_ambiguous_gap_bounded_by_budget(self):
        from repro.core.schedule_types import Schedule

        p = self._policy(FakeClock(), burst=2.0)
        ranked = [(Schedule.SERIAL, 1.00), (Schedule.UNIFORM_FUSED_1D, 1.01)]
        grants = [p.should_measure(ranked) for _ in range(6)]
        assert grants == [True, True, False, False, False, False]
        assert (p.ambiguous, p.granted, p.denied) == (6, 2, 4)

    def test_sigma_swap_widens_the_bar(self):
        from repro.core.schedule_types import Schedule

        p = self._policy(FakeClock(), burst=10.0)
        ranked = [(Schedule.SERIAL, 1.0), (Schedule.UNIFORM_FUSED_1D, 1.5)]
        assert not p.should_measure(ranked)   # gap >> 2 * 0.10
        p.set_sigma(5.0)                       # a terrible model
        assert p.should_measure(ranked)        # now inside the bar

    def test_degenerate_rankings(self):
        from repro.core.schedule_types import Schedule

        p = self._policy(FakeClock())
        assert not p.should_measure([])
        assert not p.should_measure([(Schedule.SERIAL, 1.0)])
        assert not p.should_measure(
            [(Schedule.SERIAL, 0.0), (Schedule.UNIFORM_FUSED_1D, 0.0)]
        )


class TestAdaptiveTier:
    def test_memory_tier_then_ttl_rerank(self, tmp_path):
        clk = FakeClock()
        tier = _tier(tmp_path, clock=clk, config=AdaptConfig(ttl_s=60.0))
        reg = obs_metrics.get_metrics()
        d1 = tier.pick(GEMM)
        d2 = tier.pick(GEMM)
        assert d1.schedule == d2.schedule
        assert reg.counter("serve/adapt.pick.analytic").value == 1
        assert reg.counter("serve/adapt.pick.memory").value == 1
        clk.advance(61.0)
        tier.pick(GEMM)
        assert reg.counter("serve/adapt.pick.analytic").value == 2
        assert tier.cache.expired == 1
        assert reg.counter("serve/adapt.decisions").value == 3

    def test_never_raises_falls_back_to_heuristic(self, tmp_path,
                                                  monkeypatch):
        tier = _tier(tmp_path)

        def boom(*a, **kw):
            raise RuntimeError("engine down")

        monkeypatch.setattr(tier.tuner, "executable_ranking", boom)
        dec = tier.pick(GEMM)
        assert dec.source == "heuristic"
        reg = obs_metrics.get_metrics()
        assert reg.counter("serve/adapt.pick.heuristic").value == 1
        # Un-cached: a healthy pick re-ranks instead of serving the
        # degraded answer from memory.
        monkeypatch.undo()
        assert tier.pick(GEMM).source == "analytic"

    def test_warm_start_from_persistent_store(self, tmp_path):
        tier1 = _tier(tmp_path, "shared.json")
        gemms = [GEMM, GemmShape(8192, 8192, 16384, 2)]
        for g in gemms:
            tier1.pick(g)
        tier1.tuner.cache.flush()

        reg = obs_metrics.get_metrics()
        before = reg.counter("serve/adapt.pick.analytic").value
        tier2 = _tier(tmp_path, "shared.json")
        assert reg.counter("serve/adapt.warm_start").value == len(gemms)
        for g in gemms:
            assert tier2.pick(g).schedule == tier1.pick(g).schedule
        # Every tier2 pick was a memory hit off the warm start.
        assert reg.counter("serve/adapt.pick.analytic").value == before

    def test_write_behind_defers_disk_io(self, tmp_path):
        tier = _tier(tmp_path, "defer.json")
        tier.pick(GEMM)
        path = tier.tuner.cache.path
        assert tier.tuner.cache.dirty
        assert not os.path.exists(path)  # the hot path never wrote
        tier.stop()                      # stop() flushes
        assert not tier.tuner.cache.dirty
        fresh = AutotuneCache(path=path)
        key = str(TuneKey.for_gemm(GEMM, TPU_V5E, None))
        assert key in fresh.decision_entries()

    def test_measured_tier_budget_and_audit(self, tmp_path):
        from repro.obs import audit as obs_audit

        log_path = tmp_path / "audit.jsonl"
        obs_audit.enable_audit(str(log_path))
        clk = FakeClock()
        cfg = AdaptConfig(explore_rate=0.0, explore_burst=3.0)
        tier = _tier(
            tmp_path, clock=clk, config=cfg,
            measure_fn=simulated_measure_fn(TPU_V5E, seed=0),
        )
        tier.policy.set_sigma(10.0)  # every top-2 gap is "ambiguous"
        gemms = [
            GemmShape(1024 * 8 * (i + 1), 8192, 8192, 2) for i in range(8)
        ]
        decisions = [tier.pick(g) for g in gemms]
        measured = [d for d in decisions if d.source == "measured"]
        # Frozen clock + rate 0: the burst is the whole budget.
        assert len(measured) == 3
        assert tier.policy.granted == 3
        assert tier.policy.denied == 5
        reg = obs_metrics.get_metrics()
        assert reg.counter("serve/adapt.measures").value == 3
        recs = [
            __import__("json").loads(line)
            for line in log_path.read_text().splitlines()
        ]
        assert sum(r["kind"] == "adapt_measure" for r in recs) == 3

    def test_pick_for_requests_load_digest(self, tmp_path):
        from repro.serve.engine import Request

        tier = _tier(tmp_path)

        class Cfg:
            d_model, d_ff = 4096, 16384

        reqs = [
            Request(np.zeros(8, np.int32), max_new_tokens=24),
            Request(np.zeros(16, np.int32), max_new_tokens=16),
        ]
        dec = tier.pick_for_requests(reqs, Cfg)
        assert dec.key is not None
        # Same load *shape* at different absolute scale shares the key.
        reqs2 = [
            Request(np.zeros(16, np.int32), max_new_tokens=48),
            Request(np.zeros(32, np.int32), max_new_tokens=32),
        ]
        reg = obs_metrics.get_metrics()
        before = reg.counter("serve/adapt.pick.memory").value
        tier.pick_for_requests(reqs2, Cfg)
        # 2x the tokens changes the GEMM M, so keys differ; but a
        # single request always collapses to the uniform profile.
        one = tier.pick_for_requests(
            [Request(np.zeros(8, np.int32), max_new_tokens=24)], Cfg
        )
        assert "reqload" not in (one.key or "")
        assert reg.counter("serve/adapt.pick.memory").value == before

    def test_refit_deploys_gate_and_tracks_agreement(self, tmp_path):
        cfg = AdaptConfig(refit_min_picks=64, buffer_size=512,
                          fit_min_records=10 ** 9)
        tier = _tier(tmp_path, config=cfg)
        assert tier.refit_now().get("gate_agreement") is None  # too few
        reqs = list(
            drifting_request_stream(200, seed=0, drift_every=1000)
        )
        for r in reqs:
            tier.pick(r.gemm, profile=r.profile)
        rep = tier.refit_now()
        assert tier.gate_version == 1
        assert tier.tuner.gate is not None
        assert 0.0 < rep["gate_agreement"] <= 1.0
        assert tier.last_agreement == rep["gate_agreement"]
        assert rep["flushed"]
        # The probe scores the deployed gate on held-out traffic.
        held_out = [(r.gemm, r.profile) for r in reqs[:64]]
        ag = tier.agreement_probe(held_out)
        assert 0.0 < ag <= 1.0
        # Drift + another re-fit swaps a new gate in.
        for r in drifting_request_stream(200, seed=5, drift_every=50):
            tier.pick(r.gemm, profile=r.profile)
        tier.refit_now()
        assert tier.gate_version == 2

    def test_stats_surface(self, tmp_path):
        tier = _tier(tmp_path)
        tier.pick(GEMM)
        s = tier.stats()
        assert s["cache_len"] == 1
        assert s["persistent_dirty"] is True
        assert set(s) >= {
            "cache_expired", "cache_evicted", "gate_version",
            "last_agreement", "sigma", "explore_ambiguous",
            "explore_granted", "explore_denied",
        }


class TestThreadedStress:
    def test_picks_metrics_and_flushes_under_contention(self, tmp_path):
        """N request threads hammer AdaptiveTier.pick + Autotuner.pick +
        a shared counter while the background re-fit thread swaps gates
        and flushes the write-behind cache.  Nothing may be lost."""
        cache = AutotuneCache(path=str(tmp_path / "stress.json"))
        tuner = Autotuner(cache=cache, backend="numpy", persist="defer")
        cfg = AdaptConfig(
            ttl_s=0.05,              # force mid-run TTL re-ranks
            refit_interval_s=0.01,   # re-fit as hot as possible
            refit_min_picks=32,
            buffer_size=256,
            fit_min_records=10 ** 9,  # gate refits only (numpy-fast)
        )
        tier = AdaptiveTier(tuner, machine=TPU_V5E, config=cfg)
        gemms = [
            GemmShape(1024 * 8 * (i + 1), 8192, 8192, 2)
            for i in range(12)
        ]
        n_threads, iters = 8, 150
        reg = obs_metrics.get_metrics()
        shared = reg.counter("test/stress")
        errors = []

        def worker(tid):
            try:
                for i in range(iters):
                    g = gemms[(tid + i) % len(gemms)]
                    if tid % 2:
                        tier.pick(g)
                    else:
                        tuner.pick(g, TPU_V5E)
                    shared.inc()
            except BaseException as e:  # noqa: BLE001 - the assertion
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(t,))
            for t in range(n_threads)
        ]
        with tier:  # background re-fit thread live
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert errors == []
        # No lost counter increments: the shared counter and the tier's
        # own accounting are both exact.
        assert shared.value == n_threads * iters
        tier_picks = (n_threads // 2) * iters
        assert reg.counter("serve/adapt.decisions").value == tier_picks
        assert (
            reg.histogram("serve/adapt.pick_seconds").count == tier_picks
        )
        # No hidden exceptions: the never-raise path would have routed
        # failures to the heuristic tier.
        assert reg.counter("serve/adapt.pick.heuristic").value == 0
        assert reg.counter("tuner/pick.heuristic").value == 0
        # No lost cache entries: every key survived the concurrent
        # defer-puts + background flushes, in memory and on disk.
        tier.stop()
        assert not cache.dirty
        on_disk = AutotuneCache(path=cache.path).decision_entries()
        for g in gemms:
            key = str(TuneKey.for_gemm(g, TPU_V5E, None))
            assert key in cache.decision_entries()
            assert key in on_disk
        # The re-fit thread actually did its job while all that ran.
        assert tier.gate_version >= 1


class TestDecodeEngineFixes:
    @pytest.fixture(scope="class")
    def engine_parts(self):
        import jax

        from repro.configs import get_config
        from repro.models.model import build_model

        cfg = get_config("smollm-360m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return cfg, params

    def test_zero_token_batch_executes_zero_steps(self, engine_parts):
        from repro.serve.engine import DecodeEngine, Request

        cfg, params = engine_parts
        eng = DecodeEngine(cfg, params, batch_size=2, cache_len=32)
        reqs = [
            Request(np.asarray([1, 2, 3], np.int32), max_new_tokens=0),
            Request(np.asarray([4], np.int32), max_new_tokens=0),
        ]
        out = eng.run(reqs)
        assert all(r.done and r.out == [] for r in out)
        reg = obs_metrics.get_metrics()
        assert reg.counter("serve/steps").value == 0
        assert reg.counter("serve/tokens").value == 0
        assert eng.run([]) == []  # empty batch: same early return

    def test_adapt_hook_records_batch_decision(self, engine_parts):
        from repro.serve.engine import DecodeEngine, Request

        cfg, params = engine_parts

        class FakeTier:
            calls = 0

            def pick_for_requests(self, requests, c):
                FakeTier.calls += 1
                return ("sentinel", len(requests))

        eng = DecodeEngine(
            cfg, params, batch_size=2, cache_len=32, adapt=FakeTier()
        )
        reqs = [Request(np.asarray([1, 2], np.int32), max_new_tokens=2)]
        eng.run(reqs)
        assert eng.last_decision == ("sentinel", 1)
        assert FakeTier.calls == 1
        assert len(reqs[0].out) == 2
        # Zero-token batches return before consulting the tier.
        eng.run([Request(np.asarray([1], np.int32), max_new_tokens=0)])
        assert FakeTier.calls == 1


class TestDriftingStream:
    def test_deterministic_in_seed(self):
        a = list(drifting_request_stream(300, seed=7, drift_every=100))
        b = list(drifting_request_stream(300, seed=7, drift_every=100))
        assert a == b
        c = list(drifting_request_stream(300, seed=8, drift_every=100))
        assert a != c

    def test_phases_and_quantization(self):
        reqs = list(drifting_request_stream(400, seed=0, drift_every=100,
                                            quantum=64))
        assert [r.phase for r in reqs] == [i // 100 for i in range(400)]
        for r in reqs:
            assert isinstance(r, ServeRequest)
            fr = np.asarray(r.profile.fractions)
            assert abs(fr.sum() - 1.0) < 1e-9
            # Quantized to 64ths: digests repeat within a phase.
            np.testing.assert_allclose(fr * 64, np.round(fr * 64),
                                       atol=1e-9)
        for phase in range(4):
            digs = {
                r.profile.digest()
                for r in reqs[phase * 100:(phase + 1) * 100]
            }
            assert len(digs) <= 8  # n_profiles bounds the working set

    def test_hot_step_rotates_with_phase(self):
        reqs = list(drifting_request_stream(
            600, seed=0, drift_every=200, steps=3, n_profiles=4,
            concentration=0.2, hot_boost=50.0,
        ))
        hot = []
        for phase in range(3):
            chunk = [r for r in reqs if r.phase == phase]
            mean = np.mean(
                [np.asarray(r.profile.fractions) for r in chunk], axis=0
            )
            hot.append(int(np.argmax(mean)))
        assert hot == [0, 1, 2]

    def test_validation(self):
        with pytest.raises(ValueError):
            list(drifting_request_stream(10, steps=0))
        with pytest.raises(ValueError):
            list(drifting_request_stream(10, drift_every=0))


class TestDriftSentinel:
    """The closed loop the ISSUE pins down: injected drift (a degraded
    link) trips the sentinel, the drift-triggered refit re-calibrates
    the machine model, and the post-refit residual measurably shrinks."""

    def _drift_tier(self, tmp_path, *, link_scale=0.45):
        import dataclasses as dc

        degraded = dc.replace(
            TPU_V5E, link_bw=TPU_V5E.link_bw * link_scale
        )
        cfg = AdaptConfig(
            explore_rate=0.0, explore_burst=1000.0,
            refit_min_picks=10**9,  # isolate the machine-fit path
            sentinel_min_samples=4, fit_steps=80,
        )
        tier = _tier(
            tmp_path, clock=FakeClock(), config=cfg,
            measure_fn=simulated_measure_fn(degraded, noise=0.0, seed=0),
        )
        tier.policy.set_sigma(10.0)  # every pick is ambiguous -> measured
        return tier

    @pytest.mark.autotune
    def test_drift_alarm_refit_recovery(self, tmp_path):
        tier = self._drift_tier(tmp_path)
        assert tier.sentinel is not None
        gemms = [
            GemmShape(4096 * (i + 1), 8192, 8192, 2) for i in range(8)
        ]
        for g in gemms:
            tier.pick(g)
        # Every measured pick fed the sentinel a predicted-vs-measured
        # residual; the 1/0.45 slowdown is ~0.8 in log space — far past
        # the CUSUM threshold.
        st = tier.sentinel.state()
        assert st["alarmed"] == "residual"
        assert st["ewma"] > 0.0  # measured slower than the model
        assert tier.sentinel.should_refit()
        pre_ewma = st["ewma"]

        rep = tier.refit_now()
        assert rep["trigger"] == "drift"
        assert "fit_sigma" in rep
        assert "link_bw" in rep.get("fit_deployed", ())
        assert tier.machine.link_bw < TPU_V5E.link_bw  # calibrated down
        assert tier.machine.name == TPU_V5E.name
        assert not tier.sentinel.should_refit()  # latch cleared
        refits = [
            e for e in tier.sentinel.events
            if e["kind"] == "sentinel_refit"
        ]
        assert len(refits) == 1 and refits[0]["trigger"] == "drift"

        # Post-refit traffic: the fit shrank policy sigma, so re-open
        # the measured tier and keep serving against the same degraded
        # hardware — predictions now come from the calibrated machine.
        tier.policy.set_sigma(10.0)
        for i in range(6):
            tier.pick(GemmShape(4096 * (i + 1), 8192, 8192 + 1024, 2))
        recs = [
            e for e in tier.sentinel.events
            if e["kind"] == "sentinel_recovery"
        ]
        assert len(recs) == 1
        rec = recs[0]
        assert rec["samples"] >= 4
        assert abs(rec["pre_refit_ewma"]) >= abs(pre_ewma) * 0.5
        # The acceptance bar: residual measurably shrinks post-refit.
        assert abs(rec["post_mean"]) < 0.5 * abs(rec["pre_refit_ewma"])

    def test_sentinel_disabled_by_config(self, tmp_path):
        tier = _tier(tmp_path, config=AdaptConfig(sentinel=False))
        assert tier.sentinel is None
        assert tier.stats()["sentinel"] is None
        tier.pick(GEMM)  # measured path must not touch the sentinel
        assert tier.refit_now()["trigger"] == "interval"

    def test_stats_surface_sentinel_state(self, tmp_path):
        tier = _tier(tmp_path)
        st = tier.stats()["sentinel"]
        assert st is not None
        assert st["n"] == 0 and st["alarmed"] is None

    def test_alarm_hook_wired_on_start(self, tmp_path):
        tier = _tier(tmp_path)
        assert tier.sentinel.on_alarm is None
        with tier:
            assert tier.sentinel.on_alarm == tier._refitter.kick
        assert tier.sentinel.on_alarm is None  # unhooked on stop

    def test_refitter_kick_runs_cycle_now(self, tmp_path):
        import time as _time

        cfg = AdaptConfig(refit_interval_s=60.0)  # interval never fires
        tier = _tier(tmp_path, config=cfg)
        reg = obs_metrics.get_metrics()
        with tier:
            tier._refitter.kick()
            deadline = _time.monotonic() + 5.0
            while (reg.counter("serve/adapt.refits").value < 1
                   and _time.monotonic() < deadline):
                _time.sleep(0.01)
            assert reg.counter("serve/adapt.refits").value >= 1
            assert tier._refitter.kicks == 1
