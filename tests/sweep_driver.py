"""Sharded-sweep correctness driver (subprocess, 2 forced host devices).

The main pytest process must keep seeing ONE device, so the
device-parallel sweep runs here, spawned by ``tests/test_sweep.py``.
Checks that a sweep sharded over >= 2 devices reproduces the unsharded
jitted engine's GridResult EXACTLY — bit for bit, for uniform and
ragged grids, including a scenario count not divisible by the device
count (padded remainder) — and that multi-host chunking composes with
device parallelism.  Prints ``ALL-OK`` on success.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=2 "
    + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import MI300X, TPU_V5E, get_engine  # noqa: E402
from repro.sweep import (  # noqa: E402
    concat_grid_results,
    sweep_grid,
    synthetic_batch,
    synthetic_ragged_batch,
)

from grid_asserts import assert_grid_identical  # noqa: E402

MACHINES = (MI300X, TPU_V5E)
failures: list[str] = []


def check(name: str, fn):
    try:
        fn()
        print(f"ok {name}")
    except Exception:
        failures.append(name)
        print(f"FAIL {name}")
        traceback.print_exc()


def uniform_device_sharded_exact():
    # 23 scenarios over 2 devices: padded-remainder path (12 + 11).
    sb = synthetic_batch(23, seed=11)
    ref = get_engine("jax").evaluate(sb, MACHINES)
    res = sweep_grid(sb, MACHINES, device_parallel=True, mode="gather")
    assert_grid_identical(res.grid, ref, "uniform ")


def ragged_device_sharded_exact():
    rb = synthetic_ragged_batch(19, seed=12)
    ref = get_engine("jax").evaluate(rb, MACHINES)
    res = sweep_grid(rb, MACHINES, device_parallel=True, mode="gather")
    assert_grid_identical(res.grid, ref, "ragged ")
    # Profiles travel with their scenario shard: reassembled frac rows
    # are the originals, byte for byte.
    assert np.array_equal(res.grid.scenarios.frac, rb.frac)


def divisible_count_exact():
    sb = synthetic_batch(16, seed=13)  # divisible by 2: no padding
    ref = get_engine("jax").evaluate(sb, MACHINES)
    res = sweep_grid(sb, MACHINES, device_parallel=True, mode="gather")
    assert_grid_identical(res.grid, ref, "divisible ")


def hosts_compose_with_devices():
    # 2 hosts x 4 shards, each shard pmapped over the 2 devices; the
    # union of both hosts' grids is the unsharded grid.
    sb = synthetic_batch(21, seed=14)
    ref = get_engine("jax").evaluate(sb, MACHINES)
    parts = {}
    for host in (0, 1):
        res = sweep_grid(
            sb, MACHINES, num_shards=4, host_index=host, host_count=2,
            device_parallel=True, mode="gather",
        )
        for shard, summ in zip(res.owned, res.summaries):
            start, stop = res.plan.bounds[shard]
            parts[shard] = (start, stop)
        parts[f"grid{host}"] = res
    # Reassemble in shard order from the two hosts' owned slices.
    h0, h1 = parts["grid0"], parts["grid1"]
    by_shard = {}
    for res in (h0, h1):
        offset = 0
        for shard in res.owned:
            size = res.plan.sizes[shard]
            from repro.sweep.runner import _slice_grid

            by_shard[shard] = _slice_grid(res.grid, offset, offset + size)
            offset += size
    merged = concat_grid_results(
        [by_shard[i] for i in sorted(k for k in by_shard)]
    )
    assert_grid_identical(merged, ref, "hosts+devices ")


def two_host_metrics_merge_exact():
    # Fleet obs merge under a REAL two-host sweep: each "host" runs its
    # owned shards against a fresh registry and exports an
    # identity-stamped reservoir snapshot; the merged snapshot's
    # counters must equal a single whole-sweep run bit for bit, and its
    # percentiles must be nearest-rank over the union of the per-host
    # reservoirs (exact here — counts are far below RESERVOIR_SIZE).
    import math

    from repro.obs import metrics as obs_metrics

    sb = synthetic_batch(24, seed=15)
    snaps, union = [], []
    for host in (0, 1):
        obs_metrics.reset_metrics()
        sweep_grid(
            sb, MACHINES, num_shards=4, host_index=host, host_count=2,
            device_parallel=True, mode="gather",
        )
        snap = obs_metrics.get_metrics().snapshot(
            reservoir=True, host={"host_index": host, "pid": 1000 + host},
        )
        union.extend(snap["histograms"]["sweep/shard_seconds"]["reservoir"])
        snaps.append(snap)

    obs_metrics.reset_metrics()
    sweep_grid(sb, MACHINES, num_shards=4, device_parallel=True,
               mode="gather")
    ref = obs_metrics.get_metrics().snapshot()

    merged = obs_metrics.merge_snapshots(snaps)
    assert obs_metrics.validate_merged_snapshot(merged) == [], (
        obs_metrics.validate_merged_snapshot(merged)
    )
    assert merged["hosts"] == 2
    # Counters: the two hosts' shards partition the sweep exactly.
    assert merged["counters"] == ref["counters"], (
        merged["counters"], ref["counters"],
    )
    h = merged["histograms"]["sweep/shard_seconds"]
    union.sort()
    assert h["count"] == 4 == len(union)
    assert "approx" not in h  # both inputs carried reservoirs
    assert h["reservoir_n"] == 4
    for q, want in (("p50", union[1]), ("p95", union[3])):
        assert h[q] == want, (q, h[q], want)
    assert math.isclose(h["sum"], sum(union), rel_tol=1e-12)


def main():
    assert len(jax.devices()) == 2, jax.devices()
    check("uniform_device_sharded_exact", uniform_device_sharded_exact)
    check("ragged_device_sharded_exact", ragged_device_sharded_exact)
    check("divisible_count_exact", divisible_count_exact)
    check("hosts_compose_with_devices", hosts_compose_with_devices)
    check("two_host_metrics_merge_exact", two_host_metrics_merge_exact)
    if failures:
        print("FAILED:", failures)
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
