"""Batched engine == scalar simulator, across a randomized grid slice.

Property-style equivalence (no hypothesis dependency: seeded random
sampling): for a random slice of the scenario grid x machine grid —
covering all schedules, both topologies, group sizes 8/16 and dma on/off
— every batched per-schedule total/busy/exposed figure must match the
scalar ``simulate()`` within 1e-6 relative tolerance (they are in fact
bit-identical by construction: the batched pipeline replays the scalar
accumulation order), ``best_schedule`` picks must agree, and the
validity mask must exactly mirror where the scalar model raises.
"""

import numpy as np
import pytest

from repro.core import (
    GRID_SCHEDULES,
    MI300X,
    TABLE_I,
    TPU_V5E,
    ScenarioBatch,
    best_schedule,
    evaluate_grid,
    machine_grid,
    scenario_grid,
    simulate,
)

RTOL = 1e-6

_FIELDS = {
    "total": "total",
    "comm_busy": "comm_busy",
    "compute_busy": "compute_busy",
    "exposed": "exposed_comm",
}


def _grid_slice(seed: int, count: int):
    scenarios = scenario_grid()
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(scenarios), size=count, replace=False)
    return [scenarios[i] for i in idx]


def _assert_matches_scalar(scenarios, machines, *, dma, dma_into_place=False):
    sb = ScenarioBatch.from_scenarios(scenarios)
    grid = evaluate_grid(
        sb, machines, dma=dma, dma_into_place=dma_into_place
    )
    for j, machine in enumerate(machines):
        for i, sc in enumerate(scenarios):
            for l, sched in enumerate(GRID_SCHEDULES):
                try:
                    want = simulate(
                        sc.gemm, machine, sched,
                        dma=dma, dma_into_place=dma_into_place,
                    )
                except ValueError:
                    assert not grid.valid[l, i, j], (
                        f"scalar raised but grid valid: {sched} {sc.name} "
                        f"{machine.name}"
                    )
                    assert np.isnan(grid.total[l, i, j])
                    continue
                assert grid.valid[l, i, j], (sched, sc.name, machine.name)
                for fname, attr in _FIELDS.items():
                    got = getattr(grid, fname)[l, i, j]
                    ref = getattr(want, attr)
                    assert got == pytest.approx(ref, rel=RTOL, abs=1e-15), (
                        fname, sched, sc.name, machine.name,
                    )
                assert int(grid.steps[l, j]) == want.steps
                assert grid.serial_comm[i, j] == pytest.approx(
                    want.serial_comm, rel=RTOL
                )
                assert grid.serial_gemm[i, j] == pytest.approx(
                    want.serial_gemm, rel=RTOL
                )


class TestBatchedEquivalence:
    def test_table_i_both_machines_dma_on_off(self):
        for dma in (True, False):
            _assert_matches_scalar(
                list(TABLE_I), (MI300X, TPU_V5E), dma=dma
            )

    def test_dma_into_place_matches(self):
        _assert_matches_scalar(
            list(TABLE_I)[:8], (MI300X, TPU_V5E), dma=True,
            dma_into_place=True,
        )

    def test_random_grid_slice_all_topologies(self):
        """Random scenario-grid slice x the full machine grid (both
        topologies, groups 8 and 16)."""
        scenarios = _grid_slice(seed=1234, count=24)
        machines = machine_grid()
        topos = {m.topology for m in machines}
        assert len(topos) == 2
        _assert_matches_scalar(scenarios, machines, dma=True)

    def test_random_grid_slice_rccl(self):
        scenarios = _grid_slice(seed=99, count=12)
        _assert_matches_scalar(
            scenarios, machine_grid()[:4], dma=False
        )

    def test_best_schedule_picks_agree(self):
        """Batched argmin == scalar ``best_schedule`` (same tie order)."""
        scenarios = [*TABLE_I, *_grid_slice(seed=7, count=24)]
        for machine in (MI300X, TPU_V5E):
            sb = ScenarioBatch.from_scenarios(scenarios)
            grid = evaluate_grid(sb, (machine,))
            best = grid.best_idx()[:, 0]
            for i, sc in enumerate(scenarios):
                opt, _ = best_schedule(sc.gemm, machine)
                assert GRID_SCHEDULES[int(best[i])] is opt, (
                    sc.name, machine.name,
                )


class TestGridResultApi:
    def test_sim_result_roundtrip(self):
        sb = ScenarioBatch.from_scenarios(TABLE_I)
        grid = evaluate_grid(sb, (MI300X,))
        for sched in GRID_SCHEDULES:
            r = grid.sim_result(sched, 0, 0)
            want = simulate(TABLE_I[0].gemm, MI300X, sched)
            assert r.total == pytest.approx(want.total, rel=RTOL)
            assert r.schedule is sched
            assert r.speedup == pytest.approx(want.speedup, rel=RTOL)

    def test_invalid_decomposition_masked(self):
        """m not divisible by the group -> FiCCO/P2P rows invalid, SERIAL
        fine (the scalar model raises for the same cases)."""
        from repro.core import GemmShape, Schedule

        sb = ScenarioBatch.from_gemms([GemmShape(1001, 4096, 4096)])
        grid = evaluate_grid(sb, (MI300X,))
        l_serial = grid.schedule_idx(Schedule.SERIAL)
        l_p2p = grid.schedule_idx(Schedule.SHARD_P2P)
        assert grid.valid[l_serial, 0, 0]
        assert not grid.valid[l_p2p, 0, 0]
        with pytest.raises(ValueError):
            simulate(GemmShape(1001, 4096, 4096), MI300X, Schedule.SHARD_P2P)

    def test_degenerate_hetero_chunks_masked(self):
        """m in [group, group^2): hetero schedules have a zero-row step
        GEMM — scalar raises ValueError, grid masks those rows invalid,
        the other schedules still agree."""
        from repro.core import GemmShape

        gemm = GemmShape(32, 4096, 4096)  # MI300X group=8: m_s=4, m_sg=0
        sc = type("S", (), {"gemm": gemm, "name": "degenerate"})
        _assert_matches_scalar([sc], (MI300X,), dma=True)

    def test_speedup_and_best_total_consistent(self):
        sb = ScenarioBatch.from_scenarios(TABLE_I)
        grid = evaluate_grid(sb, (MI300X, TPU_V5E))
        best = grid.best_total()
        assert (best <= np.nanmin(grid.total, axis=0) + 1e-15).all()
        assert np.isfinite(grid.speedup[grid.valid]).all()


class TestBatchedHeuristics:
    def test_select_schedule_batch_matches_scalar(self):
        from repro.core import select_schedule, select_schedule_batch

        scenarios = [*TABLE_I, *_grid_slice(seed=5, count=32)]
        sb = ScenarioBatch.from_scenarios(scenarios)
        for machine in (MI300X, TPU_V5E):
            picks = select_schedule_batch(
                sb.m, sb.n, sb.k, sb.dtype_bytes, machine
            )
            for i, sc in enumerate(scenarios):
                dec = select_schedule(sc.gemm, machine)
                assert GRID_SCHEDULES[int(picks[i])] is dec.schedule, sc.name

    def test_calibrate_tau_batched_matches_scalar_reference(self):
        """The batched calibrate_tau reproduces the scalar algorithm."""
        from repro.core import calibrate_tau, select_schedule
        from repro.core.heuristics import _TAU_OVERRIDES

        machine = MI300X
        candidates = (0.02, 0.05, 0.1, 0.2, 0.5, 1.0)
        scenarios = list(TABLE_I)
        # scalar reference (the pre-batching implementation)
        best_tau, best_acc = candidates[0], -1.0
        for tau in candidates:
            hits = 0
            for sc in scenarios:
                dec = select_schedule(sc.gemm, machine, tau=tau)
                opt, _ = best_schedule(sc.gemm, machine)
                hits += dec.schedule is opt
            acc = hits / len(scenarios)
            if acc > best_acc:
                best_tau, best_acc = tau, acc
        saved = _TAU_OVERRIDES.pop(machine.name, None)
        try:
            got = calibrate_tau(machine, scenarios, candidates=candidates)
        finally:
            if saved is None:
                _TAU_OVERRIDES.pop(machine.name, None)
            else:
                _TAU_OVERRIDES[machine.name] = saved
        assert got == best_tau


class TestExploreGrid:
    def test_matches_scalar_explore(self):
        from repro.core import explore, explore_grid

        scenarios = list(TABLE_I)
        ex = explore_grid(scenarios, machines=(MI300X,))
        for i, sc in enumerate(scenarios):
            ref = explore(sc, MI300X)
            assert GRID_SCHEDULES[int(ex.best_idx[i, 0])] is ref.best
            assert (
                GRID_SCHEDULES[int(ex.heuristic_idx[i, 0])]
                is ref.heuristic.schedule
            )
            assert bool(ex.exact[i, 0]) == ref.heuristic_correct
            if not ref.heuristic_correct:
                assert ex.heuristic_loss()[i, 0] == pytest.approx(
                    ref.heuristic_loss, rel=1e-9, abs=1e-12
                )

    def test_summary_smoke(self):
        from repro.core import explore_grid

        ex = explore_grid(list(TABLE_I)[:4], machines=(MI300X, TPU_V5E))
        s = ex.summary()
        assert "exact" in s and "within5%" in s
