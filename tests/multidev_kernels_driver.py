"""Remote-DMA Pallas kernel checks on 8 simulated devices (subprocess).

Validates the TPU DMA-offload kernels against lax-collective oracles using
the Mosaic TPU interpreter, which simulates cross-device DMAs + semaphores.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import dataclasses  # noqa: E402
import functools  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import shard_map  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.dma_exchange import (  # noqa: E402
    a2a_chunk_exchange,
    ficco_uniform_fused_1d_dma,
)
from repro.kernels.ficco_ag_matmul import ficco_ag_matmul_fused  # noqa: E402
from repro.overlap.moe import ficco_a2a_ffn, serial_a2a_ffn  # noqa: E402
from repro.tune import default_variant  # noqa: E402

G = 8
AXIS = "tp"
failures = []


def check(name, fn):
    try:
        fn()
        print(f"ok {name}")
    except Exception:
        failures.append(name)
        print(f"FAIL {name}")
        traceback.print_exc()


def mesh():
    return jax.make_mesh((G,), (AXIS,))


def exchange_matches_all_gather():
    m = mesh()
    rng = np.random.default_rng(0)
    for shape, dtype in [((8, 128), jnp.float32), ((16, 256), jnp.bfloat16)]:
        x = jnp.asarray(rng.standard_normal((G * shape[0], shape[1])), dtype)

        def body(xs):
            got = a2a_chunk_exchange(
                xs, axis_name=AXIS, group=G, interpret=True
            )
            want = ref.a2a_chunk_exchange_ref(xs, axis_name=AXIS)
            return got, want

        got, want = jax.jit(
            shard_map(
                body, mesh=m,
                in_specs=P(AXIS, None),
                out_specs=(P(AXIS, None, None), P(AXIS, None, None)),
                check_vma=False,
            )
        )(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def dma_schedule_matches_serial():
    m = mesh()
    rng = np.random.default_rng(1)
    ms, k, n_local = 64, 128, 128  # per-device shard
    x = jnp.asarray(rng.standard_normal((G * ms, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, G * n_local)), jnp.float32)

    def body(xs, ws):
        got = ficco_uniform_fused_1d_dma(
            xs, ws, axis_name=AXIS, interpret=True
        )
        want = ref.ag_matmul_ref(xs, ws, axis_name=AXIS)
        return got, want

    got, want = jax.jit(
        shard_map(
            body, mesh=m,
            in_specs=(P(AXIS, None), P(None, AXIS)),
            out_specs=(P(None, AXIS), P(None, AXIS)),
            check_vma=False,
        )
    )(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def fused_kernel_matches_serial():
    m = mesh()
    rng = np.random.default_rng(2)
    for ms, k, n_local, dtype in [
        (64, 128, 128, jnp.float32),
        (32, 256, 128, jnp.bfloat16),
    ]:
        x = jnp.asarray(rng.standard_normal((G * ms, k)), dtype)
        w = jnp.asarray(rng.standard_normal((k, G * n_local)), dtype)

        def body(xs, ws):
            got = ficco_ag_matmul_fused(
                xs, ws, axis_name=AXIS, interpret=True
            )
            want = ref.ag_matmul_ref(xs, ws, axis_name=AXIS)
            return got, want

        got, want = jax.jit(
            shard_map(
                body, mesh=m,
                in_specs=(P(AXIS, None), P(None, AXIS)),
                out_specs=(P(None, AXIS), P(None, AXIS)),
                check_vma=False,
            )
        )(x, w)
        tol = (
            dict(rtol=2e-2, atol=2e-2)
            if dtype == jnp.bfloat16
            else dict(rtol=1e-5, atol=1e-5)
        )
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
        )


def ag_fused_variants_bit_identical():
    """Chunk-count / buffer-depth / dispatch-order variants of the fused
    AG kernel must be BIT-identical to the default: every output row is
    one full-K dot whichever slot/step order produced its operand."""
    m = mesh()
    rng = np.random.default_rng(3)
    ms, k, n_local = 64, 128, 128
    x = jnp.asarray(rng.standard_normal((G * ms, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, G * n_local)), jnp.float32)
    base = default_variant("ficco_ag_matmul", group=G)
    variants = [
        base,
        dataclasses.replace(base, chunks=4),
        dataclasses.replace(base, buffer_depth=3),
        dataclasses.replace(base, chunks=4, buffer_depth=3),
        dataclasses.replace(base, dispatch_order="reverse"),
    ]

    def run(v):
        def body(xs, ws):
            return ficco_ag_matmul_fused(
                xs, ws, axis_name=AXIS, interpret=True, variant=v
            )

        return np.asarray(
            jax.jit(
                shard_map(
                    body, mesh=m,
                    in_specs=(P(AXIS, None), P(None, AXIS)),
                    out_specs=P(None, AXIS),
                    check_vma=False,
                )
            )(x, w)
        )

    want = run(variants[0])
    for v in variants[1:]:
        np.testing.assert_array_equal(run(v), want, err_msg=v.digest())


def dma_schedule_variants_match():
    """dma_exchange variants: chunk/order cuts are bit-identical (same
    full-K row dots, different step batching); a blocked step-GEMM tile
    keeps the full-K contraction so it matches to float tolerance."""
    m = mesh()
    rng = np.random.default_rng(4)
    ms, k, n_local = 64, 128, 128
    x = jnp.asarray(rng.standard_normal((G * ms, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, G * n_local)), jnp.float32)
    base = default_variant("dma_exchange", group=G)

    def run(v):
        def body(xs, ws):
            return ficco_uniform_fused_1d_dma(
                xs, ws, axis_name=AXIS, interpret=True, variant=v
            )

        return np.asarray(
            jax.jit(
                shard_map(
                    body, mesh=m,
                    in_specs=(P(AXIS, None), P(None, AXIS)),
                    out_specs=(P(None, AXIS)),
                    check_vma=False,
                )
            )(x, w)
        )

    want = run(base)
    for v in (
        dataclasses.replace(base, chunks=4),
        dataclasses.replace(base, dispatch_order="reverse"),
    ):
        np.testing.assert_array_equal(run(v), want, err_msg=v.digest())
    tiled = dataclasses.replace(base, block_m=64, block_n=64)
    np.testing.assert_allclose(
        run(tiled), want, rtol=1e-6, atol=1e-6, err_msg=tiled.digest()
    )


def a2a_ffn_variants_bit_identical():
    """MoE dispatch variants (chunk count, dispatch order) reassemble
    outputs in capacity order, so results are bit-identical to the
    serial all-to-all baseline's chunking-free layout."""
    m = mesh()
    rng = np.random.default_rng(5)
    e, c, d, f = 16, 16, 32, 64  # 16 global experts over 8 devices
    x = jnp.asarray(rng.standard_normal((G * e, c, d)), jnp.float32)
    w_up = jnp.asarray(
        rng.standard_normal((e, d, f)) / np.sqrt(d), jnp.float32
    )
    w_down = jnp.asarray(
        rng.standard_normal((e, f, d)) / np.sqrt(f), jnp.float32
    )
    base = default_variant("ficco_a2a_ffn", group=G)

    def run(v):
        def body(xs, wu, wd):
            return ficco_a2a_ffn(xs, wu, wd, axis_name=AXIS, variant=v)

        return np.asarray(
            jax.jit(
                shard_map(
                    body, mesh=m,
                    in_specs=(P(AXIS, None, None), P(AXIS, None, None),
                              P(AXIS, None, None)),
                    out_specs=P(AXIS, None, None),
                    check_vma=False,
                )
            )(x, w_up, w_down)
        )

    want = run(base)
    for v in (
        dataclasses.replace(base, chunks=4),
        dataclasses.replace(base, dispatch_order="reverse"),
        dataclasses.replace(base, chunks=4, dispatch_order="reverse"),
    ):
        np.testing.assert_array_equal(run(v), want, err_msg=v.digest())

    # and the chunked pipeline agrees with the one-shot serial baseline
    def serial_body(xs, wu, wd):
        return serial_a2a_ffn(xs, wu, wd, axis_name=AXIS)

    serial = np.asarray(
        jax.jit(
            shard_map(
                serial_body, mesh=m,
                in_specs=(P(AXIS, None, None), P(AXIS, None, None),
                          P(AXIS, None, None)),
                out_specs=P(AXIS, None, None),
                check_vma=False,
            )
        )(x, w_up, w_down)
    )
    np.testing.assert_allclose(want, serial, rtol=1e-5, atol=1e-5)


def main():
    assert len(jax.devices()) == G
    check("exchange_matches_all_gather", exchange_matches_all_gather)
    check("dma_schedule_matches_serial", dma_schedule_matches_serial)
    check("fused_kernel_matches_serial", fused_kernel_matches_serial)
    check("ag_fused_variants_bit_identical", ag_fused_variants_bit_identical)
    check("dma_schedule_variants_match", dma_schedule_variants_match)
    check("a2a_ffn_variants_bit_identical", a2a_ffn_variants_bit_identical)
    if failures:
        print("FAILED:", failures)
        sys.exit(1)
    print("ALL-OK")


if __name__ == "__main__":
    main()
