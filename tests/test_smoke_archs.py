"""Per-architecture smoke tests (reduced configs, single CPU device).

For every assigned architecture: instantiate the REDUCED variant (2 layers,
d_model <= 256, <= 4 experts), run one forward + one train-style grad step
and one decode step, asserting output shapes and finiteness.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.launch.specs import concrete_batch, encoder_len
from repro.models.model import build_model

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _reduced(name):
    return get_config(name).reduced()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_loss(arch):
    cfg = _reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, SMOKE_SHAPE)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_grad_step(arch):
    cfg = _reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, SMOKE_SHAPE)

    @jax.jit
    def step(p):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(p)
        return loss, grads

    loss, grads = step(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert np.isfinite(total) and total > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = _reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 64
    enc_len = encoder_len(cfg, SMOKE_SHAPE) if cfg.encdec else 0
    cache = model.init_cache(2, cache_len, enc_len=enc_len)
    if cfg.encdec:
        frames = jnp.zeros((2, enc_len, cfg.d_model), jnp.float32)
        cache = model.prefill_cross(params, cache, frames)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tok, jnp.int32(0))
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = _reduced("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32,
    )
    logits_fwd, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, 16)
    step = jax.jit(model.decode_step)
    for i in range(8):
        logits_dec, cache = step(
            params, cache, toks[:, i : i + 1], jnp.int32(i)
        )
        np.testing.assert_allclose(
            np.asarray(logits_dec[0, 0], np.float32),
            np.asarray(logits_fwd[0, i], np.float32),
            rtol=2e-3, atol=2e-3,
        )


def test_decode_matches_forward_ssm():
    """Recurrent-state decode must equal the parallel scan (xLSTM)."""
    cfg = _reduced("xlstm-1.3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 6)),
        jnp.int32,
    )
    logits_fwd, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, 8)
    step = jax.jit(model.decode_step)
    for i in range(6):
        logits_dec, cache = step(
            params, cache, toks[:, i : i + 1], jnp.int32(i)
        )
        np.testing.assert_allclose(
            np.asarray(logits_dec[0, 0], np.float32),
            np.asarray(logits_fwd[0, i], np.float32),
            rtol=5e-3, atol=5e-3,
        )


def test_sliding_window_limits_context():
    cfg = dataclasses.replace(
        _reduced("tinyllama-1.1b"), sliding_window=4
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(2).integers(0, cfg.vocab_size, (1, 12)),
        jnp.int32,
    )
    logits, _ = model.forward(params, {"tokens": toks})
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # ring cache: decode with a window-4 cache buffer
    cache = model.init_cache(1, 12)
    # cache[0] = layer-0 dict, leaves stacked over periods:
    # (n_periods, B, ring_len, KV, hd)
    assert cache[0]["k"].shape[2] == 4  # ring sized to the window
