"""Kernel-variant autotuning: enumerate -> prune -> measure -> refit -> promote.

Covers the `repro.tune` loop end to end on the deterministic cost model
(no accelerator needed): enumeration determinism, resource-budget
pruning, variant-keyed cache schema round-trips, search-beats-default,
the variant-timing -> `fit_machine` objective, and the skewed
`ficco_a2a_ffn` profile-keyed records feeding both the measured
shortlist and the ragged fit.  Interpret-mode bit-equivalence of the
variants lives in the multi-device driver
(``multidev_kernels_driver.py``).
"""

import dataclasses

import numpy as np
import pytest

from repro.core.machine import MI300X, TPU_V5E
from repro.core.schedule_types import Schedule
from repro.core.workload import (
    CollectiveKind,
    GemmShape,
    RaggedScenario,
    StepProfile,
)
from repro.tune import (
    KERNELS,
    KERNEL_SCHEDULE,
    KernelVariant,
    check_variant,
    default_variant,
    enumerate_variants,
    prune_variants,
    search_kernel_variants,
    variant_cost,
)

GEMM = GemmShape(4096, 4096, 4096, 2)


def _tuner(tmp_path):
    from repro.autotune.cache import AutotuneCache
    from repro.autotune.tuner import Autotuner

    cache = AutotuneCache(path=str(tmp_path / "tune.json"))
    return Autotuner(cache=cache, persist=True)


# ---------------------------------------------------------------------------
# Variant identity + enumeration.
# ---------------------------------------------------------------------------


def test_digest_round_trip():
    v = KernelVariant(
        kernel="dma_exchange", chunks=4, block_m=256, block_n=128,
        block_k=64, buffer_depth=3, dispatch_order="reverse",
    )
    assert v.digest() == "c4t256x128x64d3r"
    assert KernelVariant.from_digest("dma_exchange", v.digest()) == v
    assert KernelVariant.from_payload(v.to_payload()) == v
    with pytest.raises(ValueError):
        KernelVariant.from_digest("dma_exchange", "t128x128x128")


def test_variant_validation():
    with pytest.raises(ValueError):
        KernelVariant("nope", 4, 128, 128, 128)
    with pytest.raises(ValueError):
        KernelVariant("dma_exchange", 4, 128, 128, 128, buffer_depth=1)
    with pytest.raises(ValueError):
        KernelVariant("dma_exchange", 4, 4, 128, 128)
    with pytest.raises(ValueError):
        KernelVariant("dma_exchange", 4, 128, 128, 128,
                      dispatch_order="sideways")


def test_enumeration_deterministic_and_complete():
    for kernel in KERNELS:
        a = enumerate_variants(kernel, MI300X)
        b = enumerate_variants(kernel, MI300X)
        assert a == b  # same tuple, same order
        assert len(set(a)) == len(a)
        assert list(a) == sorted(a)
        # the incumbent default is always a candidate
        assert default_variant(kernel, MI300X) in a
        assert all(v.kernel == kernel for v in a)


def test_enumeration_respects_exposed_axes():
    # The fused AG kernel's tile is pinned to the machine tile ...
    ag = enumerate_variants("ficco_ag_matmul", MI300X)
    assert {(v.block_m, v.block_n, v.block_k) for v in ag} == {
        (MI300X.tile_mn, MI300X.tile_mn, MI300X.tile_k)
    }
    # ... but its buffer depth is searchable, unlike the a2a FFN's.
    assert {v.buffer_depth for v in ag} == {2, 3}
    a2a = enumerate_variants("ficco_a2a_ffn", MI300X)
    assert {v.buffer_depth for v in a2a} == {2}
    # The exchange schedule searches tiles.
    ex = enumerate_variants("dma_exchange", MI300X)
    assert len({(v.block_m, v.block_n, v.block_k) for v in ex}) > 1


# ---------------------------------------------------------------------------
# Feasibility pruning.
# ---------------------------------------------------------------------------


def test_prune_rejects_overbudget_vmem():
    tiny = dataclasses.replace(MI300X, fast_mem_bytes=1 << 20)
    cands = enumerate_variants("ficco_ag_matmul", tiny)
    feasible, rejected = prune_variants(cands, GEMM, tiny)
    assert not feasible
    assert any("vmem" in r.reason for r in rejected)


def test_prune_rejects_overbudget_semaphores():
    starved = dataclasses.replace(MI300X, dma_sem_slots=8)
    v = default_variant("ficco_ag_matmul", starved)
    reason = check_variant(v, GEMM, starved)
    assert reason is not None and "semaphores" in reason
    # The semaphore-free XLA-collective kernel is unaffected.
    assert check_variant(
        default_variant("ficco_a2a_ffn", starved), GEMM, starved
    ) is None


def test_prune_rejects_indivisible_and_subgranule_chunks():
    v = KernelVariant("ficco_ag_matmul", chunks=7, block_m=256,
                      block_n=256, block_k=64)
    reason = check_variant(v, GEMM, MI300X)
    assert reason is not None and "indivisible" in reason
    # A chunk smaller than the DMA granule can't be described.
    small = GemmShape(128, 4096, 8, 1)
    v2 = KernelVariant("ficco_ag_matmul", chunks=16, block_m=256,
                       block_n=256, block_k=64)
    reason2 = check_variant(v2, small, MI300X)
    assert reason2 is not None and "granule" in reason2


def test_prune_preserves_order_and_partitions():
    cands = enumerate_variants("dma_exchange", MI300X)
    feasible, rejected = prune_variants(cands, GEMM, MI300X)
    assert len(feasible) + len(rejected) == len(cands)
    # order preserved: feasible appears in enumeration order
    pos = {v: i for i, v in enumerate(cands)}
    assert [pos[v] for v in feasible] == sorted(pos[v] for v in feasible)


# ---------------------------------------------------------------------------
# Cost model sanity.
# ---------------------------------------------------------------------------


def test_variant_cost_positive_and_variant_sensitive():
    for kernel in KERNELS:
        base = default_variant(kernel, MI300X)
        costs = {
            v: variant_cost(v, GEMM, MI300X)
            for v in enumerate_variants(kernel, MI300X)
        }
        assert all(c > 0.0 and np.isfinite(c) for c in costs.values())
        # the space is not flat: some variant prices differently
        assert len({round(c, 15) for c in costs.values()}) > 1
        assert costs[base] == variant_cost(base, GEMM, MI300X)


def test_deeper_buffering_never_slower_on_skew():
    skew = StepProfile((0.5, 0.2, 0.1, 0.1, 0.05, 0.03, 0.01, 0.01),
                       name="hot")
    d2 = dataclasses.replace(default_variant("ficco_ag_matmul", MI300X),
                             buffer_depth=2)
    d3 = dataclasses.replace(d2, buffer_depth=3)
    assert variant_cost(d3, GEMM, MI300X, profile=skew) <= variant_cost(
        d2, GEMM, MI300X, profile=skew
    )


# ---------------------------------------------------------------------------
# Variant-keyed cache records.
# ---------------------------------------------------------------------------


@pytest.mark.autotune
def test_variant_keys_survive_schema_round_trip(tmp_path):
    from repro.autotune.cache import AutotuneCache
    from repro.learn import records_from_cache, variant_records_from_cache

    tuner = _tuner(tmp_path)
    feasible, _ = prune_variants(
        enumerate_variants("dma_exchange", MI300X), GEMM, MI300X
    )
    timings = tuner.measure_variants("dma_exchange", GEMM, feasible,
                                     machine=MI300X)
    assert len(timings) == len(feasible)

    # Reload the persisted file through a fresh cache object.
    reloaded = AutotuneCache(path=tuner.cache.path)
    assert len(reloaded.entries) == len(tuner.cache.entries)

    # 8-segment variant keys are invisible to the 7-segment extractor...
    assert records_from_cache(reloaded, MI300X.name) == []
    # ...and fully recovered by the variant-aware one.
    recs = variant_records_from_cache(reloaded, MI300X.name)
    assert len(recs) == len(feasible)
    assert {r.variant for r in recs} == {v.digest() for v in feasible}
    assert all(r.schedule == KERNEL_SCHEDULE["dma_exchange"] for r in recs)
    assert all(r.profile is None for r in recs)
    # kernel filter
    assert variant_records_from_cache(
        reloaded, MI300X.name, kernel="ficco_ag_matmul"
    ) == []


# ---------------------------------------------------------------------------
# Search: beats the single-variant default, promotes the winner.
# ---------------------------------------------------------------------------


@pytest.mark.autotune
def test_search_beats_default_and_promotes(tmp_path):
    from repro.tune.registry import resolve_variant

    tuner = _tuner(tmp_path)
    improved = 0
    for kernel in KERNELS:
        res = search_kernel_variants(kernel, GEMM, MI300X, tuner=tuner)
        assert res.n_feasible > 0
        assert res.best_seconds <= res.default_seconds
        improved += res.speedup > 1.0
        # the winner is what the kernels now resolve by default
        got = resolve_variant(kernel, MI300X, cache=tuner.cache)
        assert got == res.best
    # acceptance: at least one kernel's search beat the incumbent
    assert improved >= 1


@pytest.mark.autotune
def test_promotion_persists_across_processes(tmp_path):
    from repro.autotune.cache import AutotuneCache
    from repro.tune.registry import reset_variants, resolve_variant

    tuner = _tuner(tmp_path)
    res = search_kernel_variants("ficco_ag_matmul", GEMM, MI300X,
                                 tuner=tuner)
    # Simulate a new process: in-memory promotions gone, artifact left.
    reset_variants()
    reloaded = AutotuneCache(path=tuner.cache.path)
    got = resolve_variant("ficco_ag_matmul", MI300X, cache=reloaded)
    assert got == res.best
    # And with no artifact either, the structural default comes back.
    reset_variants()
    empty = AutotuneCache(path=str(tmp_path / "empty.json"))
    assert resolve_variant(
        "ficco_ag_matmul", MI300X, cache=empty
    ) == default_variant("ficco_ag_matmul", MI300X)


# ---------------------------------------------------------------------------
# Variant timings -> fit objective.
# ---------------------------------------------------------------------------


@pytest.mark.autotune
def test_variant_records_fit_machine(tmp_path):
    from repro.learn import fit_machine, variant_records_from_cache

    tuner = _tuner(tmp_path)
    for g in (
        GemmShape(2048, 4096, 4096, 2),
        GemmShape(4096, 4096, 2048, 2),
        GemmShape(8192, 2048, 4096, 1),
    ):
        feasible, _ = prune_variants(
            enumerate_variants("dma_exchange", MI300X), g, MI300X
        )
        tuner.measure_variants("dma_exchange", g, feasible, machine=MI300X)
    recs = variant_records_from_cache(tuner.cache, MI300X.name)
    assert len(recs) >= 3
    fit = fit_machine(MI300X, recs, steps=60)
    # acceptance: fitting to the variant timings strictly beats the
    # registry-default parameters in log-time MSE
    assert fit.loss < fit.loss0


# ---------------------------------------------------------------------------
# Skewed ficco_a2a_ffn: profile-keyed records join the measured
# shortlist AND the ragged fit objective.
# ---------------------------------------------------------------------------


@pytest.mark.autotune
def test_skewed_a2a_profile_records_join_shortlist_and_fit(tmp_path):
    from repro.learn import fit_machine, variant_records_from_cache
    from repro.learn.measured import MeasuredEngine

    tuner = _tuner(tmp_path)
    profile = StepProfile((0.4, 0.2, 0.15, 0.1, 0.05, 0.05, 0.03, 0.02),
                          name="zipf-hot")
    assert not profile.is_uniform
    res = search_kernel_variants(
        "ficco_a2a_ffn", GEMM, MI300X, profile=profile, tuner=tuner
    )
    assert res.n_feasible > 0

    # (a) the per-variant records carry the raw fractions and rebuild
    # the ragged fit objective.
    recs = variant_records_from_cache(
        tuner.cache, MI300X.name, kernel="ficco_a2a_ffn"
    )
    assert recs and all(r.profile is not None for r in recs)
    np.testing.assert_allclose(
        recs[0].profile, profile.trimmed().fractions
    )
    fit = fit_machine(MI300X, recs, steps=60)
    assert fit.loss < fit.loss0

    # (b) the promoted winner's plain profile-keyed record reaches the
    # measured-engine shortlist for the matching ragged scenario.
    scen = RaggedScenario(
        name="ep-moe/zipf-hot", parallelism="EP", model="moe",
        gemm=GEMM, profile=profile,
        collective=CollectiveKind.ALL_TO_ALL,
    )
    # top wide enough that the chunked lane survives the analytic
    # shortlist — the point under test is the profile-keyed override.
    eng = MeasuredEngine(cache=tuner.cache, top=8)
    grid = eng.evaluate([scen], (MI300X,))
    l = grid.schedules.index(KERNEL_SCHEDULE["ficco_a2a_ffn"])
    assert grid.valid[l, 0, 0]
    assert grid.total[l, 0, 0] == pytest.approx(res.best_seconds)
