"""Substrate tests: pipeline determinism, optimizer, checkpoint, training
convergence on the synthetic task, serve engine."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticLM, make_pipeline
from repro.models.model import build_model
from repro.serve.engine import DecodeEngine, Request
from repro.train import optimizer as opt
from repro.train.loop import train
from repro.ckpt.checkpoint import restore_checkpoint, save_checkpoint

SHAPE = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")


def test_pipeline_deterministic():
    cfg = get_config("tinyllama-1.1b").reduced()
    a = SyntheticLM(cfg, SHAPE, seed=3).batch_at(7)
    b = SyntheticLM(cfg, SHAPE, seed=3).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg, SHAPE, seed=4).batch_at(7)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_yields():
    cfg = get_config("smollm-360m").reduced()
    it = iter(make_pipeline(cfg, SHAPE))
    b1 = next(it)
    b2 = next(it)
    assert b1["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b2["tokens"]))


def test_optimizer_descends_quadratic():
    ocfg = opt.OptimizerConfig(peak_lr=0.1, warmup_steps=1, decay_steps=100)
    params = {"w": jnp.ones((4,))}
    state = opt.init_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, _ = opt.apply_updates(params, grads, state, ocfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_lr_schedule_shape():
    ocfg = opt.OptimizerConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                               decay_steps=100)
    lrs = [float(opt.lr_at(ocfg, jnp.int32(s))) for s in (0, 10, 100)]
    assert lrs[0] < 0.2 and abs(lrs[1] - 1.0) < 1e-5 and abs(
        lrs[2] - 0.1) < 1e-5


def test_training_reduces_loss():
    from repro.train.optimizer import OptimizerConfig

    cfg = get_config("smollm-360m").reduced()
    res = train(
        cfg, SHAPE, steps=40, log_every=100, log_fn=lambda *_: None,
        ocfg=OptimizerConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=40),
    )
    first = res["history"][0]["loss"]
    last = res["history"][-1]["loss"]
    assert last < first - 0.3, (first, last)


def test_checkpoint_roundtrip():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt_state": opt.init_state(params)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, 5)
        restored, step = restore_checkpoint(d, state)
        assert step == 5
        a = jax.tree.leaves(state)
        b = jax.tree.leaves(restored)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_serve_engine_greedy():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, batch_size=2, cache_len=64)
    reqs = [
        Request(np.asarray([1, 2, 3], np.int32), max_new_tokens=4),
        Request(np.asarray([5, 6], np.int32), max_new_tokens=4),
    ]
    out = eng.run(reqs)
    assert all(len(r.out) == 4 for r in out)
    assert all(0 <= t < cfg.vocab_size for r in out for t in r.out)


def test_grad_accumulation_equivalence():
    """accum_steps=4 must produce (numerically) the same update as the
    monolithic batch."""
    import jax.numpy as jnp

    from repro.models.model import build_model
    from repro.train.loop import init_train_state, make_train_step

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    ocfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10)
    state = init_train_state(model, jax.random.PRNGKey(0))
    from repro.data.pipeline import SyntheticLM

    batch = jax.tree.map(jnp.asarray, SyntheticLM(cfg, SHAPE).batch_at(0))

    s1, m1 = jax.jit(make_train_step(model, ocfg))(state, batch)
    s4, m4 = jax.jit(make_train_step(model, ocfg, accum_steps=4))(
        state, batch
    )
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-3,
        )
