"""Quickstart: the paper's contribution in 30 lines.

1. Pick a data-dependent AG->GEMM scenario (Table I),
2. let the FiCCO heuristic choose a bespoke overlap schedule,
3. compare the full design space with the batched simulator — on the
   NumPy engine or the jit-compiled JAX engine (``--backend jax``),
4. run the numerically-exact schedule on this host's devices.

Run:  PYTHONPATH=src python examples/quickstart.py [--backend jax|numpy]
      [--machine mi300x-8|tpu-v5e-axis16] [--schedule auto|autotune]
"""

import argparse
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import (
    MACHINES, SCENARIOS, engine_names, explore_grid, select_schedule,
)
from repro.overlap import ficco_linear

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--backend", choices=engine_names(), default="numpy",
                help="grid engine from the repro.core.engine registry")
ap.add_argument("--machine", choices=sorted(MACHINES), default="mi300x-8")
ap.add_argument("--schedule", choices=("auto", "autotune"), default="auto",
                help="auto: static heuristic; autotune: cached runtime tuner")
args = ap.parse_args()
machine = MACHINES[args.machine]

scenario = SCENARIOS["g9"]  # llama-3-405b QKV projection under SP+TP
print(f"scenario {scenario.name}: GEMM {scenario.gemm} "
      f"({scenario.parallelism}, {scenario.model})")

# --- 1+2: static heuristic pick (paper Fig. 12a + learned serial gate) --
dec = select_schedule(scenario.gemm, machine)
print(f"heuristic -> {dec.schedule.value}   ({dec.reason})")

# --- 3: full design-space exploration on the chosen backend ------------
ex = explore_grid([scenario], machines=[machine], backend=args.backend)
grid = ex.grid
order = np.argsort(np.where(grid.valid[:, 0, 0], grid.total[:, 0, 0],
                            np.inf))
print(f"ranking on {machine.name} via the {args.backend} engine:")
for l in order:
    if not grid.valid[l, 0, 0]:
        continue
    sched = grid.schedules[int(l)]
    mark = " <- heuristic" if sched is dec.schedule else ""
    print(f"  {sched.value:20s} speedup {grid.speedup[l, 0, 0]:5.2f}x{mark}")

# --- 4: execute the schedule exactly (8 simulated devices) -------------
mesh = jax.make_mesh((8,), ("tp",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)  # M-sharded
w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)  # N-sharded

fn = jax.jit(
    shard_map(
        functools.partial(
            ficco_linear, axis_name="tp", schedule=args.schedule,
            machine=machine,
        ),
        mesh=mesh,
        in_specs=(P("tp", None), P(None, "tp")),
        out_specs=P(None, "tp"),
        check_vma=False,
    )
)
out = fn(x, w)
np.testing.assert_allclose(
    np.asarray(out), np.asarray(x @ w), rtol=1e-3, atol=1e-3
)
print(f"ficco_linear({args.schedule}) == serial oracle: OK  "
      f"(out {out.shape})")
