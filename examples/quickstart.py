"""Quickstart: the paper's contribution in 30 lines.

1. Pick a data-dependent AG->GEMM scenario (Table I),
2. let the FiCCO heuristic choose a bespoke overlap schedule,
3. compare the full design space with the simulator,
4. run the numerically-exact schedule on this host's devices.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import MI300X, SCENARIOS, explore, select_schedule
from repro.overlap import ficco_linear

scenario = SCENARIOS["g9"]  # llama-3-405b QKV projection under SP+TP
print(f"scenario {scenario.name}: GEMM {scenario.gemm} "
      f"({scenario.parallelism}, {scenario.model})")

# --- 1+2: static heuristic pick (paper Fig. 12a) -----------------------
dec = select_schedule(scenario.gemm, MI300X)
print(f"heuristic -> {dec.schedule.value}   ({dec.reason})")

# --- 3: full design-space exploration ----------------------------------
ex = explore(scenario, MI300X)
for sched, res in sorted(ex.results.items(), key=lambda kv: kv[1].total):
    mark = " <- heuristic" if sched is dec.schedule else ""
    print(f"  {sched.value:20s} speedup {res.speedup:5.2f}x{mark}")

# --- 4: execute the schedule exactly (8 simulated devices) -------------
mesh = jax.make_mesh((8,), ("tp",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)  # M-sharded
w = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)  # N-sharded

fn = jax.jit(
    shard_map(
        functools.partial(ficco_linear, axis_name="tp", schedule="auto"),
        mesh=mesh,
        in_specs=(P("tp", None), P(None, "tp")),
        out_specs=P(None, "tp"),
        check_vma=False,
    )
)
out = fn(x, w)
np.testing.assert_allclose(
    np.asarray(out), np.asarray(x @ w), rtol=1e-3, atol=1e-3
)
print(f"ficco_linear(auto) == serial oracle: OK  (out {out.shape})")
