"""Design-space exploration (the paper's headline contribution).

Walks all Table-I scenarios + the 8-variant space, prints per-scenario
rankings, the pruning argument (§V-B), and heuristic accuracy — then does
the same on the TPU v5e machine model to show what changes on a torus.
Finishes with the batched engine: the full registry-arch scenario grid x
machine grid in one vectorized call, on the NumPy reference engine or
the jit-compiled JAX engine (``--backend jax``).

Run:  PYTHONPATH=src python examples/explore_design_space.py \
          [--backend jax|numpy]
"""

import argparse
import time

from repro.core import (
    MI300X, TABLE_I, TPU_V5E, engine_names, explore_grid, geomean,
    get_engine, machine_grid, prune_report, scenario_grid,
)

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--backend", choices=engine_names(), default="numpy",
                help="grid engine from the repro.core.engine registry")
args = ap.parse_args()

for machine in (MI300X, TPU_V5E):
    print(f"\n===== {machine.name} ({machine.topology.value}) =====")
    ex = explore_grid(TABLE_I, machines=(machine,), backend=args.backend)
    best_vals = []
    for i, sc in enumerate(TABLE_I):
        best_l = int(ex.best_idx[i, 0])
        heur_l = int(ex.heuristic_idx[i, 0])
        best = ex.grid.schedules[best_l]
        heur = ex.grid.schedules[heur_l]
        speedup = float(ex.grid.speedup[best_l, i, 0])
        best_vals.append(speedup)
        ok = "OK " if bool(ex.exact[i, 0]) else (
            "~ok" if bool(ex.within(0.05)[i, 0]) else "MISS"
        )
        print(f"{sc.name:4s} best={best.value:18s} "
              f"{speedup:4.2f}x heur={heur.value:18s} {ok}")
    print(f"geomean best speedup: {geomean(best_vals):.3f}")

print("\n===== pruning argument (g2, all 8 variants) =====")
for name, t, studied in prune_report(TABLE_I[1], MI300X):
    tag = "studied" if studied else "pruned "
    print(f"  {tag} {name:22s} {t*1e3:8.2f} ms")

# ===== batched engine: the whole design space in three lines ==========
scenarios = scenario_grid()
machines = machine_grid()
if get_engine(args.backend).jit:  # compile once outside the timed region
    explore_grid(scenarios, machines=machines, backend=args.backend)
t0 = time.perf_counter()
ex = explore_grid(scenarios, machines=machines, backend=args.backend)
dt = time.perf_counter() - t0
print(f"\n===== batched grid ({args.backend}): {len(scenarios)} scenarios "
      f"x {len(machines)} machines in {dt*1e3:.0f} ms =====")
print(ex.summary())
