"""Design-space exploration (the paper's headline contribution).

Walks all Table-I scenarios + the 8-variant space, prints per-scenario
rankings, the pruning argument (§V-B), and heuristic accuracy — then does
the same on the TPU v5e machine model to show what changes on a torus.
Finishes with the batched engine: the full registry-arch scenario grid x
machine grid in one vectorized call.

Run:  PYTHONPATH=src python examples/explore_design_space.py
"""

import time

from repro.core import (
    MI300X, TABLE_I, TPU_V5E, explore, explore_grid, geomean, machine_grid,
    prune_report, scenario_grid,
)

for machine in (MI300X, TPU_V5E):
    print(f"\n===== {machine.name} ({machine.topology.value}) =====")
    hits = speedups = 0
    best_vals = []
    for sc in TABLE_I:
        ex = explore(sc, machine)
        best = ex.results[ex.best]
        best_vals.append(best.speedup)
        ok = "OK " if ex.heuristic_correct else (
            "~ok" if ex.results[ex.heuristic.schedule].total
            <= 1.05 * best.total else "MISS"
        )
        print(f"{sc.name:4s} best={ex.best.value:18s} "
              f"{best.speedup:4.2f}x heur={ex.heuristic.schedule.value:18s} "
              f"{ok}")
    print(f"geomean best speedup: {geomean(best_vals):.3f}")

print("\n===== pruning argument (g2, all 8 variants) =====")
for name, t, studied in prune_report(TABLE_I[1], MI300X):
    tag = "studied" if studied else "pruned "
    print(f"  {tag} {name:22s} {t*1e3:8.2f} ms")

# ===== batched engine: the whole design space in three lines ==========
scenarios = scenario_grid()
machines = machine_grid()
t0 = time.perf_counter()
ex = explore_grid(scenarios, machines=machines)
dt = time.perf_counter() - t0
print(f"\n===== batched grid: {len(scenarios)} scenarios x "
      f"{len(machines)} machines in {dt*1e3:.0f} ms =====")
print(ex.summary())
