"""Serving example: batched greedy decoding on the xLSTM (O(1)-state)
architecture — the family where long-context decode is native.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import DecodeEngine, Request

cfg = get_config("xlstm-1.3b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

eng = DecodeEngine(cfg, params, batch_size=4, cache_len=256)
rng = np.random.default_rng(0)
reqs = [
    Request(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=12)
    for _ in range(4)
]
t0 = time.time()
out = eng.run(reqs)
dt = time.time() - t0
tok = sum(len(r.out) for r in out)
print(f"{tok} tokens in {dt:.2f}s ({tok/dt:.1f} tok/s, CPU)")
for i, r in enumerate(out):
    print(f"req{i}: {list(r.prompt)} -> {r.out}")
assert all(len(r.out) == 12 for r in out)
print("OK")
