"""End-to-end driver: train a reduced TinyLlama for a few hundred steps
with the FiCCO overlap context active, checkpoint, restore, serve.

Run:  PYTHONPATH=src python examples/train_tinyllama.py
"""

import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.ckpt.checkpoint import restore_checkpoint
from repro.serve.engine import DecodeEngine, Request
from repro.train.loop import train
from repro.train.optimizer import OptimizerConfig

cfg = get_config("tinyllama-1.1b").reduced()
shape = ShapeConfig("example", seq_len=64, global_batch=8, kind="train")

with tempfile.TemporaryDirectory() as ckpt_dir:
    res = train(
        cfg,
        shape,
        steps=200,
        ocfg=OptimizerConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=200),
        checkpoint_dir=ckpt_dir,
        checkpoint_every=100,
        log_every=25,
    )
    first, last = res["history"][0]["loss"], res["history"][-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "training failed to learn"

    state, step = restore_checkpoint(ckpt_dir, res["state"].copy()
                                     if isinstance(res["state"], dict)
                                     else res["state"])
    print(f"restored checkpoint at step {step}")

eng = DecodeEngine(cfg, res["state"]["params"], batch_size=2, cache_len=128)
reqs = [Request(np.asarray([5, 7, 9], np.int32), max_new_tokens=8)
        for _ in range(2)]
for i, r in enumerate(eng.run(reqs)):
    print(f"req{i} -> {r.out}")
print("OK")
