"""Sharded design-space sweep driver: 1e6-1e7-point grids, streamed.

Evaluates a synthetic (or registry) scenario grid through the sharded
sweep subsystem (``repro.sweep``), streaming one JSON line per finished
shard to ``--out`` and a merged summary at the end — so a 1e7-point
sweep never holds the full result table and an aggregator can tail the
shard stream live.

Single host, reduce mode (memory-bounded), 64 shards::

    PYTHONPATH=src python scripts/sweep.py --scenarios 1000000 \\
        --shards 64 --mode reduce --out sweep.jsonl

Multi-host: run the same command on every host with its own
``--host-index`` (the deterministic plan + round-robin owner mapping
make the shard sets disjoint and exhaustive; operands regenerate from
the seed, nothing is broadcast)::

    PYTHONPATH=src python scripts/sweep.py --scenarios 10000000 \\
        --shards 256 --mode reduce --host-index $I --host-count 8 \\
        --out sweep_host$I.jsonl

``--device-parallel`` additionally fans each owned shard out over the
local jax devices (pmap; bit-identical to the unsharded jitted engine).
``--ragged`` sweeps skewed Dirichlet step profiles instead of uniform
splits.
"""

import argparse
import json
import sys
import time

from repro.core import engine_names
from repro.core.workload import machine_grid
from repro.sweep import (
    merge_summaries,
    sweep_grid,
    synthetic_batch,
    synthetic_ragged_batch,
)


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "--scenarios", type=int, default=100_000,
        help="synthetic scenario count (points = scenarios x machines)",
    )
    ap.add_argument(
        "--ragged", action="store_true",
        help="sweep skewed ragged step profiles instead of uniform splits",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--groups", type=int, nargs="+", default=[8],
        help="overlap-group sizes for the machine grid axis",
    )
    ap.add_argument(
        "--backend", choices=engine_names(), default="numpy",
        help="engine for non-device-parallel shards",
    )
    ap.add_argument(
        "--dtype", choices=("float64", "float32", "bfloat16"),
        default="float64",
        help="evaluation dtype (non-float64 requires --backend mixed; "
        "the pipeline accumulator stays float64 either way)",
    )
    ap.add_argument(
        "--synth-device", action="store_true",
        help="synthesize scenarios with the counter-based device "
        "generator (repro.sweep.device) instead of the legacy host "
        "np.random stream — a different, shard-composable stream",
    )
    ap.add_argument(
        "--overlap-dispatch", action="store_true",
        help="double-buffer shard dispatch on two-phase engines "
        "(the mixed engine); no-op elsewhere",
    )
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count (default: one per host)")
    ap.add_argument("--mode", choices=("gather", "reduce"),
                    default="reduce")
    ap.add_argument("--host-index", type=int, default=0)
    ap.add_argument("--host-count", type=int, default=1)
    ap.add_argument(
        "--device-parallel", action="store_true",
        help="pmap each owned shard over the local jax devices",
    )
    ap.add_argument(
        "--use-fit", default=None, metavar="NAME",
        help="evaluate through the fitted engine: load the persisted "
        "sim-to-real fit artifact NAME (repro.learn.fit) and patch its "
        "calibrated parameters into the matching machine lanes",
    )
    ap.add_argument(
        "--train-gate", default=None, metavar="NAME",
        help="reduce mode only: fold every shard grid into GateStats, "
        "train a LearnedGate and persist it under artifact NAME — with "
        "--use-fit this is the fit-then-retrain loop (the gate trains "
        "against the calibrated machine model)",
    )
    ap.add_argument(
        "--out", default=None, metavar="PATH",
        help="append one JSON line per finished shard (stdout if unset)",
    )
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="export a Chrome/Perfetto trace of the shard pipeline here",
    )
    ap.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="append one metrics-snapshot JSON line here when done",
    )
    args = ap.parse_args()

    if args.trace:
        from repro.obs import trace as obs_trace

        obs_trace.enable(args.trace)

    engine = None
    if args.backend == "mixed":
        from repro.core.engine import MixedEngine

        engine = MixedEngine(dtype=args.dtype)
    elif args.dtype != "float64":
        ap.error("--dtype other than float64 requires --backend mixed")

    if args.use_fit:
        if engine is not None:
            ap.error("--use-fit is incompatible with --backend mixed")
        from repro.learn import FittedEngine, load_fit

        fit = load_fit(args.use_fit)
        if fit is None:
            ap.error(f"no persisted fit artifact {args.use_fit!r}")
        engine = FittedEngine(fit)
        print(
            f"# fitted engine: {fit.machine} params "
            f"{sorted(fit.fitted)} (loss {fit.loss0:.4g} -> "
            f"{fit.loss:.4g})",
            file=sys.stderr,
        )

    gate_stats = None
    on_shard_grid = None
    if args.train_gate:
        if args.mode != "reduce":
            ap.error("--train-gate requires --mode reduce")
        from repro.learn import GateStats

        gate_stats = GateStats.empty()

        def on_shard_grid(grid, _summ) -> None:
            gate_stats.update_from_grid(grid)

    if args.synth_device:
        from repro.sweep import device_batch, device_ragged_batch

        make = device_ragged_batch if args.ragged else device_batch
    else:
        make = synthetic_ragged_batch if args.ragged else synthetic_batch
    sb = make(args.scenarios, seed=args.seed)
    machines = machine_grid(groups=tuple(args.groups))
    points = args.scenarios * len(machines)
    print(
        f"# sweep: {args.scenarios} scenarios x {len(machines)} machines "
        f"= {points} points ({'ragged' if args.ragged else 'uniform'}), "
        f"host {args.host_index}/{args.host_count}",
        file=sys.stderr,
    )

    stream = open(args.out, "a") if args.out else sys.stdout

    def emit(summary) -> None:
        stream.write(json.dumps({"shard_summary": summary.to_json()}) + "\n")
        stream.flush()
        print(
            f"# shard {summary.shard}: {summary.n_scenarios} scenarios in "
            f"{summary.seconds:.2f}s ({summary.scenarios_per_sec:.0f}/s)",
            file=sys.stderr,
        )

    t0 = time.perf_counter()
    res = sweep_grid(
        sb,
        machines,
        backend=args.backend,
        engine=engine,
        num_shards=args.shards,
        mode=args.mode,
        host_index=args.host_index,
        host_count=args.host_count,
        device_parallel=args.device_parallel,
        on_shard=emit,
        on_shard_grid=on_shard_grid,
        overlap_dispatch=args.overlap_dispatch,
    )
    wall = time.perf_counter() - t0
    merged = merge_summaries(res.summaries)
    merged["wall_seconds"] = wall
    merged["host_index"] = args.host_index
    merged["host_count"] = args.host_count
    merged["owned_shards"] = list(res.owned)
    # Per-shard duration distribution: the straggler signal a dispatcher
    # reads before deciding to re-shard (p95 >> p50 = skewed shards).
    durations = sorted(
        s.seconds for s in res.summaries if s.n_scenarios > 0
    )
    if durations:
        from repro.obs.metrics import Histogram

        h = Histogram()
        for d in durations:
            h.observe(d)
        merged["shard_seconds_total"] = sum(durations)
        merged["shard_seconds_p50"] = h.percentile(0.5)
        merged["shard_seconds_p95"] = h.percentile(0.95)
    # Recorded so the aggregator can refuse to merge mixed-precision
    # streams with float64 ones (same no-silent-mixing rule GateStats
    # enforces for bin edges).
    merged["dtype"] = args.dtype
    merged["synth"] = "device" if args.synth_device else "host"
    if args.train_gate:
        from repro.learn import save_gate, train_gate_from_stats

        gate = train_gate_from_stats(
            gate_stats,
            meta={
                "source": "scripts/sweep.py",
                "engine": (
                    f"fitted:{args.use_fit}" if args.use_fit
                    else args.backend
                ),
            },
        )
        save_gate(gate, name=args.train_gate)
        merged["gate"] = {
            "name": args.train_gate,
            "n_leaves": gate.n_leaves,
            "trained_regret_q": gate.meta.get("trained_regret_q"),
        }
        print(
            f"# trained gate {args.train_gate!r}: {gate.n_leaves} "
            f"leaves over {gate_stats.n_points} points",
            file=sys.stderr,
        )
    # Total shard count of the deterministic plan: what the gather-side
    # aggregator (scripts/merge_sweep.py) checks completeness against.
    merged["plan_shards"] = len(res.plan.bounds)
    stream.write(json.dumps({"host_summary": merged}) + "\n")
    stream.flush()
    if args.out:
        stream.close()
    if args.metrics:
        from repro.obs import metrics as obs_metrics

        # Reservoir + host identity make the export fleet-mergeable:
        # scripts/obs_merge.py recovers exact union percentiles and
        # attributes every counter to its host.
        obs_metrics.get_metrics().export_jsonl(
            args.metrics, reservoir=True,
            host={"host_index": args.host_index},
        )
    if args.trace:
        from repro.obs import trace as obs_trace

        obs_trace.disable()  # exports to args.trace
    print(
        f"# done: {merged['n_scenarios']} scenarios "
        f"({merged['n_points']} points) in {wall:.2f}s wall "
        f"-> {merged['n_scenarios'] / wall:.0f} scenarios/s",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
