"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
dry-run JSON outputs."""

import json
import sys


def fmt_bytes(b):
    if b != b:  # nan
        return "n/a"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def ms(t):
    return f"{t * 1e3:.2f}"


def main(path, multipod_path=None):
    rows = json.load(open(path))
    print("### Roofline table (single-pod 16x16 = 256 chips, baseline "
          "gspmd_serial)\n")
    print("| arch | shape | t_compute ms | t_memory ms | t_collective ms |"
          " dominant | useful (6ND/HLO) | bytes/device | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:60]} |")
            continue
        colls = " ".join(
            f"{k.split('-')[0][:2]}{k.split('-')[1][:3] if '-' in k else ''}:"
            f"{fmt_bytes(v)}"
            for k, v in sorted(r["collectives"].items())
        )
        print(
            f"| {r['arch']} | {r['shape']} | {ms(r['t_compute'])} | "
            f"{ms(r['t_memory'])} | {ms(r['t_collective'])} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{fmt_bytes(r['bytes_per_device'])} | {colls} |"
        )
    if multipod_path:
        mrows = json.load(open(multipod_path))
        ok = sum(1 for r in mrows if r.get("ok"))
        print(f"\n### Multi-pod (2x16x16 = 512 chips): {ok}/{len(mrows)} "
              "lower+compile passed\n")
        print("| arch | shape | bytes/device | collective kinds |")
        print("|---|---|---|---|")
        for r in mrows:
            if not r.get("ok"):
                print(f"| {r['arch']} | {r['shape']} | FAILED | "
                      f"{r.get('error','')[:70]} |")
                continue
            kinds = " ".join(sorted(r["collective_counts"]))
            print(f"| {r['arch']} | {r['shape']} | "
                  f"{fmt_bytes(r['bytes_per_device'])} | {kinds} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
