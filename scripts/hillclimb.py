"""§Perf hillclimbing driver: hypothesis -> change -> measure -> validate.

Runs a named sequence of config variants through the dry-run for the three
chosen (arch x shape) pairs and records the roofline deltas.  Each variant
carries an explicit hypothesis string; the JSON output is the §Perf log's
source of truth.

Usage:
  PYTHONPATH=src python scripts/hillclimb.py --pair yi_train \
      --json results/hillclimb_yi_train.json
"""

import argparse
import dataclasses
import json
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import dryrun_one  # noqa: E402  (sets XLA_FLAGS)
from repro.configs.base import OverlapConfig  # noqa: E402


def _analytic_prepass(arch: str, shape_name: str) -> None:
    """Batched FiCCO pre-pass: before burning minutes in XLA dry-runs,
    sweep the pair's data-dependent AG->GEMMs through the vectorized
    design-space engine (one ``explore_grid`` call) and print the
    predicted best schedule + speedup per GEMM on the production mesh."""
    from repro.configs import SHAPES, get_config
    from repro.core import TPU_V5E
    from repro.core.explorer import explore_grid
    from repro.core.workload import tp_gemms, tp_token_rows

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    m = tp_token_rows(shape.global_batch, shape.seq_len)
    gemms = tp_gemms(cfg, m)
    ex = explore_grid(list(gemms.values()), machines=(TPU_V5E,))
    best_idx = ex.best_idx
    best_total = ex.grid.best_total()
    print(f"##### analytic prepass: {arch} x {shape_name} (g=16, v5e)")
    for i, name in enumerate(gemms):
        best = ex.grid.schedules[int(best_idx[i, 0])]
        pick = ex.grid.schedules[int(ex.heuristic_idx[i, 0])]
        sp = ex.grid.serial_total[i, 0] / best_total[i, 0]
        print(
            f"  {name:14s} best={best.value:18s} {sp:4.2f}x "
            f"heuristic={pick.value}"
        )


def _overlap(mode):
    def t(cfg):
        return dataclasses.replace(cfg, overlap=OverlapConfig(mode=mode))

    return t


def _no_remat(cfg):
    return dataclasses.replace(cfg, remat=False)


def _remat_dots(cfg):
    return dataclasses.replace(cfg, remat_policy="dots")


def _sm_decode(cfg):
    return dataclasses.replace(
        cfg, overlap=dataclasses.replace(cfg.overlap, decode_attn="shard_map")
    )


def _window(w):
    def t(cfg):
        return dataclasses.replace(cfg, sliding_window=w)

    return t


def _no_fsdp(cfg):
    return cfg  # handled via monkeypatch below


PAIRS = {
    # (1) Most representative of the paper's technique AND most
    # collective-bound train pair: DeepSeek EP (Table I g13 is DeepSeek!).
    # Baseline roofline: compute 455ms / memory 134ms / COLLECTIVE 711ms.
    "deepseek_train": {
        "arch": "deepseek-v2-lite-16b",
        "shape": "train_4k",
        "variants": [
            ("baseline_gspmd_serial", None,
             "Baseline: collective-dominated (MoE dispatch all-to-alls + "
             "MLA TP collectives): t_coll 711ms > t_compute 455ms."),
            ("ficco_auto", {"overlap": "ficco_auto"},
             "HYPOTHESIS (paper-faithful FiCCO): shared-expert/TP MLP "
             "AG->GEMMs run heuristic FiCCO schedules -> chunked "
             "all-gathers (count x16, each 1/16 size) XLA can pipeline; "
             "total collective bytes ~unchanged, exposure structurally "
             "reduced."),
            ("accum4", {"accum_steps": 4},
             "HYPOTHESIS (beyond-paper): 4-way grad-accumulation cuts live "
             "dispatch/activation buffers ~4x (315GiB/dev is unusable); "
             "collective bytes unchanged (same tokens), memory/device "
             "must drop several-fold."),
            ("no_remat", _no_remat,
             "HYPOTHESIS: dropping remat removes the recomputed forward "
             "(~25% of compute term) but inflates live activations; for "
             "this memory-stressed pair that is the wrong direction — "
             "expect refutation as a useful negative result."),
            ("remat_dots", _remat_dots,
             "HYPOTHESIS (from no_remat finding: remat re-runs the "
             "collectives, 711->473ms without it): dots_saveable keeps "
             "GEMM outputs so the backward skips GEMM+collective "
             "recompute — collective term should approach the no_remat "
             "473ms at far less memory than no_remat's 3.1TiB."),
            ("ficco_accum4", {"overlap": "ficco_auto", "accum_steps": 4},
             "COMBINED best: paper technique + microbatching."),
        ],
    },
    # (2) Most collective-bound decode pair: yi-9b decode_32k
    # (coll fraction 0.89: context-sharded KV cache reductions).
    "yi_decode": {
        "arch": "yi-9b",
        "shape": "decode_32k",
        "variants": [
            ("baseline", None,
             "Baseline: KV cache time-sharded over model axis -> "
             "attention partials all-reduced every step."),
            ("cache_batch_only", "CACHE_BATCH_ONLY",
             "HYPOTHESIS: batch-only cache sharding removes the "
             "context-parallel reduction collectives entirely "
             "(collective term down ~10x) at ~16x per-device cache bytes "
             "(10.8 -> ~170GiB... expect memory to explode: trade-off "
             "quantified)."),
            ("ficco_auto", {"overlap": "ficco_auto"},
             "HYPOTHESIS: decode-step GEMMs (128 rows) are below the "
             "decomposition guard -> FiCCO correctly stays serial; "
             "no regression."),
            ("weights_no_fsdp", "WEIGHTS_NO_FSDP",
             "HYPOTHESIS (from baseline breakdown: 4.9GB/step of "
             "all-gathers = ZeRO-3 weight gathering, absurd for decode): "
             "replicating params over the data axis (TP-only weight "
             "sharding, +~1GiB/dev for 9B params) should remove most of "
             "the all-gather volume -> collective term down several-fold."),
            ("shard_map_flash_decode", _sm_decode,
             "HYPOTHESIS (from headdim/batch-only refutations: GSPMD "
             "cannot keep the scores->softmax->AV chain distributed): "
             "an EXPLICIT shard_map flash-decode — local partial softmax "
             "+ pmax/psum of (B,H)-sized statistics — removes the K/V "
             "gathers entirely: collective bytes should drop from "
             "~4.6GB/step to MB-scale psums (the same explicit-"
             "decomposition move FiCCO makes for GEMMs)."),
            ("headdim_cache", "CACHE_HEADDIM",
             "HYPOTHESIS: sharding the KV cache on head_dim (128/16=8) "
             "instead of the 32k time axis makes the in-place cache "
             "update shard-local and turns attention into a cheap "
             "partial-sum all-reduce of (B,H,1,S) scores instead of "
             "gathering K/V slices."),
        ],
    },
    # (3) Worst-fit pair: jamba train (1052 GiB/device temp — activations
    # of 72 layers x 8192 width + MoE dispatch far beyond HBM).
    "jamba_train": {
        "arch": "jamba-1.5-large-398b",
        "shape": "train_4k",
        "variants": [
            ("baseline", None,
             "Baseline: memory catastrophically over HBM (1052 GiB/dev)."),
            ("accum4", {"accum_steps": 4},
             "HYPOTHESIS: 4-way microbatching divides live activations "
             "~4x; compute/collective terms unchanged (same total work)."),
            ("accum8", {"accum_steps": 8},
             "HYPOTHESIS: 8-way halves memory again vs accum4 with "
             "diminishing returns once weights+moments dominate."),
        ],
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=sorted(PAIRS), required=True)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    spec = PAIRS[args.pair]

    _analytic_prepass(spec["arch"], spec["shape"])

    results = []
    for name, transform, hypothesis in spec["variants"]:
        print(f"\n##### variant {name}: {hypothesis}\n", flush=True)
        kw = {}
        t = transform
        if isinstance(transform, dict):
            kw = dict(transform)
            t = None
        undo = None
        if transform == "CACHE_BATCH_ONLY":
            # monkeypatch the cache sharding rule for this variant
            from repro.parallel import sharding as shmod
            from jax.sharding import PartitionSpec as P

            orig = shmod.cache_leaf_spec

            def batch_only(shape, mesh):
                sp = orig(shape, mesh)
                entries = [
                    e if (isinstance(e, tuple) and "model" not in e)
                    or (e != "model")
                    else None
                    for e in sp
                ]
                return P(*entries)

            shmod.cache_leaf_spec = batch_only
            undo = lambda: setattr(shmod, "cache_leaf_spec", orig)
            t = None
        elif transform == "CACHE_HEADDIM":
            from repro.parallel import sharding as shmod
            from jax.sharding import PartitionSpec as P

            orig = shmod.cache_leaf_spec

            def headdim(shape, mesh):
                model = mesh.shape.get("model", 1)
                if len(shape) == 5 and shape[-1] % model == 0:
                    # (periods, B, S, KV, hd): batch + head_dim sharding
                    sp = list(orig(shape, mesh))
                    sp += [None] * (5 - len(sp))
                    sp[2] = None  # drop time-axis sharding
                    sp[4] = "model"
                    return P(*sp)
                return orig(shape, mesh)

            shmod.cache_leaf_spec = headdim
            undo = lambda: setattr(shmod, "cache_leaf_spec", orig)
            t = None
        elif transform == "WEIGHTS_NO_FSDP":
            from repro.parallel import sharding as shmod

            orig_fix = shmod.fix_param_spec

            def no_fsdp(spec, shape, mesh, *, fsdp_axis="data"):
                return orig_fix(spec, shape, mesh, fsdp_axis="__none__")

            shmod.fix_param_spec = no_fsdp
            undo = lambda: setattr(shmod, "fix_param_spec", orig_fix)
            t = None
        try:
            overlap = kw.pop("overlap", "gspmd_serial")
            r = dryrun_one(
                spec["arch"], spec["shape"],
                overlap=overlap,
                transform=t,
                extrapolate=True,
                **kw,
            )
        except Exception as e:
            import traceback

            traceback.print_exc()
            r = {"ok": False, "error": str(e)}
        finally:
            if undo is not None:
                undo()
        r["variant"] = name
        r["hypothesis"] = hypothesis
        results.append(r)

    if args.json:
        json.dump(results, open(args.json, "w"), indent=1)
    print("\n===== summary =====")
    for r in results:
        if not r.get("ok"):
            print(f"{r['variant']}: FAILED {r.get('error','')[:80]}")
            continue
        print(
            f"{r['variant']:24s} compute={r['t_compute']*1e3:9.2f}ms "
            f"memory={r['t_memory']*1e3:8.2f}ms "
            f"collective={r['t_collective']*1e3:8.2f}ms "
            f"mem/dev={r['bytes_per_device']/2**30:6.2f}GiB "
            f"AGs={r['collective_counts'].get('all-gather', 0) + r['collective_counts'].get('all-gather-start', 0)}"
        )


if __name__ == "__main__":
    main()
