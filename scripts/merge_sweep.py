"""Gather-side aggregator for multi-host sweep streams.

Every host of a multi-controller sweep (``scripts/sweep.py
--host-index $I --host-count N``) streams one JSON line per finished
shard plus a final host summary into its own ``sweep_host$I.jsonl``.
This tool merges any set of those streams into one host-complete
summary — the first slice of the multi-controller follow-on (ROADMAP
"true multi-controller launch"): the aggregator is where unclaimed
shards become visible for re-dispatch.

Usage::

    python scripts/merge_sweep.py sweep_host*.jsonl [--out merged.json]
        [--expect-shards N] [--strict]

Duplicate shard reports (a retried host re-evaluating its shards) are
deduplicated by shard id — the deterministic plan makes retries
idempotent, so the first report wins.  ``--expect-shards`` (or, when
absent, the plan shard count any surviving host summary carries — every
host derives the same plan) defines completeness; missing shard ids are
listed in the output and, with ``--strict``, fail the process with exit
code 3.  When neither source is available (every host died before its
summary line) trailing lost shards are undetectable, so the merge is
marked incomplete.
"""

import argparse
import json
import sys

from repro.sweep import ShardSummary, merge_summaries


def parse_stream(lines):
    """(shard summaries, host summaries) from one host's JSONL stream."""
    shards, hosts = [], []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue  # torn tail line of a dying host: skip, keep merging
        if "shard_summary" in rec:
            shards.append(ShardSummary(**rec["shard_summary"]))
        elif "host_summary" in rec:
            hosts.append(rec["host_summary"])
    return shards, hosts


def merge_streams(streams, expect_shards=None):
    """Merge parsed per-host streams into one host-complete summary dict.

    ``streams`` is a list of (shard_summaries, host_summaries) pairs.
    """
    by_shard = {}
    dupes = 0
    hosts = []
    for shards, host_summaries in streams:
        for s in shards:
            if s.shard in by_shard:
                dupes += 1
                continue
            by_shard[s.shard] = s
        hosts.extend(host_summaries)

    # No silent precision mixing: a float32/bfloat16 stream's summaries
    # are not comparable with a float64 one's (the same rule
    # GateStats.from_json enforces for bin edges).  Streams written
    # before dtype recording existed count as float64.
    dtypes = {h.get("dtype", "float64") for h in hosts}
    if len(dtypes) > 1:
        raise ValueError(
            f"refusing to merge streams with mismatched dtypes: "
            f"{sorted(dtypes)}"
        )

    owned = set()
    plan_counts = set()
    for h in hosts:
        owned.update(h.get("owned_shards", ()))
        if h.get("plan_shards") is not None:
            plan_counts.add(int(h["plan_shards"]))
    n_expected = expect_shards
    known = n_expected is not None
    if n_expected is None and plan_counts:
        # Every host derives the same deterministic plan; any surviving
        # host summary therefore knows the full shard count — even when
        # the host owning the highest shard ids died without a trace.
        n_expected = max(plan_counts)
        known = True
    if n_expected is None:
        # No plan information at all (every host died before its
        # summary line): the best available lower bound.  ``complete``
        # stays False below — trailing lost shards are undetectable.
        seen = owned | set(by_shard)
        n_expected = (max(seen) + 1) if seen else 0
    missing = sorted(set(range(n_expected)) - set(by_shard))

    merged = merge_summaries(by_shard.values())
    if dtypes:
        merged["dtype"] = dtypes.pop()

    # Per-host throughput and its spread: the load-imbalance signal a
    # re-dispatcher reads.  skew = slowest/fastest as a ratio >= 1; a
    # skew of 2 means the slowest host did half the scenarios/s of the
    # fastest and the round-robin owner map should be re-weighted.
    throughput = {}
    for h in hosts:
        wall = h.get("wall_seconds")
        idx = h.get("host_index")
        if idx is None or not wall or wall <= 0:
            continue
        throughput[str(idx)] = h.get("n_scenarios", 0) / wall
    merged["host_throughput"] = throughput
    rates = [r for r in throughput.values() if r > 0]
    merged["host_throughput_skew"] = (
        max(rates) / min(rates) if len(rates) >= 2 else None
    )
    merged["hosts_reporting"] = len(hosts)
    merged["duplicate_shard_reports"] = dupes
    merged["expected_shards"] = n_expected
    merged["expected_shards_known"] = known
    merged["missing_shards"] = missing
    merged["complete"] = known and not missing
    return merged


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "streams", nargs="+", metavar="JSONL",
        help="per-host sweep streams (sweep_host*.jsonl)",
    )
    ap.add_argument(
        "--out", default=None, metavar="PATH",
        help="write the merged summary JSON here (stdout if unset)",
    )
    ap.add_argument(
        "--expect-shards", type=int, default=None,
        help="total shard count of the plan (default: inferred from the "
        "host summaries' owner lists)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 3 if any expected shard is unreported (the signal a "
        "re-dispatcher keys off)",
    )
    ap.add_argument(
        "--metrics", nargs="+", default=None, metavar="JSONL",
        help="per-host metrics exports (sweep.py --metrics): their last "
        "snapshots are unioned (repro.obs.metrics.merge_snapshots) and "
        "folded into the output under 'metrics'",
    )
    args = ap.parse_args()

    streams = []
    for path in args.streams:
        with open(path) as f:
            streams.append(parse_stream(f))
    try:
        merged = merge_streams(streams, expect_shards=args.expect_shards)
    except ValueError as e:
        print(f"# REFUSED: {e}", file=sys.stderr)
        sys.exit(4)

    if args.metrics:
        from repro.obs import metrics as obs_metrics

        snaps = []
        for path in args.metrics:
            last = None
            with open(path) as f:
                for line in f:
                    if line.strip():
                        last = json.loads(line)
            if last is not None:
                snaps.append(last)
        if snaps:
            merged["metrics"] = obs_metrics.merge_snapshots(snaps)

    text = json.dumps(merged, indent=1, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    if not merged["expected_shards_known"]:
        print(
            "# WARNING: no host summary carried the plan's shard count "
            "and --expect-shards was not given; trailing lost shards "
            "are undetectable (treated as incomplete)",
            file=sys.stderr,
        )
        if args.strict:
            sys.exit(3)
    if merged["missing_shards"]:
        print(
            f"# INCOMPLETE: {len(merged['missing_shards'])} of "
            f"{merged['expected_shards']} shards unreported: "
            f"{merged['missing_shards']}",
            file=sys.stderr,
        )
        if args.strict:
            sys.exit(3)
    else:
        print(
            f"# complete: {merged['n_shards']} shards, "
            f"{merged['n_scenarios']} scenarios from "
            f"{merged['hosts_reporting']} host(s)",
            file=sys.stderr,
        )


if __name__ == "__main__":
    main()
