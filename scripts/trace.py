"""Observability CLI: schedule timelines, trace/metric validation, replay.

Five subcommands over the :mod:`repro.obs` stack:

``timeline``
    Render named Table-I scenarios (or any ``--gemm M N K``) as per-step
    comm/GEMM/stall lane timelines — one Perfetto process per
    (scenario, schedule) pair — annotated with the paper's inefficiency
    decomposition.  Open the output in chrome://tracing or
    https://ui.perfetto.dev::

        PYTHONPATH=src python scripts/trace.py timeline \\
            --scenario g1 g4 --schedule all --out timeline.json

``validate``
    Schema-validate an exported trace file, metrics snapshot (JSONL),
    decision-audit log, signature-snapshot stream, sentinel-event
    stream, or merged fleet snapshot; exit non-zero on any violation
    (CI hook)::

        PYTHONPATH=src python scripts/trace.py validate trace.json
        PYTHONPATH=src python scripts/trace.py validate --kind metrics \\
            metrics.jsonl
        PYTHONPATH=src python scripts/trace.py validate --kind sentinel \\
            sentinel.jsonl

``signature``
    Overlay streamed inefficiency-signature snapshots
    (``REPRO_SIGNATURES=sig.jsonl`` / ``--signatures``) on the schedule
    grid: per (machine family, scenario class) row, each observed
    schedule's decision count, mean analytic time, and dominant loss
    category::

        PYTHONPATH=src python scripts/trace.py signature sig.jsonl

``metrics``
    Summarize a metrics JSONL snapshot stream: counters, histogram
    percentiles, and tuner tier rates per snapshot line.

``audit``
    Print a decision-audit log (``decisions.jsonl`` beside the autotune
    cache); ``--replay`` re-derives every pick offline and reports
    whether the recorded schedule/tier choices reproduce::

        PYTHONPATH=src python scripts/trace.py audit --replay
"""

import argparse
import json
import sys

from repro.core.machine import MACHINES, TPU_V5E, machine_for_group
from repro.core.schedule_types import STUDIED, Schedule
from repro.core.workload import SCENARIOS, GemmShape
from repro.obs import audit as obs_audit
from repro.obs import metrics as obs_metrics
from repro.obs import sentinel as obs_sentinel
from repro.obs import signature as obs_signature
from repro.obs import timeline as obs_timeline
from repro.obs import trace as obs_trace


def _machine(name: str):
    if name in MACHINES:
        return MACHINES[name]
    known = ", ".join(sorted(MACHINES))
    raise SystemExit(f"unknown machine {name!r} (known: {known})")


def _schedules(arg: list[str]) -> list[Schedule]:
    if arg == ["all"]:
        return list(STUDIED)
    return [Schedule(a) for a in arg]


def cmd_timeline(args) -> int:
    machine = _machine(args.machine)
    if args.group:
        machine = machine_for_group(machine, args.group)
    targets = []
    for name in args.scenario:
        if name not in SCENARIOS:
            known = ", ".join(SCENARIOS)
            raise SystemExit(f"unknown scenario {name!r} (known: {known})")
        targets.append((name, SCENARIOS[name].gemm))
    if args.gemm:
        m, n, k = args.gemm
        targets.append((f"gemm {m}x{n}x{k}", GemmShape(m, n, k, 2)))
    if not targets:
        raise SystemExit("nothing to render: pass --scenario and/or --gemm")

    tr = obs_trace.Tracer()
    pid = 0
    rendered = skipped = 0
    for label, gemm in targets:
        for sched in _schedules(args.schedule):
            pid += 1
            try:
                _, sig = obs_timeline.schedule_timeline(
                    gemm, machine, sched,
                    dma=not args.no_dma, tracer=tr, pid=pid, name=label,
                )
            except ValueError as e:  # indivisible decomposition
                print(f"skip {label} / {sched.value}: {e}", file=sys.stderr)
                skipped += 1
                continue
            rendered += 1
            print(
                f"{label:>16}  {sched.value:<18} total {sig['total_s']:.6f}s"
                f"  speedup {sig['speedup']:.3f}"
                f"  exposure {sig['exposure_s']:.6f}s"
            )
    obj = tr.to_json()
    errors = obs_trace.validate_trace(obj)
    if errors:
        for e in errors:
            print(f"schema error: {e}", file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(obj, f)
    print(
        f"wrote {args.out}: {rendered} timelines"
        f" ({len(obj['traceEvents'])} events, {skipped} skipped)"
    )
    return 0


def _jsonl(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def cmd_validate(args) -> int:
    errors: list[str] = []
    if args.kind == "trace":
        with open(args.path) as f:
            errors = obs_trace.validate_trace(json.load(f))
    elif args.kind == "metrics":
        for i, snap in enumerate(_jsonl(args.path)):
            errors += [
                f"line {i}: {e}"
                for e in obs_metrics.validate_snapshot(snap)
            ]
    elif args.kind == "merged":
        with open(args.path) as f:
            errors = obs_metrics.validate_merged_snapshot(json.load(f))
    elif args.kind == "signature":
        for i, snap in enumerate(_jsonl(args.path)):
            errors += [
                f"line {i}: {e}"
                for e in obs_signature.validate_signature(snap)
            ]
    elif args.kind == "sentinel":
        errors = obs_sentinel.validate_sentinel(_jsonl(args.path))
    else:  # audit
        try:
            errors = obs_audit.validate_audit(obs_audit.read_audit(args.path))
        except ValueError as e:
            errors = [str(e)]
    for e in errors:
        print(f"invalid: {e}", file=sys.stderr)
    if not errors:
        print(f"{args.path}: valid {args.kind}")
    return 1 if errors else 0


def cmd_metrics(args) -> int:
    with open(args.path) as f:
        snaps = [json.loads(line) for line in f if line.strip()]
    if not snaps:
        print("no snapshots", file=sys.stderr)
        return 1
    for snap in snaps:
        errors = obs_metrics.validate_snapshot(snap)
        if errors:
            for e in errors:
                print(f"invalid: {e}", file=sys.stderr)
            return 1
        print(f"snapshot ts={snap['ts']:.3f}")
        for name in sorted(snap["counters"]):
            print(f"  {name:<28} {snap['counters'][name]}")
        for name in sorted(snap["histograms"]):
            h = snap["histograms"][name]
            print(
                f"  {name:<28} n={h['count']}"
                f" p50={h['p50']:.6f} p95={h['p95']:.6f}"
            )
        decisions = snap["counters"].get("tuner/decisions", 0)
        if decisions:
            rates = {
                key.split(".", 1)[1]: val / decisions
                for key, val in snap["counters"].items()
                if key.startswith("tuner/pick.")
            }
            pretty = ", ".join(
                f"{t}={r:.2%}" for t, r in sorted(rates.items())
            )
            print(f"  tier rates: {pretty}")
    return 0


def cmd_signature(args) -> int:
    snaps = _jsonl(args.path)
    if not snaps:
        print("no signature snapshots", file=sys.stderr)
        return 1
    errors = []
    for i, snap in enumerate(snaps):
        errors += [
            f"line {i}: {e}" for e in obs_signature.validate_signature(snap)
        ]
    if errors:
        for e in errors:
            print(f"invalid: {e}", file=sys.stderr)
        return 1
    grid = obs_signature.overlay(snaps)
    observed = sorted(
        {sched for row in grid.values() for sched in row}
    )
    print(
        f"{len(snaps)} snapshot(s), {len(grid)} (family, scenario) rows, "
        f"{len(observed)} schedules observed"
    )
    for (family, scenario) in sorted(grid):
        row = grid[(family, scenario)]
        print(f"\n{family} :: {scenario}")
        for sched in observed:
            agg = row.get(sched)
            if agg is None:
                print(f"  {sched:<18} -")
                continue
            fracs = ", ".join(
                f"{k}={v:.1%}"
                for k, v in sorted(
                    agg["loss_fractions"].items(),
                    key=lambda kv: -kv[1],
                )
                if v > 0.0
            )
            print(
                f"  {sched:<18} n={agg['count']:<6}"
                f" mean={agg['mean_total_s'] * 1e3:.4f}ms"
                f"  dominant={agg['dominant']}"
                + (f"  [{fracs}]" if fracs else "")
            )
    return 0


def cmd_audit(args) -> int:
    path = args.path or obs_audit.default_audit_path()
    try:
        records = obs_audit.read_audit(path)
    except FileNotFoundError:
        print(f"no audit log at {path}", file=sys.stderr)
        return 1
    errors = obs_audit.validate_audit(records)
    if errors:
        for e in errors:
            print(f"invalid: {e}", file=sys.stderr)
        return 1
    for r in records:
        print(
            f"{r['kind']:<7} {r['machine']:<18} g{r['group']}"
            f" m{r['m']} n{r['n']} k{r['k']}"
            f" -> {r['schedule']:<18} [{r['source']}]"
        )
    if args.replay:
        res = obs_audit.replay(records, backend=args.backend)
        print(json.dumps(res.to_json(), indent=2))
        return 0 if res.ok else 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    tl = sub.add_parser("timeline", help="render schedule timelines")
    tl.add_argument(
        "--scenario", nargs="*", default=[],
        help=f"Table-I scenario names ({', '.join(SCENARIOS)})",
    )
    tl.add_argument(
        "--gemm", nargs=3, type=int, metavar=("M", "N", "K"),
        help="ad-hoc GEMM shape (dtype_bytes=2)",
    )
    tl.add_argument(
        "--schedule", nargs="+", default=["all"],
        help="schedule values, or 'all' for every studied schedule",
    )
    tl.add_argument("--machine", default=TPU_V5E.name)
    tl.add_argument(
        "--group", type=int, default=0,
        help="retarget the machine at this overlap-group size",
    )
    tl.add_argument("--no-dma", action="store_true")
    tl.add_argument("--out", default="timeline.json")
    tl.set_defaults(fn=cmd_timeline)

    va = sub.add_parser("validate", help="schema-validate an export")
    va.add_argument("path")
    va.add_argument(
        "--kind",
        choices=(
            "trace", "metrics", "audit", "signature", "sentinel", "merged",
        ),
        default="trace",
    )
    va.set_defaults(fn=cmd_validate)

    sg = sub.add_parser(
        "signature",
        help="overlay streamed inefficiency signatures on the schedule grid",
    )
    sg.add_argument("path", help="signature snapshot JSONL (REPRO_SIGNATURES)")
    sg.set_defaults(fn=cmd_signature)

    me = sub.add_parser("metrics", help="summarize a metrics JSONL stream")
    me.add_argument("path")
    me.set_defaults(fn=cmd_metrics)

    au = sub.add_parser("audit", help="print / replay a decision-audit log")
    au.add_argument(
        "path", nargs="?", default=None,
        help="audit JSONL (default: decisions.jsonl beside the cache)",
    )
    au.add_argument(
        "--replay", action="store_true",
        help="re-derive every pick offline and check it reproduces",
    )
    au.add_argument("--backend", default="numpy")
    au.set_defaults(fn=cmd_audit)

    args = ap.parse_args()
    sys.exit(args.fn(args))


if __name__ == "__main__":
    main()
