"""Wipe the persistent repro.autotune decision cache.

Usage:
  PYTHONPATH=src python scripts/clear_autotune_cache.py [--dir PATH] [-n]

By default clears ``$REPRO_AUTOTUNE_CACHE_DIR`` (or
``~/.cache/repro_autotune``).  ``-n`` / ``--dry-run`` only reports what
would be removed.  Only ``autotune-v*.json`` files are touched — the
directory itself and anything else in it is left alone.  The version
glob intentionally catches every schema generation: the PR-2-era
``autotune-v1.json`` (profile-less keys) as well as the current
``autotune-v2.json`` (ragged-profile-digest keys), so orphaned stores
from before a schema bump are cleaned up too.
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--dir", default=None,
        help="cache directory (default: $REPRO_AUTOTUNE_CACHE_DIR or "
        "~/.cache/repro_autotune)",
    )
    ap.add_argument(
        "-n", "--dry-run", action="store_true",
        help="report what would be removed without removing it",
    )
    args = ap.parse_args()

    if args.dir is not None:
        cache_dir = args.dir
    else:
        # Resolve like repro.autotune.cache, without importing jax.
        cache_dir = os.environ.get("REPRO_AUTOTUNE_CACHE_DIR") or (
            os.path.join(os.path.expanduser("~"), ".cache",
                         "repro_autotune")
        )

    pattern = os.path.join(cache_dir, "autotune-v*.json")
    files = sorted(glob.glob(pattern))
    if not files:
        print(f"nothing to clear: no cache files match {pattern}")
        return
    for path in files:
        entries = "?"
        try:
            with open(path) as f:
                raw = json.load(f)
            entries = len(raw.get("entries", {}))
        except (OSError, ValueError):
            pass
        if args.dry_run:
            print(f"would remove {path} ({entries} entries)")
        else:
            os.unlink(path)
            print(f"removed {path} ({entries} entries)")


if __name__ == "__main__":
    main()
