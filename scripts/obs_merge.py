"""Fleet-wide observability merge: union per-host metrics / trace exports.

A multi-host sweep (``scripts/sweep.py --host-index $I --host-count N``)
leaves one host-stamped metrics JSONL and/or one Perfetto trace JSON per
host.  This tool unions them into a single fleet view:

``metrics``
    Take the *last* snapshot line of each per-host metrics stream
    (snapshots are cumulative — the last line subsumes the earlier
    ones), union them via :func:`repro.obs.metrics.merge_snapshots`
    (bit-exact counter sums; exact percentiles when the exports carry
    reservoirs, count-weighted approximations flagged ``approx``
    otherwise), and write one merged snapshot::

        PYTHONPATH=src python scripts/obs_merge.py metrics \\
            host0.metrics.jsonl host1.metrics.jsonl --out fleet.json

``traces``
    Union per-host Chrome trace exports into one timeline via
    :func:`repro.obs.trace.merge_traces`: host clock anchors align the
    timestamps onto the earliest host's epoch and pids are remapped so
    every host renders as its own labeled process group in Perfetto::

        PYTHONPATH=src python scripts/obs_merge.py traces \\
            host0.trace.json host1.trace.json --out fleet_trace.json

Both outputs revalidate under the corresponding schema
(``scripts/trace.py validate --kind merged`` / ``--kind trace``).
"""

import argparse
import json
import sys

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def last_snapshot(path: str) -> dict | None:
    """Last JSON line of one host's cumulative metrics stream."""
    last = None
    with open(path) as f:
        for line in f:
            if line.strip():
                last = json.loads(line)
    return last


def cmd_metrics(args) -> int:
    snaps = []
    for path in args.paths:
        snap = last_snapshot(path)
        if snap is None:
            print(f"# skipping {path}: no snapshot lines", file=sys.stderr)
            continue
        errors = obs_metrics.validate_snapshot(snap)
        if errors:
            for e in errors:
                print(f"invalid input {path}: {e}", file=sys.stderr)
            return 1
        snaps.append(snap)
    if not snaps:
        print("nothing to merge", file=sys.stderr)
        return 1
    merged = obs_metrics.merge_snapshots(snaps)
    errors = obs_metrics.validate_merged_snapshot(merged)
    if errors:
        for e in errors:
            print(f"merged snapshot invalid: {e}", file=sys.stderr)
        return 1
    text = json.dumps(merged, indent=1, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    approx = sum(
        1 for h in merged["histograms"].values() if h.get("approx")
    )
    print(
        f"# merged {merged['hosts']} host snapshot(s): "
        f"{len(merged['counters'])} counters, "
        f"{len(merged['histograms'])} histograms"
        + (f" ({approx} approximate percentiles)" if approx else ""),
        file=sys.stderr,
    )
    return 0


def cmd_traces(args) -> int:
    traces = []
    for path in args.paths:
        with open(path) as f:
            obj = json.load(f)
        errors = obs_trace.validate_trace(obj)
        if errors:
            for e in errors:
                print(f"invalid input {path}: {e}", file=sys.stderr)
            return 1
        traces.append(obj)
    merged = obs_trace.merge_traces(traces)
    errors = obs_trace.validate_trace(merged)
    if errors:
        for e in errors:
            print(f"merged trace invalid: {e}", file=sys.stderr)
        return 1
    text = json.dumps(merged)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    else:
        print(text)
    print(
        f"# merged {len(traces)} trace(s): "
        f"{len(merged['traceEvents'])} events",
        file=sys.stderr,
    )
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    me = sub.add_parser("metrics", help="union per-host metrics snapshots")
    me.add_argument("paths", nargs="+", metavar="JSONL")
    me.add_argument("--out", default=None, metavar="PATH")
    me.set_defaults(fn=cmd_metrics)

    tr = sub.add_parser("traces", help="union per-host Perfetto traces")
    tr.add_argument("paths", nargs="+", metavar="JSON")
    tr.add_argument("--out", default=None, metavar="PATH")
    tr.set_defaults(fn=cmd_traces)

    args = ap.parse_args()
    sys.exit(args.fn(args))


if __name__ == "__main__":
    main()
