"""Paper §VI-D: heuristic accuracy on studied + 16 unseen synthetic
scenarios; loss when mispredicted (paper: 81%, ~14% loss)."""

from repro.core import MI300X, TABLE_I, synthetic_scenarios
from repro.core.explorer import explore

from benchmarks.common import row, timed


def _eval(scenarios, label):
    exact = within5 = 0
    losses = []
    for sc in scenarios:
        ex = explore(sc, MI300X)
        best_t = ex.results[ex.best].total
        got_t = ex.results[ex.heuristic.schedule].total
        exact += ex.heuristic_correct
        within5 += got_t <= 1.05 * best_t
        if not ex.heuristic_correct:
            losses.append(ex.heuristic_loss)
    n = len(scenarios)
    mean_loss = sum(losses) / len(losses) if losses else 0.0
    return [
        row(f"heuristic/{label}/exact", 0.0, f"{exact}/{n}"),
        row(f"heuristic/{label}/within5pct", 0.0,
            f"{within5}/{n} ({100*within5/n:.0f}%)"),
        row(f"heuristic/{label}/misprediction_loss", 0.0,
            f"{100*mean_loss:.0f}% of optimal speedup"),
    ]


def run() -> list[str]:
    rows = _eval(TABLE_I, "studied")
    syn = synthetic_scenarios(16)
    rows += _eval(syn, "synthetic16")
    return rows
