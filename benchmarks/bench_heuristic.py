"""Paper §VI-D: heuristic accuracy on studied + 16 unseen synthetic
scenarios; loss when mispredicted (paper: 81%, ~14% loss).

Runs on the batched engine: each scenario set is one ``explore_grid``
call instead of per-scenario scalar exploration."""

import numpy as np

from repro.core import MI300X, TABLE_I, synthetic_scenarios
from repro.core.explorer import explore_grid

from benchmarks.common import row, timed


def _eval(scenarios, label):
    ex, us = timed(explore_grid, scenarios, machines=(MI300X,))
    exact = int(ex.exact.sum())
    within5 = int(ex.within(0.05).sum())
    n = ex.exact.size
    miss = ~ex.exact
    mean_loss = float(np.nanmean(ex.heuristic_loss()[miss])) if miss.any() else 0.0
    return [
        row(f"heuristic/{label}/exact", us / n, f"{exact}/{n}"),
        row(f"heuristic/{label}/within5pct", 0.0,
            f"{within5}/{n} ({100*within5/n:.0f}%)"),
        row(f"heuristic/{label}/misprediction_loss", 0.0,
            f"{100*mean_loss:.0f}% of optimal speedup"),
    ]


def run() -> list[str]:
    rows = _eval(TABLE_I, "studied")
    syn = synthetic_scenarios(16)
    rows += _eval(syn, "synthetic16")
    return rows
