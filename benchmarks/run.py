"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:
  Fig 7  -> bench_dil_gemm        Fig 12b -> bench_schedules
  Fig 8  -> bench_dil_comm        Fig 13  -> bench_shard_overlap
  Fig 9  -> bench_cil             Fig 14  -> bench_comparison
  Fig 10 -> bench_proportions     §VI-D   -> bench_heuristic
  (real CPU timings)              -> bench_cpu_overlap
  batched sweep engine            -> bench_sweep
  autotune (jit engine + tuner)   -> bench_autotune

``--json [PATH]`` additionally writes a machine-readable name ->
us_per_call map (default ``BENCH_sweep.json``) so the perf trajectory is
tracked across PRs; ``--only MOD`` runs a single module.
"""

import argparse
import json
import sys


def main() -> None:
    from benchmarks import (
        bench_arch_schedules,
        bench_autotune,
        bench_cil,
        bench_comparison,
        bench_cpu_overlap,
        bench_dil_comm,
        bench_dil_gemm,
        bench_heuristic,
        bench_proportions,
        bench_schedules,
        bench_shard_overlap,
        bench_sweep,
    )

    modules = [
        bench_dil_gemm, bench_dil_comm, bench_cil, bench_proportions,
        bench_schedules, bench_shard_overlap, bench_comparison,
        bench_heuristic, bench_cpu_overlap, bench_arch_schedules,
        bench_sweep, bench_autotune,
    ]

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_sweep.json",
        default=None,
        metavar="PATH",
        help="also write {name: us_per_call} JSON (default BENCH_sweep.json)",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="run a single module (e.g. bench_sweep)",
    )
    args = ap.parse_args()
    if args.only:
        modules = [m for m in modules if m.__name__.endswith(args.only)]
        if not modules:
            sys.exit(f"no benchmark module matches {args.only!r}")

    print("name,us_per_call,derived")
    results: dict[str, float] = {}
    failed = 0
    for mod in modules:
        try:
            for r in mod.run():
                print(r)
                name, us, _ = r.split(",", 2)
                results[name] = float(us)
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{mod.__name__},0.0,ERROR:{e}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json} ({len(results)} entries)", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
