"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:
  Fig 7  -> bench_dil_gemm        Fig 12b -> bench_schedules
  Fig 8  -> bench_dil_comm        Fig 13  -> bench_shard_overlap
  Fig 9  -> bench_cil             Fig 14  -> bench_comparison
  Fig 10 -> bench_proportions     §VI-D   -> bench_heuristic
  (real CPU timings)              -> bench_cpu_overlap
  batched sweep engine            -> bench_sweep
  autotune (jit engine + tuner)   -> bench_autotune
  ragged (non-uniform) engine     -> bench_ragged
  sharded sweep subsystem         -> bench_sweep_shard
  device-resident mixed sweep     -> bench_sweep_device (--only sweepdevice)
  learned gate + calibration      -> bench_learn (--only learn)
  online-adaptation serving tier  -> bench_serve (--only serve)
  kernel-variant autotuning       -> bench_kernel_tune (--only kerneltune)

``--json [PATH]`` additionally writes a machine-readable name ->
us_per_call map (default ``BENCH_sweep.json``) so the perf trajectory is
tracked across PRs; ``--only MOD`` runs a single module.

``--check-regression [BASELINE]`` guards the batched-engine throughput:
the freshly measured us_per_call of the engine-throughput keys is
compared against the committed baseline (default ``BENCH_sweep.json``)
and the run FAILS if any engine got more than 20% slower (us_per_call
grew past 1/0.8 = 1.25x).  The check runs BEFORE ``--json`` writes: a
failing run leaves the baseline file untouched, so re-running cannot
silently ratchet the baseline down to the regressed numbers.
"""

import argparse
import json
import sys
import time

# Keys whose us_per_call tracks engine throughput (lower is better);
# the regression guard watches these, not the model-fidelity rows.
THROUGHPUT_KEYS = (
    "sweep/batched",
    "autotune/numpy_sweep",
    "autotune/jax_sweep",
    "ragged/batched",
    "ragged/jax",
    "sweepshard/reduce",
    "obs/sweep_disabled",
    "obs/signature_overhead",
    "obs/sentinel_step",
    "sweepdevice/fused",
    "sweepdevice/stats",
    "sweepdevice/ragged_stats",
    "learn/features",
    "learn/train",
    "serve/decisions_per_s",
    "kerneltune/search",
)
# Keys whose value is an accuracy percentage (higher is better); the
# guard fails if one drops more than ACCURACY_SLACK_PCT points below
# the committed baseline.  These are deterministic (seeded training
# data, analytic grids), so the slack only absorbs intentional
# re-recordings, not run-to-run noise.
ACCURACY_KEYS = (
    "learn/within5_skewed",
    "learn/within5_skewed_refined",
    "learn/within5_uniform",
    "learn/within5_uniform_refined",
)
ACCURACY_SLACK_PCT = 2.0
# >20% throughput drop == us_per_call growing beyond 1/0.8.
REGRESSION_RATIO = 1.0 / 0.8

# ``--only`` group aliases: documented short workload names resolved to
# their exact module name BEFORE the endswith match.  Not redundant with
# the suffix rule: "learn" as a bare suffix would also catch any future
# module that happens to end in "learn", while the alias pins the
# documented name to one module.
ONLY_ALIASES = {
    "learn": "bench_learn",
    "sweepdevice": "bench_sweep_device",
    "obs": "bench_obs",
    "serve": "bench_serve",
    "kerneltune": "bench_kernel_tune",
}


def check_regression(
    results: dict[str, float],
    baseline: dict[str, float],
    ratio: float = REGRESSION_RATIO,
    warn=None,
) -> list[str]:
    """Engine-throughput / accuracy keys that regressed vs the baseline.

    A baseline value of exactly 0.0 is a placeholder (a recording made
    while the module errored, or a key stubbed in ahead of its first
    measurement) — dividing the fresh number by it would flag any
    measurement as an infinite regression, so such keys are skipped
    with a printed warning (``warn`` callback, stderr by default)
    instead of gating the run.
    """
    if warn is None:
        def warn(msg):
            print(msg, file=sys.stderr)

    def usable(key, old):
        if old is None:
            return False  # key absent (older baseline)
        if old == 0.0:
            warn(
                f"# WARNING: baseline {key} is 0.0 (placeholder or "
                "failed recording); skipping its regression check"
            )
            return False
        return True

    bad = []
    for key in THROUGHPUT_KEYS:
        old = baseline.get(key)
        new = results.get(key)
        if new is None or not usable(key, old):
            continue
        if new > old * ratio:
            bad.append(
                f"{key}: {old:.1f} -> {new:.1f} us/point "
                f"({100 * (new / old - 1):.0f}% slower)"
            )
    for key in ACCURACY_KEYS:
        old = baseline.get(key)
        new = results.get(key)
        if new is None or not usable(key, old):
            continue
        if new < old - ACCURACY_SLACK_PCT:
            bad.append(
                f"{key}: {old:.1f}% -> {new:.1f}% "
                f"(accuracy dropped {old - new:.1f} points)"
            )
    return bad


def main() -> None:
    from benchmarks import (
        bench_arch_schedules,
        bench_autotune,
        bench_cil,
        bench_comparison,
        bench_cpu_overlap,
        bench_dil_comm,
        bench_dil_gemm,
        bench_heuristic,
        bench_kernel_tune,
        bench_learn,
        bench_obs,
        bench_proportions,
        bench_ragged,
        bench_schedules,
        bench_serve,
        bench_shard_overlap,
        bench_sweep,
        bench_sweep_device,
        bench_sweep_shard,
    )

    modules = [
        bench_dil_gemm, bench_dil_comm, bench_cil, bench_proportions,
        bench_schedules, bench_shard_overlap, bench_comparison,
        bench_heuristic, bench_cpu_overlap, bench_arch_schedules,
        bench_sweep, bench_autotune, bench_ragged, bench_sweep_shard,
        bench_sweep_device, bench_learn, bench_obs, bench_serve,
        bench_kernel_tune,
    ]

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_sweep.json",
        default=None,
        metavar="PATH",
        help="also write {name: us_per_call} JSON (default BENCH_sweep.json)",
    )
    ap.add_argument(
        "--only",
        default=None,
        help="run a subset of modules, comma-separated "
        "(e.g. bench_sweep,bench_ragged)",
    )
    ap.add_argument(
        "--check-regression",
        nargs="?",
        const="BENCH_sweep.json",
        default=None,
        metavar="BASELINE",
        help="fail if batched-engine throughput drops >20%% vs the "
        "committed baseline JSON (read before --json overwrites it)",
    )
    ap.add_argument(
        "--regression-ratio",
        type=float,
        default=REGRESSION_RATIO,
        help="allowed us_per_call growth factor before the gate fails "
        "(default %(default)s == a 20%% throughput drop); loosen on "
        "noisy shared runners",
    )
    args = ap.parse_args()
    if args.only:
        wanted = [
            ONLY_ALIASES.get(w, w) for w in args.only.split(",") if w
        ]
        modules = [
            m for m in modules
            if any(m.__name__.endswith(w) for w in wanted)
        ]
        if not modules:
            sys.exit(f"no benchmark module matches {args.only!r}")

    # Snapshot the baseline up front: --json may overwrite the same file.
    baseline: dict[str, float] | None = None
    if args.check_regression:
        try:
            with open(args.check_regression) as f:
                baseline = json.load(f)
        except (OSError, ValueError):
            baseline = None

    print("name,us_per_call,derived")
    results: dict[str, float] = {}
    bench_seconds: dict[str, float] = {}
    failed = 0
    for mod in modules:
        t0 = time.perf_counter()
        try:
            for r in mod.run():
                print(r)
                name, us, _ = r.split(",", 2)
                results[name] = float(us)
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{mod.__name__},0.0,ERROR:{e}")
        bench_seconds[mod.__name__.rsplit(".", 1)[-1]] = round(
            time.perf_counter() - t0, 3
        )
    # Regression gate BEFORE --json: a failing run must leave the
    # baseline file untouched (overwriting first would make a rerun
    # compare regressed-vs-regressed and "pass").
    if args.check_regression:
        if baseline is None:
            print(
                f"# no readable baseline at {args.check_regression}; "
                "skipping regression check",
                file=sys.stderr,
            )
        else:
            bad = check_regression(
                results, baseline, ratio=args.regression_ratio
            )
            if bad:
                for b in bad:
                    print(f"# THROUGHPUT REGRESSION {b}", file=sys.stderr)
                print(
                    f"# NOT writing {args.json or 'JSON'}: baseline "
                    "preserved for the next run",
                    file=sys.stderr,
                )
                sys.exit(2)
            print("# regression check passed", file=sys.stderr)
    if args.json:
        # Per-module wall clock rides along as metadata, outside the
        # gated name -> us_per_call namespace ("__" sorts before every
        # module prefix and THROUGHPUT/ACCURACY keys never match it).
        payload = dict(results)
        payload["__meta__"] = {"bench_seconds": bench_seconds}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json} ({len(results)} entries)", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
