"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:
  Fig 7  -> bench_dil_gemm        Fig 12b -> bench_schedules
  Fig 8  -> bench_dil_comm        Fig 13  -> bench_shard_overlap
  Fig 9  -> bench_cil             Fig 14  -> bench_comparison
  Fig 10 -> bench_proportions     §VI-D   -> bench_heuristic
  (real CPU timings)              -> bench_cpu_overlap
"""

import sys


def main() -> None:
    from benchmarks import (
        bench_arch_schedules,
        bench_cil,
        bench_comparison,
        bench_cpu_overlap,
        bench_dil_comm,
        bench_dil_gemm,
        bench_heuristic,
        bench_proportions,
        bench_schedules,
        bench_shard_overlap,
    )

    modules = [
        bench_dil_gemm, bench_dil_comm, bench_cil, bench_proportions,
        bench_schedules, bench_shard_overlap, bench_comparison,
        bench_heuristic, bench_cpu_overlap, bench_arch_schedules,
    ]
    print("name,us_per_call,derived")
    failed = 0
    for mod in modules:
        try:
            for r in mod.run():
                print(r)
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{mod.__name__},0.0,ERROR:{e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
