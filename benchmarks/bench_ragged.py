"""Ragged-step engine: throughput vs the scalar loop + uniform engine,
and heuristic accuracy on the capacity-skewed EP grid.

Four sections:

  * **grid**: the skewed EP scenario family (Table-I EP rows + synthetic
    GEMMs x skew-factor sweep x Zipf/top-k profiles) over the machine
    grid.
  * **throughput**: the same ragged grid through the scalar path
    (``simulate(..., profile=...)`` in nested Python loops), the NumPy
    masked-scan engine (``evaluate_ragged_grid``) and the jitted engine
    (``repro.autotune`` ragged backend), plus the ragged engine's
    overhead relative to the uniform engine at equal point count.
  * **heuristic**: within-5% accuracy of the skew-aware decision tree
    (imbalance-scaled serial gate) over the skewed grid, through
    ``explore_grid`` — the §VI-D protocol on the widened design space.
"""

import time

from repro.core import (
    GRID_SCHEDULES,
    TABLE_I,
    RaggedBatch,
    ScenarioBatch,
    Schedule,
    evaluate_grid,
    explore_grid,
    machine_grid,
    simulate,
    synthetic_scenarios,
)
from repro.core.batch import evaluate_ragged_grid
from repro.core.workload import ragged_scenario_grid

from benchmarks.common import row

_RAGGED_SCHEDULES = tuple(
    s for s in GRID_SCHEDULES
    if s not in (Schedule.SERIAL, Schedule.SHARD_P2P)
)


def _family():
    """Skew-factor sweep x Zipf/top-k over the EP rows + synthetics."""
    base = [s for s in TABLE_I if s.parallelism == "EP"]
    base += synthetic_scenarios(12)
    return ragged_scenario_grid(
        steps=8,
        skews=(1.0, 2.0, 4.0),
        zipf_alphas=(1.0,),
        top_k=((2, 0.6),),
        scenarios=base,
    )


def _scalar_sweep(scenarios, machines):
    n = 0
    for machine in machines:
        for sc in scenarios:
            for sched in _RAGGED_SCHEDULES:
                try:
                    simulate(sc.gemm, machine, sched, profile=sc.profile)
                except ValueError:
                    pass
            n += 1
    return n


def run() -> list[str]:
    scenarios = _family()
    machines = machine_grid()
    rb = RaggedBatch.from_ragged_scenarios(scenarios)
    points = len(scenarios) * len(machines)

    # Warm calibration caches so every path times pure evaluation.
    evaluate_ragged_grid(rb, machines)

    t_batched = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        evaluate_ragged_grid(rb, machines)
        t_batched = min(t_batched, time.perf_counter() - t0)

    t0 = time.perf_counter()
    _scalar_sweep(scenarios, machines)
    t_scalar = time.perf_counter() - t0

    # Uniform engine at the same point count: the masked scan's overhead.
    sb = ScenarioBatch.from_scenarios(scenarios)
    evaluate_grid(sb, machines)
    t_uniform = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        evaluate_grid(sb, machines)
        t_uniform = min(t_uniform, time.perf_counter() - t0)

    # Jitted ragged backend (compile reported separately, amortized).
    from repro.autotune import evaluate_ragged_grid as ragged_jax

    t0 = time.perf_counter()
    ragged_jax(rb, machines, backend="jax")
    t_compile = time.perf_counter() - t0
    t_jax = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        ragged_jax(rb, machines, backend="jax")
        t_jax = min(t_jax, time.perf_counter() - t0)

    rows = [
        row("ragged/grid_points", 0.0,
            f"{len(scenarios)}x{len(machines)}={points} "
            f"x{len(_RAGGED_SCHEDULES)} ragged schedules"),
        row("ragged/scalar", 1e6 * t_scalar / points,
            f"{points / t_scalar:.0f} scenarios/s"),
        row("ragged/batched", 1e6 * t_batched / points,
            f"{points / t_batched:.0f} scenarios/s"),
        row("ragged/batched_speedup", 0.0,
            f"{t_scalar / t_batched:.0f}x over the scalar loop"),
        row("ragged/jax", 1e6 * t_jax / points,
            f"{points / t_jax:.0f} scenarios/s "
            f"(compile {t_compile:.2f}s, amortized)"),
        row("ragged/vs_uniform_overhead", 0.0,
            f"{t_batched / t_uniform:.2f}x the uniform engine's time "
            f"at equal S"),
    ]

    # Heuristic accuracy on the skewed grid (skew-aware serial gate).
    ex = explore_grid(rb, machines=machines)
    rows += [
        row("ragged/heuristic_within5", 0.0,
            f"{100 * ex.accuracy(0.05):.1f}% of {points} skewed points"),
        row("ragged/heuristic_exact", 0.0,
            f"{100 * ex.accuracy():.1f}%"),
    ]
    return rows
