"""Paper Fig. 14: geomean speedups across techniques.

serial(=1), shard overlap, FiCCO 1D (DMA), FiCCO 2D where applicable,
FiCCO-rccl (core-driven comm).
"""

from repro.core import (
    MI300X, STUDIED, TABLE_I, Schedule, geomean, simulate,
)

from benchmarks.common import row


def run() -> list[str]:
    shard, f1d, f2d, frccl = [], [], [], []
    one_d = [s for s in STUDIED if s is not Schedule.UNIFORM_FUSED_2D]
    for sc in TABLE_I:
        shard.append(simulate(sc.gemm, MI300X, Schedule.SHARD_P2P).speedup)
        f1d.append(max(simulate(sc.gemm, MI300X, s).speedup for s in one_d))
        f2d.append(
            max(
                simulate(sc.gemm, MI300X, s).speedup
                for s in STUDIED
            )
        )
        frccl.append(
            max(
                simulate(sc.gemm, MI300X, s, dma=False).speedup
                for s in one_d
            )
        )
    rows = [
        row("comparison/serial", 0.0, "1.00"),
        row("comparison/shard_overlap_geomean", 0.0, f"{geomean(shard):.3f}"),
        row("comparison/ficco_rccl_geomean", 0.0, f"{geomean(frccl):.3f}"),
        row("comparison/ficco_1d_geomean", 0.0, f"{geomean(f1d):.3f}"),
        row("comparison/ficco_best_geomean", 0.0, f"{geomean(f2d):.3f}"),
    ]
    # beyond-paper: fused DMA-into-place kernel (no gather/scatter streams)
    fused = [
        max(
            simulate(sc.gemm, MI300X, s, dma_into_place=True).speedup
            for s in STUDIED
        )
        for sc in TABLE_I
    ]
    rows.append(
        row("comparison/ficco_dma_into_place_geomean", 0.0,
            f"{geomean(fused):.3f}")
    )
    # TPU v5e torus: ring P2P is no longer catastrophic, FiCCO still wins
    from repro.core import TPU_V5E

    tp_shard = [
        simulate(sc.gemm, TPU_V5E, Schedule.SHARD_P2P).speedup
        for sc in TABLE_I
    ]
    tp_ficco = [
        max(simulate(sc.gemm, TPU_V5E, s).speedup for s in STUDIED)
        for sc in TABLE_I
    ]
    rows.append(
        row("comparison/tpu_shard_overlap_geomean", 0.0,
            f"{geomean(tp_shard):.3f}")
    )
    rows.append(
        row("comparison/tpu_ficco_geomean", 0.0, f"{geomean(tp_ficco):.3f}")
    )
    return rows
