"""Learned heuristic tranche: training throughput + gate accuracy.

Four sections:

  * **features**: vectorized feature-extraction throughput
    (``repro.learn.features``) over the training batch.
  * **train**: end-to-end gate training — reduce-mode sharded sweeps
    accumulate the integer sufficient statistics (no gathered grid),
    then the greedy tree grower fits the threshold family.
  * **within5_skewed**: within-5% accuracy of the learned gate on the
    *held-out* capacity-skewed EP family (the grid whose ~64-76% scalar
    gate accuracy motivated the learned tranche) — the value column
    carries the percentage so ``--check-regression`` can gate on it.
  * **within5_uniform**: the PR-1 uniform design-space grid, guarding
    that the skew-aware family never regresses the uniform ~84%.

Training data is seeded synthetic (Dirichlet ragged + log-uniform
scenarios) and disjoint from both evaluation grids.

Determinism: earlier benchmark modules freeze per-machine TAU overrides
(``bench_sweep`` runs the paper's one-time threshold calibration), which
would make these accuracy keys depend on module order.  ``run``
snapshots and clears the heuristic override dicts for its duration, so
``learn/*`` numbers are identical standalone (``--only learn``) and in
the full suite — a requirement for the ``--check-regression`` accuracy
floor.
"""

import contextlib
import time

from repro.core import (
    TABLE_I,
    ScenarioBatch,
    machine_grid,
    scenario_grid,
    synthetic_scenarios,
)
from repro.core.batch import RaggedBatch
from repro.core.engine import get_engine
from repro.core.workload import ragged_scenario_grid
from repro.learn import (
    gate_accuracy,
    refine_gate,
    scenario_features,
    sweep_stats,
    train_gate_from_stats,
)
from repro.sweep import synthetic_batch, synthetic_ragged_batch

from benchmarks.common import row

_TRAIN_N = 2000
_SHARDS = 8


@contextlib.contextmanager
def _frozen_default_thresholds():
    """Run with the frozen default TAU / serial gate (no overrides)."""
    from repro.core import heuristics as _h

    tau = dict(_h._TAU_OVERRIDES)
    gate = dict(_h._SERIAL_GATE_OVERRIDES)
    _h._TAU_OVERRIDES.clear()
    _h._SERIAL_GATE_OVERRIDES.clear()
    try:
        yield
    finally:
        _h._TAU_OVERRIDES.clear()
        _h._TAU_OVERRIDES.update(tau)
        _h._SERIAL_GATE_OVERRIDES.clear()
        _h._SERIAL_GATE_OVERRIDES.update(gate)


def _train(machines):
    """Sharded-sweep statistics (ragged + uniform) -> learned gate."""
    stats_r, _ = sweep_stats(
        synthetic_ragged_batch(_TRAIN_N, seed=7), machines,
        num_shards=_SHARDS,
    )
    stats_u, _ = sweep_stats(
        synthetic_batch(_TRAIN_N, seed=8), machines, num_shards=_SHARDS
    )
    return train_gate_from_stats(stats_r + stats_u)


def run() -> list[str]:
    with _frozen_default_thresholds():
        return _run()


def _run() -> list[str]:
    machines = machine_grid()
    train_points = 2 * _TRAIN_N * len(machines)

    rb = synthetic_ragged_batch(_TRAIN_N, seed=7)
    scenario_features(rb, machines[0])  # warm calibration caches
    t0 = time.perf_counter()
    for machine in machines:
        scenario_features(rb, machine)
    t_feat = time.perf_counter() - t0
    feat_points = _TRAIN_N * len(machines)

    t0 = time.perf_counter()
    gate = _train(machines)
    t_train = time.perf_counter() - t0

    # Regret-weighted threshold refinement on the (ragged) training
    # distribution: per-leaf sub-bin search between the coarse candidate
    # thresholds.  Held-out accuracy below tells whether the finer
    # thresholds generalize.
    grid_refit = get_engine("numpy").evaluate(rb, machines)
    t0 = time.perf_counter()
    refined = refine_gate(gate, grid_refit)
    t_refine = time.perf_counter() - t0
    ref_info = refined.meta["refine"]
    refit_points = _TRAIN_N * len(machines)

    # Held-out skewed EP family (the bench_ragged grid).
    base = [s for s in TABLE_I if s.parallelism == "EP"]
    base += synthetic_scenarios(12)
    fam = ragged_scenario_grid(
        steps=8, skews=(1.0, 2.0, 4.0), zipf_alphas=(1.0,),
        top_k=((2, 0.6),), scenarios=base,
    )
    grid_skew = get_engine("numpy").evaluate(
        RaggedBatch.from_ragged_scenarios(fam), machines
    )
    skew_scalar = 100 * gate_accuracy(grid_skew)
    skew_learned = 100 * gate_accuracy(grid_skew, gate)
    skew_refined = 100 * gate_accuracy(grid_skew, refined)

    # PR-1 uniform design-space grid (~720 x 8): the do-no-harm guard.
    grid_unif = get_engine("numpy").evaluate(
        ScenarioBatch.from_scenarios(scenario_grid()), machines
    )
    unif_scalar = 100 * gate_accuracy(grid_unif)
    unif_learned = 100 * gate_accuracy(grid_unif, gate)
    unif_refined = 100 * gate_accuracy(grid_unif, refined)

    n_skew = grid_skew.total.shape[1] * grid_skew.total.shape[2]
    n_unif = grid_unif.total.shape[1] * grid_unif.total.shape[2]
    return [
        row("learn/features", 1e6 * t_feat / feat_points,
            f"{feat_points / t_feat:.0f} scenario-features/s"),
        row("learn/train", 1e6 * t_train / train_points,
            f"{train_points} points via {_SHARDS}-shard reduce sweeps, "
            f"{gate.n_leaves} leaves, {t_train:.2f}s"),
        row("learn/refine", 1e6 * t_refine / refit_points,
            f"{refit_points}-point refit grid, regret_q "
            f"{ref_info['regret_q_before']} -> "
            f"{ref_info['regret_q_after']}, {t_refine:.2f}s"),
        row("learn/within5_skewed", skew_learned,
            f"{skew_learned:.1f}% of {n_skew} held-out skewed points "
            f"(scalar gate: {skew_scalar:.1f}%)"),
        row("learn/within5_skewed_scalar", skew_scalar,
            "scalar-gate baseline on the same grid"),
        row("learn/within5_skewed_refined", skew_refined,
            f"refined-gate delta {skew_refined - skew_learned:+.2f} pts "
            "vs coarse gate on the held-out skewed grid"),
        row("learn/within5_uniform", unif_learned,
            f"{unif_learned:.1f}% of {n_unif} uniform grid points "
            f"(scalar gate: {unif_scalar:.1f}%)"),
        row("learn/within5_uniform_scalar", unif_scalar,
            "scalar-gate baseline on the same grid"),
        row("learn/within5_uniform_refined", unif_refined,
            f"refined-gate delta {unif_refined - unif_learned:+.2f} pts "
            "vs coarse gate on the uniform grid"),
    ]
