"""Kernel-variant autotuning tranche: the enumerate->prune->measure->
refit->promote loop (``repro.tune``).

Four sections:

  * **space**: enumerated vs feasible variant counts per kernel on
    MI300X (the pruner's VMEM / semaphore / granule budgets at work).
  * **search**: us per candidate through ``search_kernel_variants``
    (cost-model runner, local in-memory tuner) — the gated throughput
    key; a regression here slows every cold autotune pass.
  * **speedup**: geometric-mean best-vs-default speedup across the
    three kernels (>1 means the search beats the shipped default).
  * **fit_mse**: log-time MSE of ``fit_machine`` on the variant-keyed
    cache records the search just wrote (derived shows loss0 -> loss).

Everything runs against a throwaway cache (``persist=False`` +
temp-dir path), so benchmarking never touches the user's decision
store or promotion artifacts.
"""

import math
import os
import tempfile
import time

from repro.core import MI300X
from repro.core.workload import GemmShape

from benchmarks.common import row

_GEMMS = (
    GemmShape(4096, 4096, 4096, 2),
    GemmShape(8192, 4096, 2048, 2),
)


def run() -> list[str]:
    from repro.autotune import Autotuner, AutotuneCache
    from repro.learn import fit_machine, variant_records_from_cache
    from repro.tune import (
        KERNELS,
        enumerate_variants,
        prune_variants,
        reset_variants,
        search_kernel_variants,
    )

    tmp = tempfile.mkdtemp(prefix="bench_kernel_tune_")
    tuner = Autotuner(
        cache=AutotuneCache(path=os.path.join(tmp, "tune.json")),
        persist=False,
    )

    n_enum = n_feas = 0
    for kernel in KERNELS:
        cands = enumerate_variants(kernel, MI300X, group=MI300X.group)
        feas, _ = prune_variants(
            cands, _GEMMS[0], MI300X, group=MI300X.group
        )
        n_enum += len(cands)
        n_feas += len(feas)

    t0 = time.perf_counter()
    results = [
        search_kernel_variants(
            kernel, gemm, MI300X, group=MI300X.group, tuner=tuner
        )
        for kernel in KERNELS
        for gemm in _GEMMS
    ]
    t_search = time.perf_counter() - t0
    n_cands = sum(r.n_enumerated for r in results)
    speedup = math.exp(
        sum(math.log(r.speedup) for r in results) / len(results)
    )

    recs = variant_records_from_cache(tuner.cache, MI300X.name)
    t0 = time.perf_counter()
    fit = fit_machine(MI300X, recs, steps=60)
    t_fit = time.perf_counter() - t0

    # Promotions above were process-global; a benchmark must not leak
    # winners into whatever runs after it in the same interpreter.
    reset_variants()

    return [
        row("kerneltune/space", 0.0,
            f"{n_enum} enumerated -> {n_feas} feasible across "
            f"{len(KERNELS)} kernels on {MI300X.name} g={MI300X.group}"),
        row("kerneltune/search", 1e6 * t_search / n_cands,
            f"{len(results)} searches / {n_cands} candidates in "
            f"{t_search:.3f}s (cost-model runner)"),
        row("kerneltune/speedup", speedup,
            f"geomean best-vs-default over {len(results)} "
            f"(kernel, gemm) searches"),
        row("kerneltune/fit_mse", fit.loss,
            f"{len(recs)} variant records, loss {fit.loss0:.4g} -> "
            f"{fit.loss:.4g} in {t_fit:.2f}s"),
    ]
