"""Online-adaptation serving tier: sustained decision throughput + lag.

Drives :class:`repro.serve.adapt.AdaptiveTier` with the seeded
drifting-skew request stream (``repro.sweep.synth``) and reports the
numbers ROADMAP item 1 promises:

  serve/decisions_per_s   — us per *sustained* adaptive decision
                            (GATED: joins THROUGHPUT_KEYS).  Sustained
                            = post-transient picks of each drift phase,
                            i.e. after the phase's working set entered
                            the bounded memory cache, while the
                            background re-fit thread keeps retraining
                            the gate underneath.
  serve/static_warm       — the same timed windows through a
                            pre-warmed tier with no re-fit thread: the
                            pure memory-hit floor the adaptive path is
                            held within 10% of.
  serve/adapt_overhead_pct— adaptive vs static, as a percentage.
  serve/adaptation_lag    — mean picks after a drift step until the
                            deployed gate's agreement on a *held-out*
                            new-phase sample reaches within 0.05 of
                            the level it eventually converges to for
                            that phase (``AdaptiveTier.agreement_probe``
                            trajectory; 0 == the old gate was already
                            there, i.e. nothing needed restoring).
  serve/explore_budget    — measured-tier audit: sessions granted vs
                            the token-bucket bound (burst + rate * t).

One-off costs (the machine fit's jit compile, numpy calibration
caches) are paid in an untimed warm-up segment, mirroring how a
serving process amortizes them over its lifetime.  Everything is
seeded and the persistent layer lives in a tempdir, so runs are
comparable and leave no state behind.
"""

import tempfile
import time

from benchmarks.common import row

_DRIFT = 3000          # requests per drift phase
_PHASES = 3
_N = _DRIFT * _PHASES
_TRANSIENT = 1000      # per-phase picks excluded from "sustained"
_PROBE = 256           # held-out sample size for agreement probes
_LAG_CHUNK = 64        # lag probe: picks between inline re-fits
_LAG_WINDOW = 1024     # post-drift picks the lag probe traces
_EXPLORE_RATE = 2.0    # token-bucket refill (sessions/s) for the audit
_EXPLORE_BURST = 4.0


def _make_tier(path, *, measure=False, refit_s=0.2, buffer_size=2048,
               leaves=8):
    from repro.autotune import Autotuner, AutotuneCache
    from repro.core.machine import TPU_V5E
    from repro.serve.adapt import (
        AdaptConfig, AdaptiveTier, simulated_measure_fn,
    )

    return AdaptiveTier(
        Autotuner(
            cache=AutotuneCache(path=path),
            backend="numpy",
            persist="defer",
        ),
        machine=TPU_V5E,
        config=AdaptConfig(
            refit_interval_s=refit_s,
            buffer_size=buffer_size,
            explore_rate=_EXPLORE_RATE,
            explore_burst=_EXPLORE_BURST,
            fit_min_records=2,   # let the warm-up compile the fit path
            fit_steps=60,
            gate_max_leaves=leaves,
        ),
        measure_fn=(
            simulated_measure_fn(TPU_V5E, seed=0) if measure else None
        ),
    )


def _timed_pass(tier, reqs, timed_idx):
    """Process every request; per-pick time only the sustained window."""
    total = 0.0
    for i, r in enumerate(reqs):
        if i in timed_idx:
            t0 = time.perf_counter()
            tier.pick(r.gemm, profile=r.profile)
            total += time.perf_counter() - t0
        else:
            tier.pick(r.gemm, profile=r.profile)
    return total


def _adaptation_lag(reqs, path):
    """Mean post-drift picks until the deployed gate reaches its
    eventual (converged) agreement level on a held-out new-phase
    sample.

    The probe traces the agreement trajectory a(t): the old gate's
    score right at the drift step (t=0), then after every 64-pick
    chunk + inline re-fit; lag is the first t within 0.05 of the
    trajectory's final value.  Converged-relative, because phases
    differ in how separable their argmin structure is — "back to the
    previous phase's score" is unreachable when the new phase's
    ceiling is lower.  A deliberately small gate (2 leaves) keeps the
    re-fit's work visible: it can only represent the current phase.
    """
    tier = _make_tier(path, buffer_size=512, leaves=2)
    i = 0
    lags = []
    restores = []

    def feed(n):
        nonlocal i
        for r in reqs[i:i + n]:
            tier.pick(r.gemm, profile=r.profile)
        i = min(i + n, len(reqs))

    def sample(start):
        return [
            (r.gemm, r.profile) for r in reqs[start:start + _PROBE]
        ]

    for phase in range(_PHASES):
        end = (phase + 1) * _DRIFT
        while i < end:
            feed(min(_PROBE, end - i))
            tier.refit_now()
        if end >= len(reqs):
            break
        held_out = sample(end)
        traj = [(0, tier.agreement_probe(held_out) or 0.0)]
        since = 0
        while since < _LAG_WINDOW:
            feed(_LAG_CHUNK)
            since += _LAG_CHUNK
            tier.refit_now()
            traj.append((since, tier.agreement_probe(held_out) or 0.0))
        converged = traj[-1][1]
        lags.append(
            next(t for t, a in traj if a >= converged - 0.05)
        )
        restores.append(converged - traj[0][1])
    mean = lambda xs: (sum(xs) / len(xs)) if xs else 0.0
    return mean(lags), mean(restores)


def run() -> list[str]:
    from repro.sweep.synth import drifting_request_stream

    reqs = list(
        drifting_request_stream(_N, seed=0, drift_every=_DRIFT)
    )
    timed_idx = {
        i for i in range(_N) if i % _DRIFT >= _TRANSIENT
    }
    n_timed = len(timed_idx)

    with tempfile.TemporaryDirectory() as d:
        # Static floor: warm every phase's working set first, then time
        # pure memory hits (no re-fit thread, nothing expires mid-run).
        static = _make_tier(f"{d}/static.json")
        for r in reqs:
            static.pick(r.gemm, profile=r.profile)
        t_static = _timed_pass(static, reqs, timed_idx)

        # Adaptive: background re-fit thread live + budgeted measured
        # tier, same timed windows.  Warm-up pays the one-off costs
        # (jit compile of fit_machine, calibration caches) untimed.
        adaptive = _make_tier(f"{d}/adapt.json", measure=True)
        t_build = time.perf_counter()
        for r in reqs[:_TRANSIENT]:
            adaptive.pick(r.gemm, profile=r.profile)
        adaptive.refit_now()
        adaptive.refit_now()
        with adaptive:
            t_adapt = _timed_pass(adaptive, reqs, timed_idx)
        # The token bucket fills from tier construction, so the budget
        # the audit holds `granted` to spans the tier's whole lifetime
        # (warm-up included), not just the timed windows.
        lifetime = time.perf_counter() - t_build
        pol = adaptive.policy
        budget_bound = _EXPLORE_BURST + _EXPLORE_RATE * lifetime
        stats = adaptive.stats()

        # The lag probe gets its own stream draw: a seed whose phases
        # exercise the gate's capacity limit (seed 0's working set is
        # separable enough that every phase scores 1.0 and there is
        # nothing to restore).
        lag_reqs = list(
            drifting_request_stream(_N, seed=1, drift_every=_DRIFT)
        )
        lag, restore = _adaptation_lag(lag_reqs, f"{d}/lag.json")

    overhead = 100.0 * (t_adapt / t_static - 1.0)
    return [
        row("serve/decisions_per_s", 1e6 * t_adapt / n_timed,
            f"{n_timed / t_adapt:.0f} sustained decisions/s, re-fit "
            f"thread live (gate v{stats['gate_version']}, "
            f"agreement {stats['last_agreement']})"),
        row("serve/static_warm", 1e6 * t_static / n_timed,
            f"{n_timed / t_static:.0f} decisions/s, pure memory hits"),
        row("serve/adapt_overhead_pct", 0.0,
            f"{overhead:.1f}% over static warm cache (criterion <10%)"),
        row("serve/adaptation_lag", lag,
            f"{lag:.0f} picks to re-converge on held-out post-drift "
            f"traffic (mean agreement restored {restore:+.2f})"),
        row("serve/explore_budget", 0.0,
            f"{pol.granted} measured sessions of <= "
            f"{budget_bound:.1f} budget ({pol.ambiguous} ambiguous, "
            f"{pol.denied} denied), "
            f"respected={pol.granted <= budget_bound}"),
    ]
