"""Shared benchmark helpers: timing + CSV row emission."""

from __future__ import annotations

import time


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
