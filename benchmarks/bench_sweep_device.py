"""Device-resident mixed-precision sweep: fused synth + eval + reduce.

Runs ``repro.sweep.device.sweep_device_stats`` — scenarios synthesized
on device from ``(seed, lane)`` counters, evaluated in float32 through
the mixed engine's grid kernel (float64 confined to the pipeline-scan
accumulator) and reduced to shard summaries / gate statistics inside
the same jit — and reports:

  * ``sweepdevice/fused``        — us per (scenario, machine) point for
    the reduce-mode fused program without statistics collection: the
    apples-to-apples twin of ``sweepshard/reduce`` (which also collects
    no gate statistics) and the headline engine-throughput key the
    regression gate watches;
  * ``sweepdevice/stats``        — the same program additionally
    reducing the full GateStats histogram on device;
  * ``sweepdevice/ragged_stats`` — the ragged (Dirichlet step-profile)
    variant with statistics.

All three time a single ≥1e6-lane shard (scenarios x machines), the
regime the device path is built for; jit compilation is excluded by a
warmup run per configuration.
"""

import time

from repro.core.workload import machine_grid

from benchmarks.common import row

_S = 262_144
_S_RAGGED = 65_536


def _row3(name: str, us: float, derived) -> str:
    # Sub-us per-point values: common.row's one decimal would quantize
    # the regression-gated keys by up to ~25%.
    return f"{name},{us:.3f},{derived}"


def _timed(fn, repeats: int = 3) -> float:
    fn()  # warmup: compile + autotune caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[str]:
    from repro.sweep.device import sweep_device_stats

    machines = machine_grid(groups=(8,))
    m = len(machines)
    points = _S * m
    points_r = _S_RAGGED * m

    def fused_nostats():
        sweep_device_stats(
            _S, machines, dtype="float32", num_shards=1,
            collect_stats=False,
        )

    def fused_stats():
        sweep_device_stats(_S, machines, dtype="float32", num_shards=1)

    def ragged_stats():
        sweep_device_stats(
            _S_RAGGED, machines, dtype="float32", num_shards=1,
            ragged=True,
        )

    t_fused = _timed(fused_nostats)
    t_stats = _timed(fused_stats)
    t_ragged = _timed(ragged_stats)

    return [
        row("sweepdevice/points", 0.0,
            f"{_S}x{m}={points} points/shard (float32; ragged "
            f"{_S_RAGGED}x{m}={points_r})"),
        _row3("sweepdevice/fused", 1e6 * t_fused / points,
              f"{points / t_fused:.0f} points/s fused synth+eval+reduce "
              "(no stats; twin of sweepshard/reduce)"),
        _row3("sweepdevice/stats", 1e6 * t_stats / points,
              f"{points / t_stats:.0f} points/s with on-device GateStats"),
        _row3("sweepdevice/ragged_stats", 1e6 * t_ragged / points_r,
              f"{points_r / t_ragged:.0f} points/s ragged with GateStats"),
    ]
