"""Paper Fig. 12b: FiCCO schedule speedups with heuristic picks overlaid."""

from repro.core import (
    MI300X, STUDIED, TABLE_I, Schedule, best_schedule, select_schedule,
    simulate,
)

from benchmarks.common import row, timed


def run() -> list[str]:
    rows = []
    best_seen = 0.0
    for sc in TABLE_I:
        (best, res), us = timed(best_schedule, sc.gemm, MI300X)
        dec = select_schedule(sc.gemm, MI300X)
        parts = " ".join(
            f"{s.value}={res[s].speedup:.2f}" for s in STUDIED
        )
        best_seen = max(best_seen, max(res[s].speedup for s in STUDIED))
        rows.append(
            row(f"schedules/{sc.name}", us,
                f"{parts} heuristic={dec.schedule.value}")
        )
    rows.append(row("schedules/max_speedup", 0.0, f"{best_seen:.2f}"))
    return rows
