"""Paper Fig. 12b: FiCCO schedule speedups with heuristic picks overlaid.

One batched ``explore_grid`` call covers all Table-I scenarios x all
schedules; rows print each scenario's per-schedule speedups plus the
vectorized heuristic's pick."""

from repro.core import MI300X, STUDIED, TABLE_I
from repro.core.explorer import explore_grid

from benchmarks.common import row, timed


def run() -> list[str]:
    ex, us = timed(explore_grid, TABLE_I, machines=(MI300X,))
    grid = ex.grid
    speedup = grid.speedup  # (L, S, 1)
    rows = []
    best_seen = 0.0
    for i, sc in enumerate(TABLE_I):
        parts = " ".join(
            f"{s.value}={speedup[grid.schedule_idx(s), i, 0]:.2f}"
            for s in STUDIED
        )
        best_seen = max(
            best_seen,
            max(speedup[grid.schedule_idx(s), i, 0] for s in STUDIED),
        )
        pick = grid.schedules[int(ex.heuristic_idx[i, 0])]
        rows.append(
            row(f"schedules/{sc.name}", us / len(TABLE_I),
                f"{parts} heuristic={pick.value}")
        )
    rows.append(row("schedules/max_speedup", 0.0, f"{best_seen:.2f}"))
    return rows
