"""Design-space sweep throughput: scalar loop vs the batched engine.

The tentpole metric for ``repro.core.batch``: evaluate the full scenario
grid (every registry arch x dtype x token scale, crossed with group sizes
x topologies x machines — thousands of (scenario, machine) points, all
six schedules each) through

  * the scalar path: ``simulate()`` in nested Python loops, and
  * the batched path: one ``evaluate_grid`` call,

reports scenarios/sec for both and their ratio (acceptance: >=50x), then
reproduces the paper's §VI-D heuristic-accuracy claim at grid scale:
~81% of *overlap-profitable* unseen scenarios are picked well (within 5%
of optimal).  Grid-wide accuracy is lower — an honest beyond-paper
finding: the static heuristic has no "stay serial" tranche, so it
decomposes moderate GEMMs whose analytic optimum is serial.
"""

import time

from repro.core import (
    GRID_SCHEDULES,
    ScenarioBatch,
    calibrate_tau,
    evaluate_grid,
    explore_grid,
    machine_grid,
    scenario_grid,
    simulate,
)

from benchmarks.common import row


def _scalar_sweep(scenarios, machines):
    """The pre-batching path: nested Python loops over the same grid."""
    n = 0
    for machine in machines:
        for sc in scenarios:
            for sched in GRID_SCHEDULES:
                try:
                    simulate(sc.gemm, machine, sched)
                except ValueError:
                    pass  # indivisible decomposition; grid marks it invalid
            n += 1
    return n


def run() -> list[str]:
    scenarios = scenario_grid()
    machines = machine_grid()
    sb = ScenarioBatch.from_scenarios(scenarios)
    points = len(scenarios) * len(machines)

    # Warm the per-machine calibration caches so both paths time pure
    # evaluation (the scalar path would otherwise pay them too).
    grid = evaluate_grid(sb, machines)

    t_batched = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        grid = evaluate_grid(sb, machines)
        t_batched = min(t_batched, time.perf_counter() - t0)

    t0 = time.perf_counter()
    _scalar_sweep(scenarios, machines)
    t_scalar = time.perf_counter() - t0

    speedup = t_scalar / t_batched
    rows = [
        row("sweep/grid_points", 0.0,
            f"{len(scenarios)}x{len(machines)}={points} "
            f"x{len(GRID_SCHEDULES)} schedules"),
        row("sweep/scalar", 1e6 * t_scalar / points,
            f"{points / t_scalar:.0f} scenarios/s"),
        row("sweep/batched", 1e6 * t_batched / points,
            f"{points / t_batched:.0f} scenarios/s"),
        row("sweep/batched_speedup", 0.0, f"{speedup:.0f}x (target >=50x)"),
    ]

    # §VI-D at grid scale: one-time per-machine TAU fit (paper §VIII-C).
    # The paper tunes thresholds on scenarios where overlap matters
    # (Table I is profitable by construction), so calibrate each machine
    # on its own overlap-profitable slice of the grid.
    import numpy as np

    serial_idx = grid.schedule_idx(GRID_SCHEDULES[0])
    best = grid.best_idx()
    for j, machine in enumerate(machines):
        prof_i = np.where(best[:, j] != serial_idx)[0]
        cal_i = prof_i[:: max(1, len(prof_i) // 64)]
        cal = [scenarios[i] for i in cal_i]
        if cal:
            calibrate_tau(
                machine, cal,
                candidates=(0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1,
                            0.2, 0.5, 1.0),
            )
    ex = explore_grid(sb, machines=machines)
    profitable = ex.best_idx != serial_idx
    within5 = ex.within(0.05)
    miss_prof = profitable & ~ex.exact
    # Clamp at 100%: on marginal points (optimal speedup ~1.0) the loss
    # ratio diverges; "lost the entire speedup" is the meaningful cap.
    loss_prof = (
        float(np.nanmean(np.minimum(ex.heuristic_loss()[miss_prof], 1.0)))
        if miss_prof.any()
        else 0.0
    )
    rows += [
        row("sweep/heuristic_gridwide_within5", 0.0,
            f"{100 * ex.accuracy(0.05):.1f}% of {points}"),
        row("sweep/heuristic_profitable_within5", 0.0,
            f"{100 * within5[profitable].mean():.1f}% of "
            f"{int(profitable.sum())} overlap-profitable points "
            f"(paper §VI-D: 81%)"),
        row("sweep/heuristic_profitable_misprediction_loss", 0.0,
            f"{100 * loss_prof:.0f}% of optimal speedup "
            f"(paper §VI-D: ~14%)"),
    ]
    return rows
