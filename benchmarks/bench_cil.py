"""Paper Fig. 9: contention-inefficiency loss (CIL) under overlap.

GEMM CIL with DMA vs core-driven (RCCL-style) communication, and the
communication-side CIL, vs the 8-way M-sharded Table I GEMMs.
"""

from repro.core import MI300X, TABLE_I, comm_cil, gemm_cil, geomean

from benchmarks.common import row, timed


def run() -> list[str]:
    rows = []
    g_dma, g_rccl, c_vals = [], [], []
    for sc in TABLE_I:
        sh = sc.gemm.shard(8, "m")
        dma, us = timed(gemm_cil, sh, MI300X, degree=3, dma=True)
        rccl, _ = timed(gemm_cil, sh, MI300X, degree=3, dma=False)
        cc, _ = timed(comm_cil, sh, MI300X, degree=3, dma=True)
        g_dma.append(dma)
        g_rccl.append(rccl)
        c_vals.append(cc)
        rows.append(
            row(f"cil/{sc.name}", us,
                f"gemm_dma={dma:.3f} gemm_rccl={rccl:.3f} comm={cc:.3f}")
        )
    rows.append(row("cil/gemm_dma_geomean", 0.0, f"{geomean(g_dma):.3f}"))
    rows.append(row("cil/gemm_rccl_geomean", 0.0, f"{geomean(g_rccl):.3f}"))
    rows.append(row("cil/comm_geomean", 0.0, f"{geomean(c_vals):.3f}"))
    shard_g = geomean(gemm_cil(s.gemm.shard(8, "m"), MI300X, degree=2)
                      for s in TABLE_I)
    shard_c = geomean(comm_cil(s.gemm.shard(8, "m"), MI300X, degree=2)
                      for s in TABLE_I)
    rows.append(row("cil/shard_overlap_gemm_geomean", 0.0, f"{shard_g:.3f}"))
    rows.append(row("cil/shard_overlap_comm_geomean", 0.0, f"{shard_c:.3f}"))
    return rows
