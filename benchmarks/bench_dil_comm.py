"""Paper Fig. 8: DIL for the DMA-based all-gather.

FiCCO communicates at 1/g granularity; geomean slowdown target ~10%,
shrinking as transfers grow (bandwidth-bound resilience).
"""

from repro.core import MI300X, TABLE_I, geomean
from repro.core.inefficiency import calibrated_s_half, comm_time

from benchmarks.common import row, timed


def run() -> list[str]:
    rows = []
    sh = calibrated_s_half(MI300X)
    g = MI300X.group
    dils = []
    for sc in sorted(TABLE_I, key=lambda s: s.gemm.m * s.gemm.k):
        total = sc.gemm.m * sc.gemm.k * sc.gemm.dtype_bytes
        per_link = total / g / MI300X.a2a_links
        base, _ = timed(comm_time, per_link, MI300X, s_half=0.0)
        fine, us = timed(
            comm_time, per_link, MI300X, s_half=sh, n_transfers=g
        )
        dil = fine / base
        dils.append(dil)
        rows.append(
            row(f"dil_comm/{sc.name}", us,
                f"{dil:.3f} ({total/2**30:.1f}GiB)")
        )
    rows.append(row("dil_comm/geomean", 0.0, f"{geomean(dils):.3f}"))
    return rows
