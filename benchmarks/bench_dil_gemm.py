"""Paper Fig. 7: GEMM decomposition-inefficiency loss (DIL).

8-way and 64-way row (M) / column (K) sharding over the Table I GEMMs;
validates the paper's two observations: (1) 64-way > 8-way DIL, (2)
row-sharding hurts when M < K and column-sharding when M > K.
"""

from repro.core import MI300X, TABLE_I, gemm_dil, geomean

from benchmarks.common import row, timed


def run() -> list[str]:
    rows = []
    asym_ok = 0
    for sc in TABLE_I:
        g = sc.gemm
        vals = {}
        for ways in (8, 64):
            for axis in ("m", "k"):
                dil, us = timed(gemm_dil, g, MI300X, ways, axis)
                vals[(ways, axis)] = dil
                rows.append(
                    row(f"dil_gemm/{sc.name}/{ways}way_{axis}", us,
                        f"{dil:.3f}")
                )
        if g.m < g.k:
            asym_ok += vals[(64, "m")] > vals[(64, "k")]
        else:
            asym_ok += vals[(64, "k")] > vals[(64, "m")]
    rows.append(row("dil_gemm/asymmetry_match", 0.0, f"{asym_ok}/16"))
    gm8 = geomean(
        min(gemm_dil(s.gemm, MI300X, 8, "m"), gemm_dil(s.gemm, MI300X, 8, "k"))
        for s in TABLE_I
    )
    rows.append(row("dil_gemm/geomean_8way_best_axis", 0.0, f"{gm8:.3f}"))
    return rows
