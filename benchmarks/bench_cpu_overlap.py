"""Real CPU measurements: decomposition overhead exists here too.

Times a monolithic jnp matmul vs its 8-way row decomposition on this
host (a real, measured analogue of Fig. 7 at laptop scale), plus the
Pallas chunked GEMM in interpret mode vs its oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed


def run() -> list[str]:
    rng = np.random.default_rng(0)
    m, n, k = 1024, 1024, 1024
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    full = jax.jit(lambda a, b: a @ b)

    @jax.jit
    def chunked(a, b):
        outs = [a[i * (m // 8):(i + 1) * (m // 8)] @ b for i in range(8)]
        return jnp.concatenate(outs)

    r1, us_full = timed(
        lambda: jax.block_until_ready(full(x, w)), repeats=5
    )
    r2, us_chunk = timed(
        lambda: jax.block_until_ready(chunked(x, w)), repeats=5
    )
    dil = us_chunk / us_full
    return [
        row("cpu/matmul_full_1024", us_full, "1.000"),
        row("cpu/matmul_8way_rows", us_chunk, f"dil={dil:.3f}"),
    ]
