"""Observability overhead: the tracer's cost on the instrumented paths.

The instrumentation contract (repro.obs.trace) is that DISABLED tracing
is free enough to live on every hot path permanently — so the disabled
number is the one the CI regression gate watches (``obs/sweep_disabled``
joins THROUGHPUT_KEYS; it measures the same sharded-reduce sweep as
``sweepshard/reduce`` and must stay within the same ratio).  The
enabled numbers are recorded for trend tracking, not gated: tracing on
is a debugging/profiling mode, and its cost is dominated by span-arg
dict construction.

The signature stream and drift sentinel (this PR's additions) sit on
the *decision* hot path — the serving tier picks in tens of
microseconds — so their steady-state per-call costs are gated too:
``obs/signature_overhead`` (memoized ``observe_decision``) and
``obs/sentinel_step`` (one ``observe_residual``) join THROUGHPUT_KEYS.
The signature budget is <=5% of ``serve/decisions_per_s``.

  obs/span_disabled       — one ``trace.span(...)`` call, tracer off
                            (the per-site tax every instrumented call
                            pays forever)
  obs/span_enabled        — one span open+close, tracer on
  obs/sweep_disabled      — sharded sweep us/point, tracer off (GATED)
  obs/sweep_enabled       — same sweep, tracer + metrics recording on
  obs/overhead_pct        — enabled/disabled - 1, as a percentage
  obs/signature_overhead  — one memoized signature observe (GATED)
  obs/sentinel_step       — one sentinel residual step (GATED)
"""

from repro.core.machine import TPU_V5E
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape, machine_grid
from repro.obs import sentinel as obs_sentinel
from repro.obs import signature as obs_signature
from repro.obs import trace as obs_trace
from repro.sweep import sweep_grid, synthetic_batch

from benchmarks.common import row, timed

_S = 8192
_SPAN_CALLS = 100_000
_SIG_CALLS = 50_000
_SHARDS = 4


def _span_loop(n: int) -> None:
    span = obs_trace.span
    for _ in range(n):
        with span("bench", "obs", i=0):
            pass


def _sweep(sb, machines) -> None:
    sweep_grid(sb, machines, num_shards=_SHARDS, mode="reduce")


def _signature_loop(n: int) -> None:
    stream = obs_signature.get_signatures()
    gemm = GemmShape(4096, 4096, 4096, 2)
    sched = Schedule.UNIFORM_FUSED_1D
    for _ in range(n):
        stream.observe_decision(
            gemm, TPU_V5E, sched, group=8, source="bench",
        )


def _sentinel_loop(sentinel, n: int) -> None:
    for _ in range(n):
        sentinel.observe_residual(1.0e-3, 1.0e-3, key="bench")


def run() -> list[str]:
    machines = machine_grid(groups=(8,))
    sb = synthetic_batch(_S, seed=0)
    points = _S * len(machines)

    assert not obs_trace.enabled()
    _, us_off = timed(_span_loop, _SPAN_CALLS)
    obs_trace.enable()
    _, us_on = timed(_span_loop, _SPAN_CALLS)
    obs_trace.disable()

    # Warm calibration caches so both sweeps time pure evaluation.
    _sweep(sb, machines)
    _, sweep_off = timed(_sweep, sb, machines)
    obs_trace.enable()
    _, sweep_on = timed(_sweep, sb, machines)
    tracer = obs_trace.get_tracer()
    n_events = len(tracer.events) if tracer else 0
    obs_trace.disable()

    overhead = 100.0 * (sweep_on / sweep_off - 1.0)

    # Steady state: the decomposition is memoized after the first
    # sighting of the decision key, so this measures the permanent
    # per-decision tax (dict hit + locked float adds), not the one-time
    # analytic lowering.
    obs_signature.enable_signatures(None)
    _, us_sig = timed(_signature_loop, _SIG_CALLS)
    obs_signature._STREAM = None

    sentinel = obs_sentinel.Sentinel(obs_sentinel.SentinelConfig())
    _, us_sen = timed(_sentinel_loop, sentinel, _SIG_CALLS)

    return [
        row("obs/span_disabled", us_off / _SPAN_CALLS,
            f"{1e3 * us_off / _SPAN_CALLS:.1f} ns per disabled span"),
        row("obs/span_enabled", us_on / _SPAN_CALLS,
            f"{1e3 * us_on / _SPAN_CALLS:.0f} ns per recorded span"),
        row("obs/sweep_disabled", sweep_off / points,
            f"{1e6 * points / sweep_off:.0f} points/s, tracer off"),
        row("obs/sweep_enabled", sweep_on / points,
            f"{1e6 * points / sweep_on:.0f} points/s, tracer+metrics on "
            f"({n_events} events)"),
        row("obs/overhead_pct", 0.0,
            f"{overhead:.1f}% sweep slowdown with tracing enabled"),
        row("obs/signature_overhead", us_sig / _SIG_CALLS,
            f"{1e3 * us_sig / _SIG_CALLS:.0f} ns per memoized "
            f"signature observe"),
        row("obs/sentinel_step", us_sen / _SIG_CALLS,
            f"{1e3 * us_sen / _SIG_CALLS:.0f} ns per sentinel "
            f"residual step"),
    ]
