"""Framework integration table: the bespoke FiCCO schedule the heuristic
assigns to each assigned architecture's data-dependent AG->GEMMs on the
TPU v5e production mesh (model axis g=16), per input shape.

This is what `overlap.mode = ficco_auto` executes inside the models —
the paper's "frameworks and runtimes pick bespoke schedules" realized
over the full architecture pool.
"""

from repro.configs import ARCHS, SHAPES, get_config
from repro.core import TPU_V5E, GemmShape, select_schedule

from benchmarks.common import row


def _tp_gemms(cfg, shape):
    """The TP-SP AG->GEMM pairs of one block (global dims)."""
    b, s = shape.global_batch, shape.seq_len
    dp = 16  # data axis
    m = (b // dp if b >= dp else b) * s  # per-replica token rows
    gemms = {}
    if cfg.d_ff:
        gemms["mlp_up"] = GemmShape(m, cfg.d_ff, cfg.d_model)
    h = cfg.num_heads * cfg.resolved_head_dim
    gemms["attn_qkv"] = GemmShape(
        m, h + 2 * cfg.num_kv_heads * cfg.resolved_head_dim, cfg.d_model
    )
    if cfg.moe and cfg.moe.num_shared_experts:
        gemms["shared_expert"] = GemmShape(
            m, cfg.moe.d_ff_expert * cfg.moe.num_shared_experts, cfg.d_model
        )
    return gemms


def run() -> list[str]:
    rows = []
    shape = SHAPES["train_4k"]
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        picks = []
        for name, g in _tp_gemms(cfg, shape).items():
            dec = select_schedule(g, TPU_V5E)
            picks.append(f"{name}={dec.schedule.value}")
        rows.append(row(f"arch_schedules/{arch}", 0.0, " ".join(picks)))
    return rows
