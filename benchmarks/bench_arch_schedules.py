"""Framework integration table: the bespoke FiCCO schedule the heuristic
assigns to each assigned architecture's data-dependent AG->GEMMs on the
TPU v5e production mesh (model axis g=16), per input shape.

This is what `overlap.mode = ficco_auto` executes inside the models —
the paper's "frameworks and runtimes pick bespoke schedules" realized
over the full architecture pool.  All GEMMs across all architectures are
classified in ONE ``select_schedule_batch`` call.
"""

from repro.configs import ARCHS, SHAPES, get_config
from repro.core import TPU_V5E, GemmShape, select_schedule_batch
from repro.core.batch import GRID_SCHEDULES, ScenarioBatch
from repro.core.workload import tp_gemms, tp_token_rows

from benchmarks.common import row


def run() -> list[str]:
    shape = SHAPES["train_4k"]
    m = tp_token_rows(shape.global_batch, shape.seq_len)
    labels: list[tuple[str, str]] = []
    gemms: list[GemmShape] = []
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for name, g in tp_gemms(cfg, m).items():
            labels.append((arch, name))
            gemms.append(g)
    sb = ScenarioBatch.from_gemms(gemms)
    picks = select_schedule_batch(sb.m, sb.n, sb.k, sb.dtype_bytes, TPU_V5E)

    rows = []
    per_arch: dict[str, list[str]] = {}
    for (arch, name), idx in zip(labels, picks):
        per_arch.setdefault(arch, []).append(
            f"{name}={GRID_SCHEDULES[int(idx)].value}"
        )
    for arch in sorted(per_arch):
        rows.append(
            row(f"arch_schedules/{arch}", 0.0, " ".join(per_arch[arch]))
        )
    return rows
