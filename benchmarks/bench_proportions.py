"""Paper Fig. 10: proportion of DIL vs CIL per scenario.

Higher OTB+MT scenarios shift toward CIL (8-way); 64-way stays DIL-heavy.
"""

from repro.core import MI300X, TABLE_I, gemm_cil, gemm_dil

from benchmarks.common import row


def run() -> list[str]:
    rows = []
    for ways in (8, 64):
        for sc in sorted(TABLE_I, key=lambda s: s.gemm.flops):
            dil = gemm_dil(sc.gemm, MI300X, ways, "m") - 1.0
            cil = gemm_cil(sc.gemm.shard(ways, "m"), MI300X, degree=3) - 1.0
            tot = max(dil + cil, 1e-9)
            rows.append(
                row(f"proportions/{ways}way/{sc.name}", 0.0,
                    f"dil={dil/tot:.2f} cil={cil/tot:.2f}")
            )
    return rows
