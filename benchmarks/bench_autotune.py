"""repro.autotune: jitted-vs-NumPy sweep throughput + tuner hit rate.

Three sections:

  * **sweep**: the full scenario-grid x machine-grid design space through
    the NumPy engine (``repro.core.batch``) and the jitted engine
    (``repro.autotune.jaxgrid``), compile time reported separately from
    steady-state throughput (the compile amortizes over a scheduling
    loop's lifetime).
  * **tuner**: cold-pass (analytic model per key) vs warm-pass
    (persistent-cache hit per key) lookup cost over the Table-I + 48
    synthetic distinct GEMM keys, plus the hit rate.
  * **calibrate**: gradient TAU calibration (a few Adam steps on the
    soft decision tree) vs the discrete candidate search it replaces.
"""

import tempfile
import time

from repro.core import TABLE_I, MI300X, machine_grid, scenario_grid, \
    synthetic_scenarios
from repro.core.batch import ScenarioBatch, evaluate_grid as np_grid

from benchmarks.common import row


def run() -> list[str]:
    from repro.autotune import (
        Autotuner,
        AutotuneCache,
        calibrate_tau,
        evaluate_grid_jax,
    )

    scenarios = scenario_grid()
    machines = machine_grid()
    sb = ScenarioBatch.from_scenarios(scenarios)
    points = len(scenarios) * len(machines)

    # -- sweep throughput ------------------------------------------------
    np_grid(sb, machines)  # warm calibration caches for both paths
    t0 = time.perf_counter()
    evaluate_grid_jax(sb, machines)
    t_compile = time.perf_counter() - t0

    t_np = min(
        _timed(lambda: np_grid(sb, machines)) for _ in range(3)
    )
    t_jax = min(
        _timed(lambda: evaluate_grid_jax(sb, machines)) for _ in range(3)
    )
    rows = [
        row("autotune/sweep_points", 0.0,
            f"{len(scenarios)}x{len(machines)}={points}"),
        row("autotune/numpy_sweep", 1e6 * t_np / points,
            f"{points / t_np:.0f} scenarios/s"),
        row("autotune/jax_sweep", 1e6 * t_jax / points,
            f"{points / t_jax:.0f} scenarios/s "
            f"(compile {t_compile:.2f}s, amortized)"),
        row("autotune/jit_speedup", 0.0,
            f"{t_np / t_jax:.1f}x over NumPy engine"),
    ]

    # -- tuner hit rate --------------------------------------------------
    keys = [sc.gemm for sc in (*TABLE_I, *synthetic_scenarios(48))]
    with tempfile.TemporaryDirectory() as d:
        tuner = Autotuner(cache=AutotuneCache(path=f"{d}/bench.json"))
        t0 = time.perf_counter()
        for g in keys:
            tuner.pick(g, MI300X)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        for g in keys:
            tuner.pick(g, MI300X)
        t_warm = time.perf_counter() - t0
        hit_rate = tuner.hit_rate
        # fresh tuner, same backing file: the persistence round-trip
        tuner2 = Autotuner(cache=AutotuneCache(path=f"{d}/bench.json"))
        for g in keys:
            tuner2.pick(g, MI300X)
        persisted_rate = tuner2.hit_rate
    rows += [
        row("autotune/tuner_cold", 1e6 * t_cold / len(keys),
            "analytic model per distinct key"),
        row("autotune/tuner_warm", 1e6 * t_warm / len(keys),
            "persistent-cache hit per key"),
        row("autotune/tuner_hit_rate", 0.0,
            f"{100 * hit_rate:.0f}% after warmup; fresh process "
            f"{100 * persisted_rate:.0f}% from disk"),
    ]

    # -- gradient TAU calibration ---------------------------------------
    t0 = time.perf_counter()
    tau = calibrate_tau(MI300X, TABLE_I)
    t_cal = time.perf_counter() - t0
    rows.append(
        row("autotune/calibrate_tau_grad", 1e6 * t_cal,
            f"tau={tau:.4f} (Adam on the soft decision tree)")
    )
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
