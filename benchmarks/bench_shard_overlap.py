"""Paper Fig. 13: shard-based overlap deficiencies on a full mesh.

Ideal speedup follows a bell curve in the GEMM/comm time ratio; shard
P2P under-utilizes links (~(g-1)x comm slowdown) and never wins.
"""

from repro.core import MI300X, TABLE_I, Schedule, simulate
from repro.core.inefficiency import ag_serial_time, p2p_step_time

from benchmarks.common import row, timed


def run() -> list[str]:
    rows = []
    worst = 1.0
    for sc in sorted(
        TABLE_I,
        key=lambda s: simulate(s.gemm, MI300X, Schedule.SERIAL).serial_gemm
        / simulate(s.gemm, MI300X, Schedule.SERIAL).serial_comm,
    ):
        r, us = timed(simulate, sc.gemm, MI300X, Schedule.SHARD_P2P)
        ratio = r.serial_gemm / r.serial_comm
        worst = min(worst, r.speedup)
        rows.append(
            row(f"shard_overlap/{sc.name}", us,
                f"ratio={ratio:.2f} ideal={r.ideal_speedup:.2f} "
                f"shard_p2p={r.speedup:.2f}")
        )
    mk = 1 << 30
    comm_slow = (
        (MI300X.group - 1) * p2p_step_time(mk / MI300X.group, MI300X)
        / ag_serial_time(mk, MI300X)
    )
    rows.append(row("shard_overlap/comm_slowdown", 0.0, f"{comm_slow:.1f}x"))
    rows.append(row("shard_overlap/worst_speedup", 0.0, f"{worst:.2f}"))
    return rows
