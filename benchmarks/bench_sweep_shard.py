"""Sharded sweep subsystem: per-shard throughput + scaling efficiency.

Pushes a synthetic scenario batch through ``repro.sweep.sweep_grid`` in
reduce mode (the memory-bounded form that 1e6-1e7-point sweeps use) and
reports:

  * ``sweepshard/reduce``     — us per (scenario, machine) point through
    the sharded path (1 shard) — the engine-throughput key the
    regression gate watches;
  * ``sweepshard/sharded8``   — the same sweep over 8 shards;
  * ``sweepshard/efficiency`` — t(1 shard) / t(8 shards): sharding
    overhead (plan + slicing + per-shard summaries) as a fraction of
    useful work.  ~1.0 means the scenario axis scales freely; this is
    the per-process number multi-host deployments multiply out.
"""

import time

from repro.core.workload import machine_grid
from repro.sweep import sweep_grid, synthetic_batch

from benchmarks.common import row

_S = 32768
_SHARDS = 8


def _timed_sweep(sb, machines, n_shards: int) -> float:
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        sweep_grid(sb, machines, num_shards=n_shards, mode="reduce")
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[str]:
    machines = machine_grid(groups=(8,))
    sb = synthetic_batch(_S, seed=0)
    points = _S * len(machines)

    # Warm per-machine calibration caches so shards time pure evaluation.
    sweep_grid(sb, machines, num_shards=1, mode="reduce")

    t1 = _timed_sweep(sb, machines, 1)
    tn = _timed_sweep(sb, machines, _SHARDS)
    eff = t1 / tn

    res = sweep_grid(sb, machines, num_shards=_SHARDS, mode="reduce")
    merged = res.summary()

    return [
        row("sweepshard/points", 0.0,
            f"{_S}x{len(machines)}={points} points over {_SHARDS} shards"),
        row("sweepshard/reduce", 1e6 * t1 / points,
            f"{points / t1:.0f} points/s unsharded (1 shard)"),
        row("sweepshard/sharded8", 1e6 * tn / points,
            f"{points / tn:.0f} points/s over {_SHARDS} shards"),
        row("sweepshard/efficiency", 0.0,
            f"{eff:.2f}x t1/t{_SHARDS} (1.0 == free sharding); "
            f"per-shard {merged['scenarios_per_sec']:.0f} scenarios/s"),
    ]
