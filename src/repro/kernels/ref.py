"""Pure-jnp oracles for every Pallas kernel (single-device semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for chunked_matmul."""
    return (
        x.astype(jnp.float32) @ w.astype(jnp.float32)
    ).astype(x.dtype)


def accumulate_matmul_ref(
    c: jax.Array, x: jax.Array, w: jax.Array
) -> jax.Array:
    """Oracle for accumulate_matmul (C += A @ B in fp32)."""
    return (
        c.astype(jnp.float32)
        + x.astype(jnp.float32) @ w.astype(jnp.float32)
    ).astype(c.dtype)


def a2a_chunk_exchange_ref(chunk: jax.Array, *, axis_name: str) -> jax.Array:
    """Oracle for a2a_chunk_exchange: the lax all-gather of the chunk."""
    return lax.all_gather(chunk, axis_name, axis=0)


def ag_matmul_ref(x: jax.Array, w: jax.Array, *, axis_name: str) -> jax.Array:
    """Oracle for ficco_ag_matmul_fused / ficco_uniform_fused_1d_dma."""
    x_full = lax.all_gather(x, axis_name, axis=0, tiled=True)
    return x_full @ w


__all__ = [
    "matmul_ref",
    "accumulate_matmul_ref",
    "a2a_chunk_exchange_ref",
    "ag_matmul_ref",
]
