"""Pallas TPU kernels for FiCCO's performance-critical layers.

  * dma_exchange    — the DMA-offloaded chunk all-to-all (the paper's core
                      mechanism, adapted to TPU ICI DMA engines)
  * ficco_ag_matmul — beyond-paper fused DMA+MXU pipeline (one kernel)
  * chunked_gemm    — accumulating C += A @ B with VMEM BlockSpec tiling
                      (the 2D schedule's accumulative GEMM)
  * ops / ref       — jit'd wrappers + pure-jnp oracles
"""
