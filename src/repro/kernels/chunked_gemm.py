"""Accumulating chunked GEMM: C += A @ B with explicit VMEM tiling.

The 2D (column-sharded) FiCCO schedule needs accumulative GEMM kernels
(paper §IV-C1: "column-sharding necessitates accumulative GEMM kernels").
On TPU we express this as a Pallas kernel whose grid walks (M tiles,
N tiles, K chunks); the fp32 accumulator tile lives in VMEM across the K
steps (revisiting grid dimension), and only the final K step writes the
output block — so one kernel invocation both performs the chunk GEMM and
folds it into C without a round-trip through HBM per chunk.

Block shapes default to MXU-aligned (128 multiples) and are chosen so
(bm*bk + bk*bn + bm*bn*4) stays well inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def chunked_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    variant=None,
) -> jax.Array:
    """out = x @ w with K accumulated in VMEM across grid steps.

    x: (M, K); w: (K, N) -> (M, N).  All dims must divide their blocks.
    A :class:`repro.tune.KernelVariant` passed as ``variant`` overrides
    the three block arguments with its tile.
    """
    if variant is not None:
        block_m = int(variant.block_m)
        block_n = int(variant.block_n)
        block_k = int(variant.block_k)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"({m},{n},{k}) not divisible by blocks "
            f"({block_m},{block_n},{block_k})"
        )
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)


def accumulate_matmul(
    c: jax.Array,
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """C += x @ w — the 2D schedule's per-step accumulating GEMM.

    Implemented with input/output aliasing so C is updated in place
    (no extra HBM copy of the accumulator between FiCCO steps).
    """
    m, k = x.shape
    _, n = w.shape
    if m % block_m or n % block_n or k % block_k:
        return (c.astype(jnp.float32) + x @ w).astype(c.dtype)
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    def kernel(c_ref, x_ref, w_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = c_ref[...].astype(jnp.float32)

        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

        @pl.when(pl.program_id(2) == n_k - 1)
        def _done():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(c, x, w)


__all__ = ["chunked_matmul", "accumulate_matmul"]
