"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True automatically on non-TPU backends so the
same call sites work on this CPU container (Mosaic interpreter) and on real
TPUs (compiled Mosaic).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.chunked_gemm import accumulate_matmul, chunked_matmul
from repro.kernels.dma_exchange import (
    a2a_chunk_exchange,
    ficco_uniform_fused_1d_dma,
)
from repro.kernels.ficco_ag_matmul import ficco_ag_matmul_fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(x, w, *, block_m=128, block_n=128, block_k=128):
    return chunked_matmul(
        x, w,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul_accumulate(c, x, w, *, block_m=128, block_n=128, block_k=128):
    return accumulate_matmul(
        c, x, w,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=not _on_tpu(),
    )


def chunk_exchange(chunk, *, axis_name, group):
    """shard_map-internal: DMA all-to-all of one FiCCO chunk."""
    return a2a_chunk_exchange(
        chunk, axis_name=axis_name, group=group, interpret=not _on_tpu()
    )


def ag_matmul_dma(x, w, *, axis_name):
    """shard_map-internal: uniform-fused-1D with Pallas DMA comm."""
    return ficco_uniform_fused_1d_dma(
        x, w, axis_name=axis_name, interpret=not _on_tpu()
    )


def ag_matmul_fused(x, w, *, axis_name):
    """shard_map-internal: fully fused DMA+MXU pipeline (beyond-paper)."""
    return ficco_ag_matmul_fused(
        x, w, axis_name=axis_name, interpret=not _on_tpu()
    )


__all__ = [
    "matmul",
    "matmul_accumulate",
    "chunk_exchange",
    "ag_matmul_dma",
    "ag_matmul_fused",
]
