"""FiCCO chunk exchange on TPU ICI DMA engines (Pallas).

This is the paper's "offload communication to GPU DMA engines" adapted to
TPU: one FiCCO step's *simultaneous all-to-all* — every device pushes its
current chunk to every peer — implemented with
``pltpu.make_async_remote_copy``.  No compute core (MXU/VPU) cycles move
bytes; the per-chip DMA engines drive the ICI links directly, the TPU
analogue of ``hipMemcpyDtoDAsync`` on a side stream (and the reason the
paper's *compute interference* term vanishes by construction on TPU).

The kernel is the communication half of the FiCCO schedules; the GEMMs stay
ordinary XLA/MXU matmuls — mirroring the paper's design rule of *not*
modifying the optimized GEMM library ("we make no changes to the existing
GEMM kernels").  ``ficco_ag_matmul.py`` additionally provides the fused
beyond-paper variant where DMA and MXU pipeline inside one kernel.

Validated on CPU with the Mosaic TPU interpreter
(``pltpu.InterpretParams``), which simulates cross-device DMAs faithfully.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (
    axis_size,
    remote_device_id,
    tpu_compiler_params,
    tpu_interpret,
)


def _exchange_kernel(
    group: int,
    axis_name: str,
    chunk_ref,
    out_ref,
    send_sems,
    recv_sems,
):
    """Push ``chunk_ref`` to slot ``my_id`` of every peer's ``out_ref``.

    Slot layout: out[src] = chunk that device ``src`` held, so after the
    barrier every device owns the identical (g, m_c, K) gathered buffer.
    Traffic is fully symmetric: g-1 egress and g-1 ingress DMAs per device,
    saturating every ICI link of the axis — the paper's full-mesh argument.
    """
    me = lax.axis_index(axis_name)

    # Local slot: plain on-device DMA (HBM -> HBM), no ICI traffic.
    local = pltpu.make_async_copy(
        chunk_ref, out_ref.at[me], recv_sems.at[group - 1]
    )
    local.start()

    copies = []
    for i in range(1, group):
        peer = lax.rem(me + i, group)
        device_id, id_type = remote_device_id(peer)
        rc = pltpu.make_async_remote_copy(
            src_ref=chunk_ref,
            dst_ref=out_ref.at[me],
            send_sem=send_sems.at[i - 1],
            recv_sem=recv_sems.at[i - 1],
            device_id=device_id,
            device_id_type=id_type,
        )
        rc.start()
        copies.append(rc)

    # Wait: our g-1 sends drained, then the g-1 matching ingress DMAs
    # (peer j's copy into out[j] signals recv_sems[(me - j) % g - 1]).
    for rc in copies:
        rc.wait_send()
    for rc in copies:
        rc.wait_recv()
    local.wait()


def a2a_chunk_exchange(
    chunk: jax.Array,
    *,
    axis_name: str,
    group: int,
    interpret: bool = False,
) -> jax.Array:
    """One FiCCO exchange step: (m_c, K) chunk -> (g, m_c, K) gathered.

    Must be called inside shard_map over ``axis_name`` with ``group``
    devices.  Equivalent to ``lax.all_gather(chunk, axis_name, axis=0)``
    but executed entirely by the ICI DMA engines from a single kernel.
    """
    kernel = functools.partial(_exchange_kernel, group, axis_name)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((group, *chunk.shape), chunk.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA((group - 1,)),
            pltpu.SemaphoreType.DMA((group,)),
        ],
        interpret=tpu_interpret(interpret),
        compiler_params=tpu_compiler_params(
            collective_id=0, has_side_effects=True
        ),
    )(chunk)


def ficco_uniform_fused_1d_dma(
    x: jax.Array,
    w: jax.Array,
    *,
    axis_name: str,
    interpret: bool = False,
) -> jax.Array:
    """uniform-fused-1D with DMA-offloaded communication.

    Per step: Pallas DMA all-to-all of chunk ``s`` (communication), then a
    standard XLA GEMM on the gathered step buffer (compute) — library GEMMs
    untouched, exactly the paper's realization strategy (§VI-A).  XLA's
    scheduler overlaps step s+1's kernel DMAs with step s's matmul.
    """
    g = axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    m_c = m_s // g
    chunks = x.reshape(g, m_c, k)
    out = jnp.zeros((g * m_s, n_local), dtype=jnp.result_type(x, w))
    for s in range(g):
        gathered = a2a_chunk_exchange(
            chunks[s], axis_name=axis_name, group=g, interpret=interpret
        )
        step_out = (gathered.reshape(g * m_c, k) @ w).reshape(
            g, m_c, n_local
        )
        for d in range(g):
            out = lax.dynamic_update_slice(
                out, step_out[d].astype(out.dtype), (d * m_s + s * m_c, 0)
            )
    return out


__all__ = ["a2a_chunk_exchange", "ficco_uniform_fused_1d_dma"]
