"""FiCCO chunk exchange on TPU ICI DMA engines (Pallas).

This is the paper's "offload communication to GPU DMA engines" adapted to
TPU: one FiCCO step's *simultaneous all-to-all* — every device pushes its
current chunk to every peer — implemented with
``pltpu.make_async_remote_copy``.  No compute core (MXU/VPU) cycles move
bytes; the per-chip DMA engines drive the ICI links directly, the TPU
analogue of ``hipMemcpyDtoDAsync`` on a side stream (and the reason the
paper's *compute interference* term vanishes by construction on TPU).

The kernel is the communication half of the FiCCO schedules; the GEMMs stay
ordinary XLA/MXU matmuls — mirroring the paper's design rule of *not*
modifying the optimized GEMM library ("we make no changes to the existing
GEMM kernels").  ``ficco_ag_matmul.py`` additionally provides the fused
beyond-paper variant where DMA and MXU pipeline inside one kernel.

Validated on CPU with the Mosaic TPU interpreter
(``pltpu.InterpretParams``), which simulates cross-device DMAs faithfully.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (
    axis_size,
    remote_device_id,
    tpu_compiler_params,
    tpu_interpret,
)


def _exchange_kernel(
    group: int,
    axis_name: str,
    reverse: bool,
    chunk_ref,
    out_ref,
    send_sems,
    recv_sems,
):
    """Push ``chunk_ref`` to slot ``my_id`` of every peer's ``out_ref``.

    Slot layout: out[src] = chunk that device ``src`` held, so after the
    barrier every device owns the identical (g, m_c, K) gathered buffer.
    Traffic is fully symmetric: g-1 egress and g-1 ingress DMAs per device,
    saturating every ICI link of the axis — the paper's full-mesh argument.

    ``reverse`` issues the egress DMAs to peers in descending offset
    order; every device uses the same order, so each (sender, receiver,
    semaphore index) pairing stays unique and results are unchanged.
    """
    me = lax.axis_index(axis_name)

    # Local slot: plain on-device DMA (HBM -> HBM), no ICI traffic.
    local = pltpu.make_async_copy(
        chunk_ref, out_ref.at[me], recv_sems.at[group - 1]
    )
    local.start()

    copies = []
    for i in range(1, group):
        peer = lax.rem(me + (group - i if reverse else i), group)
        device_id, id_type = remote_device_id(peer)
        rc = pltpu.make_async_remote_copy(
            src_ref=chunk_ref,
            dst_ref=out_ref.at[me],
            send_sem=send_sems.at[i - 1],
            recv_sem=recv_sems.at[i - 1],
            device_id=device_id,
            device_id_type=id_type,
        )
        rc.start()
        copies.append(rc)

    # Wait: our g-1 sends drained, then the g-1 matching ingress DMAs
    # (peer j's copy into out[j] signals recv_sems[(me - j) % g - 1]).
    for rc in copies:
        rc.wait_send()
    for rc in copies:
        rc.wait_recv()
    local.wait()


def a2a_chunk_exchange(
    chunk: jax.Array,
    *,
    axis_name: str,
    group: int,
    interpret: bool = False,
    reverse: bool = False,
) -> jax.Array:
    """One FiCCO exchange step: (m_c, K) chunk -> (g, m_c, K) gathered.

    Must be called inside shard_map over ``axis_name`` with ``group``
    devices.  Equivalent to ``lax.all_gather(chunk, axis_name, axis=0)``
    but executed entirely by the ICI DMA engines from a single kernel.
    """
    kernel = functools.partial(_exchange_kernel, group, axis_name, reverse)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((group, *chunk.shape), chunk.dtype),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA((group - 1,)),
            pltpu.SemaphoreType.DMA((group,)),
        ],
        interpret=tpu_interpret(interpret),
        compiler_params=tpu_compiler_params(
            collective_id=0, has_side_effects=True
        ),
    )(chunk)


def ficco_uniform_fused_1d_dma(
    x: jax.Array,
    w: jax.Array,
    *,
    axis_name: str,
    interpret: bool = False,
    variant=None,
) -> jax.Array:
    """uniform-fused-1D with DMA-offloaded communication.

    Per step: Pallas DMA all-to-all of chunk ``s`` (communication), then a
    standard XLA GEMM on the gathered step buffer (compute) — library GEMMs
    untouched, exactly the paper's realization strategy (§VI-A).  XLA's
    scheduler overlaps step s+1's kernel DMAs with step s's matmul.

    ``variant`` (a :class:`repro.tune.KernelVariant`) picks the chunk
    count, the step-GEMM tile (routed through
    :func:`repro.kernels.chunked_gemm.chunked_matmul` with a full-K
    contraction, so row dots — and results — are unchanged), and the DMA
    dispatch order; ``None`` resolves the promoted default from
    :mod:`repro.tune.registry`.
    """
    g = axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    if variant is None:
        from repro.tune.registry import resolve_variant

        variant = resolve_variant("dma_exchange", group=g)
    steps = int(variant.chunks)
    if m_s % steps:
        steps = g  # promoted cut doesn't divide this shard; classic cut
    m_c = m_s // steps
    reverse = variant.dispatch_order == "reverse"
    chunks = x.reshape(steps, m_c, k)
    rows = g * m_c
    # Tile the step GEMM only when the variant's blocks divide it evenly;
    # K stays un-blocked so each output row remains one full-K dot.
    blocked = (
        rows % variant.block_m == 0
        and n_local % variant.block_n == 0
        and (variant.block_m < rows or variant.block_n < n_local)
    )
    out = jnp.zeros((g * m_s, n_local), dtype=jnp.result_type(x, w))
    order = list(range(steps))
    if reverse:
        order.reverse()
    for s in order:
        gathered = a2a_chunk_exchange(
            chunks[s],
            axis_name=axis_name,
            group=g,
            interpret=interpret,
            reverse=reverse,
        )
        flat = gathered.reshape(rows, k)
        if blocked:
            from repro.kernels.chunked_gemm import chunked_matmul

            step_out = chunked_matmul(
                flat,
                w,
                block_m=variant.block_m,
                block_n=variant.block_n,
                block_k=k,
                interpret=interpret,
            ).reshape(g, m_c, n_local)
        else:
            step_out = (flat @ w).reshape(g, m_c, n_local)
        for d in range(g):
            out = lax.dynamic_update_slice(
                out, step_out[d].astype(out.dtype), (d * m_s + s * m_c, 0)
            )
    return out


__all__ = ["a2a_chunk_exchange", "ficco_uniform_fused_1d_dma"]
