"""Fused FiCCO all-gather-matmul: DMA + MXU pipelined in ONE kernel.

Beyond-paper, TPU-native variant (DESIGN.md §2): instead of alternating a
communication kernel and a library GEMM (the paper's realization, kept in
``dma_exchange.py``), this kernel double-buffers the chunk exchange against
the step GEMM *inside* a single ``pallas_call``:

    step s:  start all-to-all DMAs for chunk s+1  (ICI DMA engines)
             wait chunk s's ingress DMAs
             MXU matmul on step-s gathered buffer -> output rows

The DMAs for step s+1 fly while the MXU multiplies step s — the contention
surface is only HBM bandwidth (the paper's residual CIL-memory term); there is no
kernel-launch gap, no gather kernel (chunks are DMA'd *into place* in the
step buffer), and no scatter kernel (the output rows are written directly).
This removes the Gather/Scatter streams that give uniform-fused-1D its HIGH
CIL signature — measured in EXPERIMENTS.md §Perf as the `dma_into_place`
optimization.

Layout: x shard (m_s, K) split into g chunks of (m_c, K); w (K, n_local) is
brought into VMEM tile by tile for the step GEMM; outputs are the
(M = g*m_s, n_local) rows this device owns after the gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import (
    axis_size,
    remote_device_id,
    remote_semaphore_signal,
    tpu_compiler_params,
    tpu_interpret,
)


def _fused_kernel(
    group: int,
    axis_name: str,
    steps: int,
    depth: int,
    reverse: bool,
    m_c: int,
    k: int,
    n_local: int,
    x_ref,  # (steps, m_c, K) local chunks, ANY/HBM
    w_ref,  # (K, n_local), ANY/HBM
    o_ref,  # (steps, g, m_c, n_local): [step, src] output blocks, ANY/HBM
    step_bufs,  # VMEM (depth, g, m_c, K): slot-buffered gathered steps
    w_vmem,  # VMEM (K, n_local)
    out_vmem,  # VMEM (depth, g, m_c, n_local): slot-buffered egress staging
    send_sems,  # DMA (depth, g-1)
    recv_sems,  # DMA (depth, g)
    out_sems,  # DMA (depth,): per-slot output egress
    ready_sems,  # REGULAR (depth,): receiver->sender slot flow control
):
    me = lax.axis_index(axis_name)

    # Dispatch order: which chunk each pipeline position carries.  Output
    # blocks are indexed by the chunk id, so reversing the issue order
    # changes overlap, not results.
    order = list(range(steps))
    if reverse:
        order.reverse()

    w_copy = pltpu.make_async_copy(w_ref, w_vmem, recv_sems.at[0, group - 1])
    w_copy.start()

    def start_step(s: int, slot: int, wait_slot: bool):
        """Send chunk s to all peers; receive into step_bufs[slot].

        Flow control: a slot is reused every ``depth`` steps.  Before
        pushing a position ``>= depth`` into a peer's slot we must have
        that peer's release signal from its consumption ``depth``
        positions earlier (g-1 signals total) — otherwise a fast sender
        can overwrite a buffer a slow receiver is still multiplying from
        (a data race the Mosaic interpreter's race detector reproduces if
        this wait is removed).
        """
        if wait_slot:
            pltpu.semaphore_wait(ready_sems.at[slot], group - 1)
        local = pltpu.make_async_copy(
            x_ref.at[s],
            step_bufs.at[slot, me],
            recv_sems.at[slot, group - 1],
        )
        local.start()
        descs = [local]
        for i in range(1, group):
            peer = lax.rem(me + i, group)
            device_id, id_type = remote_device_id(peer)
            rc = pltpu.make_async_remote_copy(
                src_ref=x_ref.at[s],
                dst_ref=step_bufs.at[slot, me],
                send_sem=send_sems.at[slot, i - 1],
                recv_sem=recv_sems.at[slot, i - 1],
                device_id=device_id,
                device_id_type=id_type,
            )
            rc.start()
            descs.append(rc)
        return descs

    def wait_step(descs):
        for rc in descs[1:]:
            rc.wait_send()
        for rc in descs[1:]:
            rc.wait_recv()
        descs[0].wait()

    def release_slot(slot: int):
        """Tell every peer our copy of this slot is consumed."""
        for i in range(1, group):
            peer = lax.rem(me + i, group)
            remote_semaphore_signal(ready_sems.at[slot], 1, peer)

    w_copy.wait()
    inflight = start_step(order[0], 0, False)
    # Output egress is slot-buffered like the ingress: a position's (g,
    # m_c, n_local) block drains to HBM while later positions' exchange
    # and matmul proceed.  A slot is only rewritten after its previous
    # drain (``depth`` positions earlier) completed — without that wait a
    # fast MXU could clobber bytes the DMA engine is still reading.
    out_copies: list = [None] * depth
    for pos, s in enumerate(order):
        slot = pos % depth
        wait_step(inflight)
        # Load (consume) the gathered buffer, release the slot to peers,
        # kick off the next exchange, THEN multiply — so the next
        # position's DMAs fly while the MXU works on this one.
        gathered = step_bufs[slot].reshape(group * m_c, k)
        if pos + depth < steps:
            release_slot(slot)
        if pos + 1 < steps:
            inflight = start_step(
                order[pos + 1], (pos + 1) % depth, pos + 1 >= depth
            )
        step_out = jnp.dot(
            gathered, w_vmem[...], preferred_element_type=jnp.float32
        )
        if out_copies[slot] is not None:
            out_copies[slot].wait()
        out_vmem[slot] = step_out.reshape(group, m_c, n_local).astype(
            out_vmem.dtype
        )
        out_copy = pltpu.make_async_copy(
            out_vmem.at[slot], o_ref.at[s], out_sems.at[slot]
        )
        out_copy.start()
        out_copies[slot] = out_copy
    for out_copy in out_copies:
        if out_copy is not None:
            out_copy.wait()


def ficco_ag_matmul_fused(
    x: jax.Array,
    w: jax.Array,
    *,
    axis_name: str,
    interpret: bool = False,
    variant=None,
) -> jax.Array:
    """Fused uniform-fused-1D: returns (M, n_local) like the reference.

    Call inside shard_map over ``axis_name``.  VMEM budget: the step buffer
    slots (depth * m_s/steps * g * K), the weight panel (K * n_local) and
    the slot-buffered per-step output must fit VMEM — production shapes
    tile K/N further; sizes used in tests and smoke configs fit
    comfortably.

    ``variant`` (a :class:`repro.tune.KernelVariant`) picks the chunk
    count, DMA buffer depth and dispatch order; ``None`` resolves the
    promoted default from :mod:`repro.tune.registry`.  Results are
    bit-identical across variants: each output row is one full-K dot.
    """
    g = axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    if variant is None:
        from repro.tune.registry import resolve_variant

        variant = resolve_variant("ficco_ag_matmul", group=g)
    steps = int(variant.chunks)
    if m_s % steps:
        steps = g  # promoted cut doesn't divide this shard; classic cut
    depth = max(2, min(int(variant.buffer_depth), steps))
    reverse = variant.dispatch_order == "reverse"
    m_c = m_s // steps
    chunks = x.reshape(steps, m_c, k)
    kernel = functools.partial(
        _fused_kernel, g, axis_name, steps, depth, reverse, m_c, k, n_local
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((steps, g, m_c, n_local), x.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        scratch_shapes=[
            pltpu.VMEM((depth, g, m_c, k), x.dtype),
            pltpu.VMEM((k, n_local), w.dtype),
            pltpu.VMEM((depth, g, m_c, n_local), x.dtype),
            pltpu.SemaphoreType.DMA((depth, g - 1)),
            pltpu.SemaphoreType.DMA((depth, g)),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.REGULAR((depth,)),
        ],
        interpret=tpu_interpret(interpret),
        compiler_params=tpu_compiler_params(
            collective_id=1, has_side_effects=True
        ),
    )(chunks, w)
    # out[s, d] = rows of source d, step s -> global row d*m_s + s*m_c.
    out = out.transpose(1, 0, 2, 3)  # (src, step, m_c, n)
    return out.reshape(g * m_s, n_local)


__all__ = ["ficco_ag_matmul_fused"]
