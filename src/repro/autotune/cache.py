"""Persistent on-disk cache for tuned schedule decisions.

One JSON file per (schema version, jax version): tuned decisions survive
processes, so the first process pays the analytic-model (or measured)
tuning cost and every later launcher/server starts with the winner.

Layout (human-readable on purpose — this is an operational artifact)::

    {
      "schema": 2,
      "jax": "0.4.37",
      "entries": {
        "tpu-v5e-axis16/g16/m65536/n4096/k8192/b2/u16": {
          "schedule": "hetero_unfused_1d",
          "source": "analytic",          # analytic | measured
          "model_total_s": 0.00123,      # analytic model's time for it
          "measured_total_s": null,      # wall time when source=measured
        },
        ...
      }
    }

Schema history:
  v1 (PR 2): keys were ``machine/gG/mM/nN/kK/bB`` — uniform schedules
      only.
  v2 (this PR): keys gained the ragged step-profile digest (``/u16`` for
      the uniform 16-step split, ``/skew2-8-<hash>`` etc. for skewed
      profiles), so tuned decisions are profile-specific.  v1 files are
      invalidated wholesale: they live under the old ``autotune-v1.json``
      name (never read by v2 code), and a v1 payload written at the v2
      path fails the schema check and is treated as empty — old entries
      can never surface under new keys.

Location: ``$REPRO_AUTOTUNE_CACHE_DIR`` if set, else
``~/.cache/repro_autotune``.  The test suite sets the env var to a
tmp dir (see ``tests/conftest.py``) so tier-1 runs never touch — or get
polluted by — the user's home cache.  ``scripts/clear_autotune_cache.py``
wipes it.

Writes are atomic (tempfile + ``os.replace``) and loads are tolerant: a
corrupt or version-mismatched file is treated as empty, never an error —
the cache is an accelerator, not a source of truth.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any

SCHEMA_VERSION = 2  # v2: ragged step-profile digest joined the key schema
_ENV_VAR = "REPRO_AUTOTUNE_CACHE_DIR"

# Artifact segment: non-decision payloads (learned gates, fitted machine
# models) share the store under a reserved key prefix.  TuneKey strings
# always start with a machine name segment, never this prefix, so tuner
# lookups and artifact lookups can never collide.
ARTIFACT_PREFIX = "__artifact__"


def artifact_key(kind: str, name: str) -> str:
    return f"{ARTIFACT_PREFIX}/{kind}/{name}"


def _jax_version() -> str:
    try:
        import jax

        return jax.__version__
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return "unknown"


def default_cache_dir() -> str:
    """$REPRO_AUTOTUNE_CACHE_DIR, else ~/.cache/repro_autotune."""
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro_autotune"
    )


def default_cache_path() -> str:
    return os.path.join(
        default_cache_dir(), f"autotune-v{SCHEMA_VERSION}.json"
    )


def _read_entries(path: str) -> dict[str, Any] | None:
    """Entries in the backing file, or None if absent/corrupt/stale."""
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(raw, dict):
        return None
    if raw.get("schema") != SCHEMA_VERSION:
        return None
    if raw.get("jax") != _jax_version():
        return None  # jax upgrade invalidates tuned decisions wholesale
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        return None
    return {k: v for k, v in entries.items() if isinstance(v, dict)}


@dataclasses.dataclass
class AutotuneCache:
    """Versioned persistent key -> tuned-decision store.

    Keys are produced by :class:`repro.autotune.tuner.TuneKey` and embed
    the machine name + group, so one file safely holds entries for many
    machines; the jax version stamps the whole file (a jax upgrade can
    change what the measured path compiles to, so tuned decisions are
    invalidated wholesale — re-tuning is cheap).
    """

    path: str | None = None
    entries: dict[str, dict[str, Any]] = dataclasses.field(
        default_factory=dict
    )
    _loaded_from_disk: bool = False

    def __post_init__(self):
        if self.path is None:
            self.path = default_cache_path()
        self.load()

    # -- persistence ----------------------------------------------------

    def load(self) -> None:
        """Read the backing file; silently start empty on any mismatch."""
        entries = _read_entries(self.path)
        self.entries = entries if entries is not None else {}
        self._loaded_from_disk = entries is not None

    def save(self) -> None:
        """Atomic write (tempfile + rename) of the whole store.

        Merge-on-save: entries another process persisted since our load
        are folded in first (ours win on key collision), so concurrent
        processes tuning disjoint keys don't clobber each other — the
        union survives, whoever writes last.
        """
        merged = {**(_read_entries(self.path) or {}), **self.entries}
        self.entries = merged
        d = os.path.dirname(self.path)
        os.makedirs(d, exist_ok=True)
        payload = {
            "schema": SCHEMA_VERSION,
            "jax": _jax_version(),
            "entries": merged,
        }
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def clear(self) -> None:
        self.entries = {}
        try:
            os.unlink(self.path)
        except OSError:
            pass

    # -- access ---------------------------------------------------------

    def get(self, key: str) -> dict[str, Any] | None:
        return self.entries.get(key)

    def put(
        self, key: str, entry: dict[str, Any], *, persist: bool = True
    ) -> None:
        self.entries[key] = entry
        if persist:
            self.save()

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    # -- artifact segment (learned gates, fitted machine models) --------

    def put_artifact(
        self,
        kind: str,
        name: str,
        payload: dict[str, Any],
        *,
        persist: bool = True,
    ) -> None:
        """Store a non-decision artifact (e.g. a ``repro.learn`` gate).

        Artifacts live in the same versioned file under the reserved
        ``__artifact__/`` key prefix, so they inherit the cache's
        atomic-write, merge-on-save and schema/jax-version invalidation
        behavior for free.
        """
        self.put(artifact_key(kind, name), payload, persist=persist)

    def get_artifact(self, kind: str, name: str) -> dict[str, Any] | None:
        return self.get(artifact_key(kind, name))

    def artifact_names(self, kind: str) -> tuple[str, ...]:
        prefix = f"{ARTIFACT_PREFIX}/{kind}/"
        return tuple(
            sorted(
                k[len(prefix):]
                for k in self.entries
                if k.startswith(prefix)
            )
        )

    def decision_entries(self) -> dict[str, dict[str, Any]]:
        """Tuned-decision entries only (artifact segment filtered out)."""
        return {
            k: v
            for k, v in self.entries.items()
            if not k.startswith(f"{ARTIFACT_PREFIX}/")
        }


__all__ = [
    "SCHEMA_VERSION",
    "ARTIFACT_PREFIX",
    "artifact_key",
    "AutotuneCache",
    "default_cache_dir",
    "default_cache_path",
]
