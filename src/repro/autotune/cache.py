"""Persistent on-disk cache for tuned schedule decisions.

One JSON file per (schema version, jax version): tuned decisions survive
processes, so the first process pays the analytic-model (or measured)
tuning cost and every later launcher/server starts with the winner.

Layout (human-readable on purpose — this is an operational artifact)::

    {
      "schema": 2,
      "jax": "0.4.37",
      "entries": {
        "tpu-v5e-axis16/g16/m65536/n4096/k8192/b2/u16": {
          "schedule": "hetero_unfused_1d",
          "source": "analytic",          # analytic | measured
          "model_total_s": 0.00123,      # analytic model's time for it
          "measured_total_s": null,      # wall time when source=measured
        },
        ...
      }
    }

Schema history:
  v1 (PR 2): keys were ``machine/gG/mM/nN/kK/bB`` — uniform schedules
      only.
  v2 (this PR): keys gained the ragged step-profile digest (``/u16`` for
      the uniform 16-step split, ``/skew2-8-<hash>`` etc. for skewed
      profiles), so tuned decisions are profile-specific.  v1 files are
      invalidated wholesale: they live under the old ``autotune-v1.json``
      name (never read by v2 code), and a v1 payload written at the v2
      path fails the schema check and is treated as empty — old entries
      can never surface under new keys.

Location: ``$REPRO_AUTOTUNE_CACHE_DIR`` if set, else
``~/.cache/repro_autotune``.  The test suite sets the env var to a
tmp dir (see ``tests/conftest.py``) so tier-1 runs never touch — or get
polluted by — the user's home cache.  ``scripts/clear_autotune_cache.py``
wipes it.

Writes are atomic (tempfile + ``os.replace``) and loads are tolerant: a
corrupt or version-mismatched file is treated as empty, never an error —
the cache is an accelerator, not a source of truth.

Concurrency + hot-path persistence:

* Every mutation and ``save()`` holds a per-instance re-entrant lock,
  so a background re-fit thread writing artifacts can never race a
  serving thread's ``put`` into a lost entry (``save`` snapshots,
  merges and swaps ``entries`` under the same lock the writers take).
* ``put(..., persist="defer")`` marks the store dirty instead of
  rewriting the whole JSON file — the eager ``persist=True`` path is
  O(store) disk I/O *per decision*, which is exactly what the serving
  hot path must not pay.  Deferred writes flush on ``flush()``, and
  every dirty cache still alive at interpreter exit is flushed by an
  ``atexit`` hook (best-effort: a flush into a vanished temp dir is
  swallowed).  Merge-on-save semantics are identical on both paths.
"""

from __future__ import annotations

import atexit
import dataclasses
import json
import os
import tempfile
import threading
import weakref
from typing import Any

SCHEMA_VERSION = 2  # v2: ragged step-profile digest joined the key schema
_ENV_VAR = "REPRO_AUTOTUNE_CACHE_DIR"

# Artifact segment: non-decision payloads (learned gates, fitted machine
# models) share the store under a reserved key prefix.  TuneKey strings
# always start with a machine name segment, never this prefix, so tuner
# lookups and artifact lookups can never collide.
ARTIFACT_PREFIX = "__artifact__"


def artifact_key(kind: str, name: str) -> str:
    return f"{ARTIFACT_PREFIX}/{kind}/{name}"


def _jax_version() -> str:
    try:
        import jax

        return jax.__version__
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return "unknown"


def default_cache_dir() -> str:
    """$REPRO_AUTOTUNE_CACHE_DIR, else ~/.cache/repro_autotune."""
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro_autotune"
    )


def default_cache_path() -> str:
    return os.path.join(
        default_cache_dir(), f"autotune-v{SCHEMA_VERSION}.json"
    )


def _read_entries(path: str) -> dict[str, Any] | None:
    """Entries in the backing file, or None if absent/corrupt/stale."""
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(raw, dict):
        return None
    if raw.get("schema") != SCHEMA_VERSION:
        return None
    if raw.get("jax") != _jax_version():
        return None  # jax upgrade invalidates tuned decisions wholesale
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        return None
    return {k: v for k, v in entries.items() if isinstance(v, dict)}


# Caches holding deferred (unflushed) writes; flushed best-effort at
# interpreter exit.  A WeakSet so registration never extends a cache's
# lifetime — a collected cache simply loses its unflushed writes, the
# same contract an abrupt process death has always had.
_DIRTY_CACHES: "weakref.WeakSet[AutotuneCache]" = weakref.WeakSet()


@atexit.register
def _flush_dirty_caches() -> None:
    for cache in list(_DIRTY_CACHES):
        try:
            cache.flush()
        except Exception:
            pass  # exit-time best effort (tmp dir may be gone)


@dataclasses.dataclass(eq=False)  # identity semantics: hashable for the
class AutotuneCache:              # dirty-cache WeakSet
    """Versioned persistent key -> tuned-decision store.

    Keys are produced by :class:`repro.autotune.tuner.TuneKey` and embed
    the machine name + group, so one file safely holds entries for many
    machines; the jax version stamps the whole file (a jax upgrade can
    change what the measured path compiles to, so tuned decisions are
    invalidated wholesale — re-tuning is cheap).
    """

    path: str | None = None
    entries: dict[str, dict[str, Any]] = dataclasses.field(
        default_factory=dict
    )
    _loaded_from_disk: bool = False
    _lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False, compare=False
    )
    _dirty: bool = dataclasses.field(default=False, repr=False,
                                     compare=False)

    def __post_init__(self):
        if self.path is None:
            self.path = default_cache_path()
        self.load()

    # -- persistence ----------------------------------------------------

    def load(self) -> None:
        """Read the backing file; silently start empty on any mismatch."""
        entries = _read_entries(self.path)
        with self._lock:
            self.entries = entries if entries is not None else {}
            self._loaded_from_disk = entries is not None
            self._dirty = False

    def save(self) -> None:
        """Atomic write (tempfile + rename) of the whole store.

        Merge-on-save: entries another process persisted since our load
        are folded in first (ours win on key collision), so concurrent
        processes tuning disjoint keys don't clobber each other — the
        union survives, whoever writes last.  The merge + swap + write
        happens under the instance lock, so a ``put`` racing from
        another thread either lands before the snapshot (persisted now)
        or after the swap (persisted by the next flush) — never lost
        mid-``save``.
        """
        with self._lock:
            merged = {**(_read_entries(self.path) or {}), **self.entries}
            self.entries = merged
            self._dirty = False
            _DIRTY_CACHES.discard(self)
            d = os.path.dirname(self.path)
            os.makedirs(d, exist_ok=True)
            payload = {
                "schema": SCHEMA_VERSION,
                "jax": _jax_version(),
                "entries": merged,
            }
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

    def flush(self) -> None:
        """Persist deferred writes, if any (no-op on a clean store)."""
        with self._lock:
            if self._dirty:
                self.save()

    @property
    def dirty(self) -> bool:
        """True when deferred writes await a ``flush()``."""
        return self._dirty

    def clear(self) -> None:
        with self._lock:
            self.entries = {}
            self._dirty = False
            _DIRTY_CACHES.discard(self)
            try:
                os.unlink(self.path)
            except OSError:
                pass

    # -- access ---------------------------------------------------------

    def get(self, key: str) -> dict[str, Any] | None:
        with self._lock:
            return self.entries.get(key)

    def put(
        self,
        key: str,
        entry: dict[str, Any],
        *,
        persist: bool | str = True,
    ) -> None:
        """Record one entry.

        ``persist`` is ``True`` (write the whole store now — the
        pre-existing O(store) behavior), ``False`` (in-memory only), or
        ``"defer"`` (mark dirty; persisted by the next ``flush()`` /
        ``save()`` or the atexit hook — the serving hot path's choice).
        """
        if persist not in (True, False, "defer"):
            raise ValueError(
                f"persist must be True, False or 'defer', got {persist!r}"
            )
        with self._lock:
            self.entries[key] = entry
            if persist == "defer":
                self._dirty = True
                _DIRTY_CACHES.add(self)
            elif persist:
                self.save()

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    # -- artifact segment (learned gates, fitted machine models) --------

    def put_artifact(
        self,
        kind: str,
        name: str,
        payload: dict[str, Any],
        *,
        persist: bool | str = True,
    ) -> None:
        """Store a non-decision artifact (e.g. a ``repro.learn`` gate).

        Artifacts live in the same versioned file under the reserved
        ``__artifact__/`` key prefix, so they inherit the cache's
        atomic-write, merge-on-save and schema/jax-version invalidation
        behavior for free.
        """
        self.put(artifact_key(kind, name), payload, persist=persist)

    def get_artifact(self, kind: str, name: str) -> dict[str, Any] | None:
        return self.get(artifact_key(kind, name))

    def artifact_names(self, kind: str) -> tuple[str, ...]:
        prefix = f"{ARTIFACT_PREFIX}/{kind}/"
        with self._lock:
            return tuple(
                sorted(
                    k[len(prefix):]
                    for k in self.entries
                    if k.startswith(prefix)
                )
            )

    def decision_entries(self) -> dict[str, dict[str, Any]]:
        """Tuned-decision entries only (artifact segment filtered out)."""
        with self._lock:
            return {
                k: v
                for k, v in self.entries.items()
                if not k.startswith(f"{ARTIFACT_PREFIX}/")
            }


__all__ = [
    "SCHEMA_VERSION",
    "ARTIFACT_PREFIX",
    "artifact_key",
    "AutotuneCache",
    "default_cache_dir",
    "default_cache_path",
]
