"""On-accelerator batched FiCCO grid engine (jit + vmap + grad).

This is the ``jax.numpy`` port of ``repro.core.batch``: the roofline GEMM
model, the communication model, the CIL formulas and the two-channel
pipeline scan, all expressed as pure array math over a
``(schedule, scenario, machine)`` grid so that

  * the whole sweep compiles to one XLA program (``jax.jit``), vmapped
    over the machine axis — sweeps can run *on-accelerator* inside a
    framework scheduling loop;
  * every output is differentiable w.r.t. the machine parameters and the
    heuristic threshold horizon TAU, which turns threshold calibration
    into a few Adam steps (:func:`calibrate_tau`) instead of a discrete
    candidate search.

Numerics: the engine runs in float64 (``jax.experimental.enable_x64``
scoped to this module's entry points — the global x64 flag is never
touched) and replays the NumPy engine's accumulation order, so grids
agree with ``repro.core.batch.evaluate_grid`` to ~1e-12 relative, far
inside the 1e-5 acceptance tolerance.  The kernels are additionally
dtype-generic over the :class:`MachineArrays` float leaves: packing
them at float32/bfloat16 (``machine_arrays(..., dtype=...)``) evaluates
the whole grid at that precision with float64 confined to the pipeline
scan's accumulator — the ``"mixed"`` engine (``repro.sweep.device``)
builds on exactly this, and the float64 default is bit-identical to the
pre-dtype-generic code.

Machines with different group sizes vmap together by padding every
pipeline to ``g_max`` steps; padded steps carry zero time and a masked
dependency, which leaves totals, busy times and exposed time bit-exact.

Quick start (the whole grid on-accelerator in three lines)::

    from repro.autotune import evaluate_grid
    grid = evaluate_grid(scenarios, machines, backend="jax")
    best = grid.best_idx()
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import inefficiency as ineff
from repro.core.batch import (
    GRID_SCHEDULES,
    GridResult,
    _as_batch,
    _as_ragged_batch,
)
from repro.core.heuristics import MIN_DECOMPOSE_FLOPS
from repro.core.machine import MachineSpec, Topology
from repro.core.schedule_types import STUDIED, Schedule

_F = jnp.float64
_I = jnp.int64


class MachineArrays(NamedTuple):
    """Struct-of-arrays pytree of M machines (leading axis M).

    The calibrated coefficients (``s_half``, the four CIL coefficients,
    ``mt_ref``) are solved host-side by the NumPy bisections in
    ``repro.core.inefficiency`` — exactly the values the NumPy engine
    uses — and enter the jitted program as ordinary differentiable
    leaves.
    """

    peak_flops: jax.Array
    hbm_bw: jax.Array
    link_bw: jax.Array
    group: jax.Array  # int
    is_mesh: jax.Array  # bool: FULL_MESH vs TORUS_RING/SWITCH
    p2p_links: jax.Array  # int
    a2a_links: jax.Array  # int
    kernel_latency: jax.Array
    link_latency: jax.Array
    tile_mn: jax.Array  # int
    tile_k: jax.Array  # int
    parallel_units: jax.Array  # int
    kernel_ramp: jax.Array
    s_half: jax.Array
    cil_gemm_c2: jax.Array
    cil_gemm_c3: jax.Array
    cil_comm_c2: jax.Array
    cil_comm_c3: jax.Array
    mt_ref: jax.Array


def machine_arrays(machines, *, dtype=None) -> MachineArrays:
    """Pack MachineSpecs (plus their host-calibrated coefficients).

    ``dtype`` sets the float leaves' dtype (default float64) — the
    kernels below derive their compute dtype from the machine leaves, so
    packing at float32/bfloat16 is how the mixed-precision engine
    (``repro.sweep.device``) selects its evaluation precision without a
    second code path.  Integer/bool leaves are dtype-invariant.
    """
    ms = tuple(machines)
    fdt = _F if dtype is None else jnp.dtype(dtype)

    def fa(get):  # float leaf
        return jnp.asarray([get(m) for m in ms], dtype=fdt)

    def ia(get):  # int leaf
        return jnp.asarray([get(m) for m in ms], dtype=_I)

    return MachineArrays(
        peak_flops=fa(lambda m: m.peak_flops),
        hbm_bw=fa(lambda m: m.hbm_bw),
        link_bw=fa(lambda m: m.link_bw),
        group=ia(lambda m: m.group),
        is_mesh=jnp.asarray(
            [m.topology is Topology.FULL_MESH for m in ms], dtype=bool
        ),
        p2p_links=ia(lambda m: m.p2p_links),
        a2a_links=ia(lambda m: m.a2a_links),
        kernel_latency=fa(lambda m: m.kernel_latency),
        link_latency=fa(lambda m: m.link_latency),
        tile_mn=ia(lambda m: m.tile_mn),
        tile_k=ia(lambda m: m.tile_k),
        parallel_units=ia(lambda m: m.parallel_units),
        kernel_ramp=fa(lambda m: m.kernel_ramp),
        s_half=fa(ineff.calibrated_s_half),
        cil_gemm_c2=fa(lambda m: ineff._cil_coeff(m, "gemm", 2)),
        cil_gemm_c3=fa(lambda m: ineff._cil_coeff(m, "gemm", 3)),
        cil_comm_c2=fa(lambda m: ineff._cil_coeff(m, "comm", 2)),
        cil_comm_c3=fa(lambda m: ineff._cil_coeff(m, "comm", 3)),
        mt_ref=fa(ineff._mt_ref),
    )


def scenario_arrays(scenarios) -> tuple[jax.Array, ...]:
    """(m, n, k, dtype_bytes) int64 device arrays from any scenario form."""
    sb = _as_batch(scenarios)
    return (
        jnp.asarray(sb.m, dtype=_I),
        jnp.asarray(sb.n, dtype=_I),
        jnp.asarray(sb.k, dtype=_I),
        jnp.asarray(sb.dtype_bytes, dtype=_I),
    )


# ---------------------------------------------------------------------------
# Roofline GEMM model (port of batch.gemm_exec_vec).
# ---------------------------------------------------------------------------


def _floor_div(a, b):
    """Exact int64 floor-div via float division.

    Scalar 64-bit integer division costs ~30 cycles per lane on CPU and
    never vectorizes; float division is SIMD.  The substitution is
    *exact* — not approximate — whenever ``quotient * b < 2**53`` (f64):
    a correctly-rounded quotient then sits strictly inside the 1/b gap
    around the true rational, so its floor equals the integer result.
    Every shape field here is far smaller (m <= 2**21, n, k <= 2**16,
    tile counts <= 2**26), with the same argument holding even for an
    f32 fallback (< 2**24) if a caller traces outside the x64 scope.
    """
    af = jnp.asarray(a).astype(jnp.float64)
    bf = jnp.asarray(b).astype(jnp.float64)
    return jnp.floor(af / bf).astype(jnp.int64)


def gemm_exec_jax(m, n, k, b, mp: MachineArrays, *, accumulate=False):
    """Elementwise roofline GEMM time; mirrors ``batch.gemm_exec_vec``.

    The compute dtype follows the machine leaves (float64 by default;
    float32/bfloat16 when :func:`machine_arrays` packed them that way).
    The explicit casts below pin the integer->float promotion points:
    without them, jax promotes python-scalar x int64 products to the
    default float, silently re-widening a mixed-precision program.  In
    float64 every cast is exact for the representable shape ranges, so
    the default path is unchanged bit-for-bit.
    """
    dt = mp.peak_flops.dtype
    t_mn, pu = mp.tile_mn, mp.parallel_units
    # >= 1 tile even for sub-row ragged chunks (see batch.gemm_exec_vec).
    cm = jnp.maximum(_floor_div(m + t_mn - 1, t_mn), 1)
    cn = jnp.maximum(_floor_div(n + t_mn - 1, t_mn), 1)
    tiles = cm * cn
    split_cap = jnp.where(m <= t_mn, 2, 8)
    ceil_pu = _floor_div(pu + tiles - 1, jnp.maximum(tiles, 1))
    splits = jnp.minimum(
        jnp.minimum(ceil_pu, jnp.maximum(_floor_div(k, mp.tile_k), 1)),
        split_cap,
    )
    splits = jnp.where(tiles < pu, splits, 1)
    work = tiles * splits
    padded_flops = 2.0 * ((cm * t_mn) * (cn * t_mn)).astype(dt) * k.astype(dt)
    occ_quant = work.astype(dt) / ((-_floor_div(-work, pu)) * pu).astype(dt)
    occ_smooth = jnp.minimum(1.0, work.astype(dt) / pu)
    occupancy = 0.5 * (occ_quant + occ_smooth)
    k_eff = k.astype(dt) / (k + mp.tile_k).astype(dt)
    compute = (
        padded_flops / mp.peak_flops / jnp.maximum(occupancy * k_eff, 1e-9)
    )
    bytes_hbm = (m * k + k * n + m * n).astype(dt) * b
    if accumulate:
        bytes_hbm = bytes_hbm + (m * n).astype(dt) * b
    bytes_hbm = bytes_hbm + jnp.where(
        splits > 1,
        2.0 * (splits - 1).astype(dt) * (m * n).astype(dt) * 4,
        0.0,
    )
    memory = bytes_hbm / mp.hbm_bw
    base = jnp.maximum(compute, memory)
    ramp = mp.kernel_ramp
    t = mp.kernel_latency + base * (1.0 + ramp / (base + ramp))
    return jnp.where(m > 0, t, jnp.nan)


# ---------------------------------------------------------------------------
# Communication model.
# ---------------------------------------------------------------------------


def comm_time_jax(nbytes_per_link, mp: MachineArrays, *, n_transfers=1):
    per = nbytes_per_link / jnp.maximum(n_transfers, 1)
    t_one = mp.link_latency + (per + mp.s_half) / mp.link_bw
    return n_transfers * t_one


def ag_serial_time_jax(mk_bytes, mp: MachineArrays):
    g = mp.group
    per_link = jnp.where(
        mp.is_mesh,
        mk_bytes / g,
        mk_bytes * (g - 1) / g / mp.a2a_links,
    )
    return comm_time_jax(per_link, mp)


def p2p_step_time_jax(shard_bytes, mp: MachineArrays):
    return comm_time_jax(shard_bytes / mp.p2p_links, mp)


def a2a_chunk_step_time_jax(chunk_bytes, mp: MachineArrays):
    g = mp.group
    per_link = jnp.where(
        mp.is_mesh, chunk_bytes, chunk_bytes * (g - 1) / mp.a2a_links
    )
    n = jnp.where(mp.is_mesh, 1, jnp.maximum((g - 1) // mp.a2a_links, 1))
    return comm_time_jax(per_link, mp, n_transfers=n)


def hbm_move_time_jax(nbytes, mp: MachineArrays):
    return mp.kernel_latency + 2.0 * nbytes / mp.hbm_bw


# ---------------------------------------------------------------------------
# CIL formulas.
# ---------------------------------------------------------------------------


def _mt_norm_jax(m, n, k, b, mp: MachineArrays):
    bytes_mt = (m * k + k * n + m * n).astype(mp.mt_ref.dtype) * b
    return bytes_mt / mp.mt_ref


def _cil_jax(mt_p, c2, c3, *, degree: int, dma: bool, rccl_extra):
    c = c2 if min(max(degree, 2), 3) == 2 else c3
    cil = 1.0 + c * (min(degree, 3) - 1) * mt_p
    if degree > 3:
        cil = cil * (1.0 + 0.02 * (degree - 3))
    if not dma:
        cil = cil + rccl_extra
    return cil


def gemm_cil_jax(m, n, k, b, mp, *, degree: int, dma: bool = True):
    mt_p = _mt_norm_jax(m, n, k, b, mp) ** 0.5
    return _cil_jax(
        mt_p, mp.cil_gemm_c2, mp.cil_gemm_c3, degree=degree, dma=dma,
        rccl_extra=ineff.RCCL_EXTRA_GEMM_CIL * mt_p + 0.15,
    )


def comm_cil_jax(m, n, k, b, mp, *, degree: int, dma: bool = True):
    mt_p = _mt_norm_jax(m, n, k, b, mp) ** 0.5
    return _cil_jax(
        mt_p, mp.cil_comm_c2, mp.cil_comm_c3, degree=degree, dma=dma,
        rccl_extra=0.10,
    )


# ---------------------------------------------------------------------------
# Pipeline recurrence, padded to g_max steps.
# ---------------------------------------------------------------------------


def pipeline_jax(comm_steps, compute_steps, deps, comm_active, comp_active):
    """Two-channel pipeline over padded step lists.

    ``comm_steps`` / ``compute_steps`` are length-``g_max``(+1) lists of
    per-scenario time arrays; ``*_active`` are matching boolean masks
    (scalars or arrays) marking real steps.  Inactive steps add exactly
    0.0 time and never stall, so a group-g machine inside a
    group-``g_max`` padded scan reproduces the unpadded recurrence
    bit-for-bit.

    The scan always **accumulates in float64**, whatever dtype the step
    times arrive in: the recurrence sums ~``g_max`` terms and compares
    running channel clocks, where low-precision cancellation would turn
    stall detection into noise.  This is the mixed-precision engine's
    accumulator contract — bf16/f32 kernels, f64 pipeline — and a no-op
    for the default float64 path.
    """
    finish = []
    t = None
    for c, a in zip(comm_steps, comm_active):
        c = jnp.where(a, c, 0.0).astype(_F)
        t = c if t is None else t + c
        finish.append(t)
    zero = jnp.zeros_like(compute_steps[0], dtype=_F)
    t_comp = zero
    exposed = zero
    comp_sum = None
    for i, w in enumerate(compute_steps):
        a = comp_active[i]
        w = jnp.where(a, w, 0.0).astype(_F)
        dep = deps[i]
        if dep is not None:
            ready = finish[dep]
            stalled = a & (ready > t_comp)
            exposed = exposed + jnp.where(stalled, ready - t_comp, 0.0)
            t_comp = jnp.where(stalled, ready, t_comp)
        t_comp = t_comp + w
        comp_sum = w if comp_sum is None else comp_sum + w
    comm_sum = finish[-1] if finish else zero
    total = jnp.maximum(t_comp, comm_sum)
    return total, exposed, comm_sum, comp_sum


def pipeline_closed_jax(comm_steps, compute_steps, deps, comm_active,
                        comp_active):
    """Closed-form pipeline for *uniform* step lists (device fast path).

    Every uniform-schedule assembly in :func:`_eval_one_machine_jax`
    passes one repeated array per channel (``[t_comm] * g_max``), for
    which the scan recurrence ``t_j = max(t_{j-1}, finish_j) + w`` has
    the exact solution ``max_j (j*c + remaining_work(j))`` — linear in
    ``j``, so only the endpoint candidates matter.  That replaces
    ~``g_max`` float64 scan iterations (the dominant elementwise cost of
    a uniform grid evaluation) with a handful of ops.

    The three dep patterns assembled by ``_eval_one_machine_jax`` are
    recognised structurally:

      * ``deps[0] is None`` and one extra compute step → local-GEMM
        FiCCO (HF1D/HU1D): ``max(t_l + n*w, c + n*w, n_c*c + w)``;
      * ``deps[0] is None``, equal lengths → SHARD_P2P (first compute
        step free): ``max(n*w, n_c*c + w)``;
      * else plain FiCCO (UF2D/UF1D): ``max(c + n*w, n_c*c + w)``.

    Totals agree with :func:`pipeline_jax` to rounding only — the scan
    accumulates ``j*c`` by repeated addition, the closed form by one
    multiply — so the padded scan remains the bit-exact reference and
    this variant is opt-in (``closed_form=True``).  Ragged schedules
    (per-step distinct times) have no closed form and always scan.
    """

    def count(active):
        tot = None
        for a in active:
            v = jnp.asarray(a).astype(_F)
            tot = v if tot is None else tot + v
        return tot

    if comm_steps:
        n_c = count(comm_active)
        c = jnp.where(n_c > 0, comm_steps[0], 0.0).astype(_F)
    else:  # g_max == 1 SHARD_P2P: no inter-device steps at all
        n_c = jnp.asarray(0.0, dtype=_F)
        c = jnp.zeros_like(compute_steps[0], dtype=_F)
    comm_sum = n_c * c
    if deps[0] is None and len(compute_steps) == len(comm_steps) + 1:
        t_l = compute_steps[0].astype(_F)
        w = compute_steps[1].astype(_F)
        n_w = count(comp_active[1:])
        comp_sum = t_l + n_w * w
        t_comp = jnp.maximum(
            jnp.maximum(t_l + n_w * w, c + n_w * w), comm_sum + w
        )
    elif deps[0] is None:
        w = compute_steps[0].astype(_F)
        n_w = count(comp_active)
        comp_sum = n_w * w
        t_comp = jnp.maximum(n_w * w, comm_sum + w)
    else:
        w = compute_steps[0].astype(_F)
        n_w = count(comp_active)
        comp_sum = n_w * w
        t_comp = jnp.maximum(c + n_w * w, comm_sum + w)
    exposed = t_comp - comp_sum
    total = jnp.maximum(t_comp, comm_sum)
    return total, exposed, comm_sum, comp_sum


# ---------------------------------------------------------------------------
# Grid evaluation (one machine; vmapped over the machine axis).
# ---------------------------------------------------------------------------


def _eval_one_machine_jax(m, n, k, b, mp, g_max, schedules, dma,
                          dma_into_place, closed_form=False):
    """All schedules for one (vmapped) machine; returns (L, S) arrays.

    Kernel math runs in the machine leaves' dtype (``dt``); every output
    row is widened to float64 on the way out (``put``) so stacked
    results are homogeneous whatever precision evaluated them.

    ``closed_form=True`` swaps the padded pipeline scan for
    :func:`pipeline_closed_jax` (equal to rounding, ~2x fewer
    elementwise ops) — the device sweep fast path; the default stays the
    bit-exact scan.
    """
    pipe = pipeline_closed_jax if closed_form else pipeline_jax
    dt = mp.peak_flops.dtype
    g = mp.group
    S = m.shape[0]
    true_f = jnp.ones((S,), dtype=bool)

    n_q = _floor_div(n, g)
    dev_n = jnp.where(n == g * n_q, n_q, n)
    mk_bytes = (m * k).astype(dt) * b
    serial_comm = ag_serial_time_jax(mk_bytes, mp)
    serial_gemm = gemm_exec_jax(m, dev_n, k, b, mp)

    m_s = _floor_div(m, g)
    m_div = (m == g * m_s) & (m > 0)
    k_q = _floor_div(k, g)
    k_div = k == g * k_q
    m_sg = _floor_div(m_s, g)

    def step_active(n_steps):
        # Padded scans run g_max iterations; step s is real iff s < n_steps.
        return [s < n_steps for s in range(g_max)]

    total_rows, comm_rows, comp_rows, exp_rows = [], [], [], []
    steps_rows, valid_rows = [], []

    def put(ok, total, comm_busy, compute_busy, exposed, n_steps):
        total_rows.append(jnp.where(ok, total, jnp.nan).astype(_F))
        comm_rows.append(jnp.where(ok, comm_busy, jnp.nan).astype(_F))
        comp_rows.append(jnp.where(ok, compute_busy, jnp.nan).astype(_F))
        exp_rows.append(jnp.where(ok, exposed, jnp.nan).astype(_F))
        steps_rows.append(jnp.asarray(n_steps, dtype=_I))
        valid_rows.append(ok)

    for sched in schedules:
        if sched is Schedule.SERIAL:
            put(true_f, serial_comm + serial_gemm, serial_comm, serial_gemm,
                serial_comm, 1)
            continue

        if sched is Schedule.SHARD_P2P:
            shard_bytes = (m_s * k).astype(dt) * b
            c_cil = comm_cil_jax(m_s, dev_n, k, b, mp, degree=2, dma=dma)
            g_cil = gemm_cil_jax(m_s, dev_n, k, b, mp, degree=2, dma=dma)
            t_p2p = p2p_step_time_jax(shard_bytes, mp) * c_cil
            t_gemm = gemm_exec_jax(m_s, dev_n, k, b, mp) * g_cil
            total, exposed, comm_sum, comp_sum = pipe(
                [t_p2p] * (g_max - 1),
                [t_gemm] * g_max,
                [None] + list(range(g_max - 1)),
                step_active(g - 1),
                step_active(g),
            )
            put(m_div, total, comm_sum, comp_sum, exposed, g)
            continue

        # ---- FiCCO schedules -----------------------------------------
        if sched is Schedule.UNIFORM_FUSED_2D:
            k_g = k_q
            chunk_bytes = (m_s * k_g).astype(dt) * b
            step = (m, dev_n, k_g)
            gather_bytes = (m * k_g).astype(dt) * b
            scatter_bytes = None
            degree, accumulate = 4, True
            local = None
            per_step_gemms = jnp.asarray(1, dtype=_I)
            ok = m_div & k_div
        elif sched is Schedule.UNIFORM_FUSED_1D:
            chunk_bytes = (m_sg * k).astype(dt) * b
            step = (m_s, dev_n, k)
            gather_bytes = (m_s * k).astype(dt) * b
            scatter_bytes = (m_s * dev_n).astype(dt) * b
            degree, accumulate = 4, False
            local = None
            per_step_gemms = jnp.asarray(1, dtype=_I)
            ok = m_div
        elif sched is Schedule.HETERO_FUSED_1D:
            chunk_bytes = (m_sg * k).astype(dt) * b
            rows = (g - 1) * m_sg
            step = (rows, dev_n, k)
            gather_bytes = (rows * k).astype(dt) * b
            scatter_bytes = (rows * dev_n).astype(dt) * b
            degree, accumulate = 3, False
            local = (m_s, dev_n, k)
            per_step_gemms = jnp.asarray(1, dtype=_I)
            ok = m_div & (m_sg >= 1)
        elif sched is Schedule.HETERO_UNFUSED_1D:
            chunk_bytes = (m_sg * k).astype(dt) * b
            step = (m_sg, dev_n, k)
            gather_bytes = jnp.zeros((S,), dtype=dt)
            scatter_bytes = ((g - 1) * m_sg * dev_n).astype(dt) * b
            degree, accumulate = 2, False
            local = (m_s, dev_n, k)
            per_step_gemms = g - 1
            ok = m_div & (m_sg >= 1)
        else:  # pragma: no cover
            raise ValueError(sched)

        if dma_into_place:
            gather_bytes = jnp.zeros((S,), dtype=dt)
            scatter_bytes = None
            degree = 2
        c_cil = comm_cil_jax(m_s, dev_n, k, b, mp, degree=degree, dma=dma)
        g_cil = gemm_cil_jax(
            step[0], step[1], step[2], b, mp, degree=degree, dma=dma
        )
        t_comm = a2a_chunk_step_time_jax(chunk_bytes, mp) * c_cil
        t_gemm_step = (
            per_step_gemms
            * gemm_exec_jax(
                step[0], step[1], step[2], b, mp, accumulate=accumulate
            )
            * g_cil
        )
        t_gather = jnp.where(
            gather_bytes > 0, hbm_move_time_jax(gather_bytes, mp), 0.0
        )
        if scatter_bytes is None:
            t_scatter = jnp.zeros((S,), dtype=dt)
        else:
            t_scatter = jnp.where(
                scatter_bytes > 0,
                hbm_move_time_jax(scatter_bytes, mp),
                0.0,
            )
        t_step = jnp.maximum(t_gemm_step, t_gather + t_scatter)

        if local is not None:
            t_local = gemm_exec_jax(
                local[0], local[1], local[2], b, mp
            ) * gemm_cil_jax(
                local[0], local[1], local[2], b, mp, degree=degree, dma=dma
            )
            compute = [t_local] + [t_step] * g_max
            deps = [None] + list(range(g_max))
            comp_active = [True] + step_active(g)
        else:
            compute = [t_step] * g_max
            deps = list(range(g_max))
            comp_active = step_active(g)
        total, exposed, comm_sum, comp_sum = pipe(
            [t_comm] * g_max, compute, deps, step_active(g), comp_active
        )
        put(ok, total, comm_sum, comp_sum, exposed, g)

    return (
        jnp.stack(total_rows),
        jnp.stack(comm_rows),
        jnp.stack(comp_rows),
        jnp.stack(exp_rows),
        jnp.stack(steps_rows),
        jnp.stack(valid_rows),
        serial_comm.astype(_F),
        serial_gemm.astype(_F),
    )


# ---------------------------------------------------------------------------
# Ragged (non-uniform step) evaluation: padded (S, P) fraction matrix +
# validity masks, jit-compatible (mirrors batch.ragged_step_times).
# ---------------------------------------------------------------------------

_FICCO_SET = frozenset(STUDIED)


def ragged_step_times_jax(
    m, n, k, b, frac, mp: MachineArrays, sched: Schedule, *,
    dma: bool = True, dma_into_place: bool = False,
):
    """Per-step stream times for one (vmapped) machine; jnp twin of
    ``repro.core.batch.ragged_step_times``.

    ``frac`` is the padded ``(S, P)`` fraction matrix (static P).
    Returns ``(comm_steps, compute_steps, deps, comm_active,
    comp_active, ok)`` ready for :func:`pipeline_jax`.
    """
    if sched not in _FICCO_SET:
        raise ValueError(
            f"ragged profiles apply to the FiCCO schedules, got {sched}"
        )
    dt = mp.peak_flops.dtype
    g = mp.group
    S = m.shape[0]
    P = frac.shape[1]
    n_q = _floor_div(n, g)
    dev_n = jnp.where(n == g * n_q, n_q, n)
    m_s = _floor_div(m, g)
    m_div = (m == g * m_s) & (m > 0)
    mf = m.astype(dt)
    msf = m_s.astype(dt)
    kf = k.astype(dt)

    if sched is Schedule.UNIFORM_FUSED_2D:
        degree, accumulate = 4, True
        local = None
        per_step_gemms = jnp.asarray(1, dtype=_I)
    elif sched is Schedule.UNIFORM_FUSED_1D:
        degree, accumulate = 4, False
        local = None
        per_step_gemms = jnp.asarray(1, dtype=_I)
    elif sched is Schedule.HETERO_FUSED_1D:
        degree, accumulate = 3, False
        local = (m_s, dev_n, k)
        per_step_gemms = jnp.asarray(1, dtype=_I)
    else:  # HETERO_UNFUSED_1D
        degree, accumulate = 2, False
        local = (m_s, dev_n, k)
        per_step_gemms = g - 1
    if dma_into_place:
        degree = 2
    c_cil = comm_cil_jax(m_s, dev_n, k, b, mp, degree=degree, dma=dma)

    comm_steps, compute_steps = [], []
    comm_active, comp_active = [], []
    for s in range(P):
        f = frac[:, s]
        act = f > 0.0
        if sched is Schedule.UNIFORM_FUSED_2D:
            k_s = f * kf
            chunk_bytes = msf * k_s * b
            rows, cols, inner = mf, dev_n, k_s
            gather_bytes = mf * k_s * b
            scatter_bytes = None
        else:
            chunk_bytes = (f * msf) * kf * b
            cols, inner = dev_n, k
            if sched is Schedule.UNIFORM_FUSED_1D:
                rows = f * mf
                gather_bytes = rows * kf * b
                scatter_bytes = rows * dev_n * b
            elif sched is Schedule.HETERO_FUSED_1D:
                rows = f * ((g - 1) * msf)
                gather_bytes = rows * kf * b
                scatter_bytes = rows * dev_n * b
            else:
                rows = f * msf
                gather_bytes = None
                scatter_bytes = (g - 1) * rows * dev_n * b
        if dma_into_place:
            gather_bytes = None
            scatter_bytes = None
        t_comm = a2a_chunk_step_time_jax(chunk_bytes, mp) * c_cil
        g_cil = gemm_cil_jax(
            rows, cols, inner, b, mp, degree=degree, dma=dma
        )
        t_gemm = (
            per_step_gemms
            * gemm_exec_jax(rows, cols, inner, b, mp, accumulate=accumulate)
            * g_cil
        )
        if gather_bytes is None:
            t_gather = jnp.zeros((S,), dtype=dt)
        else:
            t_gather = jnp.where(
                gather_bytes > 0, hbm_move_time_jax(gather_bytes, mp), 0.0
            )
        if scatter_bytes is None:
            t_scatter = jnp.zeros((S,), dtype=dt)
        else:
            t_scatter = jnp.where(
                scatter_bytes > 0, hbm_move_time_jax(scatter_bytes, mp), 0.0
            )
        t_step = jnp.maximum(t_gemm, t_gather + t_scatter)
        comm_steps.append(t_comm)
        comm_active.append(act)
        compute_steps.append(t_step)
        comp_active.append(act)

    if local is not None:
        t_local = gemm_exec_jax(
            local[0], local[1], local[2], b, mp
        ) * gemm_cil_jax(
            local[0], local[1], local[2], b, mp, degree=degree, dma=dma
        )
        compute_steps = [t_local] + compute_steps
        comp_active = [jnp.ones((S,), dtype=bool)] + comp_active
        deps: list[int | None] = [None] + list(range(P))
    else:
        deps = list(range(P))
    return comm_steps, compute_steps, deps, comm_active, comp_active, m_div


def _eval_one_machine_ragged_jax(
    m, n, k, b, frac, mp, g_max, schedules, dma, dma_into_place
):
    """All schedules for one (vmapped) machine over ragged scenarios.

    SERIAL / SHARD_P2P replicate the uniform engine (profile-free); the
    FiCCO schedules run the masked ragged scan over P padded steps.
    Like the uniform evaluator, kernel math runs in the machine leaves'
    dtype and ``put`` widens every output row to float64.
    """
    dt = mp.peak_flops.dtype
    g = mp.group
    S = m.shape[0]
    P = frac.shape[1]
    true_f = jnp.ones((S,), dtype=bool)

    n_q = _floor_div(n, g)
    dev_n = jnp.where(n == g * n_q, n_q, n)
    mk_bytes = (m * k).astype(dt) * b
    serial_comm = ag_serial_time_jax(mk_bytes, mp)
    serial_gemm = gemm_exec_jax(m, dev_n, k, b, mp)

    m_s = _floor_div(m, g)
    m_div = (m == g * m_s) & (m > 0)

    def step_active(n_steps):
        return [s < n_steps for s in range(g_max)]

    total_rows, comm_rows, comp_rows, exp_rows = [], [], [], []
    steps_rows, valid_rows = [], []

    def put(ok, total, comm_busy, compute_busy, exposed, n_steps):
        total_rows.append(jnp.where(ok, total, jnp.nan).astype(_F))
        comm_rows.append(jnp.where(ok, comm_busy, jnp.nan).astype(_F))
        comp_rows.append(jnp.where(ok, compute_busy, jnp.nan).astype(_F))
        exp_rows.append(jnp.where(ok, exposed, jnp.nan).astype(_F))
        steps_rows.append(jnp.asarray(n_steps, dtype=_I))
        valid_rows.append(ok)

    for sched in schedules:
        if sched is Schedule.SERIAL:
            put(true_f, serial_comm + serial_gemm, serial_comm, serial_gemm,
                serial_comm, 1)
            continue
        if sched is Schedule.SHARD_P2P:
            shard_bytes = (m_s * k).astype(dt) * b
            c_cil = comm_cil_jax(m_s, dev_n, k, b, mp, degree=2, dma=dma)
            g_cil = gemm_cil_jax(m_s, dev_n, k, b, mp, degree=2, dma=dma)
            t_p2p = p2p_step_time_jax(shard_bytes, mp) * c_cil
            t_gemm = gemm_exec_jax(m_s, dev_n, k, b, mp) * g_cil
            total, exposed, comm_sum, comp_sum = pipeline_jax(
                [t_p2p] * (g_max - 1),
                [t_gemm] * g_max,
                [None] + list(range(g_max - 1)),
                step_active(g - 1),
                step_active(g),
            )
            put(m_div, total, comm_sum, comp_sum, exposed, g)
            continue
        comm, compute, deps, c_act, w_act, ok = ragged_step_times_jax(
            m, n, k, b, frac, mp, sched,
            dma=dma, dma_into_place=dma_into_place,
        )
        total, exposed, comm_sum, comp_sum = pipeline_jax(
            comm, compute, deps, c_act, w_act
        )
        put(ok, total, comm_sum, comp_sum, exposed, P)

    return (
        jnp.stack(total_rows),
        jnp.stack(comm_rows),
        jnp.stack(comp_rows),
        jnp.stack(exp_rows),
        jnp.stack(steps_rows),
        jnp.stack(valid_rows),
        serial_comm.astype(_F),
        serial_gemm.astype(_F),
    )


@functools.partial(
    jax.jit,
    static_argnames=("g_max", "schedules", "dma", "dma_into_place"),
)
def _ragged_grid_jit(
    m, n, k, b, frac, mp, *, g_max, schedules, dma, dma_into_place
):
    """(M-vmapped) ragged grid; outputs are (M, L, S) / (M, S) stacks."""
    return jax.vmap(
        lambda one: _eval_one_machine_ragged_jax(
            m, n, k, b, frac, one, g_max, schedules, dma, dma_into_place
        )
    )(mp)


def evaluate_ragged_grid_raw(
    scenarios,
    machines_or_arrays,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    schedules: tuple[Schedule, ...] = GRID_SCHEDULES,
    g_max: int | None = None,
):
    """Jit-evaluated ragged grid as device arrays (leading machine axis).

    ``scenarios`` is a RaggedBatch / list of RaggedScenario; the padded
    fraction matrix enters the jitted program as an ordinary operand, so
    re-running with a different skew at the same (S, P) shape costs no
    recompile.
    """
    rb = _as_ragged_batch(scenarios)
    with enable_x64():
        if isinstance(machines_or_arrays, MachineArrays):
            mp = machines_or_arrays
            if g_max is None:
                g_max = int(np.max(np.asarray(mp.group)))
        else:
            ms = tuple(machines_or_arrays)
            mp = machine_arrays(ms)
            g_max = max(m.group for m in ms)
        m, n, k, b = scenario_arrays(rb)
        frac = jnp.asarray(rb.frac, dtype=mp.peak_flops.dtype)
        return _ragged_grid_jit(
            m, n, k, b, frac, mp,
            g_max=g_max, schedules=tuple(schedules),
            dma=dma, dma_into_place=dma_into_place,
        )


def evaluate_ragged_grid(
    scenarios,
    machines,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    schedules: tuple[Schedule, ...] = GRID_SCHEDULES,
) -> GridResult:
    """Drop-in jitted replacement for ``batch.evaluate_ragged_grid``."""
    rb = _as_ragged_batch(scenarios)
    machines = tuple(machines)
    out = evaluate_ragged_grid_raw(
        rb, machines, dma=dma, dma_into_place=dma_into_place,
        schedules=schedules,
    )
    return GridResult.from_machine_major(
        out, schedules=schedules, scenarios=rb, machines=machines, dma=dma
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "g_max", "schedules", "dma", "dma_into_place", "closed_form"
    ),
)
def _grid_jit(
    m, n, k, b, mp, *, g_max, schedules, dma, dma_into_place,
    closed_form=False,
):
    """(M-vmapped) full grid; outputs are (M, L, S) / (M, S) stacks."""
    return jax.vmap(
        lambda one: _eval_one_machine_jax(
            m, n, k, b, one, g_max, schedules, dma, dma_into_place,
            closed_form,
        )
    )(mp)


def evaluate_grid_raw(
    scenarios,
    machines_or_arrays,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    schedules: tuple[Schedule, ...] = GRID_SCHEDULES,
    g_max: int | None = None,
    closed_form: bool = False,
):
    """Jit-evaluated grid as device arrays (differentiable entry point).

    Returns ``(total, comm_busy, compute_busy, exposed, steps, valid,
    serial_comm, serial_gemm)`` with leading machine axis ``M`` —
    ``total`` is ``(M, L, S)``.  Accepts either MachineSpecs or an
    already-packed (possibly perturbed) :class:`MachineArrays`, so
    gradients w.r.t. machine parameters flow through unchanged.

    ``closed_form=True`` selects :func:`pipeline_closed_jax` (totals
    equal to the scan up to rounding; the device sweep fast path).
    """
    with enable_x64():
        if isinstance(machines_or_arrays, MachineArrays):
            mp = machines_or_arrays
            if g_max is None:
                g_max = int(np.max(np.asarray(mp.group)))
        else:
            ms = tuple(machines_or_arrays)
            mp = machine_arrays(ms)
            g_max = max(m.group for m in ms)
        m, n, k, b = scenario_arrays(scenarios)
        return _grid_jit(
            m, n, k, b, mp,
            g_max=g_max, schedules=tuple(schedules),
            dma=dma, dma_into_place=dma_into_place,
            closed_form=closed_form,
        )


def evaluate_grid(
    scenarios,
    machines,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    schedules: tuple[Schedule, ...] = GRID_SCHEDULES,
) -> GridResult:
    """Drop-in jitted replacement for ``repro.core.batch.evaluate_grid``.

    Same :class:`~repro.core.batch.GridResult` out — arrays come back
    from the accelerator and are reshaped to the NumPy engine's
    ``(L, S, M)`` layout, so everything downstream (``GridExploration``,
    benchmarks, heuristic calibration) works unchanged.
    """
    sb = _as_batch(scenarios)
    machines = tuple(machines)
    out = evaluate_grid_raw(
        sb, machines, dma=dma, dma_into_place=dma_into_place,
        schedules=schedules,
    )
    return GridResult.from_machine_major(
        out, schedules=schedules, scenarios=sb, machines=machines, dma=dma
    )


# ---------------------------------------------------------------------------
# Differentiable heuristic: soft decision tree over TAU.
# ---------------------------------------------------------------------------

# Index order of the soft pick, matching GRID_SCHEDULES.
_L_SERIAL = GRID_SCHEDULES.index(Schedule.SERIAL)
_L_UF2 = GRID_SCHEDULES.index(Schedule.UNIFORM_FUSED_2D)
_L_UF1 = GRID_SCHEDULES.index(Schedule.UNIFORM_FUSED_1D)
_L_HF1 = GRID_SCHEDULES.index(Schedule.HETERO_FUSED_1D)
_L_HU1 = GRID_SCHEDULES.index(Schedule.HETERO_UNFUSED_1D)


def soft_pick_weights(
    log_tau, m, k, flops, peak_flops, *, temp=0.15, hard_serial=None
):
    """(S, L) schedule weights: the Fig.-12a tree with sigmoid-relaxed
    TAU comparisons.

    Only the two threshold comparisons involve TAU, so only they are
    softened; the serial escapes (tiny-operator guard + learned serial
    gate, passed in as ``hard_serial``) and the M-vs-K branch stay hard.
    As ``temp -> 0`` this converges to ``select_schedule``'s picks.
    """
    metric = flops  # OTB x MT == FLOPs, like the scalar tree
    log_metric = jnp.log(metric)
    log_t = log_tau + jnp.log(peak_flops)
    # P(metric < T) and P(metric >= 5T), relaxed in log space.
    p_low = jax.nn.sigmoid((log_t - log_metric) / temp)
    p_high = jax.nn.sigmoid((log_metric - (log_t + jnp.log(5.0))) / temp)
    w_uf1 = p_low
    w_hu1 = (1.0 - p_low) * p_high
    w_hf1 = (1.0 - p_low) * (1.0 - p_high)

    S = m.shape[0]
    w = jnp.zeros((S, len(GRID_SCHEDULES)), dtype=log_metric.dtype)
    w = w.at[:, _L_UF1].set(w_uf1)
    w = w.at[:, _L_HU1].set(w_hu1)
    w = w.at[:, _L_HF1].set(w_hf1)
    # Hard branches: 2D when M < K, then the serial escapes (which take
    # precedence over 2D, matching the scalar tree's branch order).
    is_2d = (m < k)[:, None]
    one_hot_2d = jnp.zeros_like(w).at[:, _L_UF2].set(1.0)
    w = jnp.where(is_2d, one_hot_2d, w)
    is_serial = (flops < MIN_DECOMPOSE_FLOPS)[:, None]
    if hard_serial is not None:
        is_serial = is_serial | hard_serial[:, None]
    one_hot_ser = jnp.zeros_like(w).at[:, _L_SERIAL].set(1.0)
    w = jnp.where(is_serial, one_hot_ser, w)
    return w


def expected_heuristic_time(
    tau, scenarios, machine: MachineSpec, *, temp: float = 0.15,
    _precomputed=None,
):
    """Differentiable mean (soft-)heuristic-picked time, normalized by the
    per-scenario optimum.  ``d(this)/d(tau)`` is finite and nonzero —
    the gradient signal :func:`calibrate_tau` descends.
    """
    with enable_x64():
        if _precomputed is None:
            _precomputed = _tau_loss_inputs(scenarios, machine)
        m, k, flops, t_norm, peak, hard = _precomputed
        log_tau = jnp.log(jnp.asarray(tau, dtype=_F))
        return _tau_loss(log_tau, m, k, flops, t_norm, peak, hard, temp)


def _tau_loss_inputs(scenarios, machine: MachineSpec):
    """Host-side precompute: normalized valid totals for one machine."""
    from repro.core.heuristics import (
        machine_serial_gate,
        serial_gate_score_batch,
    )

    sb = _as_batch(scenarios)
    out = evaluate_grid_raw(sb, (machine,))
    total = out[0][0]  # (L, S)
    valid = out[5][0]
    gate_scores = serial_gate_score_batch(
        sb.m, sb.n, sb.k, sb.dtype_bytes, machine
    )
    with enable_x64():
        m, n, k, b = scenario_arrays(sb)
        flops = 2.0 * (m * n).astype(_F) * k
        best = jnp.min(jnp.where(valid, total, jnp.inf), axis=0)
        # Invalid picks (indivisible decompositions) fall back to serial in
        # the runtime, so charge them the serial time rather than inf/NaN.
        serial = total[_L_SERIAL]
        t_norm = jnp.where(valid, total, serial[None, :]) / best[None, :]
        t_norm = t_norm.T  # (S, L)
        peak = jnp.asarray(machine.peak_flops, dtype=_F)
        hard_serial = jnp.asarray(
            gate_scores > machine_serial_gate(machine), dtype=bool
        )
    return m, k, flops, t_norm, peak, hard_serial


@functools.partial(jax.jit, static_argnames=("temp",))
def _tau_loss(log_tau, m, k, flops, t_norm, peak, hard_serial, temp):
    w = soft_pick_weights(
        log_tau, m, k, flops, peak, temp=temp, hard_serial=hard_serial
    )
    return jnp.mean(jnp.sum(w * t_norm, axis=1))


def calibrate_tau_reference(
    machine: MachineSpec,
    scenarios,
    *,
    temp: float = 0.15,
    lo: float = 1e-4,
    hi: float = 10.0,
    iters: int = 60,
) -> float:
    """Scan + bisection reference for the smooth TAU objective.

    A dense log-spaced scan brackets the global minimum, then bisection
    on the (finite-difference) slope polishes it — the discrete analogue
    the gradient calibration must reproduce.
    """
    pre = _tau_loss_inputs(scenarios, machine)
    m, k, flops, t_norm, peak, hard = pre

    with enable_x64():
        taus = np.geomspace(lo, hi, 512)
        losses = np.array([
            float(_tau_loss(jnp.log(jnp.asarray(t, dtype=_F)),
                            m, k, flops, t_norm, peak, hard, temp))
            for t in taus
        ])
        i = int(np.argmin(losses))
        llo = math.log(taus[max(i - 1, 0)])
        lhi = math.log(taus[min(i + 1, len(taus) - 1)])
        eps = 1e-4

        def slope(lt: float) -> float:
            f = lambda x: float(_tau_loss(
                jnp.asarray(x, dtype=_F), m, k, flops, t_norm, peak,
                hard, temp,
            ))
            return (f(lt + eps) - f(lt - eps)) / (2 * eps)

        for _ in range(iters):
            mid = 0.5 * (llo + lhi)
            if slope(mid) < 0.0:
                llo = mid
            else:
                lhi = mid
        return math.exp(0.5 * (llo + lhi))


def calibrate_tau(
    machine: MachineSpec,
    scenarios,
    *,
    steps: int = 120,
    lr: float = 0.08,
    temp: float = 0.15,
    inits=(0.002, 0.02, 0.2, 1.0),
) -> float:
    """Gradient TAU calibration: a few Adam steps on the soft tree loss.

    Replaces the discrete candidate search in
    ``repro.core.heuristics.calibrate_tau`` with first-order descent on
    :func:`expected_heuristic_time` — multi-start (the 1-D landscape can
    have shoulders), best final loss wins.  The result lands on the
    bisection reference (:func:`calibrate_tau_reference`) to well within
    5% on MI300X/Table-I.
    """
    pre = _tau_loss_inputs(scenarios, machine)
    m, k, flops, t_norm, peak, hard = pre

    with enable_x64():
        grad_fn = jax.jit(
            jax.value_and_grad(
                lambda lt: _tau_loss(
                    lt, m, k, flops, t_norm, peak, hard, temp
                )
            )
        )

        def adam(log_tau0: float) -> tuple[float, float]:
            lt = jnp.asarray(log_tau0, dtype=_F)
            mu = jnp.zeros((), dtype=_F)
            nu = jnp.zeros((), dtype=_F)
            b1, b2, eps = 0.9, 0.999, 1e-8
            loss = jnp.inf
            for t in range(1, steps + 1):
                loss, g = grad_fn(lt)
                mu = b1 * mu + (1 - b1) * g
                nu = b2 * nu + (1 - b2) * g * g
                mhat = mu / (1 - b1**t)
                nhat = nu / (1 - b2**t)
                lt = lt - lr * mhat / (jnp.sqrt(nhat) + eps)
            loss, _ = grad_fn(lt)
            return float(lt), float(loss)

        results = [adam(math.log(t0)) for t0 in inits]
        best_lt, _ = min(results, key=lambda r: r[1])
        return math.exp(best_lt)


def shortlist(
    gemm,
    machine: MachineSpec,
    *,
    top: int = 3,
    dma: bool = True,
    backend: str = "jax",
    profile=None,
) -> list[tuple[Schedule, float]]:
    """Top-``top`` valid schedules for one GEMM, fastest first.

    ``backend`` names any engine in the :mod:`repro.core.engine`
    registry (``"jax"`` consults the jitted engine; ``"numpy"`` the
    reference engine — useful where no accelerator/XLA is wanted on the
    hot path).  Model times accompany each schedule so callers can
    decide whether measuring is worth it (close calls) or not.
    ``profile`` ranks the schedules under a ragged step profile instead
    of the uniform split (skew-aware tuning).

    This is a thin alias of :func:`repro.core.engine.shortlist`, kept
    for backward compatibility.
    """
    from repro.core.engine import shortlist as _shortlist

    return _shortlist(
        gemm, machine, top=top, dma=dma, backend=backend, profile=profile
    )


__all__ = [
    "MachineArrays",
    "machine_arrays",
    "scenario_arrays",
    "evaluate_grid",
    "evaluate_grid_raw",
    "evaluate_ragged_grid",
    "evaluate_ragged_grid_raw",
    "ragged_step_times_jax",
    "gemm_exec_jax",
    "comm_time_jax",
    "ag_serial_time_jax",
    "p2p_step_time_jax",
    "a2a_chunk_step_time_jax",
    "hbm_move_time_jax",
    "gemm_cil_jax",
    "comm_cil_jax",
    "pipeline_jax",
    "soft_pick_weights",
    "expected_heuristic_time",
    "calibrate_tau",
    "calibrate_tau_reference",
    "shortlist",
]
