"""Runtime schedule autotuner: analytic shortlist -> (optional) measure
-> persistent record.

The paper's heuristic picks a schedule from static GEMM signals alone
(~81% of unseen scenarios within 5%).  The autotuner closes the rest of
the gap at runtime, in three escalating tiers:

  1. **cache hit** — a previous process already tuned this
     ``(machine, group, M, N, K, dtype)`` key: zero cost.
  2. **analytic** — the jitted cost model (:mod:`repro.autotune.jaxgrid`)
     ranks all schedules for the key in one device call; the winner is
     recorded.  This is strictly better-informed than the static decision
     tree (it sees the full simulated pipeline, not two thresholds) at
     microseconds of cost.
  3. **measured** — for keys worth it (long-lived serving configs), time
     the analytic shortlist's top candidates with real executions of the
     ``repro.overlap.schedules`` collectives and record the empirical
     winner.

Decisions persist via :class:`repro.autotune.cache.AutotuneCache`, so
tier 2/3 run once per key per (machine, jax version) — every later
process starts at tier 1.  ``ficco_linear(schedule="autotune")`` is the
integration point; ``select_schedule`` remains the zero-cost fallback
whenever anything here fails.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

from repro.core.heuristics import select_schedule
from repro.core.machine import TPU_V5E, MachineSpec, machine_for_group
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape
from repro.obs import audit as _audit
from repro.obs import metrics as _metrics
from repro.obs import signature as _signature
from repro.obs import trace as _trace

from repro.autotune.cache import AutotuneCache


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """Cache identity of one data-dependent AG->GEMM site.

    ``profile`` is the ragged step-profile digest
    (:meth:`repro.core.workload.StepProfile.digest`): ``uG`` for the
    paper's uniform G-step split, a name+hash for skewed profiles.  Its
    arrival is the schema-v2 key change — see ``repro.autotune.cache``.

    ``variant`` is the optional trailing kernel-variant segment
    (:attr:`repro.tune.KernelVariant.key_segment`, ``v`` + digest).  A
    non-empty variant makes the key an 8-segment *variant-timing* record
    — per-variant measurements feeding ``repro.learn.fit`` — while the
    7-segment keys stay the schedule-decision records every existing
    consumer parses (they skip variant keys structurally: the extra
    segment lands in the profile slot and fails the ``u\\d+`` filter).
    """

    machine: str
    group: int
    m: int
    n: int
    k: int
    dtype_bytes: int
    profile: str = "uniform"
    variant: str = ""

    def __str__(self) -> str:
        base = (
            f"{self.machine}/g{self.group}/m{self.m}/n{self.n}"
            f"/k{self.k}/b{self.dtype_bytes}/{self.profile}"
        )
        return f"{base}/{self.variant}" if self.variant else base

    @classmethod
    def for_gemm(
        cls,
        gemm: GemmShape,
        machine: MachineSpec,
        group: int | None = None,
        profile=None,
        variant=None,
    ) -> "TuneKey":
        g = int(group if group is not None else machine.group)
        if variant is None:
            vseg = ""
        elif isinstance(variant, str):
            vseg = variant if variant.startswith("v") else "v" + variant
        else:
            vseg = variant.key_segment
        return cls(
            machine=machine.name,
            group=g,
            m=gemm.m,
            n=gemm.n,
            k=gemm.k,
            dtype_bytes=gemm.dtype_bytes,
            profile=f"u{g}" if profile is None else profile.digest(),
            variant=vseg,
        )


@dataclasses.dataclass(frozen=True)
class TuneDecision:
    """One schedule decision plus its provenance.

    ``key`` is the :class:`TuneKey` string the decision was made under
    (None only for pre-provenance constructions), ``shortlist`` the
    analytic ranking consulted — ``(schedule value, modelled seconds)``
    pairs, empty when no ranking ran (cache hit, heuristic fallback) —
    and ``gate`` the learned-gate verdict behind a heuristic decision
    (``{"metric": ..., "threshold": ..., "reason": ...}``).
    """

    schedule: Schedule
    source: str  # "cache" | "analytic" | "measured" | "heuristic"
    model_total_s: float | None = None
    measured_total_s: float | None = None
    key: str | None = None
    shortlist: tuple = ()
    gate: dict | None = None


def _runtime_executable(gemm: GemmShape, group: int, sched: Schedule) -> bool:
    """Can ``ficco_linear`` actually run this schedule for this shape?

    Mirrors the runtime's ``overlap.api._divisible`` guard (the 1D FiCCO
    schedules chunk the per-device shard one level deeper than the cost
    model's validity mask requires).
    """
    from repro.overlap.api import _divisible  # lazy: overlap pulls in jax

    if gemm.m % group:  # shard_map cannot even row-shard the operand
        return sched is Schedule.SERIAL
    return _divisible(gemm.m // group, gemm.k, group, sched)


class Autotuner:
    """Tiered schedule selection with a persistent decision store.

    ``backend`` names the analytic engine in the
    :mod:`repro.core.engine` registry: ``"jax"`` (jitted, default),
    ``"numpy"`` (reference) or any registered third-party engine.
    Every decision — including analytic ones — is recorded, so repeated
    trace-time queries from ``jax.jit`` re-traces cost one dict lookup.
    """

    def __init__(
        self,
        cache: AutotuneCache | None = None,
        *,
        backend: str = "jax",
        persist: bool | str = True,
        gate=None,
        audit=None,
    ):
        from repro.core.engine import get_engine

        get_engine(backend)  # fail fast: ValueError lists valid engines
        self.cache = cache if cache is not None else AutotuneCache()
        self.backend = backend
        # True = eager save per decision, False = in-memory only,
        # "defer" = batched persistence (cache.flush() / atexit) — the
        # serving hot path's choice (see repro.serve.adapt).
        self.persist = persist
        self.hits = 0
        self.misses = 0
        self._gate = gate
        # Decision-audit destination: an AuditLog pins it, None defers
        # to the process-wide log (repro.obs.audit — re-checked every
        # decision, so REPRO_AUTOTUNE_AUDIT/enable_audit() apply to
        # already-built tuners), False disables auditing for this tuner
        # (the offline replayer uses this so replays never append to
        # the log being replayed).
        self._audit = audit
        # Artifact gates load lazily, once per artifact name ("default"
        # plus one "machine:<family>" slot per family queried).
        self._artifact_gates: dict = {}

    def set_gate(self, gate) -> None:
        """Atomically swap the explicit learned gate this tuner consults.

        One attribute store (atomic under the GIL), so a background
        re-fit thread can install a freshly trained gate while request
        threads are mid-``pick`` — each pick sees either the old or the
        new gate, never a torn state.  ``None`` reverts to the ambient
        gate resolution order (see :meth:`learned_gate`).
        """
        self._gate = gate

    @property
    def gate(self):
        """The explicitly installed gate (``set_gate``), or ``None``."""
        return self._gate

    # -- observability ---------------------------------------------------

    def _audit_log(self):
        if self._audit is False:
            return None
        if self._audit is not None:
            return self._audit
        return _audit.get_audit()

    def _observe(self, kind: str, key: TuneKey, dec: TuneDecision,
                 seconds: float, *, gemm=None, machine=None,
                 group=None, profile=None) -> None:
        """Metrics + audit + signature attribution for one decision.
        Never raises — the tuner's never-raise contract outranks
        observability.

        ``gemm``/``machine``/``group``/``profile`` carry the live
        scenario objects to the signature stream: the :class:`TuneKey`
        alone cannot reconstruct a ragged step profile (digests are
        one-way), so attribution takes the originals.
        """
        try:
            reg = _metrics.get_metrics()
            reg.counter("tuner/decisions").inc()
            reg.counter(f"tuner/pick.{dec.source}").inc()
            reg.histogram("tuner/pick_seconds").observe(seconds)
            stream = _signature.get_signatures()
            if stream is not None and gemm is not None and machine is not None:
                stream.observe_decision(
                    gemm, machine, dec.schedule,
                    group=group, profile=profile, source=dec.source,
                    model_total_s=dec.model_total_s,
                    measured_total_s=dec.measured_total_s,
                )
            log = self._audit_log()
            if log is not None:
                log.record({
                    "kind": kind,
                    "key": str(key),
                    "machine": key.machine,
                    "group": key.group,
                    "m": key.m,
                    "n": key.n,
                    "k": key.k,
                    "dtype_bytes": key.dtype_bytes,
                    "profile": key.profile,
                    "schedule": dec.schedule.value,
                    "source": dec.source,
                    "model_total_s": dec.model_total_s,
                    "measured_total_s": dec.measured_total_s,
                    "shortlist": list(dec.shortlist),
                    "gate": dec.gate,
                })
        except Exception:  # pragma: no cover - observability best-effort
            pass

    def learned_gate(self, machine=None):
        """The learned serial-gate family this tuner's fallback consults.

        Resolution order: explicit ``gate=`` constructor argument, the
        process-wide gates (``repro.learn.gate`` — the ``machine``'s
        family gate first, then the global default; both re-checked on
        every call, so installing or clearing one after this tuner was
        built takes effect immediately), then gates persisted in this
        cache's artifact segment (family slot ahead of the default
        slot, each loaded once).  The learned family takes precedence
        over the hand-tuned scalar gate inside ``select_schedule``;
        None means "no learned gate" and the scalar gate applies as
        before.
        """
        if self._gate is not None:
            return self._gate
        try:
            from repro.learn import gate as _gate_mod
        except Exception:  # pragma: no cover - learn is a sibling package
            return None
        if machine is not None:
            fam = _gate_mod.get_machine_gate(machine)
            if fam is not None:
                return fam
        ambient = _gate_mod.get_default_gate()
        if ambient is not None:
            return ambient
        names = ["default"]
        if machine is not None:
            names.insert(
                0,
                _gate_mod.MACHINE_GATE_PREFIX
                + _gate_mod.machine_family(machine),
            )
        for name in names:
            if name not in self._artifact_gates:
                try:
                    self._artifact_gates[name] = _gate_mod.load_gate(
                        cache=self.cache, name=name
                    )
                except Exception:
                    self._artifact_gates[name] = None
            if self._artifact_gates[name] is not None:
                return self._artifact_gates[name]
        return None

    # -- tier 1+2: cache / analytic ------------------------------------

    def pick(
        self,
        gemm: GemmShape,
        machine: MachineSpec | None = None,
        *,
        group: int | None = None,
        profile=None,
    ) -> TuneDecision:
        """Cached winner if present, else the best *executable* analytic
        winner (recorded).

        The cost model's validity mask (global M divisible by the group)
        is weaker than the runtime chunking rule for the 1D FiCCO
        schedules (the per-device shard must split again: M/g % g == 0),
        so the ranking is filtered through the same ``_divisible`` check
        ``ficco_linear`` applies — a persisted winner is always one the
        runtime will actually execute, never silently swapped for serial.

        ``profile`` tunes for a ragged step profile (capacity-skewed EP
        dispatch): the decision is keyed and ranked per profile digest,
        so a hot-expert skew and the uniform split coexist in the cache.

        Never raises: any model/backend failure degrades to the static
        heuristic (``select_schedule``) — the zero-cost fallback — and
        that decision is *not* persisted, so a healthy later process
        re-tunes.
        """
        machine = machine or TPU_V5E
        tkey = TuneKey.for_gemm(gemm, machine, group, profile=profile)
        key = str(tkey)
        t0 = time.perf_counter()
        with _trace.span("tuner/pick", "autotune", key=key) as sp:
            dec = self._pick_impl(gemm, machine, key, group, profile)
            sp.set(
                tier=dec.source,
                schedule=dec.schedule.value,
                cache="hit" if dec.source == "cache" else "miss",
                shortlist=[[s, t] for s, t in dec.shortlist],
                **({"gate": dec.gate} if dec.gate is not None else {}),
            )
        self._observe(
            "pick", tkey, dec, time.perf_counter() - t0,
            gemm=gemm, machine=machine, group=group, profile=profile,
        )
        return dec

    def _pick_impl(
        self, gemm, machine, key: str, group, profile
    ) -> TuneDecision:
        hit = self.cache.get(key)
        if hit is not None:
            try:
                sched = Schedule(hit["schedule"])
            except (KeyError, ValueError):
                sched = None
            if sched is not None:
                self.hits += 1
                return TuneDecision(
                    sched,
                    "cache",
                    hit.get("model_total_s"),
                    hit.get("measured_total_s"),
                    key=key,
                )
        self.misses += 1
        eff = machine_for_group(machine, group) if group else machine
        try:
            ranked = self.executable_ranking(gemm, eff, profile=profile)
            sched, model_t = ranked[0]  # serial always survives the filter
        except Exception:
            # Zero-cost fallback, against the group-retargeted machine so
            # the decision tree + serial gate see the real group size;
            # a learned gate (sweep-trained threshold family) is
            # consulted ahead of the hand-tuned scalar gate.  The
            # never-raise contract outranks the gate: a malformed gate
            # artifact degrades to the scalar-gated tree.
            gate_info = None
            try:
                gate = self.learned_gate(eff)
                dec = select_schedule(gemm, eff, profile=profile, gate=gate)
                gate_info = {
                    "kind": type(gate).__name__ if gate is not None else None,
                    "metric": dec.metric,
                    "threshold": dec.threshold,
                    "reason": dec.reason,
                }
            except Exception:
                dec = select_schedule(gemm, eff, profile=profile)
                gate_info = {
                    "kind": None,
                    "metric": dec.metric,
                    "threshold": dec.threshold,
                    "reason": dec.reason,
                }
            return TuneDecision(
                dec.schedule, "heuristic", key=key, gate=gate_info
            )
        self._record(key, sched, "analytic", model_total_s=model_t)
        return TuneDecision(
            sched, "analytic", model_t, key=key,
            shortlist=tuple((s.value, float(t)) for s, t in ranked[:3]),
        )

    def executable_ranking(
        self,
        gemm: GemmShape,
        machine: MachineSpec,
        *,
        group: int | None = None,
        profile=None,
    ) -> list[tuple[Schedule, float]]:
        """Full analytic ranking filtered to runtime-executable schedules.

        Uniform AG->GEMM path: ficco_linear chunks the shard one level
        deeper, so the ranking is filtered by its divisibility rule.
        Ragged picks go to the profile-quantized kernel path
        (ficco_a2a_ffn), which handles arbitrary chunk sizes — the cost
        model's own validity mask already applied.  Shared by
        ``_pick_impl`` and the adaptive serving tier
        (:mod:`repro.serve.adapt`), so an online re-rank can never pick
        a schedule the runtime would refuse.
        """
        eff = machine_for_group(machine, group) if group else machine
        ranked = self._shortlist(gemm, eff, top=None, profile=profile)
        if profile is None:
            ranked = [
                (s, t) for s, t in ranked
                if _runtime_executable(gemm, eff.group, s)
            ]
        return ranked

    def shortlist(
        self,
        gemm: GemmShape,
        machine: MachineSpec | None = None,
        *,
        group: int | None = None,
        top: int = 3,
        profile=None,
    ) -> list[tuple[Schedule, float]]:
        """Analytic top-``top`` candidates (schedule, modelled seconds)."""
        machine = machine or TPU_V5E
        eff = machine_for_group(machine, group) if group else machine
        return self._shortlist(gemm, eff, top=top, profile=profile)

    def _shortlist(self, gemm, machine, *, top, profile=None):
        from repro.core import engine as _engine

        if top is None:
            top = len(_engine.GRID_SCHEDULES)
        eng = _engine.get_engine(self.backend)
        if not eng.trace_safe:
            # Trace-time queries (ficco_linear under jit/shard_map) must
            # not stage the cost model into the caller's computation —
            # shapes are concrete there, so a trace-safe host engine
            # answers instead.
            import jax as _jax

            if not _jax.core.trace_state_clean():
                eng = _engine.get_engine("numpy")
        with _trace.span(
            "tuner/shortlist", "autotune", engine=eng.name, top=top
        ) as sp:
            out = _engine.shortlist(
                gemm, machine, top=top, engine=eng, profile=profile
            )
            sp.set(ranking=[[s.value, float(t)] for s, t in out])
        if not out:
            raise ValueError(f"no valid schedule for {gemm}")
        return out

    # -- tier 3: measured ----------------------------------------------

    def measure(
        self,
        x,
        w,
        *,
        mesh,
        axis_name: str,
        machine: MachineSpec | None = None,
        schedules: Sequence[Schedule] | None = None,
        iters: int = 3,
    ) -> TuneDecision:
        """Time real executions of the shortlist and record the winner.

        ``x`` is the *global* (M, K) activation, ``w`` the global (K, N)
        weight; both are sharded by the shard_map exactly as
        ``ficco_linear`` runs them.  The winner is persisted with
        ``source="measured"``, which tier-1 lookups prefer forever after.
        """
        import jax
        import numpy as np
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.overlap.api import _divisible
        from repro.overlap.schedules import SCHEDULE_FNS

        machine = machine or TPU_V5E
        g = mesh.shape[axis_name]
        m, k = x.shape
        n = w.shape[1]
        gemm = GemmShape(m, n, k, x.dtype.itemsize)
        tkey = TuneKey.for_gemm(gemm, machine, g)
        key = str(tkey)
        t0 = time.perf_counter()

        if schedules is None:
            try:
                ranked = self.shortlist(gemm, machine, group=g, top=3)
                schedules = [s for s, _ in ranked]
            except Exception:
                schedules = [Schedule.SERIAL]
        candidates = [
            s for s in schedules if _divisible(m // g, k, g, s)
        ] or [Schedule.SERIAL]

        timings: dict[Schedule, float] = {}
        for sched in candidates:
            fn = jax.jit(
                shard_map(
                    functools.partial(
                        SCHEDULE_FNS[sched], axis_name=axis_name
                    ),
                    mesh=mesh,
                    in_specs=(P(axis_name, None), P(None, axis_name)),
                    out_specs=P(None, axis_name),
                    check_vma=False,
                )
            )
            with _trace.span(
                "tuner/measure_candidate", "autotune",
                key=key, schedule=sched.value,
            ) as sp:
                try:
                    fn(x, w).block_until_ready()  # compile + warm
                    best = float("inf")
                    for _ in range(iters):
                        t1 = time.perf_counter()
                        fn(x, w).block_until_ready()
                        best = min(best, time.perf_counter() - t1)
                    timings[sched] = best
                    sp.set(seconds=best)
                except Exception:
                    sp.set(failed=True)
                    continue  # schedule not executable here; skip it

        if not timings:
            dec = self.pick(gemm, machine, group=g)
            return dec
        winner = min(timings, key=timings.get)
        self._record(
            key, winner, "measured", measured_total_s=timings[winner]
        )
        dec = TuneDecision(
            winner, "measured", measured_total_s=timings[winner], key=key,
            shortlist=tuple(
                (s.value, float(t))
                for s, t in sorted(timings.items(), key=lambda kv: kv[1])
            ),
        )
        try:
            _metrics.get_metrics().counter("tuner/measure").inc()
        except Exception:  # pragma: no cover
            pass
        self._observe(
            "measure", tkey, dec, time.perf_counter() - t0,
            gemm=gemm, machine=machine, group=g,
        )
        return dec

    def measure_variants(
        self,
        kernel: str,
        gemm: GemmShape,
        variants,
        *,
        machine: MachineSpec | None = None,
        group: int | None = None,
        profile=None,
        runner=None,
        iters: int = 1,
    ) -> list[tuple]:
        """Time kernel variants and persist variant-keyed records.

        ``runner(variant) -> seconds`` measures for real (the caller owns
        the mesh / sharded operands); with ``runner=None`` the
        deterministic discrete-event cost model (:mod:`repro.tune.cost`)
        stands in — the interpret-mode CI substitute, still
        variant-sensitive through wave quantization and the buffer-depth
        recurrence.

        Every variant's time lands at the 8-segment variant-keyed
        :class:`TuneKey` with the kernel name, variant digest, and (for
        skewed profiles) the raw step fractions in the entry, so
        ``repro.learn.fit.variant_records_from_cache`` can rebuild the
        fit objective — including the ragged one — from the cache alone.
        Returns ``[(variant, seconds), ...]`` in input order.
        """
        from repro.tune.cost import variant_cost
        from repro.tune.variants import KERNEL_SCHEDULE

        machine = machine or TPU_V5E
        g = int(group if group is not None else machine.group)
        sched = KERNEL_SCHEDULE[kernel]
        out: list[tuple] = []
        for variant in variants:
            if runner is not None:
                best = float("inf")
                for _ in range(max(1, iters)):
                    best = min(best, float(runner(variant)))
                source = "measured"
            else:
                best = float(
                    variant_cost(
                        variant, gemm, machine, group=g, profile=profile
                    )
                )
                source = "variant-model"
            key = str(
                TuneKey.for_gemm(
                    gemm, machine, g, profile=profile, variant=variant
                )
            )
            entry = {
                "schedule": sched.value,
                "source": source,
                "model_total_s": None if runner is not None else best,
                "measured_total_s": best,
                "kernel": kernel,
                "variant": variant.digest(),
            }
            if profile is not None:
                entry["profile_frac"] = [
                    float(f) for f in profile.trimmed().fractions
                ]
            self.cache.put(key, entry, persist=self.persist)
            out.append((variant, best))
        try:
            _metrics.get_metrics().counter("tuner/measure_variants").inc(
                len(out)
            )
        except Exception:  # pragma: no cover
            pass
        return out

    # -- bookkeeping ----------------------------------------------------

    def _record(
        self,
        key: str,
        schedule: Schedule,
        source: str,
        *,
        model_total_s: float | None = None,
        measured_total_s: float | None = None,
    ) -> None:
        self.cache.put(
            key,
            {
                "schedule": schedule.value,
                "source": source,
                "model_total_s": model_total_s,
                "measured_total_s": measured_total_s,
            },
            persist=self.persist,
        )

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


# ---------------------------------------------------------------------------
# Process-wide tuner (what ``ficco_linear(schedule="autotune")`` consults).
# ---------------------------------------------------------------------------

_GLOBAL_TUNER: Autotuner | None = None


def get_tuner() -> Autotuner:
    global _GLOBAL_TUNER
    if _GLOBAL_TUNER is None:
        _GLOBAL_TUNER = Autotuner()
    return _GLOBAL_TUNER


def set_tuner(tuner: Autotuner | None) -> None:
    global _GLOBAL_TUNER
    _GLOBAL_TUNER = tuner


def reset_tuner() -> None:
    """Drop the global tuner (e.g. after changing the cache env var)."""
    set_tuner(None)


def autotune_schedule(
    m: int,
    n: int,
    k: int,
    *,
    machine: MachineSpec | None = None,
    group: int | None = None,
    dtype_bytes: int = 2,
    profile=None,
) -> Schedule:
    """One-call convenience: tuned schedule for a global (M, N, K) GEMM.

    ``profile`` (a :class:`~repro.core.workload.StepProfile`) tunes for
    a ragged (e.g. capacity-skewed EP) step decomposition.
    """
    return get_tuner().pick(
        GemmShape(m, n, k, dtype_bytes), machine, group=group,
        profile=profile,
    ).schedule


__all__ = [
    "TuneKey",
    "TuneDecision",
    "Autotuner",
    "machine_for_group",
    "get_tuner",
    "set_tuner",
    "reset_tuner",
    "autotune_schedule",
]
