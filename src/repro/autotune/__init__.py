"""repro.autotune — on-accelerator cost model + runtime schedule autotuner.

Three layers (see ROADMAP "jax.jit backend" follow-on, now done):

  * :mod:`repro.autotune.jaxgrid` — the batched FiCCO grid engine ported
    to ``jax.numpy``: jit-compiled, vmapped over machines, numerically
    equivalent to ``repro.core.batch`` and differentiable through TAU
    and every machine parameter (``calibrate_tau`` = a few Adam steps).
  * :mod:`repro.autotune.tuner` — tiered runtime selection: persistent
    cache hit -> analytic model -> optional measured shortlist.
  * :mod:`repro.autotune.cache` — versioned on-disk JSON store
    (``$REPRO_AUTOTUNE_CACHE_DIR``, default ``~/.cache/repro_autotune``).

The three-line on-accelerator sweep::

    from repro.autotune import evaluate_grid
    grid = evaluate_grid(scenarios, machines, backend="jax")
    print(grid.best_idx())

and the runtime entry point is ``ficco_linear(schedule="autotune")``
(see ``repro.overlap.api``), with ``select_schedule`` as the zero-cost
static fallback.
"""

from repro.autotune.cache import (
    SCHEMA_VERSION,
    AutotuneCache,
    default_cache_dir,
    default_cache_path,
)
from repro.autotune.jaxgrid import (
    MachineArrays,
    calibrate_tau,
    calibrate_tau_reference,
    evaluate_grid_raw,
    evaluate_ragged_grid_raw,
    expected_heuristic_time,
    machine_arrays,
    scenario_arrays,
    shortlist,
    soft_pick_weights,
)
from repro.autotune.jaxgrid import evaluate_grid as evaluate_grid_jax
from repro.autotune.jaxgrid import (
    evaluate_ragged_grid as evaluate_ragged_grid_jax,
)
from repro.autotune.tuner import (
    Autotuner,
    TuneDecision,
    TuneKey,
    autotune_schedule,
    get_tuner,
    machine_for_group,
    reset_tuner,
    set_tuner,
)


def evaluate_grid(scenarios, machines, *, backend: str = "jax", **kw):
    """Backend-switched grid evaluation: ``"jax"`` (jitted) or ``"numpy"``
    (the reference engine in ``repro.core.batch``).  Identical
    :class:`~repro.core.batch.GridResult` either way.
    """
    if backend == "jax":
        return evaluate_grid_jax(scenarios, machines, **kw)
    if backend == "numpy":
        from repro.core.batch import evaluate_grid as _np_grid

        return _np_grid(scenarios, machines, **kw)
    raise ValueError(f"backend must be 'jax'|'numpy', got {backend!r}")


def evaluate_ragged_grid(scenarios, machines, *, backend: str = "jax", **kw):
    """Backend-switched **ragged** grid evaluation (non-uniform step
    profiles); see ``repro.core.batch.evaluate_ragged_grid``."""
    if backend == "jax":
        return evaluate_ragged_grid_jax(scenarios, machines, **kw)
    if backend == "numpy":
        from repro.core.batch import (
            evaluate_ragged_grid as _np_ragged,
        )

        return _np_ragged(scenarios, machines, **kw)
    raise ValueError(f"backend must be 'jax'|'numpy', got {backend!r}")


__all__ = [
    "SCHEMA_VERSION",
    "AutotuneCache",
    "default_cache_dir",
    "default_cache_path",
    "MachineArrays",
    "machine_arrays",
    "scenario_arrays",
    "evaluate_grid",
    "evaluate_grid_jax",
    "evaluate_grid_raw",
    "evaluate_ragged_grid",
    "evaluate_ragged_grid_jax",
    "evaluate_ragged_grid_raw",
    "expected_heuristic_time",
    "soft_pick_weights",
    "calibrate_tau",
    "calibrate_tau_reference",
    "shortlist",
    "Autotuner",
    "TuneDecision",
    "TuneKey",
    "autotune_schedule",
    "get_tuner",
    "set_tuner",
    "reset_tuner",
    "machine_for_group",
]
