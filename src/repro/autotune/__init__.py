"""repro.autotune — on-accelerator cost model + runtime schedule autotuner.

Three layers (see ROADMAP "jax.jit backend" follow-on, now done):

  * :mod:`repro.autotune.jaxgrid` — the batched FiCCO grid engine ported
    to ``jax.numpy``: jit-compiled, vmapped over machines, numerically
    equivalent to ``repro.core.batch`` and differentiable through TAU
    and every machine parameter (``calibrate_tau`` = a few Adam steps).
  * :mod:`repro.autotune.tuner` — tiered runtime selection: persistent
    cache hit -> analytic model -> optional measured shortlist.
  * :mod:`repro.autotune.cache` — versioned on-disk JSON store
    (``$REPRO_AUTOTUNE_CACHE_DIR``, default ``~/.cache/repro_autotune``).

The three-line on-accelerator sweep::

    from repro.autotune import evaluate_grid
    grid = evaluate_grid(scenarios, machines, backend="jax")
    print(grid.best_idx())

and the runtime entry point is ``ficco_linear(schedule="autotune")``
(see ``repro.overlap.api``), with ``select_schedule`` as the zero-cost
static fallback.
"""

from repro.autotune.cache import (
    SCHEMA_VERSION,
    AutotuneCache,
    default_cache_dir,
    default_cache_path,
)
from repro.autotune.jaxgrid import (
    MachineArrays,
    calibrate_tau,
    calibrate_tau_reference,
    evaluate_grid_raw,
    evaluate_ragged_grid_raw,
    expected_heuristic_time,
    machine_arrays,
    scenario_arrays,
    shortlist,
    soft_pick_weights,
)
from repro.autotune.jaxgrid import evaluate_grid as evaluate_grid_jax
from repro.autotune.jaxgrid import (
    evaluate_ragged_grid as evaluate_ragged_grid_jax,
)
from repro.autotune.tuner import (
    Autotuner,
    TuneDecision,
    TuneKey,
    autotune_schedule,
    get_tuner,
    machine_for_group,
    reset_tuner,
    set_tuner,
)


def evaluate_grid(scenarios, machines, *, backend: str = "jax", **kw):
    """Backend-switched grid evaluation via the engine registry:
    ``"jax"`` (jitted), ``"numpy"`` (the reference engine in
    ``repro.core.batch``), ``"scalar"``, or any registered engine.
    Identical :class:`~repro.core.engine.GridResult` either way.
    """
    from repro.core.engine import get_engine

    return get_engine(backend).evaluate(scenarios, machines, **kw)


def evaluate_ragged_grid(scenarios, machines, *, backend: str = "jax", **kw):
    """Backend-switched **ragged** grid evaluation (non-uniform step
    profiles); see ``repro.core.batch.evaluate_ragged_grid``."""
    from repro.core.engine import (
        as_scenario_sequence,
        get_engine,
        is_ragged,
    )

    scenarios = as_scenario_sequence(scenarios)
    if not is_ragged(scenarios):
        raise TypeError(
            "ragged evaluation needs RaggedScenario items or a RaggedBatch"
        )
    return get_engine(backend).evaluate(scenarios, machines, **kw)


__all__ = [
    "SCHEMA_VERSION",
    "AutotuneCache",
    "default_cache_dir",
    "default_cache_path",
    "MachineArrays",
    "machine_arrays",
    "scenario_arrays",
    "evaluate_grid",
    "evaluate_grid_jax",
    "evaluate_grid_raw",
    "evaluate_ragged_grid",
    "evaluate_ragged_grid_jax",
    "evaluate_ragged_grid_raw",
    "expected_heuristic_time",
    "soft_pick_weights",
    "calibrate_tau",
    "calibrate_tau_reference",
    "shortlist",
    "Autotuner",
    "TuneDecision",
    "TuneKey",
    "autotune_schedule",
    "get_tuner",
    "set_tuner",
    "reset_tuner",
    "machine_for_group",
]
