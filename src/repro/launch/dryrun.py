import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh).

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes, every
step function is lowered from ShapeDtypeStructs (no allocation), compiled
through full SPMD partitioning, and the compiled artifact yields the
memory analysis + the three roofline terms (repro.roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--overlap-mode ficco_auto] \
      [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all  # full 10x4 matrix
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh as _set_mesh  # noqa: E402
from repro.configs import ARCHS, SHAPES, get_config  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.launch import specs as specmod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.parallel.context import overlap_context  # noqa: E402
from repro.parallel.sharding import (  # noqa: E402
    BATCH_AXES,
    cache_specs,
    filter_pspec,
    fix_param_specs,
)
from repro.roofline import analysis as roofline  # noqa: E402
from repro.roofline import counters  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402

# Full-attention families run long_500k via their sliding-window variant
# (DESIGN.md §5); SSM/hybrid run it natively.
LONG_CONTEXT_WINDOW = 8192


def prepared_config(arch: str, shape: ShapeConfig, overlap: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape.name == "long_500k" and cfg.family.value in (
        "dense", "moe", "vlm", "audio"
    ):
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    if overlap != "gspmd_serial":
        cfg = dataclasses.replace(
            cfg,
            overlap=dataclasses.replace(cfg.overlap, mode=overlap),
        )
    return cfg


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, filter_pspec(sp, mesh)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_specs(batch_shapes, mesh):
    def leaf(l):
        b = l.shape[0]
        dp = 1
        axes = tuple(a for a in BATCH_AXES if a in mesh.shape)
        for a in axes:
            dp *= mesh.shape[a]
        if b % dp == 0 and dp > 1:
            return P(axes, *([None] * (len(l.shape) - 1)))
        return P(*([None] * len(l.shape)))

    return jax.tree.map(leaf, batch_shapes)


def _build_jitted(cfg, shape, mesh, accum_steps: int = 1):
    """(jitted, abstract_args) for the step function of this shape kind."""
    model = build_model(cfg)
    param_shapes = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0))
    )
    pspecs = fix_param_specs(model.param_specs(), param_shapes, mesh)
    big = (
        sum(
            float(jnp.prod(jnp.array(l.shape)))
            for l in jax.tree.leaves(param_shapes)
        )
        > 1e11
    )
    if True:
        if shape.kind == "train":
            ocfg = opt.OptimizerConfig(
                moment_dtype="bfloat16" if big else "float32"
            )
            state_shapes = {
                "params": param_shapes,
                "opt_state": jax.eval_shape(
                    lambda: opt.init_state(param_shapes, ocfg.moment_dtype)
                ),
            }
            state_specs = {
                "params": pspecs,
                "opt_state": opt.state_specs(pspecs),
            }
            batch_shapes = specmod.train_specs(cfg, shape)
            bspecs = _batch_specs(batch_shapes, mesh)
            fn = make_train_step(model, ocfg, accum_steps=accum_steps)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    _named(mesh, state_specs), _named(mesh, bspecs)
                ),
            )
            args = (state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            batch_shapes = specmod.train_specs(cfg, shape)
            bspecs = _batch_specs(batch_shapes, mesh)

            def fwd(params, batch):
                with overlap_context(cfg.overlap):
                    logits, _ = model.forward(params, batch)
                return logits

            jitted = jax.jit(
                fwd,
                in_shardings=(
                    _named(mesh, pspecs), _named(mesh, bspecs)
                ),
            )
            args = (param_shapes, batch_shapes)
        else:  # decode
            dspec = specmod.decode_specs(cfg, shape, model)
            cspecs = cache_specs(dspec["cache"], mesh)
            tspec = _batch_specs({"tokens": dspec["tokens"]}, mesh)["tokens"]

            def serve_step(params, cache, tokens, pos):
                with overlap_context(cfg.overlap):
                    return model.decode_step(params, cache, tokens, pos)

            jitted = jax.jit(
                serve_step,
                in_shardings=(
                    _named(mesh, pspecs),
                    _named(mesh, cspecs),
                    NamedSharding(mesh, filter_pspec(tspec, mesh)),
                    NamedSharding(mesh, P()),
                ),
            )
            args = (
                param_shapes, dspec["cache"], dspec["tokens"], dspec["pos"]
            )
    return jitted, args, cfg


def _compile(cfg, shape, mesh):
    jitted, args, _ = _build_jitted(cfg, shape, mesh)
    with _set_mesh(mesh):
        with overlap_context(cfg.overlap):
            lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_triple(compiled):
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    coll = roofline.parse_collectives(compiled.as_text())
    return flops, nbytes, coll.total_bytes


def extrapolated_collectives(cfg, shape, mesh):
    """Collective bytes corrected for the layer scan: compile UNROLLED
    1-period and 2-period variants, take the per-period delta, scale to
    full depth (collectives never live inside time scans; see counters).
    Returns (collective_bytes, hlo_flops_extrap, hlo_bytes_extrap)."""
    period = len(
        __import__("repro.models.model", fromlist=["layer_pattern"])
        .layer_pattern(cfg)
    )
    n_periods = cfg.num_layers // period
    if n_periods < 2:
        c = _compile(cfg, shape, mesh)
        return _cost_triple(c)[2], None, None
    enc = cfg.encdec
    mk = lambda k: dataclasses.replace(
        cfg,
        num_layers=k * period,
        scan_layers=False,
        encdec=dataclasses.replace(
            enc, encoder_layers=max(1, k * enc.encoder_layers // n_periods)
        )
        if enc
        else None,
    )
    f1, b1, c1 = _cost_triple(_compile(mk(1), shape, mesh))
    f2, b2, c2 = _cost_triple(_compile(mk(2), shape, mesh))
    body = (f2 - f1, b2 - b1, c2 - c1)
    out = (2 * f1 - f2, 2 * b1 - b2, 2 * c1 - c2)
    total = tuple(
        max(o + bd * n_periods, 0.0) for o, bd in zip(out, body)
    )
    return total[2], total[0], total[1]


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overlap: str = "gspmd_serial",
    verbose: bool = True,
    extrapolate: bool = True,
    transform=None,
    accum_steps: int = 1,
) -> dict:
    shape = SHAPES[shape_name]
    cfg = prepared_config(arch, shape, overlap)
    if transform is not None:
        cfg = transform(cfg)  # hillclimb config overrides (§Perf)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    jitted, args, _ = _build_jitted(cfg, shape, mesh, accum_steps)
    with _set_mesh(mesh):
        with overlap_context(cfg.overlap):
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    rf = roofline.analyze(
        arch=arch,
        shape=shape_name,
        mesh_name="2x16x16" if multi_pod else "16x16",
        chips=chips,
        compiled=compiled,
        model_flops=roofline.model_flops_for(cfg, shape, shape.kind),
    )
    raw = {
        "raw_hlo_flops": rf.hlo_flops,
        "raw_hlo_bytes": rf.hlo_bytes,
        "raw_collective_bytes": rf.collective_bytes,
    }
    # Analytic compute/memory terms (XLA cost_analysis counts scan bodies
    # once — see repro.roofline.counters) + depth-extrapolated collectives.
    ana = counters.step_costs(cfg, shape, shape.kind)
    rf.hlo_flops = ana.flops
    rf.hlo_bytes = ana.bytes
    if extrapolate:
        try:
            coll, _, _ = extrapolated_collectives(cfg, shape, mesh)
            rf.collective_bytes = coll
        except Exception:
            traceback.print_exc()
            raw["extrapolation_failed"] = True
    result = rf.to_dict()
    result.update(raw)
    result.update(
        overlap=overlap,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        ok=True,
    )
    if verbose:
        print(f"== {arch} x {shape_name} ({result['mesh']}, {overlap}) ==")
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print(
            f"cost: flops={result['hlo_flops']:.3e} "
            f"bytes={result['hlo_bytes']:.3e} "
            f"collective_bytes={result['collective_bytes']:.3e}"
        )
        print(
            f"roofline: compute={rf.t_compute*1e3:.2f}ms "
            f"memory={rf.t_memory*1e3:.2f}ms "
            f"collective={rf.t_collective*1e3:.2f}ms "
            f"dominant={rf.dominant} "
            f"useful={rf.useful_flops_ratio:.2f}"
        )
        print(f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--overlap-mode", default="gspmd_serial")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the unrolled-variant compiles (multi-pod "
                    "sweep: pass/fail + memory only; roofline is single-pod)")
    args = ap.parse_args()

    runs = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape in SHAPES:
                runs.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        runs.append((args.arch, args.shape))

    results = []
    for arch, shape in runs:
        try:
            results.append(
                dryrun_one(
                    arch, shape,
                    multi_pod=args.multi_pod,
                    overlap=args.overlap_mode,
                    extrapolate=not args.no_extrapolate,
                )
            )
        except Exception as e:
            traceback.print_exc()
            results.append(
                {"arch": arch, "shape": shape, "ok": False, "error": str(e)}
            )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if not r.get("ok")]
    print(f"\n{len(results) - len(bad)}/{len(results)} dry-runs passed")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
