"""Production mesh builders.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512
chips as (pod=2, data=16, model=16); the ``pod`` axis is pure data
parallelism over DCN, ``model`` is the TP/EP (FiCCO) axis along one ICI
torus dimension, ``data`` covers FSDP + batch.

Functions (not module constants) so importing never touches device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over however many (forced) host devices exist — used by
    examples and tests, never by the dry-run."""
    n = len(jax.devices())
    if model is None:
        model = n
    return jax.make_mesh((n // model, model), ("data", "model"))
