"""Input specs: ShapeDtypeStruct stand-ins (dry-run) or concrete batches.

``input_specs(cfg, shape)`` mirrors what the data pipeline / serving
frontend delivers for each assigned input shape:

  * train/prefill: {tokens, labels} (+ prefix_embeds for VLM, enc_frames
    for the audio enc-dec — the stubbed modality frontends).
  * decode: {tokens (B, 1), pos, cache} — serve_step operands; the cache
    covers the full ``seq_len`` context (ring-buffer-sized when the config
    uses a sliding window).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model, build_model


def _frontend_len(cfg: ModelConfig, seq: int) -> int:
    return min(cfg.frontend.prefix_tokens, seq // 2) if cfg.frontend else 0


def encoder_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if not cfg.encdec:
        return 0
    return max(16, int(shape.seq_len * cfg.encdec.encoder_len_ratio))


def train_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}
    if cfg.family.value == "vlm":
        p = _frontend_len(cfg, s)
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, p, cfg.frontend.embed_dim or cfg.d_model), jnp.bfloat16
        )
        s_text = s - p
    else:
        s_text = s
    if cfg.encdec:
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (b, encoder_len(cfg, shape), cfg.d_model), jnp.bfloat16
        )
    specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    return specs


def decode_specs(
    cfg: ModelConfig, shape: ShapeConfig, model: Model | None = None
) -> dict[str, Any]:
    model = model or build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    enc_len = encoder_len(cfg, dataclasses.replace(shape, seq_len=4096))
    cache = jax.eval_shape(
        lambda: model.init_cache(b, s, enc_len=enc_len)
    )
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, model: Model | None = None
) -> dict[str, Any]:
    if shape.is_decode:
        return decode_specs(cfg, shape, model)
    return train_specs(cfg, shape)


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Materialize a train/prefill batch (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, spec in train_specs(cfg, shape).items():
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, spec.shape), spec.dtype
            )
        else:
            out[name] = jnp.asarray(
                rng.standard_normal(spec.shape), spec.dtype
            )
    return out
