"""Training launcher.

Two modes:
  * --reduced (default): actually train the reduced variant on this host
    for a few hundred steps — the end-to-end driver (deliverable b).
  * --dry-run: delegate to launch.dryrun for the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 200 [--overlap-mode ficco_auto|ficco_autotune] \
      [--ckpt-dir /tmp/ckpt]

``--overlap-mode ficco_autotune`` routes every TP linear's schedule pick
through the persistent runtime autotuner (repro.autotune): the first
process pays microseconds per distinct GEMM shape for the jitted analytic
model, every later run starts from the on-disk cache.
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.train.loop import train
from repro.train.optimizer import OptimizerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument(
        "--overlap-mode", default="gspmd_serial",
        help="gspmd_serial | serial | shard_p2p | ficco_auto | "
        "ficco_autotune | explicit schedule value",
    )
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full (non-reduced) config — host-memory "
                    "bound; intended for cluster runs")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    if args.overlap_mode != "gspmd_serial":
        cfg = dataclasses.replace(
            cfg,
            overlap=dataclasses.replace(cfg.overlap, mode=args.overlap_mode),
        )
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    ocfg = OptimizerConfig(
        peak_lr=args.lr,
        warmup_steps=max(args.steps // 20, 5),
        decay_steps=args.steps,
    )
    res = train(
        cfg,
        shape,
        steps=args.steps,
        ocfg=ocfg,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
    )
    first, last = res["history"][0]["loss"], res["history"][-1]["loss"]
    print(f"done: loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
