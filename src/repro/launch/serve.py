"""Serving launcher: batched greedy decoding with the reduced model.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --prompts 4 --new-tokens 16 [--overlap-mode ficco_autotune]

``--overlap-mode ficco_autotune`` selects TP overlap schedules through
the persistent runtime autotuner (repro.autotune) — serving processes
restart often, so tuned decisions surviving on disk is exactly what the
cache is for.

``--adapt`` additionally runs the online-adaptation tier
(:mod:`repro.serve.adapt`): a bounded in-memory decision cache over the
persistent store, a background re-fit thread, and the
exploration-budget measured tier.  Knobs: ``--adapt-cache-size``,
``--adapt-ttl``, ``--adapt-refit-s``, ``--adapt-explore-rate``.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models.model import build_model
from repro.serve.engine import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument(
        "--overlap-mode", default="gspmd_serial",
        help="gspmd_serial | serial | shard_p2p | ficco_auto | "
        "ficco_autotune | explicit schedule value",
    )
    ap.add_argument(
        "--adapt", action="store_true",
        help="enable the online-adaptation tier (repro.serve.adapt)",
    )
    ap.add_argument("--adapt-cache-size", type=int, default=4096,
                    help="in-memory decision cache bound (LRU beyond)")
    ap.add_argument("--adapt-ttl", type=float, default=300.0,
                    help="decision TTL seconds (expiry forces a re-rank)")
    ap.add_argument("--adapt-refit-s", type=float, default=2.0,
                    help="background re-fit cadence seconds")
    ap.add_argument("--adapt-explore-rate", type=float, default=1.0,
                    help="measured-tier token-bucket refill (sessions/s)")
    ap.add_argument("--adapt-no-sentinel", action="store_true",
                    help="disable the drift sentinel (repro.obs.sentinel)")
    ap.add_argument("--signatures", metavar="PATH", default=None,
                    help="stream per-decision inefficiency signatures to "
                    "this JSONL path (repro.obs.signature)")
    args = ap.parse_args()

    if args.signatures:
        from repro.obs import signature as _signature

        _signature.enable_signatures(args.signatures)

    cfg = get_config(args.arch).reduced()
    if args.overlap_mode != "gspmd_serial":
        cfg = dataclasses.replace(
            cfg,
            overlap=dataclasses.replace(cfg.overlap, mode=args.overlap_mode),
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc_len = 16 if cfg.encdec else 0
    tier = None
    if args.adapt:
        from repro.serve.adapt import AdaptConfig, AdaptiveTier

        tier = AdaptiveTier(
            config=AdaptConfig(
                cache_size=args.adapt_cache_size,
                ttl_s=args.adapt_ttl,
                refit_interval_s=args.adapt_refit_s,
                explore_rate=args.adapt_explore_rate,
                sentinel=not args.adapt_no_sentinel,
            ),
        ).start()
    eng = DecodeEngine(
        cfg, params, batch_size=args.prompts, cache_len=args.cache_len,
        enc_len=enc_len, adapt=tier,
    )
    if cfg.encdec:
        import jax.numpy as jnp

        frames = jnp.zeros((args.prompts, enc_len, cfg.d_model))
        eng.cache = model.prefill_cross(params, eng.cache, frames)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.prompts)
    ]
    t0 = time.time()
    out = eng.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in out)
    print(f"decoded {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU interpret)")
    if tier is not None:
        dec = eng.last_decision
        sched = dec.schedule.value if dec is not None else "-"
        print(f"adapt: schedule={sched} stats={tier.stats()}")
        tier.stop()
    if args.signatures:
        from repro.obs import signature as _signature

        stream = _signature.get_signatures()
        if stream is not None:
            snap = stream.export_jsonl()
            print(
                f"signatures: {len(snap['cells'])} cells "
                f"-> {args.signatures}"
            )
    for i, r in enumerate(out):
        print(f"req{i}: {list(r.prompt)} -> {r.out}")


if __name__ == "__main__":
    main()
