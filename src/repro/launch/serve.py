"""Serving launcher: batched greedy decoding with the reduced model.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --prompts 4 --new-tokens 16 [--overlap-mode ficco_autotune]

``--overlap-mode ficco_autotune`` selects TP overlap schedules through
the persistent runtime autotuner (repro.autotune) — serving processes
restart often, so tuned decisions surviving on disk is exactly what the
cache is for.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models.model import build_model
from repro.serve.engine import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument(
        "--overlap-mode", default="gspmd_serial",
        help="gspmd_serial | serial | shard_p2p | ficco_auto | "
        "ficco_autotune | explicit schedule value",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.overlap_mode != "gspmd_serial":
        cfg = dataclasses.replace(
            cfg,
            overlap=dataclasses.replace(cfg.overlap, mode=args.overlap_mode),
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc_len = 16 if cfg.encdec else 0
    eng = DecodeEngine(
        cfg, params, batch_size=args.prompts, cache_len=args.cache_len,
        enc_len=enc_len,
    )
    if cfg.encdec:
        import jax.numpy as jnp

        frames = jnp.zeros((args.prompts, enc_len, cfg.d_model))
        eng.cache = model.prefill_cross(params, eng.cache, frames)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for _ in range(args.prompts)
    ]
    t0 = time.time()
    out = eng.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in out)
    print(f"decoded {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU interpret)")
    for i, r in enumerate(out):
        print(f"req{i}: {list(r.prompt)} -> {r.out}")


if __name__ == "__main__":
    main()
