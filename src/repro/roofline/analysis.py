"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the compiled HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  Hardware constants per the brief:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per chip.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[\w\[\]{},]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in the HLO.

    ``-start``/``-done`` async pairs are counted once (on the start op);
    synchronous forms count directly.
    """
    bytes_by: dict[str, float] = {}
    count_by: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        b = _shape_bytes(shape_str)
        bytes_by[kind] = bytes_by.get(kind, 0.0) + b
        count_by[kind] = count_by.get(kind, 0) + 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: dict[str, float]
    collective_counts: dict[str, int]
    model_flops: float  # 6*N*D (or 6*N_active*D for MoE)
    bytes_per_device: float  # peak memory from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful
        (catches remat / redundancy waste).  > 1 means the HLO counter
        under-reports (e.g. decode where 6ND is not the right model)."""
        if self.hlo_flops <= 0:
            return float("nan")
        return self.model_flops / self.hlo_flops

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "collective_counts": self.collective_counts,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    compiled,
    model_flops: float,
) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(
        cost.get("bytes accessed", cost.get("bytes_accessed", 0.0))
    )
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem_bytes = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        mem_bytes = float("nan")
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective_bytes=coll.total_bytes,
        collectives=coll.bytes_by_kind,
        collective_counts=coll.count_by_kind,
        model_flops=model_flops,
        bytes_per_device=mem_bytes,
    )


def count_params(cfg) -> float:
    """Total and active parameter counts from the config (analytic)."""
    d, ff, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    from repro.models.model import build_model
    import jax
    import numpy as np

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(float(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    return total


def active_params(cfg) -> float:
    """Active (per-token) params: MoE counts only top-k + shared experts."""
    total = count_params(cfg)
    if not cfg.moe:
        return total
    # subtract the inactive routed experts' share
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    n_moe_layers = cfg.num_layers // cfg.moe.every_k_layers
    routed = 3.0 * d * f * e * n_moe_layers
    active_routed = 3.0 * d * f * k * n_moe_layers
    return total - routed + active_routed


def model_flops_for(cfg, shape_cfg, kind: str) -> float:
    """MODEL_FLOPS = 6*N_active*D for training; 2*N_active*D for inference
    forward; decode D = global_batch tokens (one step)."""
    n = active_params(cfg)
    if kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape_cfg.global_batch
