"""Analytic FLOP / HBM-byte counters per architecture component.

Why: XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE —
layer scans, blockwise-attention scans and SSM time scans make the raw
numbers meaningless for deep/recurrent models.  The dry-run therefore uses:

  * **flops/bytes**: these analytic counters (precise component formulas,
    window-aware attention, MoE active-expert accounting, recurrences),
  * **collective bytes**: HLO parse of small UNROLLED variants linearly
    extrapolated over depth (collectives never live inside time scans),
  * **memory**: the real scanned compile's memory_analysis.

Raw cost_analysis numbers are still recorded for reference.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import Family, ModelConfig, ShapeConfig
from repro.models.model import layer_pattern


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o):
        return Costs(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k: float):
        return Costs(self.flops * k, self.bytes * k)

    __rmul__ = __mul__


def _gemm(m, n, k, b=2) -> Costs:
    return Costs(2.0 * m * n * k, float(m * k + k * n + m * n) * b)


def _attn_costs(cfg: ModelConfig, b, s, ctx, *, decode: bool) -> Costs:
    h, kv, hd, d = (
        cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    )
    c = _gemm(b * s, h * hd, d)  # q
    c += 2 * _gemm(b * s, kv * hd, d)  # k, v
    c += _gemm(b * s, d, h * hd)  # o
    # scores + AV; training causal halves the average context.
    eff_ctx = ctx if decode else ctx * 0.5
    flops = 2.0 * b * h * s * eff_ctx * hd * 2
    bytes_ = 2.0 * b * s * (h + 2 * kv) * hd * 2  # q/k/v streamed
    if decode:
        bytes_ += b * ctx * 2 * kv * hd * 2  # cache read
    return c + Costs(flops, bytes_)


def _mla_costs(cfg: ModelConfig, b, s, ctx, *, decode: bool) -> Costs:
    m = cfg.mla
    h, d = cfg.num_heads, cfg.d_model
    qk = m.nope_head_dim + m.rope_head_dim
    c = _gemm(b * s, h * qk, d)  # q
    c += _gemm(b * s, m.kv_lora_rank, d)  # down
    c += _gemm(b * s, m.rope_head_dim, d)
    exp_s = ctx if decode else s  # decode re-expands the latent cache
    c += _gemm(b * exp_s, h * m.nope_head_dim, m.kv_lora_rank)
    c += _gemm(b * exp_s, h * m.v_head_dim, m.kv_lora_rank)
    c += _gemm(b * s, d, h * m.v_head_dim)
    eff_ctx = ctx if decode else ctx * 0.5
    c += Costs(
        2.0 * b * h * s * eff_ctx * (qk + m.v_head_dim),
        b * ctx * (m.kv_lora_rank + m.rope_head_dim) * 2 if decode else 0,
    )
    return c


def _gated_mlp(d, ff, tokens) -> Costs:
    return 3 * _gemm(tokens, ff, d)  # up + gate + down (same cost each)


def _moe_costs(cfg: ModelConfig, tokens) -> Costs:
    mo, d = cfg.moe, cfg.d_model
    c = _gemm(tokens, mo.num_experts, d)  # router
    c += mo.top_k * _gated_mlp(d, mo.d_ff_expert, tokens)
    if mo.num_shared_experts:
        c += _gated_mlp(d, mo.d_ff_expert * mo.num_shared_experts, tokens)
    if mo.dense_residual_ff:
        c += _gated_mlp(d, mo.dense_residual_ff, tokens)
    # dispatch/combine data movement
    c += Costs(0.0, 4.0 * tokens * d * 2)
    return c


def _mamba_costs(cfg: ModelConfig, b, s, *, decode: bool) -> Costs:
    from repro.models.mamba import mamba_dims

    mc = cfg.hybrid.mamba
    d = cfg.d_model
    di, dtr = mamba_dims(d, mc)
    t = b * s
    c = _gemm(t, 2 * di, d)  # in proj
    c += Costs(2.0 * t * di * mc.d_conv, t * di * 2)  # conv
    c += _gemm(t, dtr + 2 * mc.d_state, di)
    c += _gemm(t, di, dtr)
    # selective scan: ~6 flops per (token, channel, state)
    c += Costs(6.0 * t * di * mc.d_state, 4.0 * t * di * 2)
    c += _gemm(t, d, di)  # out
    if decode:
        c += Costs(0.0, b * di * mc.d_state * 4)  # state read/write
    return c


def _mlstm_costs(cfg: ModelConfig, b, s, *, decode: bool) -> Costs:
    di = int(cfg.xlstm.proj_factor * cfg.d_model)
    h = cfg.num_heads
    hd = di // h
    t = b * s
    c = _gemm(t, 2 * di, cfg.d_model)
    c += 3 * _gemm(t, di, di)
    # per step: outer product + state update + readout: ~6 * hd^2 per head
    c += Costs(6.0 * t * h * hd * hd, 2.0 * t * di * 2)
    c += _gemm(t, cfg.d_model, di)
    if decode:
        c += Costs(0.0, b * h * hd * hd * 4 * 2)  # matrix state r/w
    return c


def _slstm_costs(cfg: ModelConfig, b, s, *, decode: bool) -> Costs:
    di = int(cfg.xlstm.proj_factor * cfg.d_model)
    t = b * s
    c = _gemm(t, di, cfg.d_model)
    c += _gemm(t, 4 * di, di)  # input gates
    c += _gemm(t, 4 * di, di)  # recurrent gates (per step, dense R)
    c += Costs(10.0 * t * di, 2.0 * t * di * 2)
    c += _gemm(t, cfg.d_model, di)
    return c


def forward_costs(
    cfg: ModelConfig, b: int, s: int, *, ctx: int | None = None,
    decode: bool = False,
) -> Costs:
    """One forward pass over ``b`` sequences of ``s`` new tokens with
    attention context ``ctx`` (defaults: s for train, window-clamped)."""
    ctx = ctx if ctx is not None else s
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    pattern = layer_pattern(cfg)
    n_periods = cfg.num_layers // len(pattern)
    tokens = b * s
    per_period = Costs()
    for spec in pattern:
        if spec.mixer == "attn":
            per_period += _attn_costs(cfg, b, s, ctx, decode=decode)
        elif spec.mixer == "mla":
            per_period += _mla_costs(cfg, b, s, ctx, decode=decode)
        elif spec.mixer == "mamba":
            per_period += _mamba_costs(cfg, b, s, decode=decode)
        elif spec.mixer == "mlstm":
            per_period += _mlstm_costs(cfg, b, s, decode=decode)
        else:
            per_period += _slstm_costs(cfg, b, s, decode=decode)
        if spec.ffn == "mlp":
            per_period += _gated_mlp(cfg.d_model, cfg.d_ff, tokens)
        elif spec.ffn == "moe":
            per_period += _moe_costs(cfg, tokens)
        # norms / residuals
        per_period += Costs(8.0 * tokens * cfg.d_model,
                            6.0 * tokens * cfg.d_model * 2)
    total = n_periods * per_period
    # embed + unembed
    total += Costs(0.0, tokens * cfg.d_model * 2)
    total += _gemm(tokens, cfg.vocab_size, cfg.d_model)
    if cfg.encdec and not decode:
        enc_tokens = b * s  # encoder frames ~ seq_len (stub ratio 1.0)
        enc = _attn_costs(cfg, b, s, s, decode=False) + _gated_mlp(
            cfg.d_model, cfg.d_ff, enc_tokens
        )
        total += cfg.encdec.encoder_layers * enc
        # cross attention per decoder layer
        total += cfg.num_layers * _attn_costs(cfg, b, s, s, decode=False)
    if cfg.encdec and decode:
        # cross-attention reads of the cached encoder K/V
        total += cfg.num_layers * Costs(
            2.0 * b * cfg.num_heads * ctx * cfg.resolved_head_dim * 2,
            b * ctx * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2,
        )
    return total


def param_bytes(cfg: ModelConfig) -> float:
    from repro.roofline.analysis import count_params

    return count_params(cfg) * 2  # bf16


def step_costs(cfg: ModelConfig, shape: ShapeConfig, kind: str) -> Costs:
    """Total analytic costs of one dry-run step function."""
    b, s = shape.global_batch, shape.seq_len
    pb = param_bytes(cfg)
    if kind == "train":
        fwd = forward_costs(cfg, b, s)
        mult = 3.0 + (1.0 if cfg.remat else 0.0)  # fwd + bwd(2x) + remat
        c = mult * fwd
        # optimizer: read p/m/v + grads, write p/m/v (mixed precision)
        c += Costs(10.0 * pb / 2, 8.0 * pb)
        c += Costs(0.0, 3.0 * pb)  # grads write + weight reads beyond acts
        return c
    if kind == "prefill":
        c = forward_costs(cfg, b, s)
        return c + Costs(0.0, pb)
    # decode: one token, context = seq_len
    c = forward_costs(cfg, b, 1, ctx=s, decode=True)
    return c + Costs(0.0, pb)  # full weight read per step
