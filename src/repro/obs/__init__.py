"""repro.obs — tracing, metrics, and schedule-decision provenance.

Three layers over the engine/sweep/autotune/serve stack:

* :mod:`repro.obs.trace` — near-zero-overhead span tracer exporting
  Chrome trace-event / Perfetto JSON (``REPRO_TRACE=path`` or
  ``trace.enable()``).
* :mod:`repro.obs.metrics` — counter/histogram registry with JSONL
  snapshot export (tuner tier rates, sweep shard percentiles, gate
  agreement).
* :mod:`repro.obs.audit` — per-decision provenance records persisted
  beside the autotune cache, replayable offline
  (``REPRO_AUTOTUNE_AUDIT=path`` or ``Autotuner(audit=...)``).
* :mod:`repro.obs.timeline` — any simulated schedule rendered as a
  per-step comm/GEMM/DMA lane trace with its inefficiency signature.
* :mod:`repro.obs.signature` — the signature as a *streaming*
  observable: every live tuner / serving-tier decision decomposed into
  the paper's loss categories and accumulated per (machine family,
  scenario class, schedule) (``REPRO_SIGNATURES=path`` or
  ``signature.enable_signatures()``).
* :mod:`repro.obs.sentinel` — EWMA/CUSUM drift monitor over
  predicted-vs-measured residuals and gate agreement, emitting typed
  refit-trigger events the serving tier's ``Refitter`` acts on.

Fleet merge: ``metrics.merge_snapshots`` / ``trace.merge_traces`` union
host-stamped exports from a multi-host sweep into one metrics/timeline
view (``scripts/obs_merge.py``).

This package ``__init__`` stays stdlib-only: the instrumented modules
(``repro.core.engine``, the sweep runner, the tuner) import
``repro.obs.trace`` at their own import time, which executes this file —
pulling ``repro.core`` back in here would be a cycle.  ``timeline``
(which needs the simulator) is therefore exported lazily, the same
PEP 562 pattern ``repro.sweep.__init__`` uses to stay jax-free;
``signature``/``sentinel`` join it for symmetry (their module bodies
are stdlib-only, their functions lazy-import the core).
"""

from __future__ import annotations

from repro.obs import audit, metrics, trace

_LAZY = {"timeline", "signature", "sentinel"}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


__all__ = [
    "trace", "metrics", "audit", "timeline", "signature", "sentinel",
]
