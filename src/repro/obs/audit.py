"""Schedule-decision audit log: persist, read back, replay, explain.

The autotune cache stores the *latest winner* per key; it cannot answer
"why did the serving run at 14:02 pick ``hetero_unfused_1d`` for this
GEMM, and which tier decided it?".  This module persists one JSONL
record per :meth:`Autotuner.pick`/``measure`` decision — key, tier,
schedule, modelled/measured seconds, the analytic shortlist, and (for
heuristic fallbacks) the gate consulted — beside the autotune cache, so
a serving run can be replayed and explained offline.

Enable per-tuner (``Autotuner(audit=AuditLog(path))``), process-wide
(:func:`enable_audit`), or via the environment::

    REPRO_AUTOTUNE_AUDIT=1 python serve.py        # default path
    REPRO_AUTOTUNE_AUDIT=run.jsonl python serve.py

Replay (:func:`replay`) re-runs the logged picks, in order, against a
fresh tuner with a fresh in-memory cache.  Determinism of the analytic
tier makes this exact: an ``analytic`` record re-derives the same
winner, a ``cache`` record is warm-started from the earlier record for
its key (reproducing the original hit), a ``measured`` record seeds the
replay cache with the empirical winner (wall time is not reproducible
offline, the downstream cache hits are), and a ``heuristic`` record
re-runs the static decision tree.  Skewed-profile records are verified
for schedule agreement only when the profile digest is reconstructible
(it is not — digests are one-way), so they are reported as skipped
rather than silently passed.
"""

from __future__ import annotations

import json
import os
import threading
import time

ENV_VAR = "REPRO_AUTOTUNE_AUDIT"
ENV_MAX_BYTES = "REPRO_AUTOTUNE_AUDIT_MAX_BYTES"
ENV_KEEP = "REPRO_AUTOTUNE_AUDIT_KEEP"
AUDIT_FILENAME = "decisions.jsonl"
DEFAULT_KEEP = 3


def default_audit_path() -> str:
    """``decisions.jsonl`` beside the autotune cache file."""
    from repro.autotune.cache import default_cache_dir  # lazy: keep
    # this module importable without the autotune package resolved.

    return os.path.join(default_cache_dir(), AUDIT_FILENAME)


class AuditLog:
    """Append-only JSONL decision log with size-based rotation.

    Each :meth:`record` call appends one line and closes the file, so
    concurrent processes auditing into the same path interleave whole
    lines (POSIX O_APPEND) and a crash loses at most the in-flight
    record.

    ``max_bytes`` bounds the live file: when an append would grow it
    past the bound, the live file rolls to ``path.1`` (existing rolled
    segments shift up, the oldest beyond ``keep`` is dropped) — a week
    of serve traffic keeps at most ``(keep + 1) * max_bytes`` on disk.
    Defaults come from ``REPRO_AUTOTUNE_AUDIT_MAX_BYTES`` /
    ``REPRO_AUTOTUNE_AUDIT_KEEP`` (unset == unbounded, the historical
    behavior).  :func:`audit_segments` / :func:`read_audit_segments`
    and :func:`replay` read across rolled segments oldest-first.
    """

    def __init__(self, path: str | None = None, *,
                 max_bytes: int | None = None, keep: int | None = None):
        self.path = path or default_audit_path()
        if max_bytes is None:
            max_bytes = int(os.environ.get(ENV_MAX_BYTES, "0") or 0)
        if keep is None:
            keep = int(os.environ.get(ENV_KEEP, str(DEFAULT_KEEP))
                       or DEFAULT_KEEP)
        self.max_bytes = max(int(max_bytes), 0)  # 0 == unbounded
        self.keep = max(int(keep), 1)
        self.rotations = 0
        self._lock = threading.Lock()

    def _rotate_locked(self) -> None:
        oldest = f"{self.path}.{self.keep}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for i in range(self.keep - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        os.replace(self.path, f"{self.path}.1")
        self.rotations += 1

    def record(self, rec: dict) -> None:
        rec.setdefault("ts", time.time())
        line = json.dumps(rec) + "\n"
        with self._lock:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            if self.max_bytes:
                try:
                    size = os.path.getsize(self.path)
                except OSError:
                    size = 0
                if size and size + len(line) > self.max_bytes:
                    self._rotate_locked()
            with open(self.path, "a") as f:
                f.write(line)


# ---------------------------------------------------------------------------
# Process-wide audit log (what Autotuner consults when audit=None).
# ---------------------------------------------------------------------------

_AUDIT: AuditLog | None = None


def enable_audit(path: str | None = None) -> AuditLog:
    global _AUDIT
    _AUDIT = AuditLog(path)
    return _AUDIT


def disable_audit() -> None:
    global _AUDIT
    _AUDIT = None


def get_audit() -> AuditLog | None:
    return _AUDIT


_env = os.environ.get(ENV_VAR)
if _env:  # pragma: no cover - exercised via subprocess in tests
    enable_audit(None if _env in ("1", "true") else _env)


# ---------------------------------------------------------------------------
# Reading + replay.
# ---------------------------------------------------------------------------


def audit_segments(path: str) -> list[str]:
    """Existing on-disk segments of a (possibly rotated) audit log,
    oldest-first: ``[path.N, ..., path.1, path]``."""
    rolled: list[str] = []
    i = 1
    while os.path.exists(f"{path}.{i}"):
        rolled.append(f"{path}.{i}")
        i += 1
    segments = list(reversed(rolled))
    if os.path.exists(path) or not segments:
        segments.append(path)
    return segments


def read_audit_segments(path: str) -> list[dict]:
    """Parse a rotated audit log across all its segments, in record
    order (oldest rolled segment first, live file last)."""
    records: list[dict] = []
    for seg in audit_segments(path):
        if os.path.exists(seg):
            records.extend(read_audit(seg))
    return records


def read_audit(path: str) -> list[dict]:
    """Parse a JSONL audit file; raises ValueError on a malformed line."""
    records: list[dict] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
            if not isinstance(rec, dict):
                raise ValueError(f"{path}:{i + 1}: record not an object")
            records.append(rec)
    return records


_PICK_FIELDS = ("machine", "group", "m", "n", "k", "dtype_bytes")


# Non-decision record kinds that legitimately share the audit stream:
# the serving tier's budgeted measured sessions and the drift
# sentinel's typed events (validated in depth by
# ``repro.obs.sentinel.validate_sentinel``) — structurally they only
# need a numeric timestamp here.
_AUX_KINDS = ("adapt_measure",)
_AUX_PREFIXES = ("sentinel_",)


def validate_audit(records: list[dict]) -> list[str]:
    """Structural errors in audit records ([] == valid)."""
    errors: list[str] = []
    for i, rec in enumerate(records):
        kind = rec.get("kind")
        if kind in _AUX_KINDS or (
            isinstance(kind, str) and kind.startswith(_AUX_PREFIXES)
        ):
            if not isinstance(rec.get("ts"), (int, float)):
                errors.append(f"record[{i}] ({kind}): no numeric 'ts'")
            continue
        if kind not in ("pick", "measure"):
            errors.append(f"record[{i}]: unknown kind {kind!r}")
            continue
        if not isinstance(rec.get("schedule"), str):
            errors.append(f"record[{i}]: no schedule string")
        if rec.get("source") not in (
            "cache", "analytic", "measured", "heuristic"
        ):
            errors.append(f"record[{i}]: bad source {rec.get('source')!r}")
        for field in _PICK_FIELDS:
            if not isinstance(rec.get(field), (int, str)):
                errors.append(f"record[{i}]: missing {field!r}")
    return errors


class ReplayResult:
    """Outcome of replaying an audit log against a fresh tuner."""

    def __init__(self):
        self.total = 0
        self.replayed = 0
        self.matched = 0
        self.mismatches: list[dict] = []
        self.skipped: list[dict] = []

    @property
    def ok(self) -> bool:
        return self.replayed > 0 and not self.mismatches

    def to_json(self) -> dict:
        return {
            "total": self.total,
            "replayed": self.replayed,
            "matched": self.matched,
            "ok": self.ok,
            "mismatches": self.mismatches,
            "skipped": self.skipped,
        }


def replay(records, *, backend: str = "numpy") -> ReplayResult:
    """Re-derive every logged decision; report agreement per record.

    ``records`` is a path or an iterable of parsed records.  The replay
    tuner starts from an *empty, non-persisted* cache so replay never
    touches (or is influenced by) the live store; ``backend`` defaults
    to the numpy engine so replay needs no accelerator.
    """
    from repro.autotune.cache import AutotuneCache
    from repro.autotune.tuner import Autotuner
    from repro.core.machine import MACHINES
    from repro.core.schedule_types import Schedule
    from repro.core.workload import GemmShape

    if isinstance(records, str):
        records = read_audit_segments(records)

    cache = AutotuneCache(path=os.devnull)
    cache.entries = {}
    # audit=False: replaying an audited process must not append the
    # replayed picks back onto the live log.
    tuner = Autotuner(cache, backend=backend, persist=False, audit=False)
    result = ReplayResult()

    for i, rec in enumerate(records):
        result.total += 1
        kind = rec.get("kind")
        if kind in _AUX_KINDS or (
            isinstance(kind, str) and kind.startswith(_AUX_PREFIXES)
        ):
            result.skipped.append(
                {"index": i, "reason": f"non-decision kind {kind!r}"}
            )
            continue
        machine = MACHINES.get(rec.get("machine"))
        if machine is None:
            result.skipped.append(
                {"index": i, "reason": f"unknown machine {rec.get('machine')!r}"}
            )
            continue
        group = int(rec["group"])
        profile = rec.get("profile", f"u{group}")
        if profile != f"u{group}":
            # Skewed profiles are keyed by a one-way digest; the step
            # decomposition cannot be reconstructed from the log.
            result.skipped.append(
                {"index": i, "reason": f"non-uniform profile {profile!r}"}
            )
            continue
        gemm = GemmShape(
            int(rec["m"]), int(rec["n"]), int(rec["k"]),
            int(rec["dtype_bytes"]),
        )
        expect_sched = rec["schedule"]
        expect_source = rec["source"]
        key = rec.get("key")

        if rec.get("kind") == "measure" or expect_source == "measured":
            # Wall time is not reproducible offline; seed the replay
            # cache with the empirical winner so downstream cache-tier
            # records for this key replay against the same state the
            # original process had.
            if key:
                cache.put(
                    key,
                    {"schedule": expect_sched, "source": "measured"},
                    persist=False,
                )
            result.skipped.append(
                {"index": i, "reason": "measured record (seeded cache)"}
            )
            continue
        if expect_source == "cache" and key and key not in cache:
            # The original process was warm-started by an earlier run;
            # reproduce that state from the record itself.
            cache.put(
                key,
                {"schedule": expect_sched, "source": "analytic"},
                persist=False,
            )
        if expect_source == "heuristic":
            # The fallback fired because a model/backend failure occurred
            # in the original process; what is reproducible offline is
            # the static decision tree's choice.
            from repro.core.heuristics import select_schedule
            from repro.core.machine import machine_for_group

            eff = (
                machine_for_group(machine, group)
                if group != machine.group else machine
            )
            got = select_schedule(gemm, eff)
            got_sched, got_source = got.schedule, "heuristic"
        else:
            dec = tuner.pick(gemm, machine, group=group)
            got_sched, got_source = dec.schedule, dec.source

        result.replayed += 1
        if got_sched is Schedule(expect_sched) and got_source == expect_source:
            result.matched += 1
        else:
            result.mismatches.append({
                "index": i,
                "key": key,
                "expected": {"schedule": expect_sched, "source": expect_source},
                "got": {"schedule": got_sched.value, "source": got_source},
            })
    return result


__all__ = [
    "ENV_VAR",
    "ENV_MAX_BYTES",
    "ENV_KEEP",
    "AUDIT_FILENAME",
    "AuditLog",
    "default_audit_path",
    "enable_audit",
    "disable_audit",
    "get_audit",
    "audit_segments",
    "read_audit",
    "read_audit_segments",
    "validate_audit",
    "ReplayResult",
    "replay",
]
