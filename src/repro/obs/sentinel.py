"""Drift sentinel: EWMA/CUSUM monitoring of predicted-vs-measured
residuals and gate agreement, with typed refit-trigger events.

The adaptive serving tier (:mod:`repro.serve.adapt`) re-fits on a
wall-clock cadence; that bounds *staleness*, not *wrongness* — a link
that silently degrades mid-stream leaves the analytic model confidently
ranking schedules with a stale bandwidth until the next interval fires,
and gate-only refits never notice at all.  This module watches the two
live correctness signals the stack already produces:

* **residual channel** — every measured-tier session yields a
  predicted/measured pair; the sentinel tracks ``r = log(measured /
  predicted)`` with an EWMA (location) and a two-sided standardized
  CUSUM (drift detection): ``S+ = max(0, S+ + z - k)``, ``S- = max(0,
  S- - z - k)`` with ``z = r / sigma``.  Crossing ``h`` raises a drift
  alarm.
* **agreement channel** — the gate-vs-analytic-argmin agreement each
  re-fit reports, EWMA'd; falling below a floor raises an alarm.

An alarm latches :meth:`Sentinel.should_refit` (the
:class:`~repro.serve.adapt.Refitter` polls it and can be kicked awake
via :attr:`Sentinel.on_alarm`), and every state transition — alarm,
refit, post-refit recovery — is emitted as a typed, schema-validated
event (:func:`validate_sentinel`), appended to the decision audit log
(kinds ``sentinel_alarm`` / ``sentinel_refit`` / ``sentinel_recovery``)
and counted in the metrics registry, so the full drift story reads
beside the decisions it affected.

Stdlib-only; pure state machine (no threads of its own) — safe to feed
from request threads and the re-fit thread concurrently.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import time


@dataclasses.dataclass(frozen=True)
class SentinelConfig:
    """Knobs of the drift monitor.

    ``k``/``h`` are the standardized CUSUM's reference and decision
    values: with in-control residuals ~N(0, sigma), ``k=0.5`` tunes the
    chart to detect ~1-sigma mean shifts fastest and ``h=8`` puts the
    in-control false-alarm run length in the thousands of samples; a
    sustained 2-sigma shift alarms after ~h / (2 - k) ~ 5 samples.
    """

    alpha: float = 0.2            # residual-EWMA smoothing
    k: float = 0.5                # CUSUM reference (in sigma units)
    h: float = 8.0                # CUSUM decision threshold
    min_samples: int = 8          # residuals before alarms are armed
    sigma0: float = 0.10          # log-time scale before any fit
    agreement_floor: float = 0.5  # EWMA agreement below this -> alarm
    agreement_alpha: float = 0.2
    agreement_min: int = 3        # agreement reports before that arms
    max_events: int = 256         # bounded in-memory event history

    def __post_init__(self):
        if self.h <= 0:
            raise ValueError(f"h must be > 0, got {self.h}")
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")


class Sentinel:
    """The drift state machine.  All mutation under one lock; the
    hot-path cost is a handful of float updates."""

    def __init__(self, config: SentinelConfig | None = None, *,
                 clock=time.time):
        self.config = config or SentinelConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._sigma = float(self.config.sigma0)
        # Residual channel.
        self._n = 0
        self._ewma: float | None = None
        self._cusum_pos = 0.0
        self._cusum_neg = 0.0
        # Agreement channel.
        self._agree_n = 0
        self._agree_ewma: float | None = None
        # Alarm latch + post-refit recovery tracking.
        self._alarmed: str | None = None   # channel name, or None
        self._recovering = False
        self._pre_refit_ewma: float | None = None
        self._post_n = 0
        self._post_sum = 0.0
        self._post_sumsq = 0.0
        self.events: list[dict] = []
        self.alarms = 0
        self.refits = 0
        self.on_alarm = None  # callable hook (e.g. Refitter.kick)

    # -- feeding ---------------------------------------------------------

    def set_sigma(self, sigma: float) -> None:
        """Atomic swap of the residual scale (the re-fit thread's hook,
        same contract as ``ExplorationPolicy.set_sigma``)."""
        self._sigma = max(float(sigma), 1e-6)

    def observe_residual(
        self, predicted_s: float, measured_s: float, *, key: str | None = None
    ) -> bool:
        """Feed one predicted/measured pair; True if this sample raised
        a drift alarm.  Never raises on degenerate inputs (skipped)."""
        if (
            not isinstance(predicted_s, (int, float))
            or not isinstance(measured_s, (int, float))
            or predicted_s <= 0.0
            or measured_s <= 0.0
        ):
            return False
        r = math.log(measured_s / predicted_s)
        cfg = self.config
        fires: list[dict] = []
        with self._lock:
            self._n += 1
            self._ewma = (
                r if self._ewma is None
                else (1.0 - cfg.alpha) * self._ewma + cfg.alpha * r
            )
            z = r / self._sigma
            self._cusum_pos = max(0.0, self._cusum_pos + z - cfg.k)
            self._cusum_neg = max(0.0, self._cusum_neg - z - cfg.k)
            if self._recovering:
                self._post_n += 1
                self._post_sum += r
                self._post_sumsq += r * r
                if self._post_n >= cfg.min_samples:
                    fires.append(self._recovery_event_locked())
            if (
                self._alarmed is None
                and self._n >= cfg.min_samples
                and max(self._cusum_pos, self._cusum_neg) > cfg.h
            ):
                self._alarmed = "residual"
                self.alarms += 1
                fires.append(self._event_locked(
                    "sentinel_alarm",
                    channel="residual",
                    key=key,
                    residual=r,
                ))
        for ev in fires:
            self._emit(ev)
        return any(ev["kind"] == "sentinel_alarm" for ev in fires)

    def observe_agreement(self, rate: float) -> bool:
        """Feed one gate-vs-argmin agreement rate; True on alarm."""
        if not isinstance(rate, (int, float)) or not 0.0 <= rate <= 1.0:
            return False
        cfg = self.config
        fire = None
        with self._lock:
            self._agree_n += 1
            self._agree_ewma = (
                rate if self._agree_ewma is None
                else (1.0 - cfg.agreement_alpha) * self._agree_ewma
                + cfg.agreement_alpha * rate
            )
            if (
                self._alarmed is None
                and self._agree_n >= cfg.agreement_min
                and self._agree_ewma < cfg.agreement_floor
            ):
                self._alarmed = "agreement"
                self.alarms += 1
                fire = self._event_locked(
                    "sentinel_alarm", channel="agreement", rate=rate
                )
        if fire is not None:
            self._emit(fire)
            return True
        return False

    # -- the refit contract ---------------------------------------------

    def should_refit(self) -> bool:
        """Latched drift verdict (cleared by :meth:`record_refit`)."""
        return self._alarmed is not None

    def record_refit(self, report: dict | None = None, *,
                     trigger: str = "interval") -> dict:
        """Note that a refit ran: emits ``sentinel_refit``, resets the
        CUSUM, clears the alarm latch, and arms recovery tracking (the
        next ``min_samples`` residuals are summarized against the
        pre-refit EWMA in a ``sentinel_recovery`` event)."""
        with self._lock:
            self.refits += 1
            ev = self._event_locked(
                "sentinel_refit",
                trigger=trigger,
                channel=self._alarmed,
                report={
                    k: v for k, v in (report or {}).items()
                    if isinstance(v, (int, float, str, bool)) or v is None
                },
            )
            self._pre_refit_ewma = self._ewma
            self._alarmed = None
            self._cusum_pos = 0.0
            self._cusum_neg = 0.0
            self._ewma = None
            self._recovering = True
            self._post_n = 0
            self._post_sum = 0.0
            self._post_sumsq = 0.0
        self._emit(ev)
        return ev

    def _recovery_event_locked(self) -> dict:
        n = self._post_n
        mean = self._post_sum / n
        var = max(self._post_sumsq / n - mean * mean, 0.0)
        self._recovering = False
        return self._event_locked(
            "sentinel_recovery",
            pre_refit_ewma=self._pre_refit_ewma,
            post_refit_ewma=self._ewma,
            post_mean=mean,
            post_rms=math.sqrt(mean * mean + var),
            samples=n,
        )

    # -- events ----------------------------------------------------------

    def _event_locked(self, kind: str, **fields) -> dict:
        ev = {
            "kind": kind,
            "ts": self._clock(),
            "n": self._n,
            "ewma": self._ewma,
            "cusum_pos": self._cusum_pos,
            "cusum_neg": self._cusum_neg,
            "sigma": self._sigma,
            "agreement_ewma": self._agree_ewma,
            **fields,
        }
        self.events.append(ev)
        if len(self.events) > self.config.max_events:
            del self.events[: len(self.events) - self.config.max_events]
        return ev

    def _emit(self, ev: dict) -> None:
        """Audit + metrics + trace + alarm hook; never raises."""
        from repro.obs import audit as _audit
        from repro.obs import metrics as _metrics
        from repro.obs import trace as _trace

        try:
            _metrics.get_metrics().counter(
                "sentinel/" + ev["kind"].split("_", 1)[1] + "s"
            ).inc()
            _trace.instant(ev["kind"], "sentinel", **{
                k: v for k, v in ev.items()
                if isinstance(v, (int, float, str, bool))
            })
            log = _audit.get_audit()
            if log is not None:
                log.record(dict(ev))
        except Exception:  # pragma: no cover - observability best-effort
            pass
        if ev["kind"] == "sentinel_alarm" and self.on_alarm is not None:
            try:
                self.on_alarm()
            except Exception:  # pragma: no cover
                pass

    # -- reporting -------------------------------------------------------

    def state(self) -> dict:
        with self._lock:
            return {
                "n": self._n,
                "ewma": self._ewma,
                "cusum_pos": self._cusum_pos,
                "cusum_neg": self._cusum_neg,
                "sigma": self._sigma,
                "agreement_ewma": self._agree_ewma,
                "alarmed": self._alarmed,
                "recovering": self._recovering,
                "alarms": self.alarms,
                "refits": self.refits,
                "events": len(self.events),
            }

    def export_jsonl(self, path: str) -> int:
        """Append every retained event as one JSONL line each; returns
        the number written."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with self._lock:
            events = list(self.events)
        with open(path, "a") as f:
            for ev in events:
                f.write(json.dumps(ev) + "\n")
        return len(events)


# ---------------------------------------------------------------------------
# Event schema (CI fast-lane gate, scripts/trace.py validate --kind
# sentinel).
# ---------------------------------------------------------------------------

EVENT_KINDS = ("sentinel_alarm", "sentinel_refit", "sentinel_recovery")
_NUMERIC = ("ts", "n", "cusum_pos", "cusum_neg", "sigma")


def validate_sentinel(records) -> list[str]:
    """Structural errors in sentinel event records ([] == valid)."""
    errors: list[str] = []
    for i, ev in enumerate(records):
        if not isinstance(ev, dict):
            errors.append(f"event[{i}]: not an object")
            continue
        kind = ev.get("kind")
        if kind not in EVENT_KINDS:
            errors.append(f"event[{i}]: unknown kind {kind!r}")
            continue
        for field in _NUMERIC:
            if not isinstance(ev.get(field), (int, float)):
                errors.append(f"event[{i}] ({kind}): no numeric {field!r}")
        if kind == "sentinel_alarm" and ev.get("channel") not in (
            "residual", "agreement"
        ):
            errors.append(f"event[{i}]: bad channel {ev.get('channel')!r}")
        if kind == "sentinel_refit" and not isinstance(
            ev.get("trigger"), str
        ):
            errors.append(f"event[{i}]: refit needs a 'trigger' string")
        if kind == "sentinel_recovery" and not isinstance(
            ev.get("samples"), int
        ):
            errors.append(f"event[{i}]: recovery needs integer 'samples'")
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    return errors


__all__ = [
    "SentinelConfig",
    "Sentinel",
    "EVENT_KINDS",
    "validate_sentinel",
]
