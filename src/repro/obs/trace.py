"""Near-zero-overhead span tracer -> Chrome trace-event / Perfetto JSON.

The repo's analytic engines decide *what* to overlap; this module makes
the deciding itself observable.  A :class:`Tracer` collects trace events
in memory and exports them in the Chrome trace-event format (the JSON
``chrome://tracing`` and https://ui.perfetto.dev load directly), so a
tuner session, a sharded sweep, or a rendered schedule timeline
(:mod:`repro.obs.timeline`) all open in the same UI.

Disabled is the default and costs one module-global read per
instrumentation site: :func:`span` returns a shared no-op context
manager when no tracer is installed, so the instrumented hot paths
(``Autotuner.pick``, the sweep shard loop, engine ``evaluate``) stay
within their CI throughput gates with tracing off
(``benchmarks/bench_obs.py`` measures the delta).

Enable via the API::

    from repro.obs import trace
    trace.enable("run.trace.json")      # path optional: export() later
    ... instrumented work ...
    trace.disable()                     # exports to the path, returns it

or via the environment — ``REPRO_TRACE=path`` turns tracing on at import
and registers an ``atexit`` export, so any launcher/script becomes
traceable without a code change::

    REPRO_TRACE=sweep.trace.json python scripts/sweep.py ...
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time

ENV_VAR = "REPRO_TRACE"


class _NullSpan:
    """Shared do-nothing span: what :func:`span` returns when disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        """Attach args to the span (no-op when disabled)."""


NULL_SPAN = _NullSpan()


class _Span:
    """One open duration ("X") event; closes on ``__exit__``."""

    __slots__ = ("_tracer", "name", "cat", "pid", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 pid: int, tid: int, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.pid = pid
        self.tid = tid
        self.args = args
        self._t0 = 0.0

    def set(self, **args) -> None:
        """Attach/overwrite args (e.g. the decision once it's known)."""
        self.args.update(args)

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer._now_us()
        self._tracer._append({
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": self._t0,
            "dur": t1 - self._t0,
            "pid": self.pid,
            "tid": self.tid,
            "args": self.args,
        })
        return False


class Tracer:
    """In-memory trace-event collector with Chrome-JSON export.

    Timestamps are microseconds relative to tracer creation
    (``perf_counter`` based — monotonic, sub-microsecond resolution).
    Appends are a single list.append under the GIL, so spans opened from
    side threads (e.g. a background re-fit thread) interleave safely.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._named: set[tuple] = set()
        self._t0 = time.perf_counter()
        # Epoch anchor of ts==0: what lets merge_traces place this
        # tracer's relative timestamps on a cross-host timeline.
        self._epoch0 = time.time()

    # -- low-level event plumbing --------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _append(self, event: dict) -> None:
        self.events.append(event)  # atomic under the GIL

    # -- event emitters -------------------------------------------------

    def span(self, name: str, cat: str = "repro", *,
             pid: int = 1, tid: int = 0, **args) -> _Span:
        """Open a duration span (context manager)."""
        return _Span(self, name, cat, pid, tid, args)

    def instant(self, name: str, cat: str = "repro", *,
                pid: int = 1, tid: int = 0, **args) -> None:
        self._append({
            "name": name, "cat": cat, "ph": "i", "ts": self._now_us(),
            "s": "t", "pid": pid, "tid": tid, "args": args,
        })

    def counter(self, name: str, value: float, *,
                cat: str = "repro", pid: int = 1) -> None:
        """Emit a Chrome counter ("C") sample (renders as a track graph)."""
        self._append({
            "name": name, "cat": cat, "ph": "C", "ts": self._now_us(),
            "pid": pid, "tid": 0, "args": {"value": value},
        })

    def name_process(self, pid: int, name: str) -> None:
        key = ("p", pid)
        if key in self._named:
            return
        self._named.add(key)
        self._append({
            "name": "process_name", "ph": "M", "ts": 0.0,
            "pid": pid, "tid": 0, "args": {"name": name},
        })

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        key = ("t", pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        self._append({
            "name": "thread_name", "ph": "M", "ts": 0.0,
            "pid": pid, "tid": tid, "args": {"name": name},
        })

    # -- export ---------------------------------------------------------

    def to_json(self) -> dict:
        from repro.obs.metrics import host_identity  # lazy: no cycle at
        # package-import time (obs/__init__ imports metrics first, but
        # this runs long after import).

        with self._lock:
            events = list(self.events)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "host": host_identity(),
            "clock": {"epoch0_s": self._epoch0},
        }

    def export(self, path: str | None = None) -> str:
        """Write the Chrome trace JSON; returns the path written."""
        path = path or self.path
        if not path:
            raise ValueError("no export path: pass one or set tracer.path")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path


# ---------------------------------------------------------------------------
# The process-wide tracer (what the instrumentation sites consult).
# ---------------------------------------------------------------------------

_TRACER: Tracer | None = None


def enable(path: str | None = None) -> Tracer:
    """Install a process-wide tracer (``path`` is the default export)."""
    global _TRACER
    _TRACER = Tracer(path)
    return _TRACER


def disable() -> str | None:
    """Uninstall the tracer; exports first if it has a path.

    Returns the exported path (None if nothing was exported).
    """
    global _TRACER
    t, _TRACER = _TRACER, None
    if t is not None and t.path:
        return t.export()
    return None


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Tracer | None:
    return _TRACER


def span(name: str, cat: str = "repro", *,
         pid: int = 1, tid: int = 0, **args):
    """Span against the process tracer; the shared no-op when disabled.

    The disabled path is one global read + returning a singleton whose
    ``__enter__``/``__exit__`` do nothing — cheap enough for every
    instrumentation site in the repo to call unconditionally.
    """
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, cat, pid=pid, tid=tid, **args)


def instant(name: str, cat: str = "repro", **args) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, **args)


def counter(name: str, value: float, *, cat: str = "repro") -> None:
    t = _TRACER
    if t is not None:
        t.counter(name, value, cat=cat)


# ---------------------------------------------------------------------------
# Schema validation (what the CI fast lane gates exported artifacts with).
# ---------------------------------------------------------------------------

_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def validate_trace(obj) -> list[str]:
    """Structural errors in a Chrome-trace JSON object ([] == valid).

    Checks the invariants Perfetto's importer relies on: a
    ``traceEvents`` list whose entries carry name/ph/ts/pid/tid, with a
    non-negative ``dur`` on every complete ("X") event.
    """
    errors: list[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing traceEvents list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event[{i}]: not an object")
            continue
        for field in _REQUIRED:
            if field not in ev:
                errors.append(f"event[{i}] ({ev.get('name')}): no {field!r}")
        if not isinstance(ev.get("name"), str):
            errors.append(f"event[{i}]: name must be a string")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event[{i}] ({ev.get('name')}): ts not numeric")
        if ev.get("ph") == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event[{i}] ({ev.get('name')}): X event needs dur >= 0"
                )
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"event[{i}] ({ev.get('name')}): args not a dict")
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    return errors


# ---------------------------------------------------------------------------
# Fleet merge: several hosts' exports -> one Perfetto timeline.
# ---------------------------------------------------------------------------

# Per-host pid stride in a merged trace: host i's original pid p becomes
# i * _MERGE_PID_STRIDE + p, so process tracks from different hosts
# never collide in the merged view (in-repo tracers use single-digit
# pids; the sweep runner's per-shard pids stay well under the stride).
_MERGE_PID_STRIDE = 10_000


def merge_traces(traces) -> dict:
    """Union per-host Chrome-trace exports onto one timeline.

    Each input is a parsed ``Tracer.to_json()`` object.  Timestamps are
    tracer-relative microseconds; the per-export ``clock.epoch0_s``
    anchor (absent on pre-fleet-merge exports — those merge at offset
    0) shifts every host onto the earliest tracer's clock, and pids are
    namespaced per host (stride :data:`_MERGE_PID_STRIDE`) with a
    ``process_name`` metadata row labelling the host, so the merged
    JSON opens in Perfetto as one timeline with per-host process
    groups.  The result revalidates under :func:`validate_trace`.
    """
    traces = list(traces)
    if not traces:
        raise ValueError("merge_traces: no traces given")
    anchors = []
    for t in traces:
        clock = t.get("clock") if isinstance(t, dict) else None
        anchors.append(
            float(clock["epoch0_s"])
            if isinstance(clock, dict)
            and isinstance(clock.get("epoch0_s"), (int, float))
            else None
        )
    known = [a for a in anchors if a is not None]
    base = min(known) if known else 0.0

    merged: list[dict] = []
    hosts: list[dict] = []
    for i, t in enumerate(traces):
        events = t.get("traceEvents") or []
        host = t.get("host") if isinstance(t.get("host"), dict) else {}
        hosts.append(host or {"hostname": f"trace{i}"})
        offset_us = (
            (anchors[i] - base) * 1e6 if anchors[i] is not None else 0.0
        )
        label = "{}#{}".format(
            host.get("hostname", f"trace{i}"), host.get("host_index", i)
        )
        seen_pids: set = set()
        for ev in events:
            ev = dict(ev)
            pid = ev.get("pid", 0)
            ev["pid"] = i * _MERGE_PID_STRIDE + (
                pid if isinstance(pid, int) else 0
            )
            if ev.get("ph") != "M":
                ev["ts"] = float(ev.get("ts", 0.0)) + offset_us
            elif ev.get("name") == "process_name":
                # Prefix the original process name with the host label
                # so per-host groups read apart in the merged view.
                args = dict(ev.get("args") or {})
                args["name"] = f"{label} | {args.get('name', '')}"
                ev["args"] = args
            seen_pids.add(ev["pid"])
            merged.append(ev)
        # Hosts whose events never named their processes still get a
        # labelled track.
        named = {
            e["pid"] for e in merged
            if e.get("ph") == "M" and e.get("name") == "process_name"
        }
        for pid in sorted(seen_pids - named):
            merged.append({
                "name": "process_name", "ph": "M", "ts": 0.0,
                "pid": pid, "tid": 0, "args": {"name": label},
            })
    return {
        "traceEvents": merged,
        "displayTimeUnit": "ms",
        "merged_from": hosts,
        "clock": {"epoch0_s": base},
    }


# ---------------------------------------------------------------------------
# Environment hook: REPRO_TRACE=path enables at import, exports at exit.
# ---------------------------------------------------------------------------


def _export_at_exit() -> None:  # pragma: no cover - atexit plumbing
    t = _TRACER
    if t is not None and t.path:
        try:
            t.export()
        except OSError:
            pass


_env = os.environ.get(ENV_VAR)
if _env:  # pragma: no cover - exercised via subprocess in tests
    enable(None if _env in ("1", "true") else _env)
    atexit.register(_export_at_exit)


__all__ = [
    "ENV_VAR",
    "Tracer",
    "NULL_SPAN",
    "enable",
    "disable",
    "enabled",
    "get_tracer",
    "span",
    "instant",
    "counter",
    "validate_trace",
    "merge_traces",
]
