"""Schedule-timeline + inefficiency-signature exporter.

Renders any simulated schedule — a ``(gemm, machine, schedule)`` triple,
or one entry of a :class:`~repro.core.engine.GridResult` — as a per-step
comm/GEMM/DMA lane timeline in the same Chrome trace-event format the
runtime tracer (:mod:`repro.obs.trace`) emits, annotated with the
paper's inefficiency decomposition (exposure, decomposition overhead,
contention) from :mod:`repro.core.inefficiency`.  This is the paper's
per-schedule Gantt figures (Fig. 6 / Fig. 11b) reproduced as a tool:
every FiCCO schedule in the design space opens in ``chrome://tracing``
or https://ui.perfetto.dev.

Lanes (threads under one process per rendered scenario):

  tid 0  comm (DMA)      — AG / P2P / per-chunk A2A steps
  tid 1  compute (GEMM)  — local-shard + per-step GEMMs (incl. the
                           gather/scatter residual folded into a step)
  tid 2  exposed comm    — intervals where compute stalls on the wire

The lowering comes from :func:`repro.core.simulator.schedule_steps`, so
what the timeline shows is *exactly* what ``simulate()`` integrates —
the rendered spans sum to ``SimResult.comm_busy``/``compute_busy`` and
the stall lane to ``SimResult.exposed_comm``.
"""

from __future__ import annotations

from repro.obs import trace as _trace

_LANE_COMM, _LANE_COMPUTE, _LANE_EXPOSED = 0, 1, 2


def lane_intervals(steps) -> dict:
    """Per-step ``(start_s, duration_s)`` intervals for each lane.

    Replays the simulator's pipeline recurrence (masked form — the
    unmasked queues are the all-active special case) keeping start
    times instead of only the final clock.  Inactive (ragged-padding)
    steps are dropped from the output rather than rendered as
    zero-width spans.
    """
    comm_active = steps.comm_active or (True,) * len(steps.comm)
    comp_active = steps.comp_active or (True,) * len(steps.compute)

    comm_iv: list[tuple[float, float]] = []
    finish: list[float] = []
    t = 0.0
    for c, active in zip(steps.comm, comm_active):
        dur = c if active else 0.0
        if active:
            comm_iv.append((t, dur))
        t += dur
        finish.append(t)

    comp_iv: list[tuple[float, float]] = []
    stall_iv: list[tuple[float, float]] = []
    t_comp = 0.0
    for i, work in enumerate(steps.compute):
        active = comp_active[i]
        w = work if active else 0.0
        dep = steps.deps[i]
        if dep is not None and active:
            ready = finish[dep]
            if ready > t_comp:
                stall_iv.append((t_comp, ready - t_comp))
                t_comp = ready
        if active:
            comp_iv.append((t_comp, w))
        t_comp += w
    return {"comm": comm_iv, "compute": comp_iv, "exposed": stall_iv}


def inefficiency_signature(steps, result=None) -> dict:
    """The schedule's inefficiency decomposition, in seconds.

    Splits the gap between the ideal overlap time and the simulated
    total into the paper's §IV loss categories, inverted from the
    streams' aggregate busy times and the CIL factors the lowering
    applied:

      exposure_s             comm the compute channel actually waited on
      comm_decomposition_s   finer-grain DMA overhead (latency + ramp
                             per chunk; link under-use for shard-P2P):
                             busy/cil − serial
      comm_contention_s      slowdown from concurrent streams:
                             busy · (1 − 1/cil)
      gemm_decomposition_s / gemm_contention_s — same split for compute
                             (decomposition = DIL: re-reads, occupancy,
                             launch latency of the chunked GEMMs)

    The contention split needs the scalar CIL factors the uniform
    lowering records; ragged lowerings apply CIL per step internally,
    so only the always-valid fields are reported there.  The hetero
    local-shard GEMM runs under the step streams' CIL factor
    approximately (its own factor differs by chunk shape), making the
    hetero splits a close decomposition, not an exact one.
    """
    res = result if result is not None else steps.run()
    sig = {
        "schedule": res.schedule.value,
        "steps": res.steps,
        "total_s": res.total,
        "serial_comm_s": res.serial_comm,
        "serial_gemm_s": res.serial_gemm,
        "serial_total_s": res.serial_total,
        "ideal_total_s": res.ideal_total,
        "speedup": res.speedup,
        "exposure_s": res.exposed_comm,
        "comm_busy_s": res.comm_busy,
        "compute_busy_s": res.compute_busy,
    }
    if steps.comm_cil is not None and steps.gemm_cil is not None:
        cc, gc = steps.comm_cil, steps.gemm_cil
        sig.update(
            comm_cil=cc,
            gemm_cil=gc,
            comm_contention_s=res.comm_busy * (1.0 - 1.0 / cc),
            comm_decomposition_s=res.comm_busy / cc - res.serial_comm,
            gemm_contention_s=res.compute_busy * (1.0 - 1.0 / gc),
            gemm_decomposition_s=res.compute_busy / gc - res.serial_gemm,
        )
    return sig


def _comm_step_name(schedule) -> str:
    from repro.core.schedule_types import Schedule

    return {
        Schedule.SERIAL: "all_gather",
        Schedule.SHARD_P2P: "p2p_step",
    }.get(schedule, "a2a_chunk")


def schedule_timeline(
    gemm,
    machine,
    schedule,
    *,
    dma: bool = True,
    dma_into_place: bool = False,
    profile=None,
    tracer=None,
    pid: int = 1,
    name: str | None = None,
):
    """Render one scenario's schedule into a tracer.

    Returns ``(tracer, signature)``; pass an existing ``tracer`` (and
    distinct ``pid``\\ s) to stack several scenarios/schedules in one
    trace for side-by-side comparison in Perfetto.  Raises ValueError
    exactly where ``simulate`` does (indivisible decompositions).
    """
    from repro.core.simulator import schedule_steps

    steps = schedule_steps(
        gemm, machine, schedule,
        dma=dma, dma_into_place=dma_into_place, profile=profile,
    )
    res = steps.run()
    sig = inefficiency_signature(steps, res)
    lanes = lane_intervals(steps)

    tr = tracer if tracer is not None else _trace.Tracer()
    label = name or f"m{gemm.m} n{gemm.n} k{gemm.k}"
    tr.name_process(pid, f"{label} | {schedule.value} @ {machine.name}")
    tr.name_thread(pid, _LANE_COMM, "comm (DMA)")
    tr.name_thread(pid, _LANE_COMPUTE, "compute (GEMM)")
    tr.name_thread(pid, _LANE_EXPOSED, "exposed comm (stall)")

    comm_name = _comm_step_name(schedule)
    for i, (t0, dur) in enumerate(lanes["comm"]):
        tr._append({
            "name": comm_name, "cat": "timeline/comm", "ph": "X",
            "ts": t0 * 1e6, "dur": dur * 1e6,
            "pid": pid, "tid": _LANE_COMM,
            "args": {"step": i, "seconds": dur},
        })
    for i, (t0, dur) in enumerate(lanes["compute"]):
        is_local = steps.local_first and i == 0
        tr._append({
            "name": "local_gemm" if is_local else "gemm_step",
            "cat": "timeline/compute", "ph": "X",
            "ts": t0 * 1e6, "dur": dur * 1e6,
            "pid": pid, "tid": _LANE_COMPUTE,
            "args": {"step": i, "seconds": dur},
        })
    for i, (t0, dur) in enumerate(lanes["exposed"]):
        tr._append({
            "name": "exposed", "cat": "timeline/exposed", "ph": "X",
            "ts": t0 * 1e6, "dur": dur * 1e6,
            "pid": pid, "tid": _LANE_EXPOSED,
            "args": {"seconds": dur},
        })
    tr._append({
        "name": "inefficiency_signature", "cat": "timeline", "ph": "i",
        "ts": 0.0, "s": "p", "pid": pid, "tid": _LANE_COMM, "args": sig,
    })
    return tr, sig


def grid_timeline(
    grid,
    scenario: int,
    machine: int = 0,
    *,
    schedule=None,
    tracer=None,
    pid: int = 1,
    name: str | None = None,
):
    """Render one ``GridResult`` entry (default: its best schedule).

    Re-lowers the scenario through the scalar simulator — bit-identical
    to the grid's own figures by the engine differential contract — so
    any sweep point can be pulled out of a result table and *looked at*.
    """
    from repro.core import batch as _batch
    from repro.core.workload import StepProfile

    if schedule is None:
        schedule = grid.schedules[int(grid.best_idx()[scenario, machine])]
    profile = None
    if isinstance(grid.scenarios, _batch.RaggedBatch):
        profile = StepProfile.from_weights(
            grid.scenarios.frac[scenario]
        ).trimmed()
    return schedule_timeline(
        grid.scenarios.gemm(scenario),
        grid.machines[machine],
        schedule,
        dma=grid.dma,
        profile=profile,
        tracer=tracer,
        pid=pid,
        name=name or f"scenario {scenario}",
    )


__all__ = [
    "lane_intervals",
    "inefficiency_signature",
    "schedule_timeline",
    "grid_timeline",
]
