"""Streaming per-decision inefficiency-signature attribution.

:mod:`repro.obs.timeline` renders the paper's inefficiency signature
(DIL / CIL-contention / exposed comm) for one ``simulate()`` result,
offline.  This module makes the signature a *streaming* observable: every
live schedule decision — ``Autotuner.pick``/``measure`` and every
:class:`repro.serve.adapt.AdaptiveTier` pick — is decomposed into the
paper's loss categories via :func:`repro.core.inefficiency.
loss_components` + :func:`repro.core.simulator.schedule_steps`, and
accumulated into windowed per-``(machine-family, scenario-class,
schedule)`` signature cells.  ``scripts/trace.py signature`` overlays
the accumulated signatures on the schedule grid.

The components **integrate exactly**: for every decision,
``sum(components.values()) == analytic total`` (uniform schedules split
the compute side into serial + DIL + contention; ragged lowerings keep
it whole; the ``comm_tail_s`` term closes the identity in comm-bound
regimes).  When the decision carries a measured time, the
log-residual ``log(measured / model)`` is accumulated beside the
components — the same signal :mod:`repro.obs.sentinel` monitors.

Hot-path budget: the serving tier picks in tens of microseconds, so
:meth:`SignatureStream.observe_decision` memoizes the (pure, analytic)
decomposition per decision key — the steady state is one dict lookup
plus a handful of locked float adds, measured by
``benchmarks/bench_obs.py`` as ``obs/signature_overhead`` and gated in
CI.

Enable process-wide (:func:`enable_signatures`) or via the
environment::

    REPRO_SIGNATURES=sig.jsonl python scripts/serve.py ...

This module stays stdlib-only at import time (``repro.obs.__init__``
executes while the instrumented core modules are importing); the
simulator/inefficiency imports happen inside the functions that need
them.
"""

from __future__ import annotations

import atexit
import collections
import json
import math
import os
import threading
import time

ENV_VAR = "REPRO_SIGNATURES"

# Component keys, per lowering family (see core.inefficiency.
# loss_components): the schema validate_signature checks against.
UNIFORM_COMPONENTS = (
    "serial_gemm_s",
    "gemm_decomposition_s",
    "gemm_contention_s",
    "exposed_comm_s",
    "comm_tail_s",
)
RAGGED_COMPONENTS = ("compute_busy_s", "exposed_comm_s", "comm_tail_s")


def machine_family(name: str) -> str:
    """``tpu_v5e/dma`` -> ``tpu_v5e`` (the per-family aggregation key).

    Mirrors :func:`repro.learn.gate.machine_family` without importing
    the learn package (this module must stay stdlib-only at import).
    """
    return name.split("/", 1)[0]


def scenario_class(gemm, profile=None) -> str:
    """Bucketed scenario identity: ``<profile-or-uniform>/f<log2 flops>``.

    Scenario classes keep the accumulator bounded under arbitrary
    traffic: GEMMs within a 2x FLOP band and the same step-profile
    family share a cell, which is the granularity the paper's
    proportion sweeps (Fig. 10) vary anyway.
    """
    flops = 2.0 * gemm.m * gemm.n * gemm.k
    band = int(math.log2(flops)) if flops > 0 else 0
    fam = "uniform" if profile is None else (profile.name or "ragged")
    return f"{fam}/f{band}"


def decision_signature(
    gemm,
    machine,
    schedule,
    *,
    group=None,
    profile=None,
    dma: bool = True,
) -> dict:
    """One decision's exactly-integrating signature decomposition.

    Lowers the scenario through :func:`~repro.core.simulator.
    schedule_steps` (the same lowering ``simulate`` integrates) and
    splits the analytic total via :func:`~repro.core.inefficiency.
    loss_components`.  Raises where ``simulate`` does (indivisible
    decompositions) — streaming callers catch.
    """
    from repro.core.inefficiency import loss_components
    from repro.core.machine import machine_for_group
    from repro.core.simulator import schedule_steps

    eff = machine_for_group(machine, group) if group else machine
    steps = schedule_steps(gemm, eff, schedule, dma=dma, profile=profile)
    res = steps.run()
    components = loss_components(
        res, comm_cil=steps.comm_cil, gemm_cil=steps.gemm_cil
    )
    return {
        "schedule": res.schedule.value,
        "family": machine_family(machine.name),
        "scenario": scenario_class(gemm, profile),
        "ragged": steps.gemm_cil is None,
        "total_s": res.total,
        "comm_busy_s": res.comm_busy,
        "compute_busy_s": res.compute_busy,
        "serial_comm_s": res.serial_comm,
        "serial_gemm_s": res.serial_gemm,
        "components": components,
    }


class _CellStat:
    """count/sum/min/max of one component inside a cell (lock held by
    the owning accumulator — plain float updates here)."""

    __slots__ = ("count", "sum", "min", "max")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def add_n(self, v: float, n: int) -> None:
        """Fold ``n`` identical observations in one step (the deferred
        flush of a memoized constant decomposition)."""
        self.count += n
        self.sum += n * v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def merge(self, other: "_CellStat") -> None:
        if not other.count:
            return
        self.count += other.count
        self.sum += other.sum
        if other.min < self.min:
            self.min = other.min
        if other.max > self.max:
            self.max = other.max

    def to_json(self) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.sum / self.count,
        }


class _Cell:
    """One (family, scenario-class, schedule) signature histogram cell."""

    __slots__ = ("components", "total", "residual", "sources", "ragged")

    def __init__(self):
        self.components: dict[str, _CellStat] = {}
        self.total = _CellStat()
        self.residual = _CellStat()   # log(measured / model)
        self.sources: dict[str, int] = {}
        self.ragged = False


class SignatureAccumulator:
    """Windowed, bounded per-(family, scenario, schedule) signature store.

    ``max_cells`` bounds memory under arbitrary traffic (LRU beyond);
    :meth:`roll` exports the window and starts a fresh one, so a
    long-lived server produces a tail-able JSONL stream of signature
    snapshots the same way the metrics registry streams counter
    snapshots.
    """

    def __init__(self, *, max_cells: int = 512):
        self.max_cells = int(max_cells)
        self._cells: "collections.OrderedDict[tuple, _Cell]" = (
            collections.OrderedDict()
        )
        self._lock = threading.Lock()
        self._window_started = time.time()
        self.evicted = 0
        # Bumped whenever a cell object may have been dropped (roll /
        # eviction): invalidates the direct cell references
        # SignatureStream memoizes for its lock-once hot path.
        self._gen = 0

    def _cell_locked(self, key: tuple, ragged: bool, comp_names) -> tuple:
        """(cell, per-component stats aligned with ``comp_names``) —
        caller holds ``self._lock``."""
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = _Cell()
            cell.ragged = ragged
            while len(self._cells) > self.max_cells:
                self._cells.popitem(last=False)
                self.evicted += 1
                self._gen += 1
        else:
            self._cells.move_to_end(key)
        stats = []
        for name in comp_names:
            stat = cell.components.get(name)
            if stat is None:
                stat = cell.components[name] = _CellStat()
            stats.append(stat)
        return cell, tuple(stats)

    def observe(
        self,
        family: str,
        scenario: str,
        schedule: str,
        components: dict,
        total_s: float,
        *,
        ragged: bool = False,
        source: str | None = None,
        model_total_s: float | None = None,
        measured_total_s: float | None = None,
    ) -> None:
        key = (family, scenario, schedule)
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = self._cells[key] = _Cell()
                while len(self._cells) > self.max_cells:
                    self._cells.popitem(last=False)
                    self.evicted += 1
                    self._gen += 1
            else:
                self._cells.move_to_end(key)
            cell.ragged = ragged
            cell.total.add(total_s)
            for name, v in components.items():
                stat = cell.components.get(name)
                if stat is None:
                    stat = cell.components[name] = _CellStat()
                stat.add(v)
            if source is not None:
                cell.sources[source] = cell.sources.get(source, 0) + 1
            if (
                measured_total_s is not None
                and model_total_s is not None
                and measured_total_s > 0.0
                and model_total_s > 0.0
            ):
                cell.residual.add(
                    math.log(measured_total_s / model_total_s)
                )

    def snapshot(self) -> dict:
        """One self-describing signature snapshot (schema:
        :func:`validate_signature`)."""
        with self._lock:
            cells = [
                {
                    "family": fam,
                    "scenario": scen,
                    "schedule": sched,
                    "ragged": cell.ragged,
                    "count": cell.total.count,
                    "total_s": cell.total.to_json(),
                    "components": {
                        k: s.to_json()
                        for k, s in sorted(cell.components.items())
                    },
                    "residual": cell.residual.to_json(),
                    "sources": dict(cell.sources),
                }
                for (fam, scen, sched), cell in self._cells.items()
            ]
            window_started = self._window_started
            evicted = self.evicted
        return {
            "ts": time.time(),
            "window_started": window_started,
            "cells": cells,
            "evicted": evicted,
        }

    def roll(self) -> dict:
        """Snapshot the current window, then start a fresh one."""
        snap = self.snapshot()
        with self._lock:
            self._cells.clear()
            self._gen += 1
            self._window_started = time.time()
        return snap

    def __len__(self) -> int:
        with self._lock:
            return len(self._cells)


class SignatureStream:
    """The live attribution pipeline the tuner / serving tier feed.

    ``observe_decision`` never raises and memoizes the analytic
    decomposition per decision identity (the decomposition is pure: the
    same key always yields the same components).  Because the
    decomposition is *constant* per key, repeat observations are folded
    lazily: the hot path appends one item to the memo entry's pending
    deque — a single C-atomic call, no lock (``obs/signature_overhead``
    in ``benchmarks/bench_obs.py``) — and :meth:`flush` drains pending
    items into the accumulator cells exactly (``n`` identical
    observations fold as ``count += n``, ``sum += n*v``) whenever a
    snapshot is taken, or when an entry's backlog reaches
    ``_DRAIN_AT``.  ``observed`` therefore updates at flush time, not
    per call.
    """

    _DRAIN_AT = 1024  # per-entry pending backlog that forces a drain

    def __init__(
        self,
        path: str | None = None,
        *,
        max_cells: int = 512,
        max_memo: int = 4096,
    ):
        self.path = path
        self.acc = SignatureAccumulator(max_cells=max_cells)
        self.max_memo = int(max_memo)
        # Entries: [family, scenario, schedule, ragged, comp_items,
        # total_s, pending_deque], or [None] for a decision key the
        # lowering rejects.  Pending items are the decision's source
        # string (pick path) or a (source, model_s, measured_s) tuple
        # (measure path).  One lock (the accumulator's) guards memo
        # mutation, flushing, and cells; the hit path only reads the
        # memo dict and appends to a deque, both atomic under the GIL.
        self._memo: "collections.OrderedDict[tuple, list]" = (
            collections.OrderedDict()
        )
        self._lock = self.acc._lock
        self.observed = 0
        self.errors = 0

    def observe_decision(
        self,
        gemm,
        machine,
        schedule,
        *,
        group=None,
        profile=None,
        source: str | None = None,
        model_total_s: float | None = None,
        measured_total_s: float | None = None,
    ) -> None:
        """Attribute one live decision.  Never raises — observability
        stays subordinate to the decision path's never-raise contract."""
        try:
            key = (
                machine.name,
                group,
                gemm.m, gemm.n, gemm.k, gemm.dtype_bytes,
                None if profile is None else profile.digest(),
                schedule,
            )
            entry = self._memo.get(key)
            if entry is not None:
                if entry[0] is None:  # remembered un-lowerable key
                    return
                pending = entry[6]
                pending.append(
                    source
                    if measured_total_s is None
                    else (source, model_total_s, measured_total_s)
                )
                if len(pending) >= self._DRAIN_AT:
                    with self._lock:
                        self._flush_entry_locked(entry)
                return
            # First sighting: lower + decompose outside the lock (the
            # decomposition is pure, so a concurrent double-compute is
            # just wasted work, never wrong).
            try:
                sig = decision_signature(
                    gemm, machine, schedule, group=group, profile=profile,
                )
                entry = [
                    sig["family"], sig["scenario"], sig["schedule"],
                    sig["ragged"], tuple(sig["components"].items()),
                    sig["total_s"], collections.deque(),
                ]
            except Exception:
                entry = [None]  # un-lowerable here; remember the miss
                self.errors += 1
            with self._lock:
                existing = self._memo.get(key)
                if existing is not None:
                    entry = existing  # lost the compute race
                else:
                    self._memo[key] = entry
                    while len(self._memo) > self.max_memo:
                        _, old = self._memo.popitem(last=False)
                        if old[0] is not None:
                            self._flush_entry_locked(old)
                if entry[0] is None:
                    return
                entry[6].append(
                    source
                    if measured_total_s is None
                    else (source, model_total_s, measured_total_s)
                )
        except Exception:  # pragma: no cover - observability best-effort
            self.errors += 1

    def _flush_entry_locked(self, entry: list) -> None:
        """Drain one memo entry's pending observations into its cell
        (caller holds the shared lock).

        Only the ``len()`` sampled up front is drained — items a
        concurrent decision appends mid-drain stay queued for the next
        flush, so nothing is lost and nothing double-counts.
        """
        pending = entry[6]
        n = len(pending)
        if not n:
            return
        total_s = entry[5]
        cell, stats = self.acc._cell_locked(
            (entry[0], entry[1], entry[2]), entry[3],
            [name for name, _ in entry[4]],
        )
        cell.total.add_n(total_s, n)
        for stat, (_, v) in zip(stats, entry[4]):
            stat.add_n(v, n)
        sources = cell.sources
        residual = cell.residual
        popleft = pending.popleft
        for _ in range(n):
            item = popleft()
            if type(item) is tuple:
                source, model, measured = item
                if measured is not None and measured > 0.0:
                    m = model if model is not None else total_s
                    if m > 0.0:
                        residual.add(math.log(measured / m))
            else:
                source = item
            if source is not None:
                sources[source] = sources.get(source, 0) + 1
        self.observed += n

    def flush(self) -> None:
        """Fold every pending memoized observation into the cells."""
        with self._lock:
            for entry in self._memo.values():
                if entry[0] is not None:
                    self._flush_entry_locked(entry)

    def snapshot(self) -> dict:
        self.flush()
        return self.acc.snapshot()

    def roll(self) -> dict:
        self.flush()
        return self.acc.roll()

    def export_jsonl(self, path: str | None = None, *, roll: bool = True) -> dict:
        """Append one signature-snapshot line; rolls the window by
        default.  Returns the snapshot."""
        path = path or self.path
        snap = self.roll() if roll else self.snapshot()
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(path, "a") as f:
                f.write(json.dumps(snap) + "\n")
        return snap


# ---------------------------------------------------------------------------
# Process-wide stream (what the tuner / serving tier consult).
# ---------------------------------------------------------------------------

_STREAM: SignatureStream | None = None


def enable_signatures(
    path: str | None = None, *, max_cells: int = 512, max_memo: int = 4096
) -> SignatureStream:
    """Install the process-wide signature stream (``path`` optional:
    :func:`disable_signatures` exports there)."""
    global _STREAM
    _STREAM = SignatureStream(path, max_cells=max_cells, max_memo=max_memo)
    return _STREAM


def disable_signatures() -> dict | None:
    """Uninstall the stream; exports a final snapshot first if it has a
    path.  Returns that snapshot (None if nothing was installed)."""
    global _STREAM
    s, _STREAM = _STREAM, None
    if s is not None and s.path:
        return s.export_jsonl()
    return None


def get_signatures() -> SignatureStream | None:
    return _STREAM


# ---------------------------------------------------------------------------
# Snapshot schema + report (scripts/trace.py signature, CI gate).
# ---------------------------------------------------------------------------

_STAT_FIELDS = ("count", "sum", "min", "max", "mean")


def _check_stat(prefix: str, obj, errors: list[str]) -> None:
    if not isinstance(obj, dict):
        errors.append(f"{prefix}: not an object")
        return
    for field in _STAT_FIELDS:
        if not isinstance(obj.get(field), (int, float)):
            errors.append(f"{prefix}: no numeric {field!r}")


def validate_signature(obj) -> list[str]:
    """Structural errors in one signature snapshot ([] == valid)."""
    errors: list[str] = []
    if not isinstance(obj, dict):
        return [f"snapshot must be an object, got {type(obj).__name__}"]
    if not isinstance(obj.get("ts"), (int, float)):
        errors.append("missing numeric 'ts'")
    cells = obj.get("cells")
    if not isinstance(cells, list):
        return errors + ["missing 'cells' list"]
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            errors.append(f"cell[{i}]: not an object")
            continue
        for field in ("family", "scenario", "schedule"):
            if not isinstance(cell.get(field), str):
                errors.append(f"cell[{i}]: no {field!r} string")
        if not isinstance(cell.get("count"), int):
            errors.append(f"cell[{i}]: no integer 'count'")
        _check_stat(f"cell[{i}].total_s", cell.get("total_s"), errors)
        comps = cell.get("components")
        if not isinstance(comps, dict) or not comps:
            errors.append(f"cell[{i}]: missing 'components'")
            continue
        expected = (
            RAGGED_COMPONENTS if cell.get("ragged") else UNIFORM_COMPONENTS
        )
        for name in expected:
            if name not in comps:
                errors.append(f"cell[{i}]: no component {name!r}")
        for name, stat in comps.items():
            _check_stat(f"cell[{i}].components[{name}]", stat, errors)
        if len(errors) > 50:
            errors.append("... (truncated)")
            break
    return errors


def overlay(snapshots) -> dict:
    """Fold signature snapshots into a schedule-grid overlay.

    Returns ``{(family, scenario): {schedule: {"count", "mean_total_s",
    "dominant", "loss_fractions"}}}`` — mean decision time per cell plus
    which loss category dominates it, the observed twin of the paper's
    signature-over-design-space figures.  ``dominant`` considers only
    the *loss* components (the serial GEMM / ragged busy term is the
    work itself, not a loss).
    """
    work_terms = ("serial_gemm_s", "compute_busy_s")
    merged: dict = {}
    for snap in snapshots:
        for cell in snap.get("cells", []):
            row = merged.setdefault(
                (cell["family"], cell["scenario"]), {}
            )
            agg = row.setdefault(
                cell["schedule"],
                {"count": 0, "total_sum": 0.0, "comp_sums": {}},
            )
            agg["count"] += cell["count"]
            agg["total_sum"] += cell["total_s"]["sum"]
            for name, stat in cell["components"].items():
                agg["comp_sums"][name] = (
                    agg["comp_sums"].get(name, 0.0) + stat["sum"]
                )
    out: dict = {}
    for rowkey, row in merged.items():
        out[rowkey] = {}
        for sched, agg in row.items():
            n = agg["count"]
            losses = {
                k: v for k, v in agg["comp_sums"].items()
                if k not in work_terms
            }
            total = agg["total_sum"]
            out[rowkey][sched] = {
                "count": n,
                "mean_total_s": total / n if n else 0.0,
                "dominant": (
                    max(losses, key=losses.get) if losses else None
                ),
                "loss_fractions": {
                    k: (v / total if total else 0.0)
                    for k, v in sorted(losses.items())
                },
            }
    return out


# ---------------------------------------------------------------------------
# Environment hook: REPRO_SIGNATURES=path enables at import, exports at
# exit (same contract as REPRO_TRACE).
# ---------------------------------------------------------------------------


def _export_at_exit() -> None:  # pragma: no cover - atexit plumbing
    s = _STREAM
    if s is not None and s.path:
        try:
            s.export_jsonl()
        except OSError:
            pass


_env = os.environ.get(ENV_VAR)
if _env:  # pragma: no cover - exercised via subprocess in tests
    enable_signatures(None if _env in ("1", "true") else _env)
    atexit.register(_export_at_exit)


__all__ = [
    "ENV_VAR",
    "UNIFORM_COMPONENTS",
    "RAGGED_COMPONENTS",
    "machine_family",
    "scenario_class",
    "decision_signature",
    "SignatureAccumulator",
    "SignatureStream",
    "enable_signatures",
    "disable_signatures",
    "get_signatures",
    "validate_signature",
    "overlay",
]
