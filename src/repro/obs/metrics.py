"""Counter/histogram metrics registry with JSONL snapshot export.

Replaces the lone ``Autotuner.hit_rate`` scalar with a process-wide
registry the whole stack reports into: tuner decisions per tier, sweep
shard durations and throughput percentiles, serve/train step counts,
and the gate-agreement rate against the analytic argmin.  Counters are
one locked attribute increment, histograms one locked reservoir update —
always-on cost is negligible next to the operations they measure
(``benchmarks/bench_obs`` gates the sweep path either way).

Both metric types are **thread-safe**: the adaptive serving tier
(:mod:`repro.serve.adapt`) puts the tuner — and therefore these
counters — on a multithreaded hot path (request threads + the
background re-fit thread), where the bare ``+=`` increments this module
shipped with lose counts under contention.  Every mutation and every
consistent read (``to_json``) takes the instance's own lock, so
``snapshot()`` never sees ``total`` disagree with ``count``.

Histograms are **bounded**: a long-lived serving process observes
millions of pick latencies, and keeping every raw sample would grow
without bound.  ``count``/``sum``/``min``/``max`` stay exact;
percentiles come from a fixed-size uniform reservoir (Vitter's
algorithm R, ``RESERVOIR_SIZE`` samples) — exact until the reservoir
fills, afterwards a uniform random sample whose nearest-rank
percentiles carry the usual ~1/sqrt(K) sampling error (K=4096 puts
p50/p95 within ~1.6 percentile points at 95% confidence).  The
reservoir RNG is seeded per instance, so single-threaded runs are
reproducible.

Snapshots are JSON dictionaries; :meth:`MetricsRegistry.export_jsonl`
appends one line per snapshot so a long-running server produces a
tail-able metrics stream the same way ``scripts/sweep.py`` streams
shard summaries.  ``scripts/trace.py metrics`` merges/validates the
stream and can convert it to Chrome counter events for Perfetto.

Metric key glossary (the canonical names the instrumentation uses):

  ``tuner/pick.<tier>``      picks decided by cache|analytic|measured|heuristic
  ``tuner/decisions``        total ``Autotuner.pick`` calls
  ``tuner/pick_seconds``     per-pick wall time histogram
  ``tuner/measure``          measured-tier sessions
  ``sweep/shards``           shards evaluated
  ``sweep/scenarios``        scenarios evaluated
  ``sweep/shard_seconds``    per-shard duration histogram (p50/p95 exported)
  ``engine/evaluate.<name>`` evaluate() calls per engine backend
  ``gate/agree``,``gate/points``  heuristic-vs-analytic-argmin agreement
  ``serve/tokens``,``serve/steps``,``train/steps``  launcher hot paths
  ``overlap/resolve.<how>``  trace-time schedule resolutions
"""

from __future__ import annotations

import json
import math
import os
import random
import threading
import time


class Counter:
    """Monotonic counter.  ``inc`` is atomic under its own lock — the
    GIL does not make ``self.value += n`` atomic (read-add-store can
    interleave), and the serving tier increments from many threads."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


# Reservoir size: percentiles are exact below this many observations,
# a uniform sample above it (~1.6pp worst-case p50/p95 error at 95%
# confidence).  Bounded regardless of process lifetime.
RESERVOIR_SIZE = 4096


class Histogram:
    """Bounded-reservoir histogram with exact count/sum and percentile
    export.

    ``count``/``total``/``min``/``max`` are exact for every observation
    ever made; ``percentile`` is nearest-rank over a fixed-size uniform
    reservoir (algorithm R) — exact while ``count <= RESERVOIR_SIZE``,
    a documented-accuracy sample beyond that.  All mutation and
    consistent reads lock, so concurrent ``observe`` never loses
    samples and ``to_json`` never reports ``sum`` torn against
    ``count``.
    """

    __slots__ = ("_samples", "total", "_count", "_min", "_max",
                 "_rng", "_lock")

    def __init__(self, *, seed: int = 0):
        self._samples: list[float] = []
        self.total = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._count += 1
            self.total += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if len(self._samples) < RESERVOIR_SIZE:
                self._samples.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < RESERVOIR_SIZE:
                    self._samples[j] = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def values(self) -> list[float]:
        """A copy of the retained reservoir samples (NOT the full
        observation history once ``count > RESERVOIR_SIZE``)."""
        with self._lock:
            return list(self._samples)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the reservoir; ``q`` in [0, 1].
        0.0 when empty; exact until the reservoir fills."""
        with self._lock:
            ordered = sorted(self._samples)
        if not ordered:
            return 0.0
        rank = max(math.ceil(q * len(ordered)), 1) - 1
        return ordered[min(rank, len(ordered) - 1)]

    def to_json(self) -> dict:
        with self._lock:
            if not self._count:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "p50": 0.0, "p95": 0.0}
            count = self._count
            total = self.total
            lo, hi = self._min, self._max
            ordered = sorted(self._samples)

        def rank(q: float) -> float:
            r = max(math.ceil(q * len(ordered)), 1) - 1
            return ordered[min(r, len(ordered) - 1)]

        return {
            "count": count,
            "sum": total,
            "min": lo,
            "max": hi,
            "p50": rank(0.50),
            "p95": rank(0.95),
        }


class MetricsRegistry:
    """Name -> Counter/Histogram store with JSON snapshot export."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._histograms: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram())
        return h

    def snapshot(self) -> dict:
        """One self-describing snapshot of every metric."""
        return {
            "ts": time.time(),
            "counters": {
                k: c.value for k, c in sorted(self._counters.items())
            },
            "histograms": {
                k: h.to_json() for k, h in sorted(self._histograms.items())
            },
        }

    def export_jsonl(self, path: str) -> dict:
        """Append one snapshot line to ``path``; returns the snapshot."""
        snap = self.snapshot()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(snap) + "\n")
        return snap

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._histograms.clear()


# ---------------------------------------------------------------------------
# The process-wide registry.
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    return _REGISTRY


def reset_metrics() -> None:
    """Clear every process-wide metric (test isolation)."""
    _REGISTRY.reset()


def tuner_tier_rates(registry: MetricsRegistry | None = None) -> dict:
    """Per-tier decision fractions — the ``hit_rate`` scalar, itemized."""
    reg = registry or _REGISTRY
    total = reg.counter("tuner/decisions").value
    tiers = ("cache", "analytic", "measured", "heuristic")
    if not total:
        return {t: 0.0 for t in tiers}
    return {
        t: reg.counter(f"tuner/pick.{t}").value / total for t in tiers
    }


def observe_gate_agreement(
    grid, *, gate=None, tau=None, registry: MetricsRegistry | None = None
) -> float:
    """Heuristic-pick agreement rate against the grid's analytic argmin.

    Folds ``gate/agree`` / ``gate/points`` counters into the registry
    and returns this grid's rate — the live signal for "is the deployed
    gate still tracking the analytic optimum" that ROADMAP item 1's
    background re-fit keys off.  Opt-in (it costs one vectorized
    heuristic evaluation per grid): ``scripts/sweep.py --observe-gate``
    wires it onto the shard stream.
    """
    from repro.core.explorer import GridExploration  # lazy: numpy stack

    ex = GridExploration.from_grid(grid, tau=tau, gate=gate)
    agree = int(ex.exact.sum())
    points = int(ex.exact.size)
    reg = registry or _REGISTRY
    reg.counter("gate/agree").inc(agree)
    reg.counter("gate/points").inc(points)
    return agree / points if points else 0.0


# ---------------------------------------------------------------------------
# Snapshot schema validation (CI fast-lane gate, scripts/trace.py).
# ---------------------------------------------------------------------------

_HIST_FIELDS = ("count", "sum", "min", "max", "p50", "p95")


def validate_snapshot(obj) -> list[str]:
    """Structural errors in one metrics snapshot ([] == valid)."""
    errors: list[str] = []
    if not isinstance(obj, dict):
        return [f"snapshot must be an object, got {type(obj).__name__}"]
    if not isinstance(obj.get("ts"), (int, float)):
        errors.append("missing numeric 'ts'")
    counters = obj.get("counters")
    if not isinstance(counters, dict):
        errors.append("missing 'counters' object")
    else:
        for k, v in counters.items():
            if not isinstance(v, (int, float)):
                errors.append(f"counter {k!r}: value not numeric")
    hists = obj.get("histograms")
    if not isinstance(hists, dict):
        errors.append("missing 'histograms' object")
    else:
        for k, h in hists.items():
            if not isinstance(h, dict):
                errors.append(f"histogram {k!r}: not an object")
                continue
            for field in _HIST_FIELDS:
                if not isinstance(h.get(field), (int, float)):
                    errors.append(f"histogram {k!r}: no numeric {field!r}")
    return errors


__all__ = [
    "Counter",
    "Histogram",
    "RESERVOIR_SIZE",
    "MetricsRegistry",
    "get_metrics",
    "reset_metrics",
    "tuner_tier_rates",
    "observe_gate_agreement",
    "validate_snapshot",
]
