"""Counter/histogram metrics registry with JSONL snapshot export.

Replaces the lone ``Autotuner.hit_rate`` scalar with a process-wide
registry the whole stack reports into: tuner decisions per tier, sweep
shard durations and throughput percentiles, serve/train step counts,
and the gate-agreement rate against the analytic argmin.  Counters are
one locked attribute increment, histograms one locked reservoir update —
always-on cost is negligible next to the operations they measure
(``benchmarks/bench_obs`` gates the sweep path either way).

Both metric types are **thread-safe**: the adaptive serving tier
(:mod:`repro.serve.adapt`) puts the tuner — and therefore these
counters — on a multithreaded hot path (request threads + the
background re-fit thread), where the bare ``+=`` increments this module
shipped with lose counts under contention.  Every mutation and every
consistent read (``to_json``) takes the instance's own lock, so
``snapshot()`` never sees ``total`` disagree with ``count``.

Histograms are **bounded**: a long-lived serving process observes
millions of pick latencies, and keeping every raw sample would grow
without bound.  ``count``/``sum``/``min``/``max`` stay exact;
percentiles come from a fixed-size uniform reservoir (Vitter's
algorithm R, ``RESERVOIR_SIZE`` samples) — exact until the reservoir
fills, afterwards a uniform random sample whose nearest-rank
percentiles carry the usual ~1/sqrt(K) sampling error (K=4096 puts
p50/p95 within ~1.6 percentile points at 95% confidence).  The
reservoir RNG is seeded per instance, so single-threaded runs are
reproducible.

Snapshots are JSON dictionaries; :meth:`MetricsRegistry.export_jsonl`
appends one line per snapshot so a long-running server produces a
tail-able metrics stream the same way ``scripts/sweep.py`` streams
shard summaries.  ``scripts/trace.py metrics`` merges/validates the
stream and can convert it to Chrome counter events for Perfetto.

Metric key glossary (the canonical names the instrumentation uses):

  ``tuner/pick.<tier>``      picks decided by cache|analytic|measured|heuristic
  ``tuner/decisions``        total ``Autotuner.pick`` calls
  ``tuner/pick_seconds``     per-pick wall time histogram
  ``tuner/measure``          measured-tier sessions
  ``sweep/shards``           shards evaluated
  ``sweep/scenarios``        scenarios evaluated
  ``sweep/shard_seconds``    per-shard duration histogram (p50/p95 exported)
  ``engine/evaluate.<name>`` evaluate() calls per engine backend
  ``gate/agree``,``gate/points``  heuristic-vs-analytic-argmin agreement
  ``serve/tokens``,``serve/steps``,``train/steps``  launcher hot paths
  ``overlap/resolve.<how>``  trace-time schedule resolutions
"""

from __future__ import annotations

import json
import math
import os
import random
import threading
import time


class Counter:
    """Monotonic counter.  ``inc`` is atomic under its own lock — the
    GIL does not make ``self.value += n`` atomic (read-add-store can
    interleave), and the serving tier increments from many threads.

    ``lock`` lets a registry share one (reentrant) lock across all its
    metrics so ``snapshot()`` can read every counter and histogram in a
    single consistent pass; standalone instances keep a private lock.
    """

    __slots__ = ("value", "_lock")

    def __init__(self, *, lock=None):
        self.value = 0
        self._lock = lock if lock is not None else threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


# Reservoir size: percentiles are exact below this many observations,
# a uniform sample above it (~1.6pp worst-case p50/p95 error at 95%
# confidence).  Bounded regardless of process lifetime.
RESERVOIR_SIZE = 4096


class Histogram:
    """Bounded-reservoir histogram with exact count/sum and percentile
    export.

    ``count``/``total``/``min``/``max`` are exact for every observation
    ever made; ``percentile`` is nearest-rank over a fixed-size uniform
    reservoir (algorithm R) — exact while ``count <= RESERVOIR_SIZE``,
    a documented-accuracy sample beyond that.  All mutation and
    consistent reads lock, so concurrent ``observe`` never loses
    samples and ``to_json`` never reports ``sum`` torn against
    ``count``.
    """

    __slots__ = ("_samples", "total", "_count", "_min", "_max",
                 "_rng", "_lock")

    def __init__(self, *, seed: int = 0, lock=None):
        self._samples: list[float] = []
        self.total = 0.0
        self._count = 0
        self._min = math.inf
        self._max = -math.inf
        self._rng = random.Random(seed)
        self._lock = lock if lock is not None else threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._count += 1
            self.total += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if len(self._samples) < RESERVOIR_SIZE:
                self._samples.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < RESERVOIR_SIZE:
                    self._samples[j] = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def values(self) -> list[float]:
        """A copy of the retained reservoir samples (NOT the full
        observation history once ``count > RESERVOIR_SIZE``)."""
        with self._lock:
            return list(self._samples)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the reservoir; ``q`` in [0, 1].
        0.0 when empty; exact until the reservoir fills."""
        with self._lock:
            ordered = sorted(self._samples)
        if not ordered:
            return 0.0
        rank = max(math.ceil(q * len(ordered)), 1) - 1
        return ordered[min(rank, len(ordered) - 1)]

    def to_json(self, *, reservoir: bool = False) -> dict:
        """Exact count/sum/min/max + reservoir percentiles.

        ``reservoir=True`` additionally exports the retained samples —
        what :func:`merge_snapshots` needs to compute cross-host
        percentiles exactly (within reservoir-sampling tolerance)
        instead of approximating from per-host p50/p95.
        """
        with self._lock:
            if not self._count:
                out = {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                       "p50": 0.0, "p95": 0.0}
                if reservoir:
                    out["reservoir"] = []
                return out
            count = self._count
            total = self.total
            lo, hi = self._min, self._max
            samples = list(self._samples)
        ordered = sorted(samples)

        def rank(q: float) -> float:
            r = max(math.ceil(q * len(ordered)), 1) - 1
            return ordered[min(r, len(ordered) - 1)]

        out = {
            "count": count,
            "sum": total,
            "min": lo,
            "max": hi,
            "p50": rank(0.50),
            "p95": rank(0.95),
        }
        if reservoir:
            out["reservoir"] = samples
        return out


def host_identity(overrides: dict | None = None) -> dict:
    """This process's identity stamp for exported obs artifacts.

    ``hostname``/``pid`` identify the process; ``host_index`` is the
    sweep-host rank (``REPRO_HOST_INDEX``, or an explicit override from
    e.g. ``scripts/sweep.py --host-index``) that lets
    :func:`merge_snapshots` line multi-host exports up with the shard
    plan's owner mapping.
    """
    import socket

    ident = {
        "hostname": socket.gethostname(),
        "pid": os.getpid(),
        "host_index": int(os.environ.get("REPRO_HOST_INDEX", "0") or 0),
    }
    if overrides:
        ident.update(overrides)
    return ident


def _clock_anchor() -> dict:
    """Paired epoch/monotonic reading: lets a merger translate another
    host's monotonic timestamps onto a shared epoch timeline."""
    return {"epoch_s": time.time(), "monotonic_s": time.monotonic()}


class MetricsRegistry:
    """Name -> Counter/Histogram store with JSON snapshot export.

    All metrics share the registry's one **reentrant** lock:
    ``snapshot()`` holds it across the whole read, so the exported
    counters and histogram states form a single consistent cut — a
    snapshot taken mid-burst can no longer observe ``tuner/pick.*``
    ahead of ``tuner/decisions`` (which made ``tuner_tier_rates`` deltas
    between snapshots go negative).  Individual ``inc``/``observe``
    calls re-acquire the same lock reentrantly, keeping the hot-path
    cost one lock acquisition as before.
    """

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._histograms: dict[str, Histogram] = {}
        self._lock = threading.RLock()

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(
                    name, Counter(lock=self._lock)
                )
        return c

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    name, Histogram(lock=self._lock)
                )
        return h

    def snapshot(self, *, reservoir: bool = False,
                 host: dict | None = None) -> dict:
        """One atomic, self-describing snapshot of every metric.

        ``reservoir=True`` exports histogram reservoir samples (for
        cross-host percentile merges); ``host`` overrides fields of the
        attached :func:`host_identity` stamp.
        """
        with self._lock:
            return {
                "ts": time.time(),
                "host": host_identity(host),
                "clock": _clock_anchor(),
                "counters": {
                    k: c.value for k, c in sorted(self._counters.items())
                },
                "histograms": {
                    k: h.to_json(reservoir=reservoir)
                    for k, h in sorted(self._histograms.items())
                },
            }

    def export_jsonl(self, path: str, *, reservoir: bool = False,
                     host: dict | None = None) -> dict:
        """Append one snapshot line to ``path``; returns the snapshot."""
        snap = self.snapshot(reservoir=reservoir, host=host)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(snap) + "\n")
        return snap

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._histograms.clear()


# ---------------------------------------------------------------------------
# The process-wide registry.
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    return _REGISTRY


def reset_metrics() -> None:
    """Clear every process-wide metric (test isolation)."""
    _REGISTRY.reset()


def tuner_tier_rates(registry: MetricsRegistry | None = None) -> dict:
    """Per-tier decision fractions — the ``hit_rate`` scalar, itemized."""
    reg = registry or _REGISTRY
    total = reg.counter("tuner/decisions").value
    tiers = ("cache", "analytic", "measured", "heuristic")
    if not total:
        return {t: 0.0 for t in tiers}
    return {
        t: reg.counter(f"tuner/pick.{t}").value / total for t in tiers
    }


def observe_gate_agreement(
    grid, *, gate=None, tau=None, registry: MetricsRegistry | None = None
) -> float:
    """Heuristic-pick agreement rate against the grid's analytic argmin.

    Folds ``gate/agree`` / ``gate/points`` counters into the registry
    and returns this grid's rate — the live signal for "is the deployed
    gate still tracking the analytic optimum" that ROADMAP item 1's
    background re-fit keys off.  Opt-in (it costs one vectorized
    heuristic evaluation per grid): ``scripts/sweep.py --observe-gate``
    wires it onto the shard stream.
    """
    from repro.core.explorer import GridExploration  # lazy: numpy stack

    ex = GridExploration.from_grid(grid, tau=tau, gate=gate)
    agree = int(ex.exact.sum())
    points = int(ex.exact.size)
    reg = registry or _REGISTRY
    reg.counter("gate/agree").inc(agree)
    reg.counter("gate/points").inc(points)
    return agree / points if points else 0.0


# ---------------------------------------------------------------------------
# Snapshot schema validation (CI fast-lane gate, scripts/trace.py).
# ---------------------------------------------------------------------------

_HIST_FIELDS = ("count", "sum", "min", "max", "p50", "p95")


def validate_snapshot(obj) -> list[str]:
    """Structural errors in one metrics snapshot ([] == valid).

    Forward/backward compatible across the snapshot schema's growth:
    ``host``/``clock`` identity stamps and per-histogram ``reservoir``
    sample lists are validated *when present* but never required, so
    pre-fleet-merge snapshots (and minimal hand-built ones) still pass
    and new-field snapshots pass older validators structurally.
    """
    errors: list[str] = []
    if not isinstance(obj, dict):
        return [f"snapshot must be an object, got {type(obj).__name__}"]
    if not isinstance(obj.get("ts"), (int, float)):
        errors.append("missing numeric 'ts'")
    host = obj.get("host")
    if host is not None:
        if not isinstance(host, dict):
            errors.append("'host' must be an object")
        else:
            if not isinstance(host.get("hostname"), str):
                errors.append("host: no 'hostname' string")
            for field in ("pid", "host_index"):
                if field in host and not isinstance(host[field], int):
                    errors.append(f"host: {field!r} not an integer")
    clock = obj.get("clock")
    if clock is not None:
        if not isinstance(clock, dict):
            errors.append("'clock' must be an object")
        else:
            for field in ("epoch_s", "monotonic_s"):
                if field in clock and not isinstance(
                    clock[field], (int, float)
                ):
                    errors.append(f"clock: {field!r} not numeric")
    counters = obj.get("counters")
    if not isinstance(counters, dict):
        errors.append("missing 'counters' object")
    else:
        for k, v in counters.items():
            if not isinstance(v, (int, float)):
                errors.append(f"counter {k!r}: value not numeric")
    hists = obj.get("histograms")
    if not isinstance(hists, dict):
        errors.append("missing 'histograms' object")
    else:
        for k, h in hists.items():
            if not isinstance(h, dict):
                errors.append(f"histogram {k!r}: not an object")
                continue
            for field in _HIST_FIELDS:
                if not isinstance(h.get(field), (int, float)):
                    errors.append(f"histogram {k!r}: no numeric {field!r}")
            res = h.get("reservoir")
            if res is not None:
                if not isinstance(res, list) or any(
                    not isinstance(v, (int, float)) for v in res
                ):
                    errors.append(
                        f"histogram {k!r}: 'reservoir' must be a "
                        "numeric list"
                    )
    return errors


# ---------------------------------------------------------------------------
# Fleet merge: union per-host snapshots into one metrics view.
# ---------------------------------------------------------------------------


def _nearest_rank(ordered: list, q: float):
    rank = max(math.ceil(q * len(ordered)), 1) - 1
    return ordered[min(rank, len(ordered) - 1)]


def _host_key(snap: dict, fallback: int):
    host = snap.get("host")
    if isinstance(host, dict):
        return (
            host.get("hostname"), host.get("pid"), host.get("host_index")
        )
    return ("<anon>", None, fallback)


def _merge_hist(members: list[dict]) -> dict:
    """Union one histogram across hosts.

    count/sum/min/max merge exactly.  Percentiles come from the union
    of the members' reservoirs when every member exported one (exact
    while each reservoir was exact, the documented ~1/sqrt(K) sampling
    tolerance beyond); without reservoirs they fall back to a
    count-weighted average of per-host percentiles, flagged
    ``"approx": true`` so downstream consumers know the difference.
    """
    live = [h for h in members if h.get("count", 0) > 0]
    if not live:
        return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                "p50": 0.0, "p95": 0.0}
    count = sum(int(h["count"]) for h in live)
    out = {
        "count": count,
        "sum": sum(float(h["sum"]) for h in live),
        "min": min(float(h["min"]) for h in live),
        "max": max(float(h["max"]) for h in live),
    }
    if all(isinstance(h.get("reservoir"), list) and h["reservoir"]
           for h in live):
        union = sorted(
            v for h in live for v in h["reservoir"]
        )
        out["p50"] = _nearest_rank(union, 0.50)
        out["p95"] = _nearest_rank(union, 0.95)
        out["reservoir_n"] = len(union)
    else:
        out["p50"] = (
            sum(float(h["p50"]) * h["count"] for h in live) / count
        )
        out["p95"] = (
            sum(float(h["p95"]) * h["count"] for h in live) / count
        )
        out["approx"] = True
    return out


def merge_snapshots(snaps) -> dict:
    """Union per-host metrics snapshots into one fleet snapshot.

    Snapshots are cumulative per process, so when several lines carry
    the same host identity only the **latest** (max ``ts``) counts —
    feeding a whole per-host JSONL stream in is safe and idempotent
    (merging a merge of one host with itself changes nothing).
    Counters sum bit-exactly (integer addition); histograms merge per
    :func:`_merge_hist`.  The result is itself a schema-valid snapshot
    (:func:`validate_snapshot` passes) plus fleet fields
    (``merged_from``, ``hosts``) checked by
    :func:`validate_merged_snapshot`.
    """
    latest: dict = {}
    for i, snap in enumerate(snaps):
        key = _host_key(snap, i)
        prev = latest.get(key)
        if prev is None or snap.get("ts", 0) >= prev.get("ts", 0):
            latest[key] = snap
    members = list(latest.values())
    if not members:
        raise ValueError("merge_snapshots: no snapshots given")

    counters: dict = {}
    for snap in members:
        for k, v in (snap.get("counters") or {}).items():
            counters[k] = counters.get(k, 0) + v
    hist_names = sorted({
        k for snap in members
        for k in (snap.get("histograms") or {})
    })
    histograms = {
        name: _merge_hist([
            snap.get("histograms", {}).get(name)
            for snap in members
            if snap.get("histograms", {}).get(name) is not None
        ])
        for name in hist_names
    }
    return {
        "ts": max(float(s.get("ts", 0.0)) for s in members),
        "merged_from": [
            s.get("host") or {"hostname": "<anon>"} for s in members
        ],
        "hosts": len(members),
        "counters": dict(sorted(counters.items())),
        "histograms": histograms,
    }


def validate_merged_snapshot(obj) -> list[str]:
    """Structural errors in one merged fleet snapshot ([] == valid)."""
    errors = validate_snapshot(obj)
    if not isinstance(obj, dict):
        return errors
    if not isinstance(obj.get("hosts"), int) or obj.get("hosts", 0) < 1:
        errors.append("missing positive integer 'hosts'")
    merged_from = obj.get("merged_from")
    if not isinstance(merged_from, list) or not merged_from:
        errors.append("missing non-empty 'merged_from' list")
    else:
        for i, h in enumerate(merged_from):
            if not isinstance(h, dict):
                errors.append(f"merged_from[{i}]: not an object")
    return errors


__all__ = [
    "Counter",
    "Histogram",
    "RESERVOIR_SIZE",
    "MetricsRegistry",
    "host_identity",
    "get_metrics",
    "reset_metrics",
    "tuner_tier_rates",
    "observe_gate_agreement",
    "validate_snapshot",
    "merge_snapshots",
    "validate_merged_snapshot",
]
