"""Expert-parallel (EP) overlap: chunked all-to-all token dispatch.

The paper's EP scenarios (Table I g13–g16): input tokens are communicated
all-to-all before the expert FFN GEMMs run — a data-dependent comm->compute
pair.  FiCCO decomposes the dispatch one level deeper: the capacity
dimension is cut into ``g`` chunks, each chunk is exchanged and its expert
GEMM starts immediately, so expert compute overlaps the remaining dispatch.
This also hides A2A *asymmetry* (paper Fig. 5): a hot expert's extra tokens
arrive across several chunks whose compute is already pipelined.

Layout convention (GShard-style, grouped):
  x: (E_local * g_chunks ... ) — concretely each device holds tokens grouped
  by destination expert: (E, C, D) where E = global expert count, C =
  per-expert capacity from this device.  ``lax.all_to_all`` over the EP axis
  swaps the expert dimension for the source-device dimension, delivering
  (g, E_local, C, D) -> reshaped to (E_local, g*C, D) expert batches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size


def _ffn(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """One expert's FFN applied batched over local experts.

    x: (E_local, T, D); w_up: (E_local, D, F); w_down: (E_local, F, D).
    """
    h = jnp.einsum("etd,edf->etf", x, w_up)
    h = jax.nn.gelu(h)
    return jnp.einsum("etf,efd->etd", h, w_down)


def serial_a2a_ffn(
    x: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    axis_name: str,
) -> jax.Array:
    """Baseline: one all-to-all dispatch, expert FFN, one combine A2A.

    x: (E, C, D) tokens grouped by destination expert (E global experts,
    E = g * E_local).  Returns (E, C, D) tokens back in source layout.
    """
    g = axis_size(axis_name)
    e, c, d = x.shape
    e_local = e // g
    # dispatch: split expert dim over devices, concat source dim.
    recv = lax.all_to_all(
        x.reshape(g, e_local, c, d), axis_name, split_axis=0, concat_axis=0
    )  # (g, e_local, c, d): tokens from every source for my experts
    expert_in = recv.transpose(1, 0, 2, 3).reshape(e_local, g * c, d)
    expert_out = _ffn(expert_in, w_up, w_down)
    send = expert_out.reshape(e_local, g, c, d).transpose(1, 0, 2, 3)
    back = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    return back.reshape(e, c, d)


def skewed_chunk_sizes(capacity: int, profile) -> tuple[int, ...]:
    """Integer per-chunk capacity slice sizes following an expert load
    profile (:class:`repro.core.workload.StepProfile`).

    Deterministic largest-remainder quantization; zero-sized chunks
    (masked profile tail, experts that received nothing) are kept in the
    tuple so chunk indices line up with profile steps — the kernel path
    simply skips them.
    """
    sizes = profile.quantize(capacity)
    assert sum(sizes) == capacity
    return sizes


def ficco_a2a_ffn(
    x: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    axis_name: str,
    chunks: int | None = None,
    chunk_sizes=None,
    profile=None,
    variant=None,
) -> jax.Array:
    """FiCCO: capacity dimension cut into chunks; each chunk's dispatch
    A2A overlaps the previous chunk's expert GEMM (XLA async collectives
    on the ICI DMA engines do the hiding).

    The default cut is uniform (``chunks`` slices of ``C/chunks``).  The
    **skew-aware path** follows a non-uniform expert load instead: pass
    ``chunk_sizes`` (static ints summing to the capacity ``C``) or a
    ``profile`` (:class:`repro.core.workload.StepProfile`, quantized via
    :func:`skewed_chunk_sizes`).  Hot-expert token mass then travels in
    proportionally larger chunks whose expert GEMMs are also larger —
    the layout the ragged schedule engine (``simulate(...,
    profile=...)``, ``evaluate_ragged_grid``) models.  All sizes are
    trace-time constants, so the loop unrolls jit-compatibly with one
    dispatch/combine A2A pair per non-empty chunk.

    ``variant`` (a :class:`repro.tune.KernelVariant`) supplies the
    uniform chunk count when ``chunks``/``chunk_sizes``/``profile`` don't
    pin one, and its dispatch order: ``"reverse"`` issues the chunk
    A2A+FFN pairs last-to-first (front-loading a skewed profile's tail
    mass) while outputs are still reassembled in capacity order, so
    results are bit-identical across variants.
    """
    g = axis_size(axis_name)
    e, c, d = x.shape
    if variant is None and chunks is None and chunk_sizes is None:
        from repro.tune.registry import resolve_variant

        variant = resolve_variant("ficco_a2a_ffn", group=g, profile=profile)
    if chunk_sizes is None and profile is not None:
        chunk_sizes = skewed_chunk_sizes(c, profile)
    if chunk_sizes is None:
        from_variant = chunks is None and variant is not None
        if from_variant:
            chunks = int(variant.chunks)
        n_chunks = chunks or g
        if c % n_chunks:
            if from_variant and c % g == 0:
                n_chunks = g  # promoted cut doesn't divide; classic cut
            else:
                return serial_a2a_ffn(x, w_up, w_down, axis_name=axis_name)
        chunk_sizes = (c // n_chunks,) * n_chunks
    else:
        chunk_sizes = tuple(int(s) for s in chunk_sizes)
        if any(s < 0 for s in chunk_sizes) or sum(chunk_sizes) != c:
            raise ValueError(
                f"chunk_sizes {chunk_sizes} must be >= 0 and sum to "
                f"capacity {c}"
            )
    e_local = e // g
    offsets = []
    offset = 0
    for c_c in chunk_sizes:
        offsets.append(offset)
        offset += c_c
    order = list(range(len(chunk_sizes)))
    if variant is not None and variant.dispatch_order == "reverse":
        order.reverse()
    outs: list = [None] * len(chunk_sizes)
    for idx in order:
        c_c = chunk_sizes[idx]
        if c_c == 0:
            continue  # empty chunk (masked tail / unloaded expert slot)
        piece = lax.dynamic_slice(x, (0, offsets[idx], 0), (e, c_c, d))
        recv = lax.all_to_all(
            piece.reshape(g, e_local, c_c, d),
            axis_name,
            split_axis=0,
            concat_axis=0,
        )
        expert_in = recv.transpose(1, 0, 2, 3).reshape(e_local, g * c_c, d)
        expert_out = _ffn(expert_in, w_up, w_down)
        send = expert_out.reshape(e_local, g, c_c, d).transpose(1, 0, 2, 3)
        back = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
        outs[idx] = back.reshape(e, c_c, d)
    pieces = [o for o in outs if o is not None]
    if len(pieces) == 1:
        return pieces[0]
    return jnp.concatenate(pieces, axis=1)


__all__ = ["serial_a2a_ffn", "ficco_a2a_ffn", "skewed_chunk_sizes"]
