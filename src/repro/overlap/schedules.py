"""Executable FiCCO schedules as JAX collectives (shard_map bodies).

Every function runs *inside* a ``jax.shard_map`` over one mesh axis (the
tensor-parallel group) and implements the data-dependent pattern of paper
Fig. 3: the activation ``x`` arrives row (M) sharded, the weight ``w`` is
column (N) sharded and resident, and the output is the full gathered-M times
local-N block:

    out[d] = all_gather_M(x) @ w[d]            # (M, N_local)

The schedules differ in *how* the all-gather is decomposed and interleaved
with the GEMM:

  * ``serial_ag_matmul``     — baseline: one AG, one GEMM (paper Fig. 3b).
  * ``shard_p2p_matmul``     — AsyncTP-style ring: shards stream peer-to-peer
    (``lax.ppermute``), GEMM per shard (paper Fig. 3c).
  * ``ficco_*``              — FiCCO: each shard is split into ``g`` chunks;
    each step performs a *simultaneous all-to-all-shaped* exchange (one
    chunk to every peer — expressed as a chunk-sized ``lax.all_gather``)
    and the configured chunk-granular GEMM (paper Fig. 4c / Fig. 11b).

TPU DMA-offload note: XLA lowers these collectives to asynchronous
ICI transfers executed by the chips' DMA engines (collective-start /
collective-done pairs that the latency-hiding scheduler overlaps with the
interleaved matmuls), so "offload communication to GPU DMA engines" is the
*default honest execution mode* here — there is no core-driven RCCL analogue
on TPU.  The Pallas kernels in ``repro.kernels`` make the same pipeline
explicit with ``pltpu.make_async_remote_copy``.

All functions are numerically exact (no approximation): every schedule must
produce bit-identical row content to ``serial_ag_matmul`` up to dot-product
reassociation in the 2D (K-chunked) schedule.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size
from repro.core.schedule_types import Schedule


def _axis_size(axis_name: str) -> int:
    return axis_size(axis_name)


def _my_index(axis_name: str):
    return lax.axis_index(axis_name)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def serial_ag_matmul(x: jax.Array, w: jax.Array, *, axis_name: str) -> jax.Array:
    """Paper Fig. 3(b): all-gather the input shards, then one big GEMM."""
    x_full = lax.all_gather(x, axis_name, axis=0, tiled=True)  # (M, K)
    return x_full @ w


def shard_p2p_matmul(
    x: jax.Array, w: jax.Array, *, axis_name: str
) -> jax.Array:
    """Shard-granularity ring overlap (PyTorch AsyncTP, paper Fig. 3c).

    Each step sends the current shard to the right neighbour
    (``lax.ppermute`` — a single P2P link per step, the topology weakness
    FiCCO fixes) while computing the GEMM on the shard already held.
    """
    g = _axis_size(axis_name)
    me = _my_index(axis_name)
    m_s, _ = x.shape
    n_local = w.shape[1]
    out = jnp.zeros((g * m_s, n_local), dtype=jnp.result_type(x, w))
    perm = [(i, (i + 1) % g) for i in range(g)]

    buf = x
    for step in range(g):
        src = (me - step) % g  # whose shard we currently hold
        out = lax.dynamic_update_slice(
            out, (buf @ w).astype(out.dtype), (src * m_s, 0)
        )
        if step != g - 1:
            buf = lax.ppermute(buf, axis_name, perm)
    return out


# ---------------------------------------------------------------------------
# FiCCO schedules (paper Fig. 11b)
# ---------------------------------------------------------------------------

def _chunk_rows(x: jax.Array, g: int) -> jax.Array:
    """(m_s, K) -> (g, m_c, K) row chunks: one per overlap step."""
    m_s, k = x.shape
    if m_s % g:
        raise ValueError(f"shard rows {m_s} not divisible by group {g}")
    return x.reshape(g, m_s // g, k)


def ficco_uniform_fused_1d(
    x: jax.Array, w: jax.Array, *, axis_name: str
) -> jax.Array:
    """uniform-fused-1D: g steps; step s exchanges chunk s with all peers
    (all-to-all shaped), Gathers local+remote into one buffer, runs ONE
    identical (M/g, N_local, K) GEMM, and Scatters the output rows."""
    g = _axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    m_c = m_s // g
    chunks = _chunk_rows(x, g)  # (g, m_c, K)
    out = jnp.zeros((g * m_s, n_local), dtype=jnp.result_type(x, w))
    for s in range(g):
        # One chunk to every peer, one chunk from every peer: the paper's
        # simultaneous all-to-all step (all links busy on a direct topology).
        gathered = lax.all_gather(chunks[s], axis_name, axis=0)  # (g, m_c, K)
        step_buf = gathered.reshape(g * m_c, k)  # Gather
        step_out = step_buf @ w  # identical GEMM every step
        # Scatter: row block from device d lands at global row d*m_s + s*m_c.
        step_out = step_out.reshape(g, m_c, n_local)
        for d in range(g):
            out = lax.dynamic_update_slice(
                out,
                step_out[d].astype(out.dtype),
                (d * m_s + s * m_c, 0),
            )
    return out


def ficco_hetero_fused_1d(
    x: jax.Array, w: jax.Array, *, axis_name: str
) -> jax.Array:
    """hetero-fused-1D: compute the whole local shard immediately (hiding
    the first exposed exchange), then per step one fused GEMM over the g-1
    *remote* chunks received in that step."""
    g = _axis_size(axis_name)
    me = _my_index(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    m_c = m_s // g
    out = jnp.zeros((g * m_s, n_local), dtype=jnp.result_type(x, w))

    # Step 0: local shard, no communication dependency.
    out = lax.dynamic_update_slice(
        out, (x @ w).astype(out.dtype), (me * m_s, 0)
    )

    chunks = _chunk_rows(x, g)
    for s in range(g):
        gathered = lax.all_gather(chunks[s], axis_name, axis=0)  # (g, m_c, K)
        # Remote-only gather: rotate so our own chunk is last, drop it.
        rolled = jnp.roll(gathered, -(me + 1), axis=0)[: g - 1]
        step_buf = rolled.reshape((g - 1) * m_c, k)
        step_out = (step_buf @ w).reshape(g - 1, m_c, n_local)
        for j in range(g - 1):
            src = (me + 1 + j) % g
            out = lax.dynamic_update_slice(
                out,
                step_out[j].astype(out.dtype),
                (src * m_s + s * m_c, 0),
            )
    return out


def ficco_hetero_unfused_1d(
    x: jax.Array, w: jax.Array, *, axis_name: str
) -> jax.Array:
    """hetero-unfused-1D: like hetero-fused but one GEMM *per chunk* —
    no Gather at all, maximum scheduling freedom, highest DIL."""
    g = _axis_size(axis_name)
    me = _my_index(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    m_c = m_s // g
    out = jnp.zeros((g * m_s, n_local), dtype=jnp.result_type(x, w))
    out = lax.dynamic_update_slice(
        out, (x @ w).astype(out.dtype), (me * m_s, 0)
    )
    chunks = _chunk_rows(x, g)
    for s in range(g):
        gathered = lax.all_gather(chunks[s], axis_name, axis=0)
        rolled = jnp.roll(gathered, -(me + 1), axis=0)
        for j in range(g - 1):
            src = (me + 1 + j) % g
            piece = rolled[j] @ w  # (m_c, N_local): unfused chunk GEMM
            out = lax.dynamic_update_slice(
                out, piece.astype(out.dtype), (src * m_s + s * m_c, 0)
            )
    return out


def ficco_uniform_fused_2d(
    x: jax.Array, w: jax.Array, *, axis_name: str
) -> jax.Array:
    """uniform-fused-2D: chunks are K (column) slices; step s assembles the
    full-M (M, K/g) panel and runs an accumulating GEMM C += panel @ w_slice.
    Output rows are contiguous — no Scatter; requires accumulation instead.
    """
    g = _axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    if k % g:
        raise ValueError(f"K={k} not divisible by group {g}")
    k_c = k // g
    acc = jnp.zeros((g * m_s, n_local), dtype=jnp.float32)
    for s in range(g):
        chunk = lax.dynamic_slice(x, (0, s * k_c), (m_s, k_c))  # (m_s, K/g)
        gathered = lax.all_gather(chunk, axis_name, axis=0)  # (g, m_s, K/g)
        panel = gathered.reshape(g * m_s, k_c)  # Gather (rows contiguous)
        w_slice = lax.dynamic_slice(w, (s * k_c, 0), (k_c, n_local))
        acc = acc + (panel @ w_slice).astype(jnp.float32)  # C += A_s @ B_s
    return acc.astype(jnp.result_type(x, w))


SCHEDULE_FNS: dict[Schedule, Callable[..., jax.Array]] = {
    Schedule.SERIAL: serial_ag_matmul,
    Schedule.SHARD_P2P: shard_p2p_matmul,
    Schedule.UNIFORM_FUSED_1D: ficco_uniform_fused_1d,
    Schedule.HETERO_FUSED_1D: ficco_hetero_fused_1d,
    Schedule.HETERO_UNFUSED_1D: ficco_hetero_unfused_1d,
    Schedule.UNIFORM_FUSED_2D: ficco_uniform_fused_2d,
}


def run_schedule(
    schedule: Schedule,
    x: jax.Array,
    w: jax.Array,
    *,
    axis_name: str,
) -> jax.Array:
    return SCHEDULE_FNS[schedule](x, w, axis_name=axis_name)
