"""Public overlap API: heuristic-driven bespoke schedules (paper §VI-A).

"To incorporate FiCCO, the user provides only the GEMM inputs; based on the
GEMM dimensions our heuristic will select and execute the optimum overlap
schedule, replacing the serial communication and computation."

``ficco_linear`` is that entry point for JAX: call it *inside* a shard_map
whose ``axis_name`` is the tensor-parallel group.  ``schedule="auto"``
consults :func:`repro.core.heuristics.select_schedule` with the *static*
global GEMM dimensions — no profiling — and dispatches the chosen schedule.
``schedule="autotune"`` goes one step further: it consults the process-wide
:class:`repro.autotune.Autotuner` (persistent cache -> jitted analytic
model -> optional measured shortlist) and falls back to the static
heuristic if the tuner cannot answer.
"""

from __future__ import annotations

from typing import Union

import jax

from repro.compat import axis_size
from repro.core.heuristics import select_schedule
from repro.core.machine import TPU_V5E, MachineSpec, machine_for_group
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape
from repro.overlap.schedules import SCHEDULE_FNS, run_schedule

ScheduleLike = Union[Schedule, str]


def resolve_schedule(
    schedule: ScheduleLike,
    *,
    m: int,
    n: int,
    k: int,
    machine: MachineSpec | None = None,
    dtype_bytes: int = 2,
    group: int | None = None,
) -> Schedule:
    """Static schedule resolution (trace-time: shapes are concrete).

    ``group`` is the actual overlap-axis size; the decision tree (and in
    particular its group-sensitive serial gate) is evaluated against the
    machine model retargeted at that group, not the model's default.
    """
    if isinstance(schedule, Schedule):
        return schedule
    eff = machine or TPU_V5E
    if group:
        eff = machine_for_group(eff, group)
    if schedule == "autotune":
        gemm = GemmShape(m, n, k, dtype_bytes)
        try:
            from repro.autotune import get_tuner  # local: keep import lazy

            return get_tuner().pick(gemm, machine, group=group).schedule
        except Exception:
            # Zero-cost fallback: the static decision tree.
            return select_schedule(gemm, eff).schedule
    if schedule != "auto":
        return Schedule(schedule)
    dec = select_schedule(GemmShape(m, n, k, dtype_bytes), eff)
    # The serial guard may also fire for shapes the schedules cannot chunk.
    return dec.schedule


def _divisible(m_s: int, k: int, g: int, sched: Schedule) -> bool:
    if sched in (Schedule.SERIAL,):
        return True
    if sched is Schedule.UNIFORM_FUSED_2D:
        return k % g == 0
    if sched is Schedule.SHARD_P2P:
        return True
    return m_s % g == 0  # 1D FiCCO chunks rows one level deeper


def ficco_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    axis_name: str,
    schedule: ScheduleLike = "auto",
    machine: MachineSpec | None = None,
) -> jax.Array:
    """Data-dependent AG->GEMM with a bespoke overlap schedule.

    Args:
      x: (M/g, K) row shard of the activation (inside shard_map).
      w: (K, N/g) resident column shard of the weight.
      axis_name: mesh axis of the TP group.
      schedule: explicit :class:`Schedule`, its string value, "auto"
        (static heuristic) or "autotune" (cached/analytic runtime tuner).

    Returns:
      (M, N/g): the full gathered-M rows times this device's weight columns.
    """
    g = axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    sched = resolve_schedule(
        schedule,
        m=m_s * g,
        n=n_local * g,
        k=k,
        machine=machine,
        dtype_bytes=x.dtype.itemsize,
        group=g,
    )
    if not _divisible(m_s, k, g, sched):
        sched = Schedule.SERIAL  # shape can't be chunked one level deeper
    return run_schedule(sched, x, w, axis_name=axis_name)


__all__ = [
    "Schedule",
    "SCHEDULE_FNS",
    "ficco_linear",
    "resolve_schedule",
    "run_schedule",
]
