"""Public overlap API: heuristic-driven bespoke schedules (paper §VI-A).

"To incorporate FiCCO, the user provides only the GEMM inputs; based on the
GEMM dimensions our heuristic will select and execute the optimum overlap
schedule, replacing the serial communication and computation."

``ficco_linear`` is that entry point for JAX: call it *inside* a shard_map
whose ``axis_name`` is the tensor-parallel group.  ``schedule="auto"``
consults :func:`repro.core.heuristics.select_schedule` with the *static*
global GEMM dimensions — no profiling — and dispatches the chosen schedule.
``schedule="autotune"`` goes one step further: it consults the process-wide
:class:`repro.autotune.Autotuner` (persistent cache -> jitted analytic
model -> optional measured shortlist) and falls back to the static
heuristic if the tuner cannot answer.
"""

from __future__ import annotations

from typing import Union

import jax

from repro.compat import axis_size
from repro.core.heuristics import select_schedule
from repro.core.machine import TPU_V5E, MachineSpec, machine_for_group
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.overlap.schedules import SCHEDULE_FNS, run_schedule

ScheduleLike = Union[Schedule, str]


def resolve_schedule(
    schedule: ScheduleLike,
    *,
    m: int,
    n: int,
    k: int,
    machine: MachineSpec | None = None,
    dtype_bytes: int = 2,
    group: int | None = None,
) -> Schedule:
    """Static schedule resolution (trace-time: shapes are concrete).

    ``group`` is the actual overlap-axis size; the decision tree (and in
    particular its group-sensitive serial gate) is evaluated against the
    machine model retargeted at that group, not the model's default.
    """
    def _resolved(how: str, sched: Schedule, sp) -> Schedule:
        _metrics.get_metrics().counter(f"overlap/resolve.{how}").inc()
        sp.set(how=how, schedule=sched.value)
        return sched

    with _trace.span(
        "overlap/resolve", "overlap", m=m, n=n, k=k, group=group,
    ) as sp:
        if isinstance(schedule, Schedule):
            return _resolved("explicit", schedule, sp)
        eff = machine or TPU_V5E
        if group:
            eff = machine_for_group(eff, group)
        if schedule == "autotune":
            gemm = GemmShape(m, n, k, dtype_bytes)
            try:
                from repro.autotune import get_tuner  # keep import lazy

                sched = get_tuner().pick(gemm, machine, group=group).schedule
                return _resolved("autotune", sched, sp)
            except Exception:
                # Zero-cost fallback: the static decision tree.
                sched = select_schedule(gemm, eff).schedule
                return _resolved("autotune_fallback", sched, sp)
        if schedule != "auto":
            return _resolved("named", Schedule(schedule), sp)
        dec = select_schedule(GemmShape(m, n, k, dtype_bytes), eff)
        # The serial guard may also fire for shapes the schedules can't chunk.
        return _resolved("auto", dec.schedule, sp)


def _divisible(m_s: int, k: int, g: int, sched: Schedule) -> bool:
    if sched in (Schedule.SERIAL,):
        return True
    if sched is Schedule.UNIFORM_FUSED_2D:
        return k % g == 0
    if sched is Schedule.SHARD_P2P:
        return True
    return m_s % g == 0  # 1D FiCCO chunks rows one level deeper


def ficco_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    axis_name: str,
    schedule: ScheduleLike = "auto",
    machine: MachineSpec | None = None,
) -> jax.Array:
    """Data-dependent AG->GEMM with a bespoke overlap schedule.

    Args:
      x: (M/g, K) row shard of the activation (inside shard_map).
      w: (K, N/g) resident column shard of the weight.
      axis_name: mesh axis of the TP group.
      schedule: explicit :class:`Schedule`, its string value, "auto"
        (static heuristic) or "autotune" (cached/analytic runtime tuner).

    Returns:
      (M, N/g): the full gathered-M rows times this device's weight columns.
    """
    g = axis_size(axis_name)
    m_s, k = x.shape
    n_local = w.shape[1]
    sched = resolve_schedule(
        schedule,
        m=m_s * g,
        n=n_local * g,
        k=k,
        machine=machine,
        dtype_bytes=x.dtype.itemsize,
        group=g,
    )
    if not _divisible(m_s, k, g, sched):
        sched = Schedule.SERIAL  # shape can't be chunked one level deeper
    return run_schedule(sched, x, w, axis_name=axis_name)


__all__ = [
    "Schedule",
    "SCHEDULE_FNS",
    "ficco_linear",
    "resolve_schedule",
    "run_schedule",
]
