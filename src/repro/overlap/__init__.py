"""Executable overlap schedules (shard_map) + heuristic-driven public API."""

from repro.overlap.api import ficco_linear, resolve_schedule, run_schedule
from repro.overlap.moe import ficco_a2a_ffn, serial_a2a_ffn
from repro.overlap.schedules import (
    SCHEDULE_FNS,
    ficco_hetero_fused_1d,
    ficco_hetero_unfused_1d,
    ficco_uniform_fused_1d,
    ficco_uniform_fused_2d,
    serial_ag_matmul,
    shard_p2p_matmul,
)

__all__ = [
    "SCHEDULE_FNS",
    "ficco_linear",
    "resolve_schedule",
    "run_schedule",
    "ficco_a2a_ffn",
    "serial_a2a_ffn",
    "ficco_hetero_fused_1d",
    "ficco_hetero_unfused_1d",
    "ficco_uniform_fused_1d",
    "ficco_uniform_fused_2d",
    "serial_ag_matmul",
    "shard_p2p_matmul",
]
