"""Synthetic deterministic data pipeline with host-side prefetch.

Produces language-model batches (tokens/labels) plus the stub-frontend
extras (patch embeddings for VLM, encoder frames for the audio enc-dec).
Deterministic per (seed, step) so training is reproducible and restartable
from a checkpoint without data-state checkpointing.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.specs import encoder_len, train_specs


class SyntheticLM:
    """Markov-ish synthetic token stream: learnable but non-trivial."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.specs = train_specs(cfg, shape)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        out = {}
        spec = self.specs["tokens"]
        b, s = spec.shape
        v = self.cfg.vocab_size
        # token[t+1] depends on token[t] -> a model can actually learn it.
        base = rng.integers(0, v, (b, 1))
        steps = rng.integers(1, 3, (b, s))  # 1-bit transitions: learnable fast
        toks = (base + np.cumsum(steps, axis=1)) % v
        out["tokens"] = toks.astype(np.int32)
        out["labels"] = out["tokens"]
        for name, sp in self.specs.items():
            if name in ("tokens", "labels"):
                continue
            out[name] = rng.standard_normal(sp.shape).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Host thread that keeps ``depth`` device batches ready."""

    def __init__(self, it, put_fn=None, depth: int = 2):
        self.it = iter(it)
        self.put = put_fn or (lambda b: jax.tree.map(jnp.asarray, b))
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        for batch in self.it:
            self.q.put(self.put(batch))

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()


def make_pipeline(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    seed: int = 0,
    sharding=None,
    depth: int = 2,
):
    """Prefetching iterator of device-resident batches."""
    src = SyntheticLM(cfg, shape, seed)
    if sharding is not None:
        put = lambda b: jax.tree.map(
            lambda a, s=sharding: jax.device_put(a, s), b
        )
    else:
        put = None
    return Prefetcher(src, put_fn=put, depth=depth)
