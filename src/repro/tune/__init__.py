"""Kernel-variant autotuning: enumerate, prune, measure, refit, promote.

The Pallas kernels (`ficco_ag_matmul_fused`, the `dma_exchange` schedule,
`ficco_a2a_ffn`) each admit a family of shapes — chunk count, tile shape,
DMA buffer depth, dispatch order — that the analytic engines silently
assumed.  This package closes the kernel-level sim-to-real loop:

- :mod:`repro.tune.variants` — typed :class:`KernelVariant` records with
  deterministic enumeration of the per-kernel design space.
- :mod:`repro.tune.prune` — feasibility pruning against the hardware
  resource budgets carried by :class:`~repro.core.machine.MachineSpec`
  (VMEM footprint, DMA/regular semaphore slots, min-DMA-granule
  alignment, divisibility).
- :mod:`repro.tune.cost` — a deterministic discrete-event cost model for
  one variant (wave-quantized step GEMMs + depth-``d`` slot recurrence),
  the interpret-mode stand-in for wall-clock timing.
- :mod:`repro.tune.search` — time the feasible set through
  :meth:`Autotuner.measure_variants`, persist variant-keyed records, and
  promote per-(machine-family, scenario-class) winners.
- :mod:`repro.tune.registry` — the promotion registry the kernels
  consult when called without an explicit ``variant=``.
"""

from repro.tune.variants import (
    DISPATCH_ORDERS,
    KERNELS,
    KERNEL_SCHEDULE,
    KernelVariant,
    default_variant,
    enumerate_variants,
)
from repro.tune.prune import (
    Infeasible,
    ResourceBudget,
    check_variant,
    prune_variants,
)
from repro.tune.cost import variant_cost
from repro.tune.search import SearchResult, search_kernel_variants
from repro.tune.registry import (
    VARIANT_ARTIFACT_KIND,
    promote_variant,
    reset_variants,
    resolve_variant,
    set_variant,
)

__all__ = [
    "DISPATCH_ORDERS",
    "KERNELS",
    "KERNEL_SCHEDULE",
    "KernelVariant",
    "default_variant",
    "enumerate_variants",
    "Infeasible",
    "ResourceBudget",
    "check_variant",
    "prune_variants",
    "variant_cost",
    "SearchResult",
    "search_kernel_variants",
    "VARIANT_ARTIFACT_KIND",
    "promote_variant",
    "reset_variants",
    "resolve_variant",
    "set_variant",
]
