"""Typed kernel-variant records and deterministic enumeration.

A :class:`KernelVariant` pins every free shape parameter of one FiCCO
kernel: how many chunks the decomposed dimension is cut into, the M/N/K
block of the step GEMM, how many DMA buffer slots the pipeline rotates
through (double/triple/n-slot), and the order chunks are dispatched in
(forward or reverse — reverse front-loads the tail steps of a skewed
profile).  Variants are frozen, ordered, and hashable so enumeration
order, cache keys, and promotion artifacts are all deterministic.

Not every kernel exposes every axis (``VARIANT_AXES``): the fused
all-gather GEMM performs one full-width dot per step, so its tile is the
machine's native tile; the chunked-exchange schedule launches one XLA
GEMM per step, so its tile *is* searchable; the MoE all-to-all FFN only
chooses chunk count and dispatch order.
"""

from __future__ import annotations

import dataclasses
import re
from typing import TYPE_CHECKING

from repro.core.schedule_types import Schedule

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.machine import MachineSpec

KERNELS = ("ficco_ag_matmul", "dma_exchange", "ficco_a2a_ffn")

DISPATCH_ORDERS = ("forward", "reverse")

# The grid-schedule row each kernel realizes: all three are chunked
# 1D pipelines, so their measured times calibrate the uniform-fused-1d
# lane of the analytic model (and the ragged lanes when profile-keyed).
KERNEL_SCHEDULE = {
    "ficco_ag_matmul": Schedule.UNIFORM_FUSED_1D,
    "dma_exchange": Schedule.UNIFORM_FUSED_1D,
    "ficco_a2a_ffn": Schedule.UNIFORM_FUSED_1D,
}

# Which variant axes each kernel actually exposes; the rest stay at the
# structural default from `default_variant`.
VARIANT_AXES = {
    "ficco_ag_matmul": ("chunks", "depth", "order"),
    "dma_exchange": ("chunks", "tile", "order"),
    "ficco_a2a_ffn": ("chunks", "order"),
}

_DIGEST_RE = re.compile(r"c(\d+)t(\d+)x(\d+)x(\d+)d(\d+)([fr])")


@dataclasses.dataclass(frozen=True, order=True)
class KernelVariant:
    """One point of a kernel's design space."""

    kernel: str
    # Number of chunks the decomposed dimension (shard rows / expert
    # capacity) is cut into == pipeline steps.
    chunks: int
    # Step-GEMM output tile (M x N) and contraction block (K).
    block_m: int
    block_n: int
    block_k: int
    # DMA buffer slots the pipeline rotates through: 2 = classic double
    # buffering, 3+ = deeper in-flight window for skewed step lists.
    buffer_depth: int = 2
    dispatch_order: str = "forward"

    def __post_init__(self):
        if self.kernel not in KERNELS:
            raise ValueError(f"unknown kernel {self.kernel!r}; known: {KERNELS}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.buffer_depth < 2:
            # A single slot would be overwritten by the next inbound DMA
            # while the compute step still reads it.
            raise ValueError("buffer_depth < 2 races DMA against compute")
        if self.dispatch_order not in DISPATCH_ORDERS:
            raise ValueError(
                f"dispatch_order {self.dispatch_order!r} not in {DISPATCH_ORDERS}"
            )
        if min(self.block_m, self.block_n, self.block_k) < 8:
            raise ValueError("tile blocks must be >= 8")

    # ---- identity -----------------------------------------------------
    def digest(self) -> str:
        """Compact spelling used in cache keys and artifacts."""
        return (
            f"c{self.chunks}t{self.block_m}x{self.block_n}x{self.block_k}"
            f"d{self.buffer_depth}{self.dispatch_order[0]}"
        )

    @property
    def key_segment(self) -> str:
        """The trailing `TuneKey` segment: ``v`` + digest."""
        return "v" + self.digest()

    @classmethod
    def from_digest(cls, kernel: str, digest: str) -> "KernelVariant":
        m = _DIGEST_RE.fullmatch(digest)
        if m is None:
            raise ValueError(f"malformed variant digest {digest!r}")
        c, bm, bn, bk, d, o = m.groups()
        return cls(
            kernel=kernel,
            chunks=int(c),
            block_m=int(bm),
            block_n=int(bn),
            block_k=int(bk),
            buffer_depth=int(d),
            dispatch_order="forward" if o == "f" else "reverse",
        )

    # ---- persistence --------------------------------------------------
    def to_payload(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, payload: dict) -> "KernelVariant":
        return cls(**payload)


def default_variant(
    kernel: str,
    machine: "MachineSpec | None" = None,
    *,
    group: int | None = None,
) -> KernelVariant:
    """The single variant the kernels shipped with before the search.

    One chunk per group member, the machine's native GEMM tile, double
    buffering, forward dispatch.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; known: {KERNELS}")
    g = int(group if group is not None else (machine.group if machine else 8))
    bm = int(machine.tile_mn) if machine is not None else 128
    bk = int(machine.tile_k) if machine is not None else 128
    return KernelVariant(
        kernel=kernel,
        chunks=g,
        block_m=bm,
        block_n=bm,
        block_k=bk,
        buffer_depth=2,
        dispatch_order="forward",
    )


def enumerate_variants(
    kernel: str,
    machine: "MachineSpec | None" = None,
    *,
    group: int | None = None,
    chunk_counts: tuple[int, ...] | None = None,
    tile_scales: tuple[float, ...] = (0.5, 1.0, 2.0),
    depths: tuple[int, ...] = (2, 3),
    orders: tuple[str, ...] = DISPATCH_ORDERS,
) -> tuple[KernelVariant, ...]:
    """Deterministically enumerate a kernel's variant space.

    The candidate set is the cross product of the axes the kernel
    exposes (``VARIANT_AXES``); axes it does not expose stay pinned at
    the default.  The result is duplicate-free and sorted by the
    variant's natural (field-lexicographic) order, so two calls with the
    same arguments return the same tuple in the same order.
    """
    base = default_variant(kernel, machine, group=group)
    axes = VARIANT_AXES[kernel]
    g = base.chunks

    if chunk_counts is None:
        chunk_counts = tuple(
            sorted({c for c in (g // 2, g, 2 * g) if c >= 2})
        )
    chunk_axis = chunk_counts if "chunks" in axes else (base.chunks,)

    if "tile" in axes:
        tiles = sorted(
            {
                (
                    max(64, int(base.block_m * s)),
                    max(64, int(base.block_n * s)),
                    max(64, int(base.block_k * s)),
                )
                for s in tile_scales
            }
        )
    else:
        tiles = [(base.block_m, base.block_n, base.block_k)]

    depth_axis = depths if "depth" in axes else (base.buffer_depth,)
    order_axis = orders if "order" in axes else (base.dispatch_order,)

    out = {
        KernelVariant(
            kernel=kernel,
            chunks=c,
            block_m=tm,
            block_n=tn,
            block_k=tk,
            buffer_depth=d,
            dispatch_order=o,
        )
        for c in chunk_axis
        for (tm, tn, tk) in tiles
        for d in depth_axis
        for o in order_axis
    }
    out.add(base)  # the incumbent is always a candidate
    return tuple(sorted(out))
