"""Kernel-variant search: enumerate → prune → time → record → promote.

One call closes the loop for one (kernel, GEMM, machine, profile)
context: the feasible set is timed through
:meth:`Autotuner.measure_variants` (variant-keyed 8-segment cache
records, the `fit_machine` food), the winner is *also* recorded at the
plain 7-segment profile-keyed key with ``source="measured"`` — exactly
the record :class:`repro.learn.measured.MeasuredEngine` and the tier-1
cache lookup consume — and promoted in :mod:`repro.tune.registry` so
subsequent kernel invocations default to it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Callable, Sequence

from repro.core.machine import MachineSpec, TPU_V5E, machine_for_group
from repro.core.workload import GemmShape
from repro.tune.cost import variant_cost
from repro.tune.prune import Infeasible, prune_variants
from repro.tune.registry import promote_variant
from repro.tune.variants import (
    KERNEL_SCHEDULE,
    KernelVariant,
    default_variant,
    enumerate_variants,
)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.autotune.tuner import Autotuner
    from repro.core.workload import StepProfile


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Everything one variant search learned."""

    kernel: str
    machine: str
    group: int
    n_enumerated: int
    n_feasible: int
    rejected: tuple[Infeasible, ...]
    # (variant, seconds) for every feasible candidate, input order.
    timings: tuple[tuple[KernelVariant, float], ...]
    best: KernelVariant
    best_seconds: float
    default: KernelVariant
    default_seconds: float
    # Wall-clock seconds the search itself took.
    seconds: float

    @property
    def speedup(self) -> float:
        """Best-vs-default: > 1 means the search beat the incumbent."""
        return self.default_seconds / self.best_seconds if self.best_seconds else 1.0


def search_kernel_variants(
    kernel: str,
    gemm: GemmShape,
    machine: MachineSpec | None = None,
    *,
    group: int | None = None,
    profile: "StepProfile | None" = None,
    tuner: "Autotuner | None" = None,
    variants: Sequence[KernelVariant] | None = None,
    runner: Callable[[KernelVariant], float] | None = None,
    promote: bool = True,
) -> SearchResult:
    """Search one kernel's variant space for one GEMM on one machine.

    ``runner(variant) -> seconds`` times a variant for real; ``None``
    falls back to the deterministic variant cost model.  ``promote=False``
    measures and records without touching the promotion registry or the
    plain schedule-decision key.
    """
    t0 = time.perf_counter()
    machine = machine or TPU_V5E
    g = int(group if group is not None else machine.group)
    eff = machine_for_group(machine, g)
    if tuner is None:
        from repro.autotune.tuner import get_tuner

        tuner = get_tuner()

    cands = (
        tuple(variants)
        if variants is not None
        else enumerate_variants(kernel, eff, group=g)
    )
    feasible, rejected = prune_variants(cands, gemm, eff, group=g)
    default = default_variant(kernel, eff, group=g)

    timings = tuple(
        tuner.measure_variants(
            kernel,
            gemm,
            feasible,
            machine=machine,
            group=g,
            profile=profile,
            runner=runner,
        )
    )
    if timings:
        best, best_seconds = min(timings, key=lambda vt: vt[1])
    else:
        # Nothing feasible: fall back to the incumbent, modeled.
        best = default
        best_seconds = variant_cost(default, gemm, eff, profile=profile)

    by_variant = dict(timings)
    default_seconds = by_variant.get(default)
    if default_seconds is None:
        default_seconds = variant_cost(default, gemm, eff, profile=profile)

    if promote:
        # The winner's time is the kernel's realized schedule time: write
        # it at the plain profile-keyed decision record the MeasuredEngine
        # shortlist and tier-1 cache lookups consume.
        from repro.autotune.tuner import TuneKey

        key = str(TuneKey.for_gemm(gemm, machine, g, profile=profile))
        tuner.cache.put(
            key,
            {
                "schedule": KERNEL_SCHEDULE[kernel].value,
                "source": "measured",
                "model_total_s": None,
                "measured_total_s": float(best_seconds),
                "kernel": kernel,
                "variant": best.digest(),
            },
            persist=tuner.persist,
        )
        promote_variant(
            kernel,
            best,
            machine=machine,
            profile=profile,
            cache=tuner.cache,
            persist=tuner.persist,
        )

    return SearchResult(
        kernel=kernel,
        machine=machine.name,
        group=g,
        n_enumerated=len(cands),
        n_feasible=len(feasible),
        rejected=rejected,
        timings=timings,
        best=best,
        best_seconds=float(best_seconds),
        default=default,
        default_seconds=float(default_seconds),
        seconds=time.perf_counter() - t0,
    )
