"""Promotion registry: per-(machine-family, kernel, scenario-class) winners.

``search_kernel_variants`` promotes its winner here; the kernels consult
:func:`resolve_variant` when called without an explicit ``variant=``.
Winners are keyed by the machine *family* (the name prefix before the
first ``/``, matching ``repro.learn.gate``'s machine-gate convention)
and the scenario class (``"uniform"`` vs ``"skewed"`` step profiles),
and persisted as ``kernel_variant`` artifacts in the autotune cache so a
search survives process restarts.

Resolution order: exact family entry → wildcard (``*``, the most recent
promotion for the kernel) → persisted artifact → structural default.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING

from repro.tune.variants import KERNELS, KernelVariant, default_variant

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.autotune.cache import AutotuneCache
    from repro.core.machine import MachineSpec
    from repro.core.workload import StepProfile

VARIANT_ARTIFACT_KIND = "kernel_variant"

SCENARIO_CLASSES = ("uniform", "skewed")

_LOCK = threading.Lock()
_PROMOTED: dict[tuple[str, str, str], KernelVariant] = {}


def variant_family(machine: "MachineSpec | str | None") -> str:
    """Machine-family key: the name prefix before the first ``/``."""
    if machine is None:
        return "*"
    name = machine if isinstance(machine, str) else machine.name
    return name.split("/", 1)[0]


def scenario_class(profile: "StepProfile | None" = None) -> str:
    return "uniform" if profile is None or profile.is_uniform else "skewed"


def artifact_name(family: str, kernel: str, scen: str) -> str:
    return f"{family}/{kernel}/{scen}"


def set_variant(
    kernel: str,
    variant: KernelVariant | None,
    *,
    family: str = "*",
    scen: str = "uniform",
) -> None:
    """Install (or with None, drop) an in-process winner without persisting."""
    key = (family, kernel, scen)
    with _LOCK:
        if variant is None:
            _PROMOTED.pop(key, None)
        else:
            _PROMOTED[key] = variant


def promote_variant(
    kernel: str,
    variant: KernelVariant,
    *,
    machine: "MachineSpec | str | None" = None,
    profile: "StepProfile | None" = None,
    cache: "AutotuneCache | None" = None,
    persist: bool = True,
) -> None:
    """Make ``variant`` the default the kernel resolves for this context.

    Registered under both the machine family and the ``*`` wildcard (so
    kernels invoked without machine knowledge still pick up the latest
    winner), and written to the autotune cache artifact segment when
    ``persist`` is set.
    """
    fam = variant_family(machine)
    scen = scenario_class(profile)
    with _LOCK:
        _PROMOTED[(fam, kernel, scen)] = variant
        _PROMOTED[("*", kernel, scen)] = variant
    if persist:
        if cache is None:
            from repro.autotune.tuner import get_tuner

            cache = get_tuner().cache
        payload = variant.to_payload()
        cache.put_artifact(VARIANT_ARTIFACT_KIND, artifact_name(fam, kernel, scen), payload)
        if fam != "*":
            cache.put_artifact(
                VARIANT_ARTIFACT_KIND, artifact_name("*", kernel, scen), payload
            )


def resolve_variant(
    kernel: str,
    machine: "MachineSpec | None" = None,
    *,
    group: int | None = None,
    profile: "StepProfile | None" = None,
    cache: "AutotuneCache | None" = None,
) -> KernelVariant:
    """The variant a kernel should run with when none was passed."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; known: {KERNELS}")
    scen = scenario_class(profile)
    fams = [variant_family(machine)]
    if fams[0] != "*":
        fams.append("*")
    with _LOCK:
        for fam in fams:
            hit = _PROMOTED.get((fam, kernel, scen))
            if hit is not None:
                return hit
    # Persisted promotion from an earlier process.
    try:
        if cache is None:
            from repro.autotune.tuner import get_tuner

            cache = get_tuner().cache
        for fam in fams:
            payload = cache.get_artifact(
                VARIANT_ARTIFACT_KIND, artifact_name(fam, kernel, scen)
            )
            if payload:
                variant = KernelVariant.from_payload(dict(payload))
                with _LOCK:
                    _PROMOTED[(fam, kernel, scen)] = variant
                return variant
    except Exception:  # pragma: no cover - cache unavailable is non-fatal
        pass
    return default_variant(kernel, machine, group=group)


def reset_variants() -> None:
    """Drop every in-process promotion (test isolation)."""
    with _LOCK:
        _PROMOTED.clear()
