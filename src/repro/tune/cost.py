"""Deterministic discrete-event cost of one kernel variant.

The interpret-mode stand-in for wall-clock timing: CI boxes have no
accelerator, so the search times variants through this model unless the
caller supplies a real ``runner``.  It is intentionally *finer-grained*
than the analytic schedule engines — it sees the variant's chunk count,
tile shape (through wave quantization), buffer depth (through the slot
recurrence), and dispatch order (through the step-size permutation) —
which is exactly what makes the search non-trivial: differently-shaped
variants of the same schedule get different times.

Model, per step ``i`` carrying fraction ``f_i`` of the work:

- comm:   ``t_comm[i] = f_i * shard_bytes * (g-1) / ag_bw + link_latency``
- compute: wave-quantized GEMM — output tiles ``ceil(rows/bm) *
  ceil(n_local/bn)`` spread over ``parallel_units``; each wave costs
  ``2*bm*bn*k / peak_flops``; plus per-step launch overhead
  (``kernel_latency`` when the pipeline is one fused kernel,
  ``+ kernel_ramp`` when every step launches its own kernel).
- pipeline with ``d`` buffer slots: the DMA for step ``i`` cannot start
  until the compute of step ``i-d`` has released its slot.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

from repro.core.machine import MachineSpec, machine_for_group
from repro.core.workload import GemmShape
from repro.tune.variants import KernelVariant

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.workload import StepProfile

# Kernels whose whole pipeline is one fused Pallas kernel (DMA issued
# from inside) vs. one launched kernel/collective per step.
_FUSED = {
    "ficco_ag_matmul": True,
    "dma_exchange": False,
    "ficco_a2a_ffn": False,
}


def step_fractions(
    variant: KernelVariant, profile: "StepProfile | None" = None
) -> tuple[float, ...]:
    """The per-step work shares the variant executes, in dispatch order."""
    if profile is not None:
        fracs = list(profile.trimmed().fractions)
    else:
        fracs = [1.0 / variant.chunks] * variant.chunks
    if variant.dispatch_order == "reverse":
        fracs.reverse()
    return tuple(fracs)


def variant_cost(
    variant: KernelVariant,
    gemm: GemmShape,
    machine: MachineSpec,
    *,
    group: int | None = None,
    profile: "StepProfile | None" = None,
) -> float:
    """Modeled seconds for one variant of one kernel on one machine."""
    eff = machine_for_group(machine, int(group)) if group else machine
    g = eff.group
    b = float(gemm.dtype_bytes)
    n_local = max(1, gemm.n // g)
    fracs = step_fractions(variant, profile)

    # Whole-op egress per device: its shard to g-1 peers (AG) or the
    # dispatched capacity rows (A2A) — both scale with m*k/g.
    total_comm_bytes = (gemm.m / g) * gemm.k * b * (g - 1)
    t_comm = [
        f * total_comm_bytes / eff.ag_bw + eff.link_latency for f in fracs
    ]

    bm, bn = variant.block_m, variant.block_n
    per_wave = 2.0 * bm * bn * gemm.k / eff.peak_flops
    overhead = eff.kernel_latency
    if not _FUSED[variant.kernel]:
        overhead += eff.kernel_ramp

    def gemm_time(rows: float) -> float:
        tiles = math.ceil(max(1.0, rows) / bm) * math.ceil(n_local / bn)
        waves = math.ceil(tiles / eff.parallel_units)
        return waves * per_wave

    t_cmp = [gemm_time(f * gemm.m) + overhead for f in fracs]

    # Depth-d slot recurrence: comm for step i waits on the slot freed
    # by compute step i-d; compute chains on its own predecessor and on
    # the arrival of its chunk.
    d = variant.buffer_depth
    comm_done: list[float] = []
    cmp_done: list[float] = []
    for i in range(len(fracs)):
        start = comm_done[i - 1] if i else 0.0
        if i >= d:
            start = max(start, cmp_done[i - d])
        comm_done.append(start + t_comm[i])
        c_start = max(comm_done[i], cmp_done[i - 1] if i else 0.0)
        cmp_done.append(c_start + t_cmp[i])
    # One pipeline fill (first kernel's cold ramp) for the fused path;
    # the unfused paths already pay ramp per step.
    fill = eff.kernel_ramp if _FUSED[variant.kernel] else 0.0
    return cmp_done[-1] + fill
