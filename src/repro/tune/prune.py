"""Feasibility pruning of kernel variants against hardware budgets.

Mirrors the candidate-enumeration-with-feasibility-filtering pattern of
FPGA design-space explorers: before anything reaches the timer, a
variant must fit the machine's fast-memory (VMEM/LLC) budget, stay
inside its DMA and regular semaphore slot counts, cut the shard into
whole DMA granules, and divide the shard evenly.  Rejections carry a
human-readable reason so searches can report *why* the space shrank.

All footprints are computed for the **per-device** shapes the kernels
actually allocate (shard rows ``m/g``, local output columns ``n/g``),
from the global :class:`~repro.core.workload.GemmShape`.
"""

from __future__ import annotations

import dataclasses

from repro.core.machine import MachineSpec
from repro.core.workload import GemmShape
from repro.tune.variants import KernelVariant


@dataclasses.dataclass(frozen=True)
class ResourceBudget:
    """The slice of :class:`MachineSpec` the pruner checks against."""

    vmem_bytes: int
    dma_sem_slots: int
    reg_sem_slots: int
    dma_granule: int

    @classmethod
    def from_machine(cls, machine: MachineSpec) -> "ResourceBudget":
        return cls(
            vmem_bytes=int(machine.fast_mem_bytes),
            dma_sem_slots=int(machine.dma_sem_slots),
            reg_sem_slots=int(machine.reg_sem_slots),
            dma_granule=int(machine.dma_granule),
        )


@dataclasses.dataclass(frozen=True)
class Infeasible:
    """A rejected variant plus the budget it violated."""

    variant: KernelVariant
    reason: str


def vmem_footprint(variant: KernelVariant, gemm: GemmShape, group: int) -> int:
    """Bytes of fast memory one device's kernel instance allocates."""
    g = int(group)
    b = int(gemm.dtype_bytes)
    c = variant.chunks
    d = variant.buffer_depth
    if variant.kernel == "ficco_ag_matmul":
        # Scratch mirrors the kernel: `depth` slots of (g, m_c, k) inbound
        # chunks, the resident (k, n_local) weight shard, and `depth`
        # slots of (g, m_c, n_local) outbound results.
        m_c = max(1, (gemm.m // g) // c)
        n_local = max(1, gemm.n // g)
        return b * (d * g * m_c * gemm.k + gemm.k * n_local + d * g * m_c * n_local)
    if variant.kernel == "dma_exchange":
        # One gathered (g, m_c, k) exchange buffer per step kernel, plus
        # the blocked step-GEMM working set: double-buffered input
        # panels and an f32 accumulator tile.
        m_c = max(1, (gemm.m // g) // c)
        n_local = max(1, gemm.n // g)
        gather = b * g * m_c * gemm.k
        panels = 2 * b * (
            variant.block_m * variant.block_k + variant.block_k * variant.block_n
        )
        acc = 4 * variant.block_m * variant.block_n
        return gather + panels + acc
    if variant.kernel == "ficco_a2a_ffn":
        # Per-chunk dispatch/return buffers (rows m/c of width k) plus
        # one expert-FFN panel of local width n/g.
        rows = max(1, gemm.m // c)
        n_local = max(1, gemm.n // g)
        return b * (2 * rows * gemm.k + gemm.k * n_local)
    raise ValueError(f"unknown kernel {variant.kernel!r}")


def sem_slots(variant: KernelVariant, group: int) -> tuple[int, int]:
    """(DMA completion slots, regular flow-control slots) the variant needs."""
    g = int(group)
    d = variant.buffer_depth
    if variant.kernel == "ficco_ag_matmul":
        # Per slot: g-1 send sems + g recv sems + 1 output-copy sem, and
        # one regular ready-sem per slot for remote flow control.
        return d * (g - 1) + d * g + d, d
    if variant.kernel == "dma_exchange":
        # One exchange kernel in flight: g-1 send + g recv sems.
        return (g - 1) + g, 0
    if variant.kernel == "ficco_a2a_ffn":
        # XLA collectives own their semaphores; nothing to budget.
        return 0, 0
    raise ValueError(f"unknown kernel {variant.kernel!r}")


def check_variant(
    variant: KernelVariant,
    gemm: GemmShape,
    machine: MachineSpec,
    *,
    group: int | None = None,
) -> str | None:
    """Return None if the variant is feasible, else the rejection reason."""
    g = int(group if group is not None else machine.group)
    budget = ResourceBudget.from_machine(machine)
    b = int(gemm.dtype_bytes)

    # -- divisibility: the cut must produce whole chunks ---------------
    if variant.kernel in ("ficco_ag_matmul", "dma_exchange"):
        if gemm.m % g or gemm.n % g:
            return f"indivisible: gemm {gemm.m}x{gemm.n} not shardable {g} ways"
        m_s = gemm.m // g
        if m_s % variant.chunks:
            return f"indivisible: shard rows {m_s} % chunks {variant.chunks} != 0"
        chunk_bytes = (m_s // variant.chunks) * gemm.k * b
    else:  # ficco_a2a_ffn — cuts global capacity rows
        if gemm.m % variant.chunks:
            return (
                f"indivisible: capacity {gemm.m} % chunks {variant.chunks} != 0"
            )
        chunk_bytes = (gemm.m // variant.chunks) * gemm.k * b

    # -- DMA granule: every descriptor moves whole granules ------------
    if chunk_bytes < budget.dma_granule or chunk_bytes % budget.dma_granule:
        return (
            f"dma granule: chunk {chunk_bytes}B not a whole multiple of "
            f"{budget.dma_granule}B"
        )

    # -- fast-memory footprint -----------------------------------------
    vmem = vmem_footprint(variant, gemm, g)
    if vmem > budget.vmem_bytes:
        return f"vmem: footprint {vmem}B > budget {budget.vmem_bytes}B"

    # -- semaphore slots -----------------------------------------------
    dma_s, reg_s = sem_slots(variant, g)
    if dma_s > budget.dma_sem_slots:
        return f"semaphores: {dma_s} DMA slots > budget {budget.dma_sem_slots}"
    if reg_s > budget.reg_sem_slots:
        return f"semaphores: {reg_s} regular slots > budget {budget.reg_sem_slots}"
    return None


def prune_variants(
    variants: tuple[KernelVariant, ...],
    gemm: GemmShape,
    machine: MachineSpec,
    *,
    group: int | None = None,
) -> tuple[tuple[KernelVariant, ...], tuple[Infeasible, ...]]:
    """Split an enumerated set into (feasible, rejected-with-reasons).

    Order is preserved from the input, so a deterministic enumeration
    stays deterministic through the pruner.
    """
    feasible: list[KernelVariant] = []
    rejected: list[Infeasible] = []
    for v in variants:
        reason = check_variant(v, gemm, machine, group=group)
        if reason is None:
            feasible.append(v)
        else:
            rejected.append(Infeasible(v, reason))
    return tuple(feasible), tuple(rejected)
