"""repro.sweep — sharded design-space sweeps (devices + hosts).

The scenario axis of the FiCCO design-space grid is embarrassingly
parallel; this package cuts it with deterministic
:class:`~repro.sweep.plan.ShardPlan`\\ s, evaluates shards through any
registered engine (:mod:`repro.core.engine`), SPMD over local jax
devices when asked, round-robin over identical host processes, and
either gathers the shards back into one bit-identical
:class:`~repro.core.engine.GridResult` or streams compact per-shard
summaries (1e6-1e7-point sweeps).

The three-line sharded sweep::

    from repro.sweep import sweep_grid, synthetic_batch
    res = sweep_grid(synthetic_batch(100_000), machines,
                     num_shards=16, mode="reduce")
    print(res.summary())

and the CLI driver is ``scripts/sweep.py`` (per-shard JSON streaming,
multi-host owner mapping, device-parallel evaluation).
"""

from repro.sweep.plan import (
    ShardPlan,
    owner_of,
    plan_shards,
    shards_for_host,
)
from repro.sweep.runner import (
    ShardSummary,
    SweepResult,
    concat_batches,
    concat_grid_results,
    merge_summaries,
    shard_batch,
    summarize_shard,
    sweep_grid,
)
from repro.sweep.synth import (
    ServeRequest,
    drifting_request_stream,
    synthetic_batch,
    synthetic_ragged_batch,
)

# Device-resident pieces (repro.sweep.device) are exported lazily via
# PEP 562 so importing the package never imports jax: the fast CI lane
# and numpy-only deployments keep their import graph jax-free.
_DEVICE_EXPORTS = (
    "host_batch",
    "host_ragged_batch",
    "device_batch",
    "device_ragged_batch",
    "evaluate_mixed_grid",
    "dispatch_mixed_grid",
    "sweep_device_stats",
    "device_merge_stats",
)


def __getattr__(name):
    if name in _DEVICE_EXPORTS:
        from repro.sweep import device

        return getattr(device, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}"
    )


def __dir__():
    return sorted(list(globals()) + list(_DEVICE_EXPORTS))


__all__ = [
    "ShardPlan",
    "plan_shards",
    "owner_of",
    "shards_for_host",
    "ShardSummary",
    "SweepResult",
    "shard_batch",
    "concat_batches",
    "concat_grid_results",
    "summarize_shard",
    "merge_summaries",
    "sweep_grid",
    "synthetic_batch",
    "synthetic_ragged_batch",
    "ServeRequest",
    "drifting_request_stream",
    *_DEVICE_EXPORTS,
]
