"""Deterministic scenario-axis shard plans.

The design-space grid is embarrassingly parallel over scenarios: every
``(scenario, machine, schedule)`` cell is computed from its own lane of
the batched array math, so cutting the scenario axis into contiguous
shards and evaluating them independently reproduces the unsharded
:class:`~repro.core.engine.GridResult` bit for bit.

A :class:`ShardPlan` is pure arithmetic — no RNG, no process state — so
every host in a multi-host sweep derives the *same* plan from
``(n_scenarios, n_shards)`` and the round-robin owner mapping, and the
union of all hosts' shards tiles the scenario axis exactly once.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Contiguous split of ``n_scenarios`` lanes into ``n_shards`` shards.

    ``bounds[i]`` is shard i's half-open ``[start, stop)`` scenario
    range.  ``padded_size > 0`` marks an *equalized* plan (every shard
    evaluates exactly ``padded_size`` lanes, short shards padded at the
    tail) — what SPMD device sharding (pmap) needs; the padding lanes
    are trimmed before results are returned.
    """

    n_scenarios: int
    n_shards: int
    bounds: tuple[tuple[int, int], ...]
    padded_size: int = 0

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(stop - start for start, stop in self.bounds)

    @property
    def pad(self) -> int:
        """Total padded lanes across all shards (0 for exact plans)."""
        if not self.padded_size:
            return 0
        return self.padded_size * self.n_shards - self.n_scenarios


def plan_shards(
    n_scenarios: int, n_shards: int, *, equalize: bool = False
) -> ShardPlan:
    """Split the scenario axis into ``n_shards`` contiguous shards.

    Default: remainder lanes spread over the leading shards, so sizes
    differ by at most one and no padding exists.  ``equalize=True``:
    every shard spans ``ceil(S / n)`` lanes (trailing shards short or
    even empty, tracked via ``padded_size``) — the layout an SPMD
    evaluation pads to.
    """
    if n_scenarios < 0:
        raise ValueError(f"n_scenarios must be >= 0, got {n_scenarios}")
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if equalize:
        size = -(-n_scenarios // n_shards) if n_scenarios else 0
        bounds = tuple(
            (
                min(i * size, n_scenarios),
                min((i + 1) * size, n_scenarios),
            )
            for i in range(n_shards)
        )
        return ShardPlan(n_scenarios, n_shards, bounds, padded_size=size)
    q, r = divmod(n_scenarios, n_shards)
    bounds = []
    start = 0
    for i in range(n_shards):
        stop = start + q + (1 if i < r else 0)
        bounds.append((start, stop))
        start = stop
    return ShardPlan(n_scenarios, n_shards, tuple(bounds))


def owner_of(shard: int, n_hosts: int) -> int:
    """Round-robin shard -> host owner mapping (deterministic)."""
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    return shard % n_hosts


def shards_for_host(
    plan: ShardPlan, host: int, n_hosts: int
) -> tuple[int, ...]:
    """Shard ids this host owns under the round-robin mapping."""
    if not 0 <= host < n_hosts:
        raise ValueError(f"host {host} outside [0, {n_hosts})")
    return tuple(
        i for i in range(plan.n_shards) if owner_of(i, n_hosts) == host
    )


__all__ = ["ShardPlan", "plan_shards", "owner_of", "shards_for_host"]
