"""Accelerator-resident mixed-precision sweeps (the ``"mixed"`` engine).

Three pieces that together keep a 1e8-lane sweep on the device:

  * **On-device synthesis** — a counter-based splitmix64 generator whose
    numpy twin runs the *identical* arithmetic, so a shard materializes
    from ``(seed, lane_range)`` directly in device memory and
    ``host_batch(...) == device_batch(...)`` exactly for integer fields
    (float fields agree to libm ulps).  Unlike the legacy
    ``sweep/synth.py`` recipes (stateful ``np.random.Generator``
    streams, which jax cannot reproduce), every draw is a pure function
    of ``(seed, field, lane)`` — shard-composable by construction: lane
    ``i`` draws the same scenario no matter how the sweep is sharded.
    This deviates from the issue's "port to ``jax.random``" letter
    deliberately: ``jax.random`` streams cannot be twinned on the host
    for parity tests, and counter addressing is what makes shard
    boundaries free.
  * **Mixed-precision evaluation** — :func:`evaluate_mixed_grid` /
    :func:`dispatch_mixed_grid` pack the machine leaves at
    bf16/f32/f64 (``repro.autotune.jaxgrid.machine_arrays(dtype=...)``)
    and reuse the jitted kernels unchanged; the pipeline scan still
    accumulates in float64 (see ``jaxgrid.pipeline_jax``).  The
    two-phase ``dispatch`` form returns a ``finalize()`` thunk so the
    double-buffered shard loop can keep shard ``k+1`` in flight while
    shard ``k`` materializes — the paper's own overlap discipline
    applied to the sweep itself.
  * **Fused statistics reduction** — :func:`sweep_device_stats` runs
    synthesis, grid evaluation *and* the :class:`~repro.learn.stats.
    GateStats` integer-histogram reduction inside one jit, so only the
    (feature-bin, score-bin) histogram and a few summary scalars ever
    leave the accelerator; no ``(L, S, M)`` ``GridResult`` is assembled
    off-device.  The heuristic twins (gate terms, base picks, feature
    matrix) are computed in float64 on-device regardless of the
    evaluation dtype, mirroring ``repro.learn.stats.GateStats.
    update_from_grid`` operation for operation.

Dirichlet note: ragged profiles use Marsaglia–Tsang gamma sampling
(boosted for concentration < 1) with four fixed, vectorized
accept-rounds; the ~1e-5 of lanes still unresolved after four rounds
fall back deterministically to the distribution mode.  The profiles are
distribution-equivalent to ``synth.synthetic_ragged_batch`` but not
stream-identical to it — parity is defined against the numpy twin
(:func:`host_ragged_batch`), which runs the same arithmetic.
"""

from __future__ import annotations

import functools
import math
import time

import numpy as np

from repro.core.batch import RaggedBatch, ScenarioBatch
from repro.core.engine import (
    GRID_SCHEDULES,
    SCHEDULE_INDEX,
    GridResult,
    as_scenario_sequence,
    is_ragged,
)
from repro.core.heuristics import (
    _GATE_COMM_CIL,
    MIN_DECOMPOSE_FLOPS,
    machine_threshold,
)
from repro.core.schedule_types import Schedule
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.sweep.plan import plan_shards, shards_for_host
from repro.sweep.runner import ShardSummary, SweepResult
from repro.sweep.synth import _M_QUANTUM

# ---------------------------------------------------------------------------
# Counter-based generator (splitmix64): identical on numpy and jax.
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1
_U_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_U_MIX2 = np.uint64(0x94D049BB133111EB)
_U_GOLD = np.uint64(0x9E3779B97F4A7C15)

# Field addresses (the per-(seed, field) key spaces never collide).
_FIELD_M, _FIELD_N, _FIELD_K, _FIELD_B, _FIELD_SHORT, _FIELD_TAIL = range(6)
_FIELD_GAMMA0 = 16  # gamma draws for ragged step s start at 16 + 16*s
_GAMMA_STRIDE = 16
_GAMMA_ROUNDS = 4  # fixed vectorized accept-rounds (3 draws each)
_GAMMA_BOOST = 12  # 13th draw of a step: the alpha<1 boost uniform


def _mix64_int(x: int) -> int:
    """Scalar splitmix64 finalizer on python ints (key derivation)."""
    z = x & _MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return z ^ (z >> 31)


def _field_key(seed: int, field: int) -> int:
    """Per-(seed, field) stream key — a plain python int, so it is a
    compile-time constant inside the jitted program."""
    return _mix64_int(
        (_mix64_int(seed & _MASK64) + field * 0x9E3779B97F4A7C15) & _MASK64
    )


def _mix64(xp, z):
    """Vector splitmix64 finalizer; ``xp`` is numpy or jax.numpy.

    numpy uint64 arithmetic wraps silently; jax needs the x64 scope the
    device entry points always hold.
    """
    z = (z ^ (z >> np.uint64(30))) * _U_MIX1
    z = (z ^ (z >> np.uint64(27))) * _U_MIX2
    return z ^ (z >> np.uint64(31))


def _u01(xp, key: int, lane):
    """Uniform draw in (0, 1] (log-safe), exact function of (key, lane).

    The top 53 bits map to ``(k + 1) * 2**-53`` — every step (integer
    ops, uint64->f64 of values <= 2**53, power-of-two scaling) is exact,
    so numpy and jax produce bitwise-identical uniforms.
    """
    bits = _mix64(xp, np.uint64(key) + lane * _U_GOLD)
    return ((bits >> np.uint64(11)) + np.uint64(1)).astype(
        xp.float64
    ) * (2.0 ** -53)


def _lanes(xp, n: int, start):
    """uint64 lane ids ``start + [0, n)``; ``start`` may be traced."""
    if xp is np:
        start = np.uint64(int(start))
    return start + xp.arange(n, dtype=xp.uint64)


# ---------------------------------------------------------------------------
# Synthesis twins (xp-generic; xp=np is the host twin, xp=jnp the device).
# ---------------------------------------------------------------------------


def _int_field(xp, key: int, lane, quantum: int, lo: float, hi: float):
    """``quantum * int(exp(U(log lo, log hi)))`` — the synth.py recipe
    (truncate-then-multiply, matching ``synthetic_batch``)."""
    u = _u01(xp, key, lane)
    v = xp.exp(u * (math.log(hi) - math.log(lo)) + math.log(lo))
    return quantum * v.astype(xp.int64)


def _choice_field(xp, key: int, lane, choices):
    u = _u01(xp, key, lane)
    i = xp.minimum(
        xp.floor(u * len(choices)).astype(xp.int64), len(choices) - 1
    )
    return xp.asarray(choices, dtype=xp.int64)[i]


def _synth_uniform(xp, lane, seed: int, dtype_bytes):
    """(m, n, k, b) int64 arrays; same ranges as ``synthetic_batch``."""
    m = _int_field(xp, _field_key(seed, _FIELD_M), lane, _M_QUANTUM, 1, 2048)
    n = _int_field(xp, _field_key(seed, _FIELD_N), lane, 128, 8, 512)
    k = _int_field(xp, _field_key(seed, _FIELD_K), lane, 128, 8, 512)
    b = _choice_field(xp, _field_key(seed, _FIELD_B), lane, tuple(dtype_bytes))
    return m, n, k, b


def _gamma_boosted(xp, seed: int, lane, step: int, alpha: float):
    """Gamma(alpha) draws via Marsaglia–Tsang at ``alpha + 1`` plus the
    ``u**(1/alpha)`` boost (alpha < 1 support), vectorized.

    Four fixed accept-rounds resolve all but ~1e-5 of lanes (the M–T
    acceptance rate at the boosted shape is >95%); stragglers fall back
    deterministically to ``d`` (the distribution mode) so the result is
    a pure function of (seed, step, lane) with no data-dependent loop.
    """
    d = (alpha + 1.0) - 1.0 / 3.0
    c = 1.0 / math.sqrt(9.0 * d)
    base = _FIELD_GAMMA0 + step * _GAMMA_STRIDE
    g = xp.full(lane.shape, -1.0, dtype=xp.float64)
    for j in range(_GAMMA_ROUNDS):
        u1 = _u01(xp, _field_key(seed, base + 3 * j), lane)
        u2 = _u01(xp, _field_key(seed, base + 3 * j + 1), lane)
        ua = _u01(xp, _field_key(seed, base + 3 * j + 2), lane)
        # Box–Muller normal from two (0, 1] uniforms.
        x = xp.sqrt(-2.0 * xp.log(u1)) * xp.cos((2.0 * math.pi) * u2)
        v = (1.0 + c * x) ** 3
        v_safe = xp.where(v > 0.0, v, 1.0)
        ok = (v > 0.0) & (
            xp.log(ua) < 0.5 * x * x + d - d * v_safe + d * xp.log(v_safe)
        )
        g = xp.where((g < 0.0) & ok, d * v_safe, g)
    g = xp.where(g < 0.0, d, g)
    boost = _u01(xp, _field_key(seed, base + _GAMMA_BOOST), lane)
    return g * boost ** (1.0 / alpha)


def _synth_frac(xp, lane, seed: int, steps: int, concentration: float):
    """(S, steps) float64 Dirichlet profiles with masked short tails.

    Mirrors ``synthetic_ragged_batch``'s post-processing: ~25% of rows
    are truncated to a random tail in [1, steps-1], then rows
    renormalize to sum to 1 exactly.
    """
    gs = xp.stack(
        [
            _gamma_boosted(xp, seed, lane, s, concentration)
            for s in range(steps)
        ],
        axis=1,
    )
    if steps > 1:
        short = _u01(xp, _field_key(seed, _FIELD_SHORT), lane) < 0.25
        u_tail = _u01(xp, _field_key(seed, _FIELD_TAIL), lane)
        tail = xp.minimum(
            (1.0 + xp.floor(u_tail * (steps - 1))).astype(xp.int64),
            steps - 1,
        )
        cols = xp.arange(steps, dtype=xp.int64)[None, :]
        gs = xp.where(short[:, None] & (cols >= tail[:, None]), 0.0, gs)
    return gs / gs.sum(axis=1, keepdims=True)


def host_batch(
    n: int, *, seed: int = 0, start: int = 0, dtype_bytes=(2, 1)
) -> ScenarioBatch:
    """Numpy twin of :func:`device_batch` — bitwise-identical integers.

    ``start`` is the global lane offset: ``host_batch(k, start=s)`` is
    rows ``[s, s+k)`` of ``host_batch(s+k)``, which is what lets every
    shard regenerate exactly its slice.
    """
    lane = _lanes(np, n, start)
    m, nn, kk, b = _synth_uniform(np, lane, seed, dtype_bytes)
    return ScenarioBatch(m=m, n=nn, k=kk, dtype_bytes=b)


def host_ragged_batch(
    n: int,
    *,
    seed: int = 0,
    start: int = 0,
    steps: int = 8,
    concentration: float = 0.7,
    dtype_bytes=(2, 1),
) -> RaggedBatch:
    """Numpy twin of :func:`device_ragged_batch`."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    lane = _lanes(np, n, start)
    m, nn, kk, b = _synth_uniform(np, lane, seed, dtype_bytes)
    frac = _synth_frac(np, lane, seed, steps, concentration)
    return RaggedBatch(m=m, n=nn, k=kk, dtype_bytes=b, frac=frac)


def device_batch(
    n: int, *, seed: int = 0, start: int = 0, dtype_bytes=(2, 1)
) -> ScenarioBatch:
    """On-device synthesis, materialized back as a ScenarioBatch.

    The materialized form exists for parity tests and engine reuse; the
    fused sweep (:func:`sweep_device_stats`) never leaves the device.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        lane = _lanes(jnp, n, np.uint64(start))
        m, nn, kk, b = _synth_uniform(jnp, lane, seed, dtype_bytes)
        return ScenarioBatch(
            m=np.asarray(m), n=np.asarray(nn), k=np.asarray(kk),
            dtype_bytes=np.asarray(b),
        )


def device_ragged_batch(
    n: int,
    *,
    seed: int = 0,
    start: int = 0,
    steps: int = 8,
    concentration: float = 0.7,
    dtype_bytes=(2, 1),
) -> RaggedBatch:
    """On-device ragged synthesis, materialized as a RaggedBatch."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    with enable_x64():
        lane = _lanes(jnp, n, np.uint64(start))
        m, nn, kk, b = _synth_uniform(jnp, lane, seed, dtype_bytes)
        frac = _synth_frac(jnp, lane, seed, steps, concentration)
        return RaggedBatch(
            m=np.asarray(m), n=np.asarray(nn), k=np.asarray(kk),
            dtype_bytes=np.asarray(b), frac=np.asarray(frac),
        )


# ---------------------------------------------------------------------------
# Mixed-precision grid evaluation (the "mixed" engine's backend).
# ---------------------------------------------------------------------------

_DTYPES = ("float64", "float32", "bfloat16")


def _coerce(scenarios):
    from repro.core import batch as _batch

    scenarios = as_scenario_sequence(scenarios)
    if is_ragged(scenarios):
        return _batch._as_ragged_batch(scenarios)
    return _batch._as_batch(scenarios)


def dispatch_mixed_grid(
    scenarios,
    machines,
    *,
    dtype: str = "float32",
    dma: bool = True,
    dma_into_place: bool = False,
    schedules=GRID_SCHEDULES,
):
    """Asynchronously dispatch a mixed-precision grid evaluation.

    Returns a zero-argument ``finalize()`` that materializes the
    :class:`GridResult` (blocking on device completion).  jax dispatch
    is asynchronous, so the device starts computing the moment this
    returns — the double-buffered shard loop dispatches shard ``k+1``
    before finalizing shard ``k``.
    """
    from jax.experimental import enable_x64

    from repro.autotune import jaxgrid

    if dtype not in _DTYPES:
        raise ValueError(f"dtype must be one of {_DTYPES}, got {dtype!r}")
    machines = tuple(machines)
    schedules = tuple(schedules)
    sb = _coerce(scenarios)
    with _trace.span(
        "sweepdevice/dispatch", "sweepdevice",
        dtype=dtype, n_scenarios=len(sb), n_machines=len(machines),
    ):
        with enable_x64():
            # Machine arrays MUST pack inside the x64 scope: outside it
            # the int64 leaves silently truncate to int32.
            mp = jaxgrid.machine_arrays(
                machines, dtype=None if dtype == "float64" else dtype
            )
            g_max = max(m.group for m in machines)
            if isinstance(sb, RaggedBatch):
                out = jaxgrid.evaluate_ragged_grid_raw(
                    sb, mp, dma=dma, dma_into_place=dma_into_place,
                    schedules=schedules, g_max=g_max,
                )
            else:
                out = jaxgrid.evaluate_grid_raw(
                    sb, mp, dma=dma, dma_into_place=dma_into_place,
                    schedules=schedules, g_max=g_max,
                )

    def finalize() -> GridResult:
        # The np.asarray conversions inside from_machine_major block on
        # the async device computation — this span is the "compute"
        # half of the two-phase overlap.
        with _trace.span(
            "sweepdevice/finalize", "sweepdevice",
            dtype=dtype, n_scenarios=len(sb),
        ):
            return GridResult.from_machine_major(
                out, schedules=schedules, scenarios=sb, machines=machines,
                dma=dma,
            )

    return finalize


def evaluate_mixed_grid(
    scenarios,
    machines,
    *,
    dtype: str = "float32",
    dma: bool = True,
    dma_into_place: bool = False,
    schedules=GRID_SCHEDULES,
) -> GridResult:
    """Synchronous form of :func:`dispatch_mixed_grid`."""
    return dispatch_mixed_grid(
        scenarios, machines, dtype=dtype, dma=dma,
        dma_into_place=dma_into_place, schedules=schedules,
    )()


# ---------------------------------------------------------------------------
# Fused synthesis + evaluation + GateStats reduction (one jit).
# ---------------------------------------------------------------------------


def _quantize_regret_jnp(t, tb):
    """jnp twin of ``repro.learn.stats._quantize_regret`` (rint is
    round-half-even on both sides)."""
    import jax.numpy as jnp

    from repro.learn.stats import REGRET_CAP, REGRET_SCALE

    regret = t / tb - 1.0
    regret = jnp.nan_to_num(
        regret, nan=REGRET_CAP, posinf=REGRET_CAP, neginf=0.0
    )
    regret = jnp.clip(regret, 0.0, REGRET_CAP)
    return jnp.rint(regret * REGRET_SCALE).astype(jnp.int64)


def _stats_one_machine(m, n, k, b, imb, act, row, thr, t, tb):
    """One machine's GateStats contribution, all float64, on device.

    Twins ``GateStats.update_from_grid``'s per-machine body operation
    for operation (terms -> score -> base picks -> features -> binned
    integer scatter): casts, op order and bin conventions match the
    numpy source exactly, so the integer histogram agrees with the host
    reduction up to float ulps landing on bin edges (measure-zero in
    practice; the parity test bounds the stray mass).

    ``row`` is a float64 MachineArrays row; ``t`` is the machine's
    nan_to_num'd (L, S) total; ``tb`` its (S,) best total; ``act`` is
    None for uniform batches (the ``group`` sentinel).
    """
    import jax.numpy as jnp

    from repro.autotune import jaxgrid
    from repro.learn.stats import (
        FEATURE_EDGES,
        SCORE_EDGES,
        _hist_shape,
    )
    from repro.learn.features import GATE_FEATURES

    f64 = jnp.float64
    mf, nf, kf, bf = (a.astype(f64) for a in (m, n, k, b))
    g = row.group
    gf = g.astype(f64)

    # -- serial_gate_terms_batch twin (floats first, like the source) --
    dev_n = jnp.where(nf % gf == 0.0, nf / gf, nf)
    mk_bytes = mf * kf * bf
    ag_bw = jnp.where(
        row.is_mesh,
        row.link_bw * (g - 1).astype(f64),
        row.link_bw * row.a2a_links.astype(f64),
    )
    t_comm = mk_bytes / ag_bw
    t_gemm = 2.0 * mf * dev_n * kf / row.peak_flops
    r = t_comm / t_gemm
    t_serial_ag = jaxgrid.ag_serial_time_jax(mk_bytes, row)
    t_chunked_ag = gf * jaxgrid.a2a_chunk_step_time_jax(
        mk_bytes / (gf * gf), row
    )
    inflate = t_chunked_ag / t_serial_ag
    score = r * (inflate * _GATE_COMM_CIL - 1.0)

    # -- select_schedule_batch twin (serial_gate=inf -> flops guard) ---
    flops_i = 2.0 * m * n * k  # int chain, matching the numpy source
    bytes_i = (m * k + k * n + m * n).astype(f64) * b
    metric = (flops_i / bytes_i) * bytes_i
    base = jnp.select(
        [
            flops_i < MIN_DECOMPOSE_FLOPS,
            m < k,
            metric < thr,
            metric >= 5.0 * thr,
        ],
        [
            SCHEDULE_INDEX[Schedule.SERIAL],
            SCHEDULE_INDEX[Schedule.UNIFORM_FUSED_2D],
            SCHEDULE_INDEX[Schedule.UNIFORM_FUSED_1D],
            SCHEDULE_INDEX[Schedule.HETERO_UNFUSED_1D],
        ],
        SCHEDULE_INDEX[Schedule.HETERO_FUSED_1D],
    ).astype(jnp.int32)

    # -- feature_matrix twin (floats-first sums, unlike the picks) -----
    act_col = jnp.ones_like(imb) * gf if act is None else act
    flops_f = 2.0 * mf * nf * kf
    bytes_f = (mf * kf + kf * nf + mf * nf) * bf
    otb = flops_f / bytes_f
    m_over_k = mf / kf
    log_flops = jnp.log10(jnp.maximum(flops_f, 1.0))
    cil = jaxgrid.comm_cil_jax(mf / gf, dev_n, kf, bf, row, degree=4)
    feats = jnp.stack(
        [
            imb, act_col, otb, r, inflate, cil, log_flops, m_over_k,
            jnp.ones_like(imb) * gf,
            jnp.ones_like(imb) * (row.peak_flops / row.hbm_bw),
        ],
        axis=1,
    )

    # -- binning + integer scatter (GATE_FEATURES order, then score) ---
    gate_cols = {"imbalance": imb, "active_steps": act_col, "otb": otb,
                 "r": r}
    idx = jnp.zeros(imb.shape, dtype=jnp.int64)
    for fname in GATE_FEATURES:
        edges = jnp.asarray(FEATURE_EDGES[fname], dtype=f64)
        idx = idx * (len(FEATURE_EDGES[fname]) + 1) + jnp.searchsorted(
            edges, gate_cols[fname], side="right"
        )
    idx = idx * (len(SCORE_EDGES) + 1) + jnp.searchsorted(
        jnp.asarray(SCORE_EDGES, dtype=f64), score, side="right"
    )

    serial_l = SCHEDULE_INDEX[Schedule.SERIAL]
    t_serial = t[serial_l, :]
    # base only ever holds the five pick indices; a select chain over
    # contiguous rows avoids a strided take_along_axis gather.
    picks = sorted({
        SCHEDULE_INDEX[s] for s in (
            Schedule.SERIAL, Schedule.UNIFORM_FUSED_2D,
            Schedule.UNIFORM_FUSED_1D, Schedule.HETERO_UNFUSED_1D,
            Schedule.HETERO_FUSED_1D,
        )
    })
    t_pick = jnp.select(
        [base == j for j in picks], [t[j, :] for j in picks], jnp.inf
    )
    w5_serial = (t_serial <= 1.05 * tb).astype(jnp.int64)
    w5_base = (t_pick <= 1.05 * tb).astype(jnp.int64)
    reg_serial = _quantize_regret_jnp(t_serial, tb)
    reg_base = _quantize_regret_jnp(t_pick, tb)

    shape = _hist_shape()
    flat = int(np.prod(shape[:-1]))
    # One fused scatter of the (S, 5) stat payload beats five scatter
    # passes over the 874k-cell histogram by ~4x on CPU.
    payload = jnp.stack(
        [
            jnp.ones_like(w5_serial), w5_serial, w5_base,
            reg_serial, reg_base,
        ],
        axis=1,
    )
    h = jnp.zeros((flat, shape[-1]), dtype=jnp.int64)
    h = h.at[idx].add(payload)

    finite = jnp.isfinite(feats)
    mom = jnp.stack(
        [
            finite.sum(axis=0).astype(f64),
            jnp.where(finite, feats, 0.0).sum(axis=0),
            jnp.where(finite, feats ** 2, 0.0).sum(axis=0),
        ],
        axis=1,
    )
    return h, mom


@functools.lru_cache(maxsize=None)
def _shard_fn():
    """Build (once) the jitted fused shard program.

    Deferred so importing this module never imports jax; the jit caches
    per static-argument combination as usual.
    """
    import jax
    import jax.numpy as jnp

    from repro.autotune import jaxgrid

    @functools.partial(
        jax.jit,
        static_argnames=(
            "n", "seed", "steps", "concentration", "dtype_bytes",
            "g_max", "dma", "dma_into_place", "collect", "per_machine",
        ),
    )
    def shard_fn(
        start, mp_dt, mp64, thresholds, *,
        n, seed, steps, concentration, dtype_bytes,
        g_max, dma, dma_into_place, collect, per_machine,
    ):
        lane = start + jnp.arange(n, dtype=jnp.uint64)
        m, nn, kk, b = _synth_uniform(jnp, lane, seed, dtype_bytes)
        frac64 = (
            None if steps is None
            else _synth_frac(jnp, lane, seed, steps, concentration)
        )
        dt = mp_dt.peak_flops.dtype
        if frac64 is None:
            # closed_form=True: uniform schedules use the exact
            # closed-form pipeline (equal to the scan up to rounding),
            # ~2x fewer elementwise ops — the sweep fast path.
            outs = jax.vmap(
                lambda one: jaxgrid._eval_one_machine_jax(
                    m, nn, kk, b, one, g_max, GRID_SCHEDULES,
                    dma, dma_into_place, True,
                )
            )(mp_dt)
        else:
            frac_dt = frac64.astype(dt)
            outs = jax.vmap(
                lambda one: jaxgrid._eval_one_machine_ragged_jax(
                    m, nn, kk, b, frac_dt, one, g_max, GRID_SCHEDULES,
                    dma, dma_into_place,
                )
            )(mp_dt)
        total, _c, _w, _e, _st, valid, sc, sg = outs
        L = len(GRID_SCHEDULES)
        serial_l = SCHEDULE_INDEX[Schedule.SERIAL]
        tv = jnp.where(valid, total, jnp.inf)
        # Min/argmin over the schedule axis as L contiguous (M, S)
        # passes: lanes sit 2 MB apart along axis 1, so the native
        # jnp.argmin(axis=1) gather pattern thrashes the cache.
        tb = tv[:, 0, :]
        best = jnp.zeros(tb.shape, dtype=jnp.int32)
        for j in range(1, L):
            better = tv[:, j, :] < tb
            tb = jnp.where(better, tv[:, j, :], tb)
            best = jnp.where(better, jnp.int32(j), best)
        best_counts = jax.vmap(
            lambda bj: jnp.zeros((L,), dtype=jnp.int64).at[bj].add(1)
        )(best)  # (M, L) — scatter beats an (M, S, L) one-hot sum

        n_prof = jnp.sum(best != serial_l)
        speedup = (sc + sg) / tb
        fin = jnp.isfinite(speedup)
        sp_sum = jnp.sum(jnp.where(fin, speedup, 0.0))
        sp_cnt = jnp.sum(fin)
        if not collect:
            return best_counts, n_prof, sp_sum, sp_cnt

        if frac64 is None:
            imb = jnp.ones((n,), dtype=jnp.float64)
            act = None
        else:
            act = (frac64 > 0.0).sum(axis=1).astype(jnp.float64)
            imb = frac64.max(axis=1) * act
        t = jnp.nan_to_num(total, nan=jnp.inf, posinf=jnp.inf)
        hist, mom = jax.vmap(
            lambda row, thr, t_j, tb_j: _stats_one_machine(
                m, nn, kk, b, imb, act, row, thr, t_j, tb_j
            )
        )(mp64, thresholds, t, tb)
        if not per_machine:
            hist = hist.sum(axis=0)
            mom = mom.sum(axis=0)
        return best_counts, n_prof, sp_sum, sp_cnt, hist, mom

    return shard_fn


def sweep_device_stats(
    n_scenarios: int,
    machines,
    *,
    seed: int = 0,
    dtype: str = "float32",
    num_shards: int | None = None,
    ragged: bool = False,
    steps: int = 8,
    concentration: float = 0.7,
    dtype_bytes=(2, 1),
    dma: bool = True,
    dma_into_place: bool = False,
    host_index: int = 0,
    host_count: int = 1,
    on_shard=None,
    overlap_dispatch: bool = True,
    collect_stats: bool = True,
    per_family: bool = False,
):
    """The fully device-resident sweep: synth + eval + stats in one jit.

    Shards the global lane range ``[0, n_scenarios)`` with the standard
    deterministic plan (so multi-host runs regenerate exactly their
    owned lanes), dispatches each owned shard's fused program, and —
    with ``overlap_dispatch`` (default on; this path has no bit-identity
    contract to preserve) — keeps shard ``k+1`` in flight while shard
    ``k``'s reduced outputs transfer.  Per-shard ``seconds`` therefore
    overlap wall-clock; their sum exceeds elapsed time by design.

    Returns ``(stats, sweep_result)``:

      * ``stats`` — a :class:`~repro.learn.stats.GateStats` (or, with
        ``per_family=True``, a dict mapping machine-family name — the
        ``name.split("/")[0]`` prefix — to its own GateStats; families
        sum to the global statistics exactly).  ``None`` when
        ``collect_stats=False``.
      * ``sweep_result`` — a reduce-mode :class:`SweepResult` whose
        summaries mirror ``sweep_grid``'s (``on_shard`` streams them).

    The GateStats histogram is reduced in the jit from float64 heuristic
    twins, so a gate trained from it matches host-reduced training up to
    bin-edge ulps regardless of the evaluation ``dtype``.
    """
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.autotune import jaxgrid
    from repro.learn.stats import GateStats, _hist_shape

    if dtype not in _DTYPES:
        raise ValueError(f"dtype must be one of {_DTYPES}, got {dtype!r}")
    if ragged and steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    machines = tuple(machines)
    M = len(machines)
    families = [m.name.split("/", 1)[0] for m in machines]
    L = len(GRID_SCHEDULES)
    shard_fn = _shard_fn()
    per_machine = bool(per_family and collect_stats)

    plan = plan_shards(
        n_scenarios, num_shards if num_shards is not None else host_count
    )
    owned = shards_for_host(plan, host_index, host_count)

    summaries: list[ShardSummary] = []
    hist_acc: dict[str, np.ndarray] = {}
    mom_acc: dict[str, np.ndarray] = {}
    pts_acc: dict[str, int] = {}
    bc_acc: dict[str, np.ndarray] = {}
    shape = _hist_shape()
    flat = int(np.prod(shape[:-1]))

    def _bucket(key):
        if key not in hist_acc:
            hist_acc[key] = np.zeros((flat, shape[-1]), dtype=np.int64)
            mom_acc[key] = np.zeros((len(_feature_count()), 3))
            pts_acc[key] = 0
            bc_acc[key] = np.zeros(L, dtype=np.int64)
        return key

    with enable_x64():
        mp_dt = jaxgrid.machine_arrays(
            machines, dtype=None if dtype == "float64" else dtype
        )
        mp64 = jaxgrid.machine_arrays(machines)
        thresholds = jnp.asarray(
            [machine_threshold(m) for m in machines], dtype=jnp.float64
        )
        g_max = max(m.group for m in machines)

        reg = _metrics.get_metrics()

        def _dispatch(shard):
            start, stop = plan.bounds[shard]
            t0 = time.perf_counter()
            with _trace.span(
                "sweepdevice/dispatch", "sweepdevice",
                shard=shard, start=start, stop=stop,
                overlap=overlap_dispatch,
            ):
                outs = shard_fn(
                    np.uint64(start), mp_dt, mp64, thresholds,
                    n=stop - start, seed=seed,
                    steps=steps if ragged else None,
                    concentration=concentration,
                    dtype_bytes=tuple(dtype_bytes),
                    g_max=g_max, dma=dma, dma_into_place=dma_into_place,
                    collect=collect_stats, per_machine=per_machine,
                )
            return (shard, start, stop, t0, outs)

        def _complete(entry):
            shard, start, stop, t0, outs = entry
            with _trace.span(
                "sweepdevice/compute", "sweepdevice", shard=shard,
            ):
                host = [np.asarray(o) for o in outs]  # blocks on device
            secs = time.perf_counter() - t0
            S = stop - start
            reg.counter("sweep/shards").inc()
            reg.counter("sweep/scenarios").inc(S)
            reg.histogram("sweep/shard_seconds").observe(secs)
            with _trace.span(
                "sweepdevice/reduce", "sweepdevice",
                shard=shard, n_scenarios=S, seconds=secs,
            ):
                bc_ml, n_prof, sp_sum, sp_cnt = host[:4]
                bc = bc_ml.sum(axis=0)
                counts = {
                    sched.value: int(c)
                    for sched, c in zip(GRID_SCHEDULES, bc) if c
                }
                summ = ShardSummary(
                    shard=shard, start=start, stop=stop, n_scenarios=S,
                    n_points=S * M, seconds=secs,
                    scenarios_per_sec=S / secs if secs > 0 else 0.0,
                    best_counts=counts,
                    frac_overlap_profitable=float(n_prof) / (S * M),
                    mean_best_speedup=(
                        float(sp_sum) / float(sp_cnt) if sp_cnt else 0.0
                    ),
                )
                if collect_stats:
                    hist, mom = host[4], host[5]
                    if per_machine:
                        for j, fam in enumerate(families):
                            key = _bucket(fam)
                            hist_acc[key] += hist[j]
                            mom_acc[key] += mom[j]
                            pts_acc[key] += S
                            bc_acc[key] += bc_ml[j]
                    else:
                        key = _bucket("__all__")
                        hist_acc[key] += hist
                        mom_acc[key] += mom
                        pts_acc[key] += S * M
                        bc_acc[key] += bc
                summaries.append(summ)
                if on_shard is not None:
                    on_shard(summ)

        pending = None
        for shard in owned:
            start, stop = plan.bounds[shard]
            if start == stop:
                if pending is not None:
                    _complete(pending)
                    pending = None
                summ = ShardSummary(
                    shard, start, stop, 0, 0, 0.0, 0.0, {}, 0.0, 0.0
                )
                summaries.append(summ)
                if on_shard is not None:
                    on_shard(summ)
                continue
            entry = _dispatch(shard)
            if pending is not None:
                _complete(pending)
            if overlap_dispatch:
                pending = entry
            else:
                _complete(entry)
        if pending is not None:
            _complete(pending)

    def _as_stats(key) -> GateStats:
        st = GateStats.empty()
        st.hist = st.hist + hist_acc[key].reshape(st.hist.shape)
        st.moments = st.moments + mom_acc[key]
        st.best_counts = {
            sched.value: int(c)
            for sched, c in zip(GRID_SCHEDULES, bc_acc[key]) if c
        }
        st.n_points = pts_acc[key]
        return st

    stats = None
    if collect_stats:
        if per_family:
            stats = {
                fam: _as_stats(_bucket(fam))
                for fam in dict.fromkeys(families)
            }
        else:
            stats = _as_stats("__all__") if hist_acc else GateStats.empty()

    result = SweepResult(
        plan=plan, mode="reduce", host_index=host_index,
        host_count=host_count, owned=owned, summaries=tuple(summaries),
        grid=None,
    )
    return stats, result


def _feature_count():
    from repro.learn.features import FEATURE_NAMES

    return FEATURE_NAMES


def device_merge_stats(stats_list):
    """Device-side multi-host :class:`GateStats` merge.

    The multi-host stat streams (``sweep_host*.jsonl``) merge their
    integer histograms on the accelerator instead of the host: when the
    local device count covers the list, each histogram is laid on its
    own device and a ``psum`` over a ``"hosts"`` axis reduces them —
    the same collective a real multi-host pod would run, exercised here
    on simulated devices; longer lists fall back to a jitted on-device
    sum.  int64 addition is associative and exact, so either path is
    bit-identical to the host-side left fold
    ``functools.reduce(GateStats.merge, stats_list)``.  The float
    moments and the best-count/point tallies are reporting-only and
    tiny; they fold on the host in list order so even their float
    rounding matches the ``merge`` chain.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.learn.stats import GateStats

    stats_list = list(stats_list)
    if not stats_list:
        return GateStats.empty()
    first = stats_list[0]
    for other in stats_list[1:]:
        if other.schema != first.schema:
            raise ValueError(
                f"cannot merge GateStats schema {other.schema} "
                f"into schema {first.schema}"
            )
        if other.hist.shape != first.hist.shape:
            raise ValueError("GateStats bin layouts differ")
    with enable_x64():
        stacked = jnp.asarray(
            np.stack([s.hist for s in stats_list]), dtype=jnp.int64
        )
        if len(stats_list) <= jax.local_device_count():
            merged = jax.pmap(
                lambda h: jax.lax.psum(h, "hosts"), axis_name="hosts"
            )(stacked)[0]
        else:
            merged = jax.jit(lambda h: h.sum(axis=0))(stacked)
        hist = np.asarray(merged)

    moments = first.moments.copy()
    counts = dict(first.best_counts)
    n_points = first.n_points
    for other in stats_list[1:]:
        moments = moments + other.moments
        for key, v in other.best_counts.items():
            counts[key] = counts.get(key, 0) + v
        n_points += other.n_points
    return GateStats(
        hist=hist,
        moments=moments,
        best_counts=counts,
        n_points=n_points,
        schema=first.schema,
    )


__all__ = [
    "host_batch",
    "host_ragged_batch",
    "device_batch",
    "device_ragged_batch",
    "evaluate_mixed_grid",
    "dispatch_mixed_grid",
    "sweep_device_stats",
    "device_merge_stats",
]
