"""Sharded design-space sweeps: the scenario axis over devices and hosts.

The grid is embarrassingly parallel over scenarios, so a sweep is: cut
the scenario axis with a deterministic :class:`~repro.sweep.plan.ShardPlan`,
evaluate each shard through any registered engine
(:mod:`repro.core.engine`), and either **gather** the shards back into
one bit-identical :class:`~repro.core.engine.GridResult` or **reduce**
each shard to a compact :class:`ShardSummary` the moment it finishes
(1e7-point sweeps never hold the full ``(L, S, M)`` table in memory).

Two parallelism levels compose:

  * **hosts** — shards are owned round-robin by ``host_index`` out of
    ``host_count`` identical processes; every host derives the same plan
    and evaluates only its shards (operands regenerate locally, e.g.
    ``repro.sweep.synth``), streaming summaries for an aggregator.
  * **devices** — ``device_parallel=True`` evaluates each owned shard
    SPMD over the local jax devices (``jax.pmap`` over an equalized,
    padded-remainder split of the shard's lanes; padding lanes are
    copies of the last real lane and are trimmed before assembly, so
    the result is bit-identical to the unsharded jitted engine).

Uniform and ragged batches shard identically — a ``RaggedBatch``'s
padded fraction matrix is row-sliced with the scenario axis, so
profiles travel with their scenarios.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.batch import RaggedBatch, ScenarioBatch
from repro.core.engine import (
    GRID_SCHEDULES,
    Engine,
    GridResult,
    get_engine,
    is_ragged,
)
from repro.core.schedule_types import Schedule
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.sweep.plan import ShardPlan, plan_shards, shards_for_host


# ---------------------------------------------------------------------------
# Batch / grid slicing and concatenation (scenario axis).
# ---------------------------------------------------------------------------


def _coerce_batch(scenarios) -> ScenarioBatch:
    from repro.core import batch as _batch
    from repro.core.engine import as_scenario_sequence

    scenarios = as_scenario_sequence(scenarios)
    if is_ragged(scenarios):
        return _batch._as_ragged_batch(scenarios)
    return _batch._as_batch(scenarios)


def _slice_batch(sb: ScenarioBatch, start: int, stop: int) -> ScenarioBatch:
    names = sb.names[start:stop] if sb.names else ()
    if isinstance(sb, RaggedBatch):
        return RaggedBatch(
            m=sb.m[start:stop], n=sb.n[start:stop], k=sb.k[start:stop],
            dtype_bytes=sb.dtype_bytes[start:stop], names=names,
            frac=sb.frac[start:stop],
        )
    return ScenarioBatch(
        m=sb.m[start:stop], n=sb.n[start:stop], k=sb.k[start:stop],
        dtype_bytes=sb.dtype_bytes[start:stop], names=names,
    )


def shard_batch(scenarios, plan: ShardPlan) -> list[ScenarioBatch]:
    """Slice a (possibly ragged) batch into the plan's shards."""
    sb = _coerce_batch(scenarios)
    return [_slice_batch(sb, start, stop) for start, stop in plan.bounds]


def concat_batches(parts) -> ScenarioBatch:
    """Concatenate scenario batches; ragged frac matrices pad to max P."""
    parts = list(parts)
    if not parts:
        raise ValueError("nothing to concatenate")
    if len(parts) == 1:
        return parts[0]
    names = ()
    if all(len(p.names) == len(p) for p in parts):
        names = tuple(nm for p in parts for nm in p.names)
    m = np.concatenate([p.m for p in parts])
    n = np.concatenate([p.n for p in parts])
    k = np.concatenate([p.k for p in parts])
    b = np.concatenate([p.dtype_bytes for p in parts])
    if any(isinstance(p, RaggedBatch) for p in parts):
        if not all(isinstance(p, RaggedBatch) for p in parts):
            raise TypeError("cannot mix ragged and uniform batches")
        p_max = max(p.frac.shape[1] for p in parts)
        frac = np.concatenate([
            np.pad(p.frac, ((0, 0), (0, p_max - p.frac.shape[1])))
            for p in parts
        ])
        return RaggedBatch(
            m=m, n=n, k=k, dtype_bytes=b, names=names, frac=frac
        )
    return ScenarioBatch(m=m, n=n, k=k, dtype_bytes=b, names=names)


def _slice_grid(g: GridResult, start: int, stop: int) -> GridResult:
    return GridResult(
        schedules=g.schedules,
        scenarios=_slice_batch(g.scenarios, start, stop),
        machines=g.machines,
        total=g.total[:, start:stop],
        comm_busy=g.comm_busy[:, start:stop],
        compute_busy=g.compute_busy[:, start:stop],
        exposed=g.exposed[:, start:stop],
        steps=g.steps,
        serial_comm=g.serial_comm[start:stop],
        serial_gemm=g.serial_gemm[start:stop],
        valid=g.valid[:, start:stop],
        dma=g.dma,
    )


def concat_grid_results(parts) -> GridResult:
    """Reassemble scenario-axis shards into one GridResult.

    The inverse of :func:`shard_batch` + per-shard evaluation: because
    every engine is elementwise over the scenario axis, the result is
    bit-identical to evaluating the concatenated batch directly.
    """
    parts = list(parts)
    if not parts:
        raise ValueError("nothing to concatenate")
    head = parts[0]
    for p in parts[1:]:
        if p.schedules != head.schedules or p.machines != head.machines:
            raise ValueError("shards disagree on schedules/machines")
        if p.dma != head.dma or not np.array_equal(p.steps, head.steps):
            raise ValueError("shards disagree on dma/step counts")
    if len(parts) == 1:
        return head
    return GridResult(
        schedules=head.schedules,
        scenarios=concat_batches([p.scenarios for p in parts]),
        machines=head.machines,
        total=np.concatenate([p.total for p in parts], axis=1),
        comm_busy=np.concatenate([p.comm_busy for p in parts], axis=1),
        compute_busy=np.concatenate(
            [p.compute_busy for p in parts], axis=1
        ),
        exposed=np.concatenate([p.exposed for p in parts], axis=1),
        steps=head.steps,
        serial_comm=np.concatenate([p.serial_comm for p in parts], axis=0),
        serial_gemm=np.concatenate([p.serial_gemm for p in parts], axis=0),
        valid=np.concatenate([p.valid for p in parts], axis=1),
        dma=head.dma,
    )


# ---------------------------------------------------------------------------
# Per-shard summaries (the "reduce" result mode).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardSummary:
    """Compact per-shard statistics — what multi-host sweeps stream."""

    shard: int
    start: int
    stop: int
    n_scenarios: int
    n_points: int  # scenarios x machines
    seconds: float
    scenarios_per_sec: float
    best_counts: dict[str, int]  # schedule value -> optimal-pick count
    frac_overlap_profitable: float
    mean_best_speedup: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def summarize_shard(
    grid: GridResult, shard: int, start: int, stop: int, seconds: float
) -> ShardSummary:
    """Reduce one shard's GridResult to a ShardSummary."""
    S, M = grid.total.shape[1], grid.total.shape[2]
    points = S * M
    if points == 0:
        return ShardSummary(
            shard, start, stop, S, 0, seconds, 0.0, {}, 0.0, 0.0
        )
    best = grid.best_idx()
    with np.errstate(divide="ignore", invalid="ignore"):
        speedup = grid.serial_total / grid.best_total()
    counts = {
        sched.value: int((best == l).sum())
        for l, sched in enumerate(grid.schedules)
    }
    if Schedule.SERIAL in grid.schedules:
        profitable = best != grid.schedule_idx(Schedule.SERIAL)
    else:
        profitable = np.ones_like(best, dtype=bool)
    finite = np.isfinite(speedup)
    return ShardSummary(
        shard=shard,
        start=start,
        stop=stop,
        n_scenarios=S,
        n_points=points,
        seconds=seconds,
        scenarios_per_sec=S / seconds if seconds > 0 else 0.0,
        best_counts=counts,
        frac_overlap_profitable=float(np.mean(profitable)),
        mean_best_speedup=float(np.mean(speedup[finite]))
        if finite.any()
        else 0.0,
    )


def merge_summaries(summaries) -> dict:
    """Aggregate shard summaries (from any subset of hosts) into totals."""
    summaries = list(summaries)
    counts: dict[str, int] = {}
    for s in summaries:
        for k, v in s.best_counts.items():
            counts[k] = counts.get(k, 0) + v
    scen = sum(s.n_scenarios for s in summaries)
    pts = sum(s.n_points for s in summaries)
    secs = sum(s.seconds for s in summaries)
    wmean = (
        sum(s.mean_best_speedup * s.n_points for s in summaries) / pts
        if pts
        else 0.0
    )
    wprof = (
        sum(s.frac_overlap_profitable * s.n_points for s in summaries) / pts
        if pts
        else 0.0
    )
    return {
        "n_shards": len(summaries),
        "n_scenarios": scen,
        "n_points": pts,
        "seconds": secs,
        "scenarios_per_sec": scen / secs if secs > 0 else 0.0,
        "best_counts": counts,
        "frac_overlap_profitable": wprof,
        "mean_best_speedup": wmean,
    }


# ---------------------------------------------------------------------------
# Device-parallel evaluation (pmap over an equalized padded shard split).
# ---------------------------------------------------------------------------


def _device_sharded_grid(
    sb: ScenarioBatch,
    machines,
    *,
    dma: bool,
    dma_into_place: bool,
    schedules,
    devices,
) -> GridResult:
    """One batch SPMD over ``devices``: pad-equalize, pmap, trim, assemble.

    Reuses the jitted engine's per-machine kernels unchanged, so every
    lane computes exactly what the unsharded jitted grid computes —
    padding lanes (copies of the last real lane) are dropped before the
    :class:`GridResult` is assembled.
    """
    import jax
    from jax.experimental import enable_x64

    from repro.autotune import jaxgrid

    machines = tuple(machines)
    schedules = tuple(schedules)
    D = len(devices)
    S = len(sb)
    if S == 0:
        raise ValueError("cannot device-shard an empty batch")
    ragged = isinstance(sb, RaggedBatch)
    size = plan_shards(S, D, equalize=True).padded_size
    pad = D * size - S

    def stack(a):
        a = np.asarray(a)
        if pad:
            tail = np.broadcast_to(a[-1:], (pad,) + a.shape[1:])
            a = np.concatenate([a, tail])
        return np.ascontiguousarray(a.reshape((D, size) + a.shape[1:]))

    with enable_x64():
        mp = jaxgrid.machine_arrays(machines)
        g_max = max(m.group for m in machines)
        # The machine arrays ride along as broadcast *operands*
        # (in_axes=None), exactly like ``_grid_jit``'s parameters — as
        # closure constants XLA would fold them into the program with
        # different roundings than the unsharded jitted engine.
        if ragged:
            def shard_fn(m, n, k, b, frac, mp_):
                return jax.vmap(
                    lambda one: jaxgrid._eval_one_machine_ragged_jax(
                        m, n, k, b, frac, one, g_max, schedules,
                        dma, dma_into_place,
                    )
                )(mp_)

            operands = (
                stack(sb.m), stack(sb.n), stack(sb.k),
                stack(sb.dtype_bytes), stack(sb.frac),
            )
            in_axes = (0, 0, 0, 0, 0, None)
        else:
            def shard_fn(m, n, k, b, mp_):
                return jax.vmap(
                    lambda one: jaxgrid._eval_one_machine_jax(
                        m, n, k, b, one, g_max, schedules,
                        dma, dma_into_place,
                    )
                )(mp_)

            operands = (
                stack(sb.m), stack(sb.n), stack(sb.k),
                stack(sb.dtype_bytes),
            )
            in_axes = (0, 0, 0, 0, None)
        out = jax.pmap(shard_fn, devices=devices, in_axes=in_axes)(
            *operands, mp
        )
    total, comm, comp, exp, steps, valid, sc, sg = (
        np.asarray(a) for a in out
    )

    def cat3(a):  # (D, M, L, size) -> (M, L, D*size) -> trim pad
        return np.moveaxis(a, 0, 2).reshape(
            a.shape[1], a.shape[2], D * size
        )[..., :S]

    def cat2(a):  # (D, M, size) -> (M, D*size) -> trim pad
        return np.moveaxis(a, 0, 1).reshape(a.shape[1], D * size)[:, :S]

    raw = (
        cat3(total), cat3(comm), cat3(comp), cat3(exp),
        steps[0], cat3(valid), cat2(sc), cat2(sg),
    )
    return GridResult.from_machine_major(
        raw, schedules=schedules, scenarios=sb, machines=machines, dma=dma
    )


# ---------------------------------------------------------------------------
# The sweep driver.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """What one host's sweep produced.

    ``grid`` is the reassembled GridResult over this host's owned shards
    (``mode="gather"``; None in reduce mode).  With ``host_count == 1``
    the owned shards are all shards, so ``grid`` is bit-identical to the
    unsharded engine evaluation.
    """

    plan: ShardPlan
    mode: str
    host_index: int
    host_count: int
    owned: tuple[int, ...]
    summaries: tuple[ShardSummary, ...]
    grid: GridResult | None

    def summary(self) -> dict:
        return merge_summaries(self.summaries)


def sweep_grid(
    scenarios,
    machines,
    *,
    backend: str = "numpy",
    engine: Engine | None = None,
    num_shards: int | None = None,
    mode: str = "gather",
    dma: bool = True,
    dma_into_place: bool = False,
    schedules=None,
    host_index: int = 0,
    host_count: int = 1,
    device_parallel: bool = False,
    devices=None,
    on_shard=None,
    on_shard_grid=None,
    overlap_dispatch: bool = False,
) -> SweepResult:
    """Sharded design-space sweep over the scenario axis.

    ``scenarios`` is anything the engines accept (uniform or ragged —
    ragged fraction matrices shard with their scenarios).  The plan cuts
    the axis into ``num_shards`` contiguous shards (default: one per
    host), owned round-robin by ``host_index`` of ``host_count``
    identical processes; only owned shards are evaluated.

    ``mode="gather"`` reassembles the owned shards into one
    :class:`GridResult` (bit-identical to the unsharded evaluation when
    a single host owns everything); ``mode="reduce"`` keeps only
    :class:`ShardSummary` per shard — the memory-bounded form for
    1e6-1e7-point sweeps.  ``on_shard`` (if given) is called with each
    summary as soon as its shard finishes — the streaming hook
    ``scripts/sweep.py`` uses to emit JSON lines.

    ``on_shard_grid`` (if given) is called with ``(grid, summary)``
    while the shard's GridResult is still alive — i.e. *before* reduce
    mode drops it.  This is the sufficient-statistics hook: consumers
    like ``repro.learn.stats.sweep_stats`` fold each shard into compact
    mergeable accumulators, so 1e6–1e7-point training sweeps stay
    memory-bounded without gathering a grid.  Empty shards skip both
    hooks' grid work (the summary hook still fires).

    ``device_parallel=True`` evaluates each owned shard SPMD over the
    local jax ``devices`` (defaults to all of them) via the jitted
    engine's kernels; otherwise shards run through the engine named by
    ``backend`` / passed as ``engine``.

    ``overlap_dispatch=True`` double-buffers shards on engines exposing
    a two-phase ``dispatch()`` (the ``"mixed"`` engine): shard ``k+1``
    is dispatched asynchronously before shard ``k`` finalizes, the same
    overlap discipline ``ficco_ag_matmul`` applies to DMA egress.
    Per-shard ``seconds`` then overlap wall-clock.  Engines without
    ``dispatch`` fall back to eager evaluation — results are identical
    either way (summary order and all hook orderings are preserved),
    and the flag defaults off so every pre-existing path keeps its
    bit-identity contract trivially.  Ignored under ``device_parallel``.
    """
    if mode not in ("gather", "reduce"):
        raise ValueError(f"mode must be 'gather'|'reduce', got {mode!r}")
    if not 0 <= host_index < host_count:
        raise ValueError(
            f"host_index {host_index} outside [0, {host_count})"
        )
    sb = _coerce_batch(scenarios)
    machines = tuple(machines)
    schedules = (
        GRID_SCHEDULES if schedules is None else tuple(schedules)
    )
    if device_parallel:
        import jax

        if devices is None:
            devices = jax.local_devices()
        eval_shard = lambda piece: _device_sharded_grid(  # noqa: E731
            piece, machines, dma=dma, dma_into_place=dma_into_place,
            schedules=schedules, devices=devices,
        )
    else:
        eng = engine if engine is not None else get_engine(backend)
        eval_shard = lambda piece: eng.evaluate(  # noqa: E731
            piece, machines, dma=dma, dma_into_place=dma_into_place,
            schedules=schedules,
        )

    dispatch_shard = (
        None if device_parallel else getattr(eng, "dispatch", None)
    )
    two_phase = overlap_dispatch and dispatch_shard is not None

    plan = plan_shards(
        len(sb), num_shards if num_shards is not None else host_count
    )
    owned = shards_for_host(plan, host_index, host_count)
    summaries: list[ShardSummary] = []
    parts: list[GridResult] = []

    reg = _metrics.get_metrics()

    def _complete(entry):
        shard, start, stop, t0, finalize = entry
        # Under two-phase dispatch this span is where the dispatched
        # work blocks — in a trace, shard k+1's sweep/dispatch span
        # appears *before* shard k's sweep/compute closes, making the
        # double-buffered overlap directly visible in Perfetto.
        with _trace.span("sweep/compute", "sweep", shard=shard):
            grid = finalize()
        dt = time.perf_counter() - t0
        summ = summarize_shard(grid, shard, start, stop, dt)
        reg.counter("sweep/shards").inc()
        reg.counter("sweep/scenarios").inc(summ.n_scenarios)
        reg.histogram("sweep/shard_seconds").observe(dt)
        with _trace.span(
            "sweep/reduce", "sweep", shard=shard,
            n_scenarios=summ.n_scenarios, seconds=dt,
        ):
            if on_shard_grid is not None:
                on_shard_grid(grid, summ)
            if mode == "gather":
                parts.append(grid)
            summaries.append(summ)
            if on_shard is not None:
                on_shard(summ)

    pending = None
    with _trace.span(
        "sweep/run", "sweep", mode=mode, n_owned=len(owned),
        n_scenarios=len(sb), two_phase=two_phase,
        host_index=host_index, host_count=host_count,
    ):
        for shard in owned:
            start, stop = plan.bounds[shard]
            if start == stop:  # degenerate empty shard (more shards than S)
                if pending is not None:  # keep summaries in shard order
                    _complete(pending)
                    pending = None
                summ = ShardSummary(
                    shard, start, stop, 0, 0, 0.0, 0.0, {}, 0.0, 0.0
                )
                summaries.append(summ)
                if on_shard is not None:
                    on_shard(summ)
                continue
            piece = _slice_batch(sb, start, stop)
            t0 = time.perf_counter()
            with _trace.span(
                "sweep/dispatch", "sweep", shard=shard,
                start=start, stop=stop, two_phase=two_phase,
            ):
                if two_phase:
                    finalize = dispatch_shard(
                        piece, machines, dma=dma,
                        dma_into_place=dma_into_place,
                        schedules=schedules,
                    )
                else:
                    grid_now = eval_shard(piece)
                    finalize = lambda g=grid_now: g  # noqa: E731
            entry = (shard, start, stop, t0, finalize)
            if pending is not None:
                _complete(pending)
                pending = None
            if two_phase:
                pending = entry  # shard k+1 dispatches before k finalizes
            else:
                _complete(entry)
        if pending is not None:
            _complete(pending)
    grid = None
    if mode == "gather":
        if parts:
            grid = concat_grid_results(parts)
        else:
            # Every owned shard was empty (or the batch itself is):
            # honor the gather contract with a 0-scenario GridResult
            # rather than None.  The NumPy engine handles S == 0 and
            # any engine agrees on an empty lane set.
            grid = get_engine("numpy").evaluate(
                _slice_batch(sb, 0, 0), machines,
                dma=dma, dma_into_place=dma_into_place,
                schedules=schedules,
            )
    return SweepResult(
        plan=plan,
        mode=mode,
        host_index=host_index,
        host_count=host_count,
        owned=owned,
        summaries=tuple(summaries),
        grid=grid,
    )


__all__ = [
    "ShardSummary",
    "SweepResult",
    "concat_batches",
    "concat_grid_results",
    "merge_summaries",
    "shard_batch",
    "summarize_shard",
    "sweep_grid",
]
