"""Synthetic scenario batches at sweep scale (1e6-1e7 lanes).

``workload.scenario_grid`` enumerates the registry architectures (~720
scenarios); the sweep subsystem wants millions.  These constructors
build :class:`~repro.core.batch.ScenarioBatch` / ``RaggedBatch``
struct-of-arrays *directly* — four int64 arrays (plus one float matrix
for ragged) — so a 1e7-lane batch costs ~300 MB of array memory and no
Python-object churn.

Everything is seeded and vectorized: the same ``(n, seed)`` reproduces
the same batch on every host, which is what lets multi-host sweeps
regenerate their owned shard locally instead of broadcasting operands.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.batch import RaggedBatch, ScenarioBatch
from repro.core.workload import GemmShape, StepProfile

# M is drawn in multiples of this, so every group size up to 32
# decomposes evenly (matching workload.scenario_grid's convention); the
# engines mask indivisible combinations anyway.
_M_QUANTUM = 1024


def synthetic_batch(
    n: int,
    *,
    seed: int = 0,
    dtype_bytes: tuple[int, ...] = (2, 1),
) -> ScenarioBatch:
    """n log-uniform GEMM scenarios, deterministic in ``seed``.

    Shapes span the paper's regime: M in [1k, 2M] token rows (multiples
    of 1024), N/K in [1k, 64k] model dims (multiples of 128).
    """
    rng = np.random.default_rng(seed)
    m = _M_QUANTUM * np.exp(
        rng.uniform(np.log(1), np.log(2048), n)
    ).astype(np.int64)
    n_dim = 128 * np.exp(rng.uniform(np.log(8), np.log(512), n)).astype(
        np.int64
    )
    k_dim = 128 * np.exp(rng.uniform(np.log(8), np.log(512), n)).astype(
        np.int64
    )
    b = rng.choice(np.asarray(dtype_bytes, dtype=np.int64), size=n)
    return ScenarioBatch(m=m, n=n_dim, k=k_dim, dtype_bytes=b)


def synthetic_ragged_batch(
    n: int,
    *,
    steps: int = 8,
    seed: int = 0,
    dtype_bytes: tuple[int, ...] = (2, 1),
    concentration: float = 0.7,
) -> RaggedBatch:
    """n ragged scenarios with Dirichlet step profiles (skewed EP-like).

    ``concentration < 1`` produces hot-expert skew; rows renormalize to
    sum to 1 exactly, and a random tail of steps is zeroed on ~25% of
    rows to model masked/empty dispatch steps (mixed profile lengths).
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    sb = synthetic_batch(n, seed=seed, dtype_bytes=dtype_bytes)
    rng = np.random.default_rng(seed + 1)
    frac = rng.dirichlet(np.full(steps, concentration), size=n)
    if steps > 1:
        # Mask a tail on a quarter of the rows: profiles shorter than
        # ``steps`` (a 1-step profile is already the degenerate [1.0]).
        short = rng.random(n) < 0.25
        tail = rng.integers(1, steps, size=n)
        cols = np.arange(steps)[None, :]
        frac = np.where(
            short[:, None] & (cols >= tail[:, None]), 0.0, frac
        )
    frac /= frac.sum(axis=1, keepdims=True)
    return RaggedBatch(
        m=sb.m, n=sb.n, k=sb.k, dtype_bytes=sb.dtype_bytes, frac=frac
    )


# ---------------------------------------------------------------------------
# Drifting-skew serving traffic (ROADMAP item 1 / repro.serve.adapt).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One schedule-selection request of the synthetic serving stream."""

    gemm: GemmShape
    profile: StepProfile
    phase: int
    index: int


def drifting_request_stream(
    n: int,
    *,
    steps: int = 8,
    seed: int = 0,
    drift_every: int = 2000,
    n_shapes: int = 6,
    n_profiles: int = 8,
    concentration: float = 0.5,
    hot_boost: float = 8.0,
    quantum: int = 64,
) -> Iterator[ServeRequest]:
    """Seeded drifting-skew request stream for the adaptive serving tier.

    Serving traffic has a *small* working set at any moment — a few hot
    GEMM shapes and a family of expert-load profiles — that **drifts**:
    every ``drift_every`` requests the Dirichlet family's hot step
    rotates (phase ``p`` boosts step ``p % steps`` by ``hot_boost``)
    and the per-phase profile pool is redrawn, so cached decisions and
    the deployed gate go stale together.  Profiles are quantized to
    ``quantum``-ths (the same largest-remainder rounding the kernel
    layer applies), so digests repeat exactly within a phase — which is
    what makes a bounded decision cache effective between drift steps.

    Deterministic in ``seed``: the same ``(n, seed, ...)`` always
    yields the same stream, so benchmark runs are comparable.
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if drift_every < 1:
        raise ValueError(f"drift_every must be >= 1, got {drift_every}")
    sb = synthetic_batch(n_shapes, seed=seed)
    shapes = [
        GemmShape(int(sb.m[i]), int(sb.n[i]), int(sb.k[i]),
                  int(sb.dtype_bytes[i]))
        for i in range(n_shapes)
    ]
    phase = -1
    pool: list[StepProfile] = []
    pick_rng = np.random.default_rng(seed + 2)
    for i in range(n):
        p = i // drift_every
        if p != phase:
            phase = p
            # Per-phase profile family: hot step rotates with the phase.
            alpha = np.full(steps, concentration)
            alpha[phase % steps] *= hot_boost
            prng = np.random.default_rng((seed, phase))
            pool = []
            for j in range(n_profiles):
                frac = prng.dirichlet(alpha)
                raw = StepProfile.from_weights(
                    frac, name=f"drift{phase}.{j}"
                )
                counts = raw.quantize(quantum)
                if sum(counts) != quantum or not any(counts):
                    counts = (quantum,) + (0,) * (steps - 1)
                pool.append(
                    StepProfile(
                        tuple(c / quantum for c in counts),
                        name=f"drift{phase}.{j}",
                    )
                )
        yield ServeRequest(
            gemm=shapes[int(pick_rng.integers(n_shapes))],
            profile=pool[int(pick_rng.integers(len(pool)))],
            phase=phase,
            index=i,
        )


__all__ = [
    "synthetic_batch",
    "synthetic_ragged_batch",
    "ServeRequest",
    "drifting_request_stream",
]
