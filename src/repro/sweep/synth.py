"""Synthetic scenario batches at sweep scale (1e6-1e7 lanes).

``workload.scenario_grid`` enumerates the registry architectures (~720
scenarios); the sweep subsystem wants millions.  These constructors
build :class:`~repro.core.batch.ScenarioBatch` / ``RaggedBatch``
struct-of-arrays *directly* — four int64 arrays (plus one float matrix
for ragged) — so a 1e7-lane batch costs ~300 MB of array memory and no
Python-object churn.

Everything is seeded and vectorized: the same ``(n, seed)`` reproduces
the same batch on every host, which is what lets multi-host sweeps
regenerate their owned shard locally instead of broadcasting operands.
"""

from __future__ import annotations

import numpy as np

from repro.core.batch import RaggedBatch, ScenarioBatch

# M is drawn in multiples of this, so every group size up to 32
# decomposes evenly (matching workload.scenario_grid's convention); the
# engines mask indivisible combinations anyway.
_M_QUANTUM = 1024


def synthetic_batch(
    n: int,
    *,
    seed: int = 0,
    dtype_bytes: tuple[int, ...] = (2, 1),
) -> ScenarioBatch:
    """n log-uniform GEMM scenarios, deterministic in ``seed``.

    Shapes span the paper's regime: M in [1k, 2M] token rows (multiples
    of 1024), N/K in [1k, 64k] model dims (multiples of 128).
    """
    rng = np.random.default_rng(seed)
    m = _M_QUANTUM * np.exp(
        rng.uniform(np.log(1), np.log(2048), n)
    ).astype(np.int64)
    n_dim = 128 * np.exp(rng.uniform(np.log(8), np.log(512), n)).astype(
        np.int64
    )
    k_dim = 128 * np.exp(rng.uniform(np.log(8), np.log(512), n)).astype(
        np.int64
    )
    b = rng.choice(np.asarray(dtype_bytes, dtype=np.int64), size=n)
    return ScenarioBatch(m=m, n=n_dim, k=k_dim, dtype_bytes=b)


def synthetic_ragged_batch(
    n: int,
    *,
    steps: int = 8,
    seed: int = 0,
    dtype_bytes: tuple[int, ...] = (2, 1),
    concentration: float = 0.7,
) -> RaggedBatch:
    """n ragged scenarios with Dirichlet step profiles (skewed EP-like).

    ``concentration < 1`` produces hot-expert skew; rows renormalize to
    sum to 1 exactly, and a random tail of steps is zeroed on ~25% of
    rows to model masked/empty dispatch steps (mixed profile lengths).
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    sb = synthetic_batch(n, seed=seed, dtype_bytes=dtype_bytes)
    rng = np.random.default_rng(seed + 1)
    frac = rng.dirichlet(np.full(steps, concentration), size=n)
    if steps > 1:
        # Mask a tail on a quarter of the rows: profiles shorter than
        # ``steps`` (a 1-step profile is already the degenerate [1.0]).
        short = rng.random(n) < 0.25
        tail = rng.integers(1, steps, size=n)
        cols = np.arange(steps)[None, :]
        frac = np.where(
            short[:, None] & (cols >= tail[:, None]), 0.0, frac
        )
    frac /= frac.sum(axis=1, keepdims=True)
    return RaggedBatch(
        m=sb.m, n=sb.n, k=sb.k, dtype_bytes=sb.dtype_bytes, frac=frac
    )


__all__ = ["synthetic_batch", "synthetic_ragged_batch"]
