"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed into a low-rank latent ``c_kv`` of
``kv_lora_rank`` dims plus a single shared RoPE key head; the decode cache
stores only (c_kv, k_rope) — the architecture's whole point — and
up-projects per step.  Training/prefill materializes per-head K/V from the
latent (mathematically identical).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MLAConfig
from repro.models import layers
from repro.models.layers import apply_rope, blockwise_attention, cache_attention
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, constrain


def mla_init(rng, d_model: int, num_heads: int, cfg: MLAConfig, dtype):
    r = jax.random.split(rng, 6)
    qk_head = cfg.nope_head_dim + cfg.rope_head_dim
    return {
        # Q: full-rank (V2-Lite has no Q compression)
        "wq": layers.dense_init(r[0], d_model, num_heads * qk_head, dtype),
        # KV latent down-projection + shared rope key
        "w_dkv": layers.dense_init(r[1], d_model, cfg.kv_lora_rank, dtype),
        "w_kr": layers.dense_init(r[2], d_model, cfg.rope_head_dim, dtype),
        # latent -> per-head K(nope), V
        "w_uk": layers.dense_init(
            r[3], cfg.kv_lora_rank, num_heads * cfg.nope_head_dim, dtype
        ),
        "w_uv": layers.dense_init(
            r[4], cfg.kv_lora_rank, num_heads * cfg.v_head_dim, dtype
        ),
        "wo": layers.dense_init(
            r[5], num_heads * cfg.v_head_dim, d_model, dtype
        ),
    }


def mla_param_specs():
    return {
        "wq": P(None, MODEL_AXIS),
        "w_dkv": P(None, None),
        "w_kr": P(None, None),
        "w_uk": P(None, MODEL_AXIS),
        "w_uv": P(None, MODEL_AXIS),
        "wo": P(MODEL_AXIS, None),
    }


def _project(params, x, num_heads: int, cfg: MLAConfig, positions):
    b, s, _ = x.shape
    qk_head = cfg.nope_head_dim + cfg.rope_head_dim
    q = (x @ params["wq"]).reshape(b, s, num_heads, qk_head)
    q_nope, q_rope = jnp.split(q, [cfg.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, 10000.0)
    q = jnp.concatenate([q_nope, q_rope], -1)

    c_kv = x @ params["w_dkv"]  # (B, S, r)
    k_rope = apply_rope(
        (x @ params["w_kr"]).reshape(b, s, 1, cfg.rope_head_dim),
        positions,
        10000.0,
    )
    return q, c_kv, k_rope


def _expand_kv(params, c_kv, k_rope, num_heads: int, cfg: MLAConfig):
    b, s, _ = c_kv.shape
    k_nope = (c_kv @ params["w_uk"]).reshape(
        b, s, num_heads, cfg.nope_head_dim
    )
    v = (c_kv @ params["w_uv"]).reshape(b, s, num_heads, cfg.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, num_heads, cfg.rope_head_dim))],
        -1,
    )
    return k, v


def mla_apply(
    params,
    x: jax.Array,
    num_heads: int,
    cfg: MLAConfig,
    *,
    positions,
    window: Optional[int] = None,
) -> jax.Array:
    b, s, _ = x.shape
    q, c_kv, k_rope = _project(params, x, num_heads, cfg, positions)
    k, v = _expand_kv(params, c_kv, k_rope, num_heads, cfg)
    q = constrain(q, BATCH_AXES, None, MODEL_AXIS, None)
    k = constrain(k, BATCH_AXES, None, MODEL_AXIS, None)
    out = blockwise_attention(q, k, v, causal=True, window=window)
    y = out.reshape(b, s, num_heads * cfg.v_head_dim) @ params["wo"]
    return constrain(y, BATCH_AXES, None, None)


def mla_init_cache(batch: int, seq: int, cfg: MLAConfig, dtype):
    """The MLA cache: latent + shared rope key only (its memory win)."""
    return {
        "c_kv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq, 1, cfg.rope_head_dim), dtype),
    }


def mla_decode(
    params,
    x: jax.Array,
    cache: dict,
    pos,
    num_heads: int,
    cfg: MLAConfig,
):
    b = x.shape[0]
    posv = jnp.full((b, 1), pos)
    q, c_kv_new, k_rope_new = _project(params, x, num_heads, cfg, posv)
    c_kv = lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0)
    )
    k_rope = lax.dynamic_update_slice(
        cache["k_rope"],
        k_rope_new.astype(cache["k_rope"].dtype),
        (0, pos, 0, 0),
    )
    # Up-project the whole latent cache for this step's attention (the
    # recompute trade MLA makes for its 1/~10x cache size).
    k, v = _expand_kv(params, c_kv, k_rope, num_heads, cfg)
    out = cache_attention(q, k, v, valid_len=pos + 1)
    y = out.reshape(b, 1, num_heads * cfg.v_head_dim) @ params["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope}
