"""xLSTM blocks: mLSTM (matrix memory) + sLSTM (scalar memory).

arXiv:2405.04517.  xlstm-1.3b interleaves mLSTM and sLSTM blocks (7:1 here
per the assigned config); d_ff = 0 — each block carries its own up/down
projection (proj_factor).  Both recurrences are attention-free with O(1)
decode state, so the long_500k shape is native (DESIGN.md §5); they contain
no data-dependent collective, hence FiCCO applies only to their in/out
projections (§Arch-applicability).

mLSTM uses stabilized exponential gating with a per-head running maximum
``m`` (Appendix A of the paper); we scan over time carrying (C, n, m) —
exact, O(1) memory; the chunkwise-parallel form is a production alternative.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import XLSTMConfig
from repro.models import layers
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, constrain


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(rng, d_model: int, num_heads: int, cfg: XLSTMConfig, dtype):
    d_inner = int(cfg.proj_factor * d_model)
    hd = d_inner // num_heads
    r = jax.random.split(rng, 7)
    return {
        "w_up": layers.dense_init(r[0], d_model, 2 * d_inner, dtype),
        "wq": layers.dense_init(r[1], d_inner, d_inner, dtype),
        "wk": layers.dense_init(r[2], d_inner, d_inner, dtype),
        "wv": layers.dense_init(r[3], d_inner, d_inner, dtype),
        "w_if": layers.dense_init(r[4], d_inner, 2 * num_heads, jnp.float32),
        "w_out": layers.dense_init(r[5], d_inner, d_model, dtype),
        "skip_scale": jnp.ones((d_inner,), dtype),
    }


def mlstm_param_specs():
    return {
        "w_up": P(None, MODEL_AXIS),
        "wq": P(None, MODEL_AXIS),
        "wk": P(None, MODEL_AXIS),
        "wv": P(None, MODEL_AXIS),
        "w_if": P(None, None),
        "w_out": P(MODEL_AXIS, None),
        "skip_scale": P(MODEL_AXIS),
    }


def _mlstm_gates(params, u, num_heads):
    gates = (u @ params["w_if"]).astype(jnp.float32)  # (B,S,2H)
    log_i, log_f = jnp.split(gates, 2, axis=-1)
    log_f = -jax.nn.softplus(-log_f)  # log sigmoid(f)
    return log_i, log_f


def mlstm_apply(params, x: jax.Array, num_heads: int, cfg: XLSTMConfig):
    b, s, d_model = x.shape
    d_inner = int(cfg.proj_factor * d_model)
    hd = d_inner // num_heads
    uz = x @ params["w_up"]
    u, z = jnp.split(uz, 2, axis=-1)
    u = constrain(u, BATCH_AXES, None, MODEL_AXIS)
    q = (u @ params["wq"]).reshape(b, s, num_heads, hd)
    k = (u @ params["wk"]).reshape(b, s, num_heads, hd) / jnp.sqrt(
        jnp.asarray(hd, x.dtype)
    )
    v = (u @ params["wv"]).reshape(b, s, num_heads, hd)
    log_i, log_f = _mlstm_gates(params, u, num_heads)  # (B,S,H)

    def step(carry, inputs):
        c, n, m = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        q_t, k_t, v_t, li_t, lf_t = inputs
        m_new = jnp.maximum(lf_t + m, li_t)
        i_g = jnp.exp(li_t - m_new)  # (B,H)
        f_g = jnp.exp(lf_t + m - m_new)
        c = (
            f_g[..., None, None] * c
            + i_g[..., None, None]
            * (k_t[..., :, None] * v_t[..., None, :]).astype(jnp.float32)
        )
        n = f_g[..., None] * n + i_g[..., None] * k_t.astype(jnp.float32)
        num = jnp.einsum("bhd,bhde->bhe", q_t.astype(jnp.float32), c)
        den = jnp.abs(
            jnp.einsum("bhd,bhd->bh", q_t.astype(jnp.float32), n)
        )
        h_t = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c, n, m_new), h_t

    c0 = jnp.zeros((b, num_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, num_heads, hd), jnp.float32)
    m0 = jnp.full((b, num_heads), -1e30, jnp.float32)
    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    _, hs = lax.scan(step, (c0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d_inner).astype(x.dtype)
    h = h + u * params["skip_scale"][None, None, :]
    out = (h * jax.nn.silu(z)) @ params["w_out"]
    return constrain(out, BATCH_AXES, None, None)


def mlstm_init_cache(batch, d_model, num_heads, cfg: XLSTMConfig):
    d_inner = int(cfg.proj_factor * d_model)
    hd = d_inner // num_heads
    return {
        "c": jnp.zeros((batch, num_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, num_heads, hd), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }


def mlstm_decode(params, x, cache, num_heads: int, cfg: XLSTMConfig):
    b, one, d_model = x.shape
    d_inner = int(cfg.proj_factor * d_model)
    hd = d_inner // num_heads
    uz = x @ params["w_up"]
    u, z = jnp.split(uz, 2, axis=-1)
    q = (u @ params["wq"]).reshape(b, num_heads, hd)
    k = (u @ params["wk"]).reshape(b, num_heads, hd) / jnp.sqrt(
        jnp.asarray(hd, x.dtype)
    )
    v = (u @ params["wv"]).reshape(b, num_heads, hd)
    log_i, log_f = _mlstm_gates(params, u, num_heads)
    li_t, lf_t = log_i[:, 0], log_f[:, 0]
    c, n, m = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf_t + m, li_t)
    i_g, f_g = jnp.exp(li_t - m_new), jnp.exp(lf_t + m - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    ).astype(jnp.float32)
    n = f_g[..., None] * n + i_g[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), c)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n))
    h = (num / jnp.maximum(den, jnp.exp(-m_new))[..., None]).astype(x.dtype)
    h = h.reshape(b, 1, d_inner) + u * params["skip_scale"][None, None, :]
    out = (h * jax.nn.silu(z)) @ params["w_out"]
    return out, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(rng, d_model: int, cfg: XLSTMConfig, dtype):
    d_inner = int(cfg.proj_factor * d_model)
    r = jax.random.split(rng, 4)
    return {
        "w_up": layers.dense_init(r[0], d_model, d_inner, dtype),
        "w_gates": layers.dense_init(r[1], d_inner, 4 * d_inner, jnp.float32),
        "r_gates": (
            jax.random.normal(r[2], (d_inner, 4 * d_inner)) * 0.02
        ).astype(jnp.float32),
        "w_out": layers.dense_init(r[3], d_inner, d_model, dtype),
    }


def slstm_param_specs():
    return {
        "w_up": P(None, MODEL_AXIS),
        "w_gates": P(MODEL_AXIS, None),
        "r_gates": P(None, None),
        "w_out": P(MODEL_AXIS, None),
    }


def _slstm_cell(params, u_t, state):
    """One sLSTM step with stabilized exponential gating."""
    c, n, h, m = state  # all (B, D) fp32
    pre = (
        u_t.astype(jnp.float32) @ params["w_gates"] + h @ params["r_gates"]
    )
    z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)
    log_f = -jax.nn.softplus(-f_p)
    m_new = jnp.maximum(log_f + m, i_p)
    i_g = jnp.exp(i_p - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    z_g = jnp.tanh(z_p)
    o_g = jax.nn.sigmoid(o_p)
    c = f_g * c + i_g * z_g
    n = f_g * n + i_g
    h = o_g * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new), h


def slstm_apply(params, x: jax.Array, cfg: XLSTMConfig) -> jax.Array:
    b, s, d_model = x.shape
    d_inner = int(cfg.proj_factor * d_model)
    u = x @ params["w_up"]
    u = constrain(u, BATCH_AXES, None, MODEL_AXIS)

    def step(state, u_t):
        return _slstm_cell(params, u_t, state)

    zeros = jnp.zeros((b, d_inner), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((b, d_inner), -1e30))
    _, hs = lax.scan(step, state0, u.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    out = h @ params["w_out"]
    return constrain(out, BATCH_AXES, None, None)


def slstm_init_cache(batch, d_model, cfg: XLSTMConfig):
    d_inner = int(cfg.proj_factor * d_model)
    zeros = jnp.zeros((batch, d_inner), jnp.float32)
    return {
        "c": zeros, "n": zeros, "h": zeros,
        "m": jnp.full((batch, d_inner), -1e30, jnp.float32),
    }


def slstm_decode(params, x, cache, cfg: XLSTMConfig):
    u = x @ params["w_up"]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    state, h = _slstm_cell(params, u[:, 0], state)
    out = h[:, None, :].astype(x.dtype) @ params["w_out"]
    return out, {
        "c": state[0], "n": state[1], "h": state[2], "m": state[3]
    }
