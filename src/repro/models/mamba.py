"""Mamba selective SSM block (Jamba's sequence mixer, arXiv:2403.19887).

The recurrence h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t is evaluated
with ``lax.scan`` over time (O(1) memory per step; compiles to one while
loop).  A chunked associative-scan variant is the production alternative;
the recurrence is the part of Jamba FiCCO does *not* apply to (no
data-dependent collective — DESIGN.md §5), so we keep it simple and exact.

Decode carries (conv window, ssm state): O(1) per token — why long_500k is
native for the Mamba layers.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MambaConfig
from repro.models import layers
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, constrain


def mamba_dims(d_model: int, cfg: MambaConfig):
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, math.ceil(d_model / 16))
    return d_inner, dt_rank


def mamba_init(rng, d_model: int, cfg: MambaConfig, dtype):
    d_inner, dt_rank = mamba_dims(d_model, cfg)
    r = jax.random.split(rng, 6)
    a = jnp.broadcast_to(
        jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32),
        (d_inner, cfg.d_state),
    )
    return {
        "w_in": layers.dense_init(r[0], d_model, 2 * d_inner, dtype),
        "conv_w": (
            jax.random.normal(r[1], (cfg.d_conv, d_inner)) * 0.1
        ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_x": layers.dense_init(
            r[2], d_inner, dt_rank + 2 * cfg.d_state, dtype
        ),
        "w_dt": layers.dense_init(r[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "a_log": jnp.log(a),  # fp32
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "w_out": layers.dense_init(r[4], d_inner, d_model, dtype),
    }


def mamba_param_specs():
    return {
        "w_in": P(None, MODEL_AXIS),
        "conv_w": P(None, MODEL_AXIS),
        "conv_b": P(MODEL_AXIS),
        "w_x": P(MODEL_AXIS, None),
        "w_dt": P(None, MODEL_AXIS),
        "dt_bias": P(MODEL_AXIS),
        "a_log": P(MODEL_AXIS, None),
        "d_skip": P(MODEL_AXIS),
        "w_out": P(MODEL_AXIS, None),
    }


def _causal_conv(x, conv_w, conv_b, state=None):
    """Depthwise causal conv. x: (B, S, D); conv_w: (K, D)."""
    k = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, D)
    out = sum(
        xp[:, i : i + x.shape[1], :] * conv_w[i][None, None, :]
        for i in range(k)
    )
    new_state = xp[:, -(k - 1) :, :]
    return out + conv_b[None, None, :], new_state


def _ssm_params(params, u, cfg: MambaConfig, dt_rank: int):
    proj = u @ params["w_x"]  # (B, S, dt_rank + 2*N)
    dt_low, b_mat, c_mat = jnp.split(
        proj, [dt_rank, dt_rank + cfg.d_state], axis=-1
    )
    dt = jax.nn.softplus(
        dt_low @ params["w_dt"] + params["dt_bias"][None, None, :]
    ).astype(jnp.float32)  # (B, S, D)
    a = -jnp.exp(params["a_log"])  # (D, N)
    return dt, a, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32)


def mamba_apply(params, x: jax.Array, cfg: MambaConfig) -> jax.Array:
    """x: (B, S, d_model) -> (B, S, d_model)."""
    d_inner, dt_rank = mamba_dims(x.shape[-1], cfg)
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)  # (B, S, D)
    u = constrain(u, BATCH_AXES, None, MODEL_AXIS)
    u, _ = _causal_conv(u, params["conv_w"], params["conv_b"])
    u = jax.nn.silu(u)
    dt, a, b_mat, c_mat = _ssm_params(params, u, cfg, dt_rank)

    uf = u.astype(jnp.float32)

    def step(h, inputs):
        u_t, dt_t, b_t, c_t = inputs  # (B,D),(B,D),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a[None])  # (B, D, N)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((x.shape[0], d_inner, cfg.d_state), jnp.float32)
    xs = (
        uf.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
        b_mat.transpose(1, 0, 2),
        c_mat.transpose(1, 0, 2),
    )
    _, ys = lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + uf * params["d_skip"][None, None, :]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"]
    return constrain(out, BATCH_AXES, None, None)


def mamba_init_cache(batch: int, d_model: int, cfg: MambaConfig, dtype):
    d_inner, _ = mamba_dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32),
    }


def mamba_decode(params, x: jax.Array, cache: dict, cfg: MambaConfig):
    """x: (B, 1, d_model); O(1) state update."""
    d_inner, dt_rank = mamba_dims(x.shape[-1], cfg)
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _causal_conv(
        u, params["conv_w"], params["conv_b"], state=cache["conv"]
    )
    u = jax.nn.silu(u)
    dt, a, b_mat, c_mat = _ssm_params(params, u, cfg, dt_rank)
    u_t, dt_t = u[:, 0].astype(jnp.float32), dt[:, 0]
    b_t, c_t = b_mat[:, 0], c_mat[:, 0]
    da = jnp.exp(dt_t[..., None] * a[None])
    h = da * cache["h"] + (dt_t * u_t)[..., None] * b_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t) + u_t * params["d_skip"][None, :]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out, {"conv": conv_state, "h": h}
