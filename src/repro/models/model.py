"""Model assembly: all six architecture families behind one interface.

A model is a stack of **periods**: the smallest repeating layer pattern
(dense: 1 layer; Jamba: 8 layers = 1 attention + 7 Mamba with MoE on odd
layers; xLSTM: 8 = 7 mLSTM + 1 sLSTM; ...).  Periods are scanned with
``lax.scan`` over stacked parameters so 80-layer models compile fast at
512-way SPMD, and each period is optionally rematerialized.

Interface (used by train/serve/launch):
    model = build_model(config)
    params        = model.init(rng)
    specs         = model.param_specs()          # PartitionSpec pytree
    logits, aux   = model.forward(params, batch)
    cache         = model.init_cache(batch, cache_len)
    logits, cache = model.decode_step(params, cache, tokens, pos)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import Family, ModelConfig
from repro.models import layers, mamba, mla, moe, xlstm
from repro.models.layers import AttnDims
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, constrain


# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # attn | mla | mamba | mlstm | slstm
    ffn: str  # mlp | moe | none


def layer_pattern(cfg: ModelConfig) -> list[LayerSpec]:
    """The repeating period of layer kinds for this architecture."""
    if cfg.family is Family.SSM:
        x = cfg.xlstm
        period = x.slstm_every
        return [
            LayerSpec(
                "slstm" if i % x.slstm_every == x.slstm_offset else "mlstm",
                "none",
            )
            for i in range(period)
        ]
    if cfg.family is Family.HYBRID:
        h = cfg.hybrid
        period = h.attn_every
        out = []
        for i in range(period):
            mixer = "attn" if i % h.attn_every == h.attn_offset else "mamba"
            ffn = (
                "moe"
                if cfg.moe and i % cfg.moe.every_k_layers
                == cfg.moe.every_k_layers - 1
                else "mlp"
            )
            out.append(LayerSpec(mixer, ffn))
        return out
    mixer = "mla" if cfg.mla else "attn"
    if cfg.moe:
        period = cfg.moe.every_k_layers
        return [
            LayerSpec(mixer, "moe" if i == period - 1 else "mlp")
            for i in range(period)
        ]
    return [LayerSpec(mixer, "mlp")]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _attn_dims(cfg: ModelConfig) -> AttnDims:
    return AttnDims(
        cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    )


# ---------------------------------------------------------------------------
# Single-layer init / apply / decode
# ---------------------------------------------------------------------------

def _layer_init(rng, spec: LayerSpec, cfg: ModelConfig, *, cross: bool):
    dt = _dtype(cfg)
    r = jax.random.split(rng, 8)
    p: dict[str, Any] = {"norm1": layers.norm_init(cfg.d_model, cfg.norm, dt)}
    if spec.mixer == "attn":
        p["attn"] = layers.attn_init(r[0], _attn_dims(cfg), dt)
    elif spec.mixer == "mla":
        p["attn"] = mla.mla_init(r[0], cfg.d_model, cfg.num_heads, cfg.mla, dt)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba.mamba_init(r[0], cfg.d_model, cfg.hybrid.mamba, dt)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.mlstm_init(
            r[0], cfg.d_model, cfg.num_heads, cfg.xlstm, dt
        )
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.slstm_init(r[0], cfg.d_model, cfg.xlstm, dt)
    if cross:
        p["norm_cross"] = layers.norm_init(cfg.d_model, cfg.norm, dt)
        p["cross"] = layers.attn_init(r[1], _attn_dims(cfg), dt)
    if spec.ffn != "none":
        p["norm2"] = layers.norm_init(cfg.d_model, cfg.norm, dt)
        if spec.ffn == "moe":
            p["ffn"] = moe.moe_init(r[2], cfg.d_model, cfg.moe, dt)
        else:
            p["ffn"] = layers.mlp_init(r[2], cfg.d_model, cfg.d_ff, dt)
    return p


def _layer_specs(spec: LayerSpec, cfg: ModelConfig, *, cross: bool):
    s: dict[str, Any] = {"norm1": _norm_spec(cfg)}
    if spec.mixer == "attn":
        s["attn"] = layers.attn_param_specs()
    elif spec.mixer == "mla":
        s["attn"] = mla.mla_param_specs()
    elif spec.mixer == "mamba":
        s["mixer"] = mamba.mamba_param_specs()
    elif spec.mixer == "mlstm":
        s["mixer"] = xlstm.mlstm_param_specs()
    elif spec.mixer == "slstm":
        s["mixer"] = xlstm.slstm_param_specs()
    if cross:
        s["norm_cross"] = _norm_spec(cfg)
        s["cross"] = layers.attn_param_specs()
    if spec.ffn != "none":
        s["norm2"] = _norm_spec(cfg)
        s["ffn"] = (
            moe.moe_param_specs(cfg.moe)
            if spec.ffn == "moe"
            else layers.mlp_param_specs()
        )
    return s


def _norm_spec(cfg: ModelConfig):
    if cfg.norm == "rmsnorm":
        return {"scale": P(None)}
    if cfg.norm == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {}


def _window(cfg: ModelConfig) -> Optional[int]:
    if cfg.sliding_window:
        return cfg.sliding_window
    return None


def _layer_apply(
    p,
    spec: LayerSpec,
    cfg: ModelConfig,
    x,
    positions,
    *,
    enc_out=None,
    causal: bool = True,
):
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        if causal:
            y = layers.attn_apply(
                p["attn"], h, _attn_dims(cfg),
                rope_theta=cfg.rope_theta, positions=positions,
                window=_window(cfg),
            )
        else:  # encoder self-attention: bidirectional
            dims = _attn_dims(cfg)
            b, s, _ = h.shape
            q = (h @ p["attn"]["wq"]).reshape(b, s, dims.num_heads,
                                              dims.head_dim)
            k = (h @ p["attn"]["wk"]).reshape(b, s, dims.num_kv_heads,
                                              dims.head_dim)
            v = (h @ p["attn"]["wv"]).reshape(b, s, dims.num_kv_heads,
                                              dims.head_dim)
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            o = layers.blockwise_attention(q, k, v, causal=False)
            y = o.reshape(b, s, -1) @ p["attn"]["wo"]
    elif spec.mixer == "mla":
        y = mla.mla_apply(
            p["attn"], h, cfg.num_heads, cfg.mla,
            positions=positions, window=_window(cfg),
        )
    elif spec.mixer == "mamba":
        y = mamba.mamba_apply(p["mixer"], h, cfg.hybrid.mamba)
    elif spec.mixer == "mlstm":
        y = xlstm.mlstm_apply(p["mixer"], h, cfg.num_heads, cfg.xlstm)
    else:  # slstm
        y = xlstm.slstm_apply(p["mixer"], h, cfg.xlstm)
    x = x + y

    if enc_out is not None:
        h = layers.apply_norm(p["norm_cross"], x, cfg.norm)
        y = layers.attn_apply(
            p["cross"], h, _attn_dims(cfg),
            rope_theta=cfg.rope_theta, positions=positions,
            kv_for_cross=enc_out,
        )
        x = x + y

    if spec.ffn != "none":
        h = layers.apply_norm(p["norm2"], x, cfg.norm)
        if spec.ffn == "moe":
            y, l = moe.moe_apply(p["ffn"], h, cfg.moe)
            aux = aux + l
        else:
            y = layers.mlp_apply(p["ffn"], h)
        x = x + y
    # Megatron tensor-SEQUENCE parallelism: the residual stream lives
    # sequence-sharded over the model axis (paper Fig. 3a start state);
    # each block's projections all-gather it -> the data-dependent
    # AG->GEMM pair FiCCO overlaps.  Also cuts activation memory g-fold.
    return constrain(x, BATCH_AXES, MODEL_AXIS, None), aux


def _layer_init_cache(
    spec: LayerSpec, cfg: ModelConfig, batch: int, cache_len: int, dt
):
    if spec.mixer in ("attn",):
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        s = min(cache_len, cfg.sliding_window or cache_len)
        return {
            "k": jnp.zeros((batch, s, kv, hd), dt),
            "v": jnp.zeros((batch, s, kv, hd), dt),
        }
    if spec.mixer == "mla":
        return mla.mla_init_cache(batch, cache_len, cfg.mla, dt)
    if spec.mixer == "mamba":
        return mamba.mamba_init_cache(batch, cfg.d_model, cfg.hybrid.mamba, dt)
    if spec.mixer == "mlstm":
        return xlstm.mlstm_init_cache(batch, cfg.d_model, cfg.num_heads,
                                      cfg.xlstm)
    return xlstm.slstm_init_cache(batch, cfg.d_model, cfg.xlstm)


def _layer_decode(p, spec: LayerSpec, cfg: ModelConfig, x, cache, pos,
                  *, has_cross: bool = False):
    h = layers.apply_norm(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        y, cache2 = layers.attn_decode(
            p["attn"], h, cache, pos, _attn_dims(cfg),
            rope_theta=cfg.rope_theta, window=_window(cfg),
        )
    elif spec.mixer == "mla":
        y, cache2 = mla.mla_decode(
            p["attn"], h, cache, pos, cfg.num_heads, cfg.mla
        )
    elif spec.mixer == "mamba":
        y, cache2 = mamba.mamba_decode(p["mixer"], h, cache, cfg.hybrid.mamba)
    elif spec.mixer == "mlstm":
        y, cache2 = xlstm.mlstm_decode(
            p["mixer"], h, cache, cfg.num_heads, cfg.xlstm
        )
    else:
        y, cache2 = xlstm.slstm_decode(p["mixer"], h, cache, cfg.xlstm)
    x = x + y
    if has_cross:
        h = layers.apply_norm(p["norm_cross"], x, cfg.norm)
        b = x.shape[0]
        dims = _attn_dims(cfg)
        q = (h @ p["cross"]["wq"]).reshape(b, 1, dims.num_heads,
                                           dims.head_dim)
        out = layers.cache_attention(
            q, cache["cross_k"], cache["cross_v"],
            valid_len=cache["cross_k"].shape[1], ring=True,
        )
        y = out.reshape(b, 1, -1) @ p["cross"]["wo"]
        cache2 = dict(cache2)
        cache2["cross_k"] = cache["cross_k"]
        cache2["cross_v"] = cache["cross_v"]
        x = x + y
    if spec.ffn != "none":
        h = layers.apply_norm(p["norm2"], x, cfg.norm)
        if spec.ffn == "moe":
            y, _ = moe.moe_apply(p["ffn"], h, cfg.moe)
        else:
            y = layers.mlp_apply(p["ffn"], h)
        x = x + y
    return x, cache2


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    """Decoder LM (all families) with optional encoder (audio enc-dec)."""

    def __init__(self, config: ModelConfig):
        self.config = config
        self.pattern = layer_pattern(config)
        if config.num_layers % len(self.pattern):
            raise ValueError(
                f"{config.name}: {config.num_layers} layers not divisible "
                f"by period {len(self.pattern)}"
            )
        self.n_periods = config.num_layers // len(self.pattern)
        self.is_encdec = config.encdec is not None

    # ---- init -----------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.config
        dt = _dtype(cfg)
        r = jax.random.split(rng, 8)
        std = 0.02
        params: dict[str, Any] = {
            "embed": (
                jax.random.normal(r[0], (cfg.vocab_size, cfg.d_model)) * std
            ).astype(dt),
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dt),
            "layers": self._init_stack(r[1], cross=self.is_encdec),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(r[2], (cfg.d_model, cfg.vocab_size)) * std
            ).astype(dt)
        if self.is_encdec:
            params["encoder"] = self._init_enc_stack(r[3])
            params["enc_norm"] = layers.norm_init(cfg.d_model, cfg.norm, dt)
        if cfg.frontend and cfg.frontend.embed_dim:
            params["frontend_proj"] = layers.dense_init(
                r[4], cfg.frontend.embed_dim, cfg.d_model, dt
            )
        return params

    def _init_stack(self, rng, *, cross: bool):
        def init_period(r):
            rs = jax.random.split(r, len(self.pattern))
            return [
                _layer_init(rs[i], s, self.config, cross=cross)
                for i, s in enumerate(self.pattern)
            ]

        rngs = jax.random.split(rng, self.n_periods)
        periods = [init_period(r) for r in rngs]
        # stack over periods
        return jax.tree.map(lambda *xs: jnp.stack(xs), *periods)

    def _init_enc_stack(self, rng):
        cfg = self.config
        n = cfg.encdec.encoder_layers
        spec = LayerSpec("attn", "mlp")
        rngs = jax.random.split(rng, n)
        ps = [_layer_init(r, spec, cfg, cross=False) for r in rngs]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    # ---- sharding specs ---------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.config
        stack = [
            _layer_specs(s, cfg, cross=self.is_encdec) for s in self.pattern
        ]
        # prepend scan dim (periods) to every leaf
        stack = jax.tree.map(
            lambda sp: P(None, *sp), stack,
            is_leaf=lambda x: isinstance(x, P),
        )
        specs: dict[str, Any] = {
            "embed": P(MODEL_AXIS, None),
            "final_norm": _norm_spec(cfg),
            "layers": stack,
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = P(None, MODEL_AXIS)
        if self.is_encdec:
            enc = _layer_specs(LayerSpec("attn", "mlp"), cfg, cross=False)
            specs["encoder"] = jax.tree.map(
                lambda sp: P(None, *sp), enc,
                is_leaf=lambda x: isinstance(x, P),
            )
            specs["enc_norm"] = _norm_spec(cfg)
        if cfg.frontend and cfg.frontend.embed_dim:
            specs["frontend_proj"] = P(None, None)
        return specs

    # ---- forward ----------------------------------------------------------
    def _run_stack(self, stack_params, x, positions, *, enc_out=None,
                   causal=True):
        cfg = self.config

        def period_fn(x, period_params):
            aux = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(self.pattern):
                x, a = _layer_apply(
                    period_params[i], spec, cfg, x, positions,
                    enc_out=enc_out, causal=causal,
                )
                aux = aux + a
            return x, aux

        if cfg.remat:
            policy = (
                jax.checkpoint_policies.dots_saveable
                if cfg.remat_policy == "dots"
                else jax.checkpoint_policies.nothing_saveable
            )
            period_fn = jax.checkpoint(period_fn, policy=policy)

        if cfg.scan_layers and self.n_periods > 1:
            def body(x, pp):
                x, aux = period_fn(x, pp)
                return x, aux

            x, auxs = lax.scan(body, x, stack_params)
            return x, jnp.sum(auxs)
        # unrolled
        aux = jnp.zeros((), jnp.float32)
        for i in range(self.n_periods):
            pp = jax.tree.map(lambda a, i=i: a[i], stack_params)
            x, a = period_fn(x, pp)
            aux = aux + a
        return x, aux

    def _encode(self, params, enc_frames):
        cfg = self.config
        x = enc_frames.astype(_dtype(cfg))
        pos = jnp.broadcast_to(
            jnp.arange(x.shape[1]), x.shape[:2]
        )

        def enc_fn(x, lp):
            y, aux = _layer_apply(
                lp, LayerSpec("attn", "mlp"), cfg, x, pos, causal=False
            )
            return y, aux

        if cfg.remat:
            enc_fn = jax.checkpoint(enc_fn)
        if cfg.scan_layers and cfg.encdec.encoder_layers > 1:
            x, _ = lax.scan(lambda c, lp: enc_fn(c, lp), x, params["encoder"])
        else:
            for i in range(cfg.encdec.encoder_layers):
                lp = jax.tree.map(lambda a, i=i: a[i], params["encoder"])
                x, _ = enc_fn(x, lp)
        return layers.apply_norm(params["enc_norm"], x, cfg.norm)

    def forward(self, params, batch: dict):
        """batch keys: tokens (B, S); optional prefix_embeds (B, P, d) |
        enc_frames (B, S_enc, d).  Returns (logits, aux_loss)."""
        cfg = self.config
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(_dtype(cfg))
        x = constrain(x, BATCH_AXES, MODEL_AXIS, None)

        enc_out = None
        if self.is_encdec:
            enc_out = self._encode(params, batch["enc_frames"])

        if cfg.frontend is not None and "prefix_embeds" in batch:
            pe = batch["prefix_embeds"].astype(_dtype(cfg))
            if "frontend_proj" in params:
                pe = pe @ params["frontend_proj"]
            x = jnp.concatenate([pe, x], axis=1)

        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux = self._run_stack(
            params["layers"], x, positions, enc_out=enc_out
        )
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        if cfg.frontend is not None and "prefix_embeds" in batch:
            x = x[:, -tokens.shape[1]:]  # logits over the text segment
        logits = self._unembed(params, x)
        return logits, aux

    def _unembed(self, params, x):
        w = (
            params["embed"].T
            if self.config.tie_embeddings
            else params["unembed"]
        )
        logits = x @ w.astype(x.dtype)
        return constrain(logits, BATCH_AXES, None, MODEL_AXIS)

    def loss(self, params, batch: dict):
        """Vocab-parallel-safe cross entropy: all reductions run over the
        (possibly model-axis-sharded) vocab dimension — no gather ops that
        would force GSPMD to replicate the fp32 logits."""
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        lg = logits[:, :-1].astype(jnp.float32)
        lg = constrain(lg, BATCH_AXES, None, MODEL_AXIS)
        tg = labels[:, 1:]
        m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
        logz = (
            jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
        )
        vocab_iota = jnp.arange(lg.shape[-1])[None, None, :]
        gold = jnp.sum(
            jnp.where(vocab_iota == tg[..., None], lg, 0.0), axis=-1
        )
        ce = jnp.mean(logz - gold)
        return ce + aux, {"ce": ce, "aux": aux}

    # ---- decode ------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, *, enc_len: int = 0):
        cfg = self.config
        dt = _dtype(cfg)

        def period_cache():
            caches = [
                _layer_init_cache(s, cfg, batch, cache_len, dt)
                for s in self.pattern
            ]
            if self.is_encdec:
                kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
                for c in caches:
                    c["cross_k"] = jnp.zeros((batch, enc_len, kv, hd), dt)
                    c["cross_v"] = jnp.zeros((batch, enc_len, kv, hd), dt)
            return caches

        one = period_cache()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_periods, *a.shape)), one
        )

    def prefill_cross(self, params, cache, enc_frames):
        """Enc-dec: run the encoder and fill per-layer cross K/V."""
        cfg = self.config
        enc_out = self._encode(params, enc_frames)
        dims = _attn_dims(cfg)
        b, s_enc, _ = enc_out.shape

        def fill(period_params, period_cache):
            for i in range(len(self.pattern)):
                pa = period_params[i]["cross"]
                k = (enc_out @ pa["wk"]).reshape(
                    b, s_enc, dims.num_kv_heads, dims.head_dim
                )
                v = (enc_out @ pa["wv"]).reshape(
                    b, s_enc, dims.num_kv_heads, dims.head_dim
                )
                period_cache[i]["cross_k"] = k.astype(_dtype(cfg))
                period_cache[i]["cross_v"] = v.astype(_dtype(cfg))
            return period_cache

        def body(_, args):
            pp, pc = args
            return None, fill(pp, pc)

        _, new_cache = lax.scan(body, None, (params["layers"], cache))
        return new_cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar position. -> (logits, cache)."""
        cfg = self.config
        x = params["embed"][tokens].astype(_dtype(cfg))

        def body(x, args):
            pp, pc = args
            new_pc = []
            for i, spec in enumerate(self.pattern):
                x, c2 = _layer_decode(
                    pp[i], spec, cfg, x, pc[i], pos,
                    has_cross="cross_k" in pc[i],
                )
                new_pc.append(c2)
            return x, new_pc

        if cfg.scan_layers and self.n_periods > 1:
            x, new_cache = lax.scan(body, x, (params["layers"], cache))
        else:
            new_caches = []
            for i in range(self.n_periods):
                pp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
                pc = jax.tree.map(lambda a, i=i: a[i], cache)
                x, npc = body(x, (pp, pc))
                new_caches.append(npc)
            new_cache = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_caches
            )
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        logits = self._unembed(params, x)
        return logits, new_cache


def build_model(config: ModelConfig) -> Model:
    return Model(config)
