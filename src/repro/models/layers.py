"""Shared neural layers: norms, RoPE, GQA attention, MLPs.

All layers are pure functions over param pytrees (no framework dep).  Every
linear that participates in tensor parallelism is annotated with sharding
constraints via ``repro.parallel.sharding.constrain`` so GSPMD shards it
over the ``model`` axis; the FiCCO overlap path replaces the AG->GEMM pairs
with explicit shard_map schedules (see parallel/tp.py).

Attention is doubly-blocked (scan over query blocks, scan over KV blocks
with online softmax) so 32k-token prefill fits per-device memory; the same
code handles full-causal and sliding-window masks.  Decode uses a KV cache
(ring buffer when a sliding window is configured).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, constrain


def dense_init(rng, in_dim: int, out_dim: int, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim)) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparametric_ln":  # OLMo: no affine parameters
        return {}
    raise ValueError(kind)


def apply_norm(params, x: jax.Array, kind: str) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (blockwise online-softmax; full-causal or sliding window)
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _block_attn(q, k, v, mask):
    """q: (B,bq,H,Dk); k: (B,bk,KV,Dk); v: (B,bk,KV,Dv); mask: (bq,bk)."""
    b, bq, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    kr = jnp.repeat(k, rep, axis=2)  # (B,bk,H,Dk)
    vr = jnp.repeat(v, rep, axis=2)  # (B,bk,H,Dv)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) / math.sqrt(d)
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    m = jnp.max(scores, -1)  # (B,H,bq)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, -1)  # (B,H,bq)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return m, l, o


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Memory-O(S*block) exact attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  ``q_offset`` is the absolute
    position of q[0] relative to k[0] (cross-attention uses causal=False).
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # pad to block multiples
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k

    q_blocks = qp.reshape(b, nq, block_q, h, d).transpose(1, 0, 2, 3, 4)
    k_blocks = kp.reshape(b, nk, block_k, k.shape[2], d).transpose(
        1, 0, 2, 3, 4
    )
    v_blocks = vp.reshape(b, nk, block_k, v.shape[2], dv).transpose(
        1, 0, 2, 3, 4
    )

    q_pos_base = jnp.arange(block_q)
    k_pos_base = jnp.arange(block_k)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk
        q_pos = q_offset + qi * block_q + q_pos_base  # absolute

        def kv_step(carry, ki_kv):
            m_run, l_run, o_run = carry
            ki, kblk, vblk = ki_kv
            k_pos = ki * block_k + k_pos_base
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            mask &= (k_pos < sk)[None, :]
            mask &= ((q_offset + qi * block_q + q_pos_base) < q_offset + sq)[
                :, None
            ]
            m_b, l_b, o_b = _block_attn(qblk, kblk, vblk, mask)
            m_new = jnp.maximum(m_run, m_b)
            a1 = jnp.exp(m_run - m_new)
            a2 = jnp.exp(m_b - m_new)
            l_new = l_run * a1 + l_b * a2
            o_new = (
                o_run * a1.transpose(0, 2, 1)[..., None]
                + o_b * a2.transpose(0, 2, 1)[..., None]
            )
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, h, block_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        o0 = jnp.zeros((b, block_q, h, dv), jnp.float32)
        (m_f, l_f, o_f), _ = lax.scan(
            kv_step, (m0, l0, o0), (jnp.arange(nk), k_blocks, v_blocks)
        )
        out = o_f / jnp.maximum(l_f, 1e-30).transpose(0, 2, 1)[..., None]
        return None, out

    _, outs = lax.scan(q_step, None, (jnp.arange(nq), q_blocks))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * block_q, h, dv)
    return out[:, :sq].astype(q.dtype)


def cache_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid_len: jax.Array,
    *,
    ring: bool = False,
) -> jax.Array:
    """Single-token decode attention over a (B, S, KV, D) cache.

    ``valid_len`` - number of valid cache entries (scalar).  With ``ring``
    the whole buffer is valid (sliding-window ring cache, already full).
    """
    b, one, h, d = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    rep = h // kv
    kr = jnp.repeat(k_cache, rep, axis=2)
    vr = jnp.repeat(v_cache, rep, axis=2)
    kr = constrain(kr, BATCH_AXES, "data" if b == 1 else None, None, None)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) / math.sqrt(d)
    if not ring:
        valid = jnp.arange(s)[None, None, None, :] < valid_len
        scores = jnp.where(valid, scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (params + apply for train & decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int


def attn_init(rng, dims: AttnDims, dtype):
    r = jax.random.split(rng, 4)
    h, kv, hd, d = (
        dims.num_heads, dims.num_kv_heads, dims.head_dim, dims.d_model
    )
    return {
        "wq": dense_init(r[0], d, h * hd, dtype),
        "wk": dense_init(r[1], d, kv * hd, dtype),
        "wv": dense_init(r[2], d, kv * hd, dtype),
        "wo": dense_init(r[3], h * hd, d, dtype),
    }


def attn_param_specs():
    return {
        "wq": P(None, MODEL_AXIS),
        "wk": P(None, MODEL_AXIS),
        "wv": P(None, MODEL_AXIS),
        "wo": P(MODEL_AXIS, None),
    }


def attn_apply(
    params,
    x: jax.Array,
    dims: AttnDims,
    *,
    rope_theta: float,
    positions: jax.Array,
    window: Optional[int] = None,
    kv_for_cross: Optional[jax.Array] = None,
) -> jax.Array:
    """Training/prefill attention.  x: (B, S, d)."""
    b, s, _ = x.shape
    h, kv, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    src = kv_for_cross if kv_for_cross is not None else x
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (src @ params["wk"]).reshape(b, src.shape[1], kv, hd)
    v = (src @ params["wv"]).reshape(b, src.shape[1], kv, hd)
    q = constrain(q, BATCH_AXES, None, MODEL_AXIS, None)
    k = constrain(k, BATCH_AXES, None, MODEL_AXIS if kv > 1 else None, None)
    v = constrain(v, BATCH_AXES, None, MODEL_AXIS if kv > 1 else None, None)
    if kv_for_cross is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        out = blockwise_attention(q, k, v, causal=True, window=window)
    else:
        out = blockwise_attention(q, k, v, causal=False)
    out = out.reshape(b, s, h * hd)
    y = out @ params["wo"]
    return constrain(y, BATCH_AXES, None, None)


def attn_decode(
    params,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    dims: AttnDims,
    *,
    rope_theta: float,
    window: Optional[int] = None,
):
    """One-token decode. x: (B, 1, d); cache: {"k","v"} (B, S, KV, D)."""
    b, one, _ = x.shape
    h, kv, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kv, hd)
    v = (x @ params["wv"]).reshape(b, 1, kv, hd)
    posv = jnp.full((b, 1), pos)
    q = apply_rope(q, posv, rope_theta)
    k = apply_rope(k, posv, rope_theta)

    from repro.parallel.context import get_overlap

    ov = get_overlap()
    if ov is not None and getattr(ov, "decode_attn", "gspmd") == "shard_map":
        from repro.parallel import decode_attn

        if decode_attn.applicable(cache["k"], window):
            out, k_cache, v_cache = decode_attn.shard_map_attn_decode(
                q, k, v, cache["k"], cache["v"], pos
            )
            y = out.reshape(b, 1, h * hd) @ params["wo"]
            return y, {"k": k_cache, "v": v_cache}

    s_cache = cache["k"].shape[1]
    slot = pos % s_cache if window is not None else pos
    k_cache = lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
    )
    v_cache = lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
    )
    out = cache_attention(
        q, k_cache, v_cache, valid_len=pos + 1, ring=window is not None
    )
    y = out.reshape(b, 1, h * hd) @ params["wo"]
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, ff: int, dtype, *, gated: bool = True):
    r = jax.random.split(rng, 3)
    p = {
        "w_up": dense_init(r[0], d, ff, dtype),
        "w_down": dense_init(r[1], ff, d, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(r[2], d, ff, dtype)
    return p


def mlp_param_specs(*, gated: bool = True):
    p = {"w_up": P(None, MODEL_AXIS), "w_down": P(MODEL_AXIS, None)}
    if gated:
        p["w_gate"] = P(None, MODEL_AXIS)
    return p


def mlp_apply(params, x: jax.Array) -> jax.Array:
    """TP MLP.  The up/gate projections are the paper's data-dependent
    AG->GEMM pair: with an overlap context active they run a bespoke
    FiCCO schedule (repro.parallel.tp); otherwise GSPMD serial.  The down
    projection's RS-side is left to XLA (the paper omits reduction-fused
    scenarios: DMA engines lack arithmetic, §IV-B2)."""
    from repro.parallel.context import get_overlap

    ov = get_overlap()
    if ov is not None and ov.mode != "gspmd_serial":
        from repro.parallel import tp

        if tp.overlap_applicable(x, params["w_up"]):
            h = tp.tp_ficco_linear(x, params["w_up"], ov)
            if "w_gate" in params:
                g = tp.tp_ficco_linear(x, params["w_gate"], ov)
                h = jax.nn.silu(g) * h
            else:
                h = jax.nn.gelu(h)
            h = constrain(h, BATCH_AXES, None, MODEL_AXIS)
            y = h @ params["w_down"]
            return constrain(y, BATCH_AXES, None, None)

    h = x @ params["w_up"]
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, BATCH_AXES, None, MODEL_AXIS)
    y = h @ params["w_down"]
    return constrain(y, BATCH_AXES, None, None)
