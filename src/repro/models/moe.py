"""Mixture-of-Experts FFN: top-k router + capacity-based sorted dispatch.

Dispatch is sort-based (argsort by expert id + capacity clipping) rather
than one-hot-einsum so it scales to Arctic's 128 experts x 1M tokens under
GSPMD: the (E, C, D) expert batches are sharded over the ``model`` axis
(expert parallelism) and XLA lowers the gather/scatter to all-to-alls — the
exact data-dependent A2A -> expert-GEMM pattern of the paper's EP scenarios
(Table I g13–g16).  The chunked FiCCO EP overlap lives in
``repro.overlap.moe``; this module is the pjit-friendly production path.

Supports DeepSeek-style shared experts and Arctic's dense residual FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models import layers
from repro.parallel.sharding import BATCH_AXES, MODEL_AXIS, constrain


def moe_init(rng, d_model: int, cfg: MoEConfig, dtype):
    r = jax.random.split(rng, 6)
    e, ff = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": layers.dense_init(r[0], d_model, e, jnp.float32),
        "w_gate": _expert_init(r[1], e, d_model, ff, dtype),
        "w_up": _expert_init(r[2], e, d_model, ff, dtype),
        "w_down": _expert_init(r[3], e, ff, d_model, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.mlp_init(
            r[4], d_model, ff * cfg.num_shared_experts, dtype
        )
    if cfg.dense_residual_ff:
        p["dense_residual"] = layers.mlp_init(
            r[5], d_model, cfg.dense_residual_ff, dtype
        )
    return p


def _expert_init(rng, e, d_in, d_out, dtype):
    std = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(rng, (e, d_in, d_out)) * std).astype(dtype)


def moe_param_specs(cfg: MoEConfig):
    p = {
        "router": P(None, None),
        "w_gate": P(MODEL_AXIS, None, None),  # expert parallel
        "w_up": P(MODEL_AXIS, None, None),
        "w_down": P(MODEL_AXIS, None, None),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.mlp_param_specs()
    if cfg.dense_residual_ff:
        p["dense_residual"] = layers.mlp_param_specs()
    return p


def moe_apply(params, x: jax.Array, cfg: MoEConfig):
    """x: (B, S, D) -> (out, aux_losses)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux losses (GShard load balance + router z-loss)
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k)
    )
    lb_loss = cfg.load_balance_loss * e * jnp.sum(me * ce)
    z_loss = cfg.router_z_loss * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2
    )

    capacity = int(max(cfg.capacity_factor * t * k / e, 4))

    # ---- sorted capacity dispatch -----------------------------------
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos = jnp.arange(t * k) - starts[se]  # position within expert
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)  # dummy tail

    disp = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(xf[stok])
    expert_in = disp[: e * capacity].reshape(e, capacity, d)
    expert_in = constrain(expert_in, MODEL_AXIS, None, None)

    # ---- expert FFN (A2A -> grouped GEMM: the paper's EP hot spot) ---
    h = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
    h = jax.nn.silu(g) * h
    h = constrain(h, MODEL_AXIS, None, None)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    expert_out = constrain(expert_out, MODEL_AXIS, None, None)

    # ---- combine back -------------------------------------------------
    flat_out = jnp.concatenate(
        [expert_out.reshape(e * capacity, d), jnp.zeros((1, d), x.dtype)]
    )
    routed = flat_out[slot] * sw[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[stok].add(
        jnp.where(keep[:, None], routed, 0)
    )
    out = y.reshape(b, s, d)

    if "shared" in params:
        out = out + layers.mlp_apply(params["shared"], x)
    if "dense_residual" in params:
        out = out + layers.mlp_apply(params["dense_residual"], x)
    out = constrain(out, BATCH_AXES, None, None)
    return out, lb_loss + z_loss
