"""Per-scenario feature extraction for schedule-selection learning.

The paper's claim (§VI-D) is that *inefficiency signatures* — static
quantities computable without profiling — carry enough signal to pick
bespoke FiCCO schedules.  This module turns any scenario batch (uniform
or ragged) plus a machine into a dense ``(S, F)`` feature matrix, fully
vectorized, reusing the exact formulas the heuristic gate and the
batched engines use (``repro.core.heuristics.serial_gate_terms_batch``,
``repro.core.batch.comm_cil_vec``) so the learner and the runtime
decision tree can never drift apart on definitions.

Features (``FEATURE_NAMES`` order):

  * ``imbalance``    — ragged-profile max/mean active-step share (1.0
                       for uniform splits).
  * ``active_steps`` — number of non-empty pipeline steps (``group``
                       for uniform splits).
  * ``otb``          — the paper's static op-to-byte ratio.
  * ``r``            — T_comm / T_gemm roofline ratio (comm-boundedness).
  * ``inflate``      — chunked/serial all-gather inflation from the
                       link model (per-chunk latency + ramp cost).
  * ``comm_cil``     — comm-side concurrency-induced-latency factor at
                       the FiCCO concurrency degree.
  * ``log_flops``    — log10 of the global GEMM's FLOPs (size scale).
  * ``m_over_k``     — M/K aspect ratio (the tree's 1D-vs-2D branch).
  * ``group``        — overlap-group size (machine param).
  * ``balance_otb``  — machine balance point, ops/byte (machine param).

The learned gate (:mod:`repro.learn.gate`) conditions on the first four
(:data:`GATE_FEATURES`); the rest feed analysis and future learners.
"""

from __future__ import annotations

import numpy as np

from repro.core.batch import RaggedBatch, comm_cil_vec
from repro.core.engine import GridResult
from repro.core.heuristics import serial_gate_terms_batch
from repro.core.machine import MachineSpec

FEATURE_NAMES: tuple[str, ...] = (
    "imbalance",
    "active_steps",
    "otb",
    "r",
    "inflate",
    "comm_cil",
    "log_flops",
    "m_over_k",
    "group",
    "balance_otb",
)
FEATURE_INDEX = {name: i for i, name in enumerate(FEATURE_NAMES)}

# The subset the learned gate's threshold family conditions on.
GATE_FEATURES: tuple[str, ...] = ("imbalance", "active_steps", "otb", "r")


def profile_features(batch) -> tuple[np.ndarray, np.ndarray]:
    """``(imbalance, active_steps)`` of a batch, machine-independent.

    Uniform batches report ``imbalance == 1`` and ``active_steps == 0``
    (a sentinel the machine-aware callers replace with ``group`` — the
    uniform split's step count is a machine property, not a scenario
    one).
    """
    if isinstance(batch, RaggedBatch):
        return (
            np.asarray(batch.imbalance, dtype=np.float64),
            batch.active_steps,
        )
    S = len(batch)
    return np.ones(S), np.zeros(S)


def scenario_features(
    batch,
    machine: MachineSpec,
    *,
    imbalance=None,
    active_steps=None,
) -> np.ndarray:
    """Dense ``(S, F)`` feature matrix for one machine, vectorized.

    ``batch`` is anything the engines accept (``ScenarioBatch`` /
    ``RaggedBatch`` / scenario lists).  ``imbalance`` / ``active_steps``
    override the profile-derived values (e.g. when features are built
    from raw shape arrays instead of a batch).
    """
    from repro.core import batch as _batch
    from repro.core.engine import as_scenario_sequence, is_ragged

    batch = as_scenario_sequence(batch)
    sb = (
        _batch._as_ragged_batch(batch)
        if is_ragged(batch)
        else _batch._as_batch(batch)
    )
    imb, act = profile_features(sb)
    if imbalance is not None:
        imb = np.broadcast_to(
            np.asarray(imbalance, np.float64), imb.shape
        ).copy()
    if active_steps is not None:
        act = np.broadcast_to(
            np.asarray(active_steps, np.float64), act.shape
        ).copy()
    return feature_matrix(
        sb.m, sb.n, sb.k, sb.dtype_bytes, machine,
        imbalance=imb, active_steps=act,
    )


def feature_matrix(
    m,
    n,
    k,
    dtype_bytes,
    machine: MachineSpec,
    *,
    imbalance,
    active_steps,
    terms=None,
) -> np.ndarray:
    """``(S, F)`` features from raw shape arrays (the vectorized core).

    ``terms`` optionally carries precomputed
    :func:`~repro.core.heuristics.serial_gate_terms_batch` output —
    callers that already evaluated the gate score (the batch selector,
    the statistics accumulator) avoid recomputing the link model.
    """
    m = np.asarray(m, dtype=np.float64)
    n = np.asarray(n, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    b = np.asarray(dtype_bytes, dtype=np.float64)
    g = machine.group
    imb = np.broadcast_to(np.asarray(imbalance, np.float64), m.shape)
    act = np.asarray(
        np.broadcast_to(np.asarray(active_steps, np.float64), m.shape)
    ).copy()
    act[act == 0.0] = float(g)  # uniform sentinel -> group-step pipeline

    r, inflate = (
        terms
        if terms is not None
        else serial_gate_terms_batch(m, n, k, b, machine)
    )
    flops = 2.0 * m * n * k
    bytes_mt = (m * k + k * n + m * n) * b
    with np.errstate(divide="ignore", invalid="ignore"):
        otb = flops / bytes_mt
        m_over_k = m / k
        log_flops = np.log10(np.maximum(flops, 1.0))
    dev_n = np.where(n % g == 0, n / g, n)
    cil = comm_cil_vec(m / g, dev_n, k, b, machine, degree=4)

    S = m.shape[0]
    out = np.empty((S, len(FEATURE_NAMES)), dtype=np.float64)
    out[:, FEATURE_INDEX["imbalance"]] = imb
    out[:, FEATURE_INDEX["active_steps"]] = act
    out[:, FEATURE_INDEX["otb"]] = otb
    out[:, FEATURE_INDEX["r"]] = r
    out[:, FEATURE_INDEX["inflate"]] = inflate
    out[:, FEATURE_INDEX["comm_cil"]] = cil
    out[:, FEATURE_INDEX["log_flops"]] = log_flops
    out[:, FEATURE_INDEX["m_over_k"]] = m_over_k
    out[:, FEATURE_INDEX["group"]] = float(g)
    out[:, FEATURE_INDEX["balance_otb"]] = machine.balance_otb
    return out


def grid_features(grid: GridResult) -> np.ndarray:
    """``(S, M, F)`` features for every (scenario, machine) grid point.

    Works on any engine's :class:`~repro.core.engine.GridResult` —
    features are recomputed from the batch + machine specs the grid
    carries, so a gathered sweep result is a ready-made training set.
    """
    cols = [
        scenario_features(grid.scenarios, machine)
        for machine in grid.machines
    ]
    return np.stack(cols, axis=1)


__all__ = [
    "FEATURE_NAMES",
    "FEATURE_INDEX",
    "GATE_FEATURES",
    "profile_features",
    "scenario_features",
    "feature_matrix",
    "grid_features",
]
