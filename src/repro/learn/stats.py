"""Per-shard sufficient statistics for gate learning at sweep scale.

Training a serial/overlap gate needs, for every (scenario, machine)
point, only (a) where the point lands in a *fixed* binning of the gate
features ``(imbalance, active_steps, otb, r)`` and the gate score, and
(b) what staying serial vs taking the ungated tree pick would have cost
relative to the analytic optimum.  Those reduce to an **integer
histogram**: per (feature-bin..., score-bin) cell we count points,
within-5% wins for each side, and quantized regret sums.

Because every statistic is an integer, per-shard histograms merge
*exactly* — a gate trained from summed shard statistics is
bit-identical to one trained on the gathered grid, which is what lets
``repro.sweep``'s reduce mode feed 1e6–1e7-point training sweeps
without ever materializing an ``(L, S, M)`` table (the
``on_shard_grid`` hook hands each shard's GridResult to
:meth:`GateStats.update_from_grid` and drops it).

The candidate gate thresholds are the score-bin edges: choosing
threshold index ``i`` means "serial iff score >= SCORE_EDGES[i-1]"
(``i=0`` -> always serial, ``i=n_bins`` -> never), so any axis-aligned
threshold family over the binned features can be evaluated exactly from
the histogram — see :mod:`repro.learn.gate` for the greedy tree grower.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.engine import SCHEDULE_INDEX, GridResult
from repro.core.heuristics import (
    select_schedule_batch,
    serial_gate_score_from_terms,
    serial_gate_terms_batch,
)
from repro.core.schedule_types import Schedule
from repro.learn.features import GATE_FEATURES, feature_matrix, profile_features
from repro.learn import features as _features

STATS_SCHEMA = 1

# Fixed bin edges per gate feature (axis order == GATE_FEATURES).
# Values below the first edge land in bin 0; >= the last edge in the
# final bin.  Edges are part of the stats identity: two GateStats only
# merge if their edges match exactly.
FEATURE_EDGES: dict[str, tuple[float, ...]] = {
    "imbalance": (1.05, 1.25, 1.5, 2.0, 3.0, 4.5, 7.0),
    "active_steps": (1.5, 2.5, 3.5, 5.5, 8.5, 16.5),
    "otb": tuple(np.geomspace(32.0, 8192.0, 9)),
    "r": tuple(np.geomspace(1.0 / 32.0, 32.0, 11)),
}
# Candidate gate thresholds == score-bin edges (the learnable family).
SCORE_EDGES: tuple[float, ...] = tuple(np.geomspace(0.05, 20.0, 25))

# Regret (t/t_best - 1) is clipped here and quantized to integers so
# shard sums are exact; 1e7 points x 1e7 quanta stays far inside int64.
REGRET_CAP = 10.0
REGRET_SCALE = 1.0e6

# Histogram stat columns.
_N_STAT = 5
_C_COUNT, _C_W5_SERIAL, _C_W5_BASE, _C_REG_SERIAL, _C_REG_BASE = range(_N_STAT)


def _hist_shape() -> tuple[int, ...]:
    dims = tuple(len(FEATURE_EDGES[f]) + 1 for f in GATE_FEATURES)
    return dims + (len(SCORE_EDGES) + 1, _N_STAT)


def _quantize_regret(t, t_best) -> np.ndarray:
    with np.errstate(divide="ignore", invalid="ignore"):
        regret = t / t_best - 1.0
    regret = np.nan_to_num(
        regret, nan=REGRET_CAP, posinf=REGRET_CAP, neginf=0.0
    )
    regret = np.clip(regret, 0.0, REGRET_CAP)
    return np.rint(regret * REGRET_SCALE).astype(np.int64)


@dataclasses.dataclass
class GateStats:
    """Mergeable sufficient statistics for the learned serial gate.

    ``hist`` is the integer histogram described in the module docstring;
    ``moments`` carries per-feature (count, sum, sum-of-squares) for
    reporting (floats — informative, not part of the exact-merge
    contract); ``best_counts`` tallies the analytic optimum per
    schedule (the sweep-scale twin of ``ShardSummary.best_counts``).
    """

    hist: np.ndarray
    moments: np.ndarray  # (F, 3) float64: count, sum, sumsq
    best_counts: dict[str, int]
    n_points: int = 0
    schema: int = STATS_SCHEMA

    @classmethod
    def empty(cls) -> "GateStats":
        return cls(
            hist=np.zeros(_hist_shape(), dtype=np.int64),
            moments=np.zeros((len(_features.FEATURE_NAMES), 3)),
            best_counts={},
            n_points=0,
        )

    @classmethod
    def from_grid(cls, grid: GridResult) -> "GateStats":
        stats = cls.empty()
        stats.update_from_grid(grid)
        return stats

    # -- accumulation ---------------------------------------------------

    def update_from_grid(self, grid: GridResult, machine_indices=None) -> None:
        """Fold one (shard's) GridResult into the statistics.

        Integer columns accumulate exactly, so any sharding of the same
        grid produces the same histogram.  ``machine_indices`` restricts
        accumulation to a subset of the grid's machine axis (the
        per-machine-family training path: one grid evaluation feeds one
        :class:`GateStats` per family, and the per-family histograms sum
        exactly to the unrestricted one).
        """
        from repro.core.engine import GRID_SCHEDULES

        if tuple(grid.schedules) != GRID_SCHEDULES:
            # The serial row index and the base-pick indices below are
            # SCHEDULE_INDEX positions — a schedule-subset grid would be
            # silently misread, so refuse it loudly.
            raise ValueError(
                "GateStats needs the full GRID_SCHEDULES grid, got "
                f"{tuple(s.value for s in grid.schedules)}"
            )
        sb = grid.scenarios
        S = len(sb)
        if S == 0:
            return
        imb, act = profile_features(sb)
        t = np.nan_to_num(grid.total, nan=np.inf, posinf=np.inf)
        t_best = grid.best_total()
        serial_l = SCHEDULE_INDEX[Schedule.SERIAL]
        s_idx = np.arange(S)
        if machine_indices is None:
            machine_indices = range(len(grid.machines))
        machine_indices = [int(j) for j in machine_indices]
        best = grid.best_idx()[:, machine_indices]
        for l, sched in enumerate(grid.schedules):
            n = int((best == l).sum())
            if n:
                self.best_counts[sched.value] = (
                    self.best_counts.get(sched.value, 0) + n
                )
        flat = self.hist.reshape(-1, _N_STAT)
        for j in machine_indices:
            machine = grid.machines[j]
            # One link-model evaluation feeds the score, the base picks
            # and the feature matrix alike.
            terms = serial_gate_terms_batch(
                sb.m, sb.n, sb.k, sb.dtype_bytes, machine
            )
            scores = serial_gate_score_from_terms(*terms)
            base = select_schedule_batch(
                sb.m, sb.n, sb.k, sb.dtype_bytes, machine,
                serial_gate=np.inf, terms=terms,
            )
            feats = feature_matrix(
                sb.m, sb.n, sb.k, sb.dtype_bytes, machine,
                imbalance=imb, active_steps=act, terms=terms,
            )
            t_serial = t[serial_l, :, j]
            t_pick = t[base, s_idx, j]
            tb = t_best[:, j]
            w5_serial = (t_serial <= 1.05 * tb).astype(np.int64)
            w5_base = (t_pick <= 1.05 * tb).astype(np.int64)
            reg_serial = _quantize_regret(t_serial, tb)
            reg_base = _quantize_regret(t_pick, tb)

            idx = np.zeros(S, dtype=np.int64)
            for f in GATE_FEATURES:
                edges = np.asarray(FEATURE_EDGES[f])
                col = feats[:, _features.FEATURE_INDEX[f]]
                idx = idx * (len(edges) + 1) + np.searchsorted(
                    edges, col, side="right"
                )
            idx = idx * (len(SCORE_EDGES) + 1) + np.searchsorted(
                np.asarray(SCORE_EDGES), scores, side="right"
            )
            np.add.at(flat[:, _C_COUNT], idx, 1)
            np.add.at(flat[:, _C_W5_SERIAL], idx, w5_serial)
            np.add.at(flat[:, _C_W5_BASE], idx, w5_base)
            np.add.at(flat[:, _C_REG_SERIAL], idx, reg_serial)
            np.add.at(flat[:, _C_REG_BASE], idx, reg_base)

            finite = np.isfinite(feats)
            self.moments[:, 0] += finite.sum(axis=0)
            self.moments[:, 1] += np.where(finite, feats, 0.0).sum(axis=0)
            self.moments[:, 2] += np.where(finite, feats**2, 0.0).sum(axis=0)
            self.n_points += S

    def merge(self, other: "GateStats") -> "GateStats":
        """Exact (integer) merge of two compatible statistic sets."""
        if other.schema != self.schema:
            raise ValueError(
                f"cannot merge GateStats schema {other.schema} "
                f"into schema {self.schema}"
            )
        if other.hist.shape != self.hist.shape:
            raise ValueError("GateStats bin layouts differ")
        counts = dict(self.best_counts)
        for k, v in other.best_counts.items():
            counts[k] = counts.get(k, 0) + v
        return GateStats(
            hist=self.hist + other.hist,
            moments=self.moments + other.moments,
            best_counts=counts,
            n_points=self.n_points + other.n_points,
            schema=self.schema,
        )

    def __add__(self, other: "GateStats") -> "GateStats":
        return self.merge(other)

    # -- reporting ------------------------------------------------------

    def feature_summary(self) -> dict[str, dict[str, float]]:
        out = {}
        for i, name in enumerate(_features.FEATURE_NAMES):
            cnt, s, ss = self.moments[i]
            mean = s / cnt if cnt else 0.0
            var = max(ss / cnt - mean * mean, 0.0) if cnt else 0.0
            out[name] = {
                "count": float(cnt), "mean": mean, "std": var**0.5,
            }
        return out

    # -- serialization (multi-host stat streams) ------------------------

    def to_json(self) -> str:
        flat = self.hist.reshape(-1)
        nz = np.flatnonzero(flat)
        payload = {
            "schema": self.schema,
            "features": list(GATE_FEATURES),
            "feature_edges": {
                f: list(FEATURE_EDGES[f]) for f in GATE_FEATURES
            },
            "score_edges": list(SCORE_EDGES),
            "shape": list(self.hist.shape),
            "nz": [
                [int(i), int(v)]
                for i, v in zip(nz.tolist(), flat[nz].tolist())
            ],
            "moments": self.moments.tolist(),
            "best_counts": self.best_counts,
            "n_points": self.n_points,
        }
        return json.dumps(payload, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "GateStats":
        raw = json.loads(text)
        if raw.get("schema") != STATS_SCHEMA:
            raise ValueError(
                f"GateStats schema {raw.get('schema')!r} != {STATS_SCHEMA}"
            )
        if tuple(raw.get("shape", ())) != _hist_shape():
            raise ValueError("GateStats bin layout mismatch")
        # The bin *edges* are part of the identity too: equal-sized
        # histograms binned on different boundaries (a re-tuned
        # geomspace without a schema bump) must never merge.
        if raw.get("features") != list(GATE_FEATURES) or raw.get(
            "feature_edges"
        ) != {f: list(FEATURE_EDGES[f]) for f in GATE_FEATURES}:
            raise ValueError("GateStats feature-edge mismatch")
        if raw.get("score_edges") != list(SCORE_EDGES):
            raise ValueError("GateStats score-edge mismatch")
        hist = np.zeros(int(np.prod(_hist_shape())), dtype=np.int64)
        for i, v in raw["nz"]:
            hist[int(i)] = int(v)
        return cls(
            hist=hist.reshape(_hist_shape()),
            moments=np.asarray(raw["moments"], dtype=np.float64),
            best_counts={k: int(v) for k, v in raw["best_counts"].items()},
            n_points=int(raw["n_points"]),
        )


def sweep_stats(
    scenarios,
    machines,
    *,
    backend: str = "numpy",
    engine=None,
    num_shards: int | None = None,
    host_index: int = 0,
    host_count: int = 1,
    device_parallel: bool = False,
    dma: bool = True,
    on_shard=None,
):
    """Accumulate :class:`GateStats` over a reduce-mode sharded sweep.

    The memory-bounded training-data path: each shard's GridResult is
    folded into the statistics the moment it finishes (via
    ``sweep_grid``'s ``on_shard_grid`` hook) and then dropped — a
    1e6-point sweep trains a gate without ever gathering the grid.
    Returns ``(stats, sweep_result)``; merge stats across hosts with
    :meth:`GateStats.merge` (they serialize via ``to_json`` for the
    ``sweep_host*.jsonl``-style streams).

    ``engine`` passes an engine *instance* through to ``sweep_grid``
    (overriding ``backend``) — the fit-then-retrain path hands a
    :class:`~repro.learn.fit.FittedEngine` here so the gate trains
    against the calibrated machine model instead of registry defaults.
    """
    from repro.sweep import sweep_grid

    stats = GateStats.empty()
    res = sweep_grid(
        scenarios,
        machines,
        backend=backend,
        engine=engine,
        num_shards=num_shards,
        mode="reduce",
        dma=dma,
        host_index=host_index,
        host_count=host_count,
        device_parallel=device_parallel,
        on_shard=on_shard,
        on_shard_grid=lambda grid, _summ: stats.update_from_grid(grid),
    )
    return stats, res


__all__ = [
    "STATS_SCHEMA",
    "FEATURE_EDGES",
    "SCORE_EDGES",
    "REGRET_CAP",
    "REGRET_SCALE",
    "GateStats",
    "sweep_stats",
]
