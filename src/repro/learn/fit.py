"""Sim-to-real machine calibration: fit machine parameters to measured
schedule times by gradient descent.

The jitted grid engine (:mod:`repro.autotune.jaxgrid`) is differentiable
w.r.t. every :class:`~repro.autotune.jaxgrid.MachineArrays` leaf, so
closing the gap between the analytic model and a real deployment is a
few Adam steps: collect ``(gemm, schedule, measured seconds)`` records —
``Autotuner.measure`` persists exactly these — and descend the mean
squared *log*-time error over the fittable parameters (``link_bw``,
``s_half``, the CIL coefficients, ...).  Log-space on both sides keeps
the loss scale-free across microsecond and millisecond operators and
guarantees positive parameters.

This lands the ROADMAP item "calibrate machine models from
measurements": per deployment, the persisted measured tier feeds
:func:`records_from_cache`, :func:`fit_machine` recovers the machine's
effective ``link_bw``/``s_half``/CIL, and the resulting
:class:`FitResult` (a) re-evaluates grids through
``evaluate_grid_raw(..., fit.machine_arrays())`` and (b) persists in the
autotune cache's artifact segment next to the learned gate.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.machine import MachineSpec, machine_for_group
from repro.core.schedule_types import Schedule
from repro.core.workload import GemmShape

FIT_SCHEMA_VERSION = 1
FIT_ARTIFACT_KIND = "machine_fit"

# MachineArrays leaves fit_machine may optimize.  All are positive and
# enter the model smoothly; integer/topology leaves are not fittable.
FITTABLE_PARAMS = (
    "link_bw",
    "s_half",
    "hbm_bw",
    "peak_flops",
    "kernel_latency",
    "link_latency",
    "kernel_ramp",
    "cil_gemm_c2",
    "cil_gemm_c3",
    "cil_comm_c2",
    "cil_comm_c3",
)


@dataclasses.dataclass(frozen=True)
class MeasuredRecord:
    """One measured schedule execution (what ``Autotuner.measure`` logs).

    ``profile`` carries the ragged step fractions the execution ran with
    (None = the uniform cut): profile-bearing records route
    :func:`fit_machine` through the ragged grid evaluator so skewed
    ``ficco_a2a_ffn`` timings calibrate the machine too.  ``variant`` is
    the kernel-variant digest for records produced by
    ``Autotuner.measure_variants`` ("" for plain schedule timings).
    """

    gemm: GemmShape
    schedule: Schedule
    seconds: float
    group: int
    profile: tuple[float, ...] | None = None
    variant: str = ""


def records_from_cache(cache, machine_name: str) -> list[MeasuredRecord]:
    """Extract measured-tier records for one machine from the autotune
    decision cache.

    Keys are ``TuneKey`` strings (``machine/gG/mM/nN/kK/bB/profile``);
    machine names may themselves contain ``/`` (the machine-grid
    variants do), so fields parse from the right.  Only uniform-profile
    entries (digest exactly ``u<steps>`` — a *named* skewed profile can
    legitimately start with ``u``) with a recorded ``measured_total_s``
    qualify.
    """
    import re

    out: list[MeasuredRecord] = []
    for key, entry in cache.decision_entries().items():
        t = entry.get("measured_total_s")
        if not t:
            continue
        parts = key.split("/")
        if len(parts) < 7:
            continue
        mach = "/".join(parts[:-6])
        g, m, n, k, b, profile = parts[-6:]
        if mach != machine_name or not re.fullmatch(r"u\d+", profile):
            continue
        try:
            sched = Schedule(entry["schedule"])
            out.append(
                MeasuredRecord(
                    gemm=GemmShape(
                        int(m[1:]), int(n[1:]), int(k[1:]), int(b[1:])
                    ),
                    schedule=sched,
                    seconds=float(t),
                    group=int(g[1:]),
                )
            )
        except (KeyError, ValueError):
            continue
    return out


def variant_records_from_cache(
    cache, machine_name: str, *, kernel: str | None = None
) -> list[MeasuredRecord]:
    """Extract kernel-variant timing records for one machine.

    These are the 8-segment keys ``Autotuner.measure_variants`` writes
    (``machine/gG/mM/nN/kK/bB/profile/vDIGEST``).  Skewed entries carry
    their raw step fractions in the cache entry (``profile_frac``), so
    the returned records rebuild the *ragged* fit objective exactly;
    uniform entries (digest ``u<steps>``) come back with
    ``profile=None``.  ``kernel`` filters to one kernel's records.
    """
    import re

    seg = re.compile(r"vc\d+t\d+x\d+x\d+d\d+[fr]")
    out: list[MeasuredRecord] = []
    for key, entry in cache.decision_entries().items():
        t = entry.get("measured_total_s")
        if not t:
            continue
        parts = key.split("/")
        if len(parts) < 8 or not seg.fullmatch(parts[-1]):
            continue
        mach = "/".join(parts[:-7])
        g, m, n, k, b, profile = parts[-7:-1]
        if mach != machine_name:
            continue
        if kernel is not None and entry.get("kernel") != kernel:
            continue
        frac = entry.get("profile_frac")
        try:
            out.append(
                MeasuredRecord(
                    gemm=GemmShape(
                        int(m[1:]), int(n[1:]), int(k[1:]), int(b[1:])
                    ),
                    schedule=Schedule(entry["schedule"]),
                    seconds=float(t),
                    group=int(g[1:]),
                    profile=(
                        tuple(float(f) for f in frac) if frac else None
                    ),
                    variant=entry.get("variant", parts[-1][1:]),
                )
            )
        except (KeyError, ValueError):
            continue
    return out


def _spec_payload(machine: MachineSpec) -> dict:
    raw = dataclasses.asdict(machine)
    raw["topology"] = machine.topology.value
    return raw


def _spec_from_payload(raw: dict) -> MachineSpec:
    from repro.core.machine import Topology

    fields = dict(raw)
    fields["topology"] = Topology(fields["topology"])
    return MachineSpec(**fields)


@dataclasses.dataclass(frozen=True)
class FitResult:
    """Fitted machine parameters + fit quality.

    ``fitted`` maps parameter name -> fitted value; ``initial`` holds
    the pre-fit values (the analytic model's calibration).  ``loss0`` /
    ``loss`` are mean squared log-time errors before/after.
    ``machine_spec`` is the full spec the fit ran against (a
    machine-grid variant's topology/link counts survive persistence —
    rebuilding from the base registry machine would silently change the
    comm model under the fitted parameters).
    """

    machine: str
    group: int
    params: tuple[str, ...]
    fitted: dict[str, float]
    initial: dict[str, float]
    loss0: float
    loss: float
    n_records: int
    machine_spec: dict = dataclasses.field(default_factory=dict)
    version: int = FIT_SCHEMA_VERSION

    def scale(self, name: str) -> float:
        """fitted/initial ratio — 1.0 means the model was already right."""
        return self.fitted[name] / self.initial[name]

    def spec(self) -> MachineSpec:
        """The exact (pre-fit) MachineSpec the records were fitted on."""
        return _spec_from_payload(self.machine_spec)

    def machine_arrays(self):
        """The fitted :class:`~repro.autotune.jaxgrid.MachineArrays`
        (single machine), ready for ``evaluate_grid_raw``."""
        return _patched_arrays(self.spec(), self.fitted)

    def to_payload(self) -> dict:
        return {
            "version": self.version,
            "machine": self.machine,
            "group": self.group,
            "params": list(self.params),
            "fitted": dict(self.fitted),
            "initial": dict(self.initial),
            "loss0": self.loss0,
            "loss": self.loss,
            "n_records": self.n_records,
            "machine_spec": dict(self.machine_spec),
        }

    @classmethod
    def from_payload(cls, raw: dict) -> "FitResult":
        if raw.get("version") != FIT_SCHEMA_VERSION:
            raise ValueError(
                f"FitResult schema {raw.get('version')!r} != "
                f"{FIT_SCHEMA_VERSION}"
            )
        return cls(
            machine=raw["machine"],
            group=int(raw["group"]),
            params=tuple(raw["params"]),
            fitted={k: float(v) for k, v in raw["fitted"].items()},
            initial={k: float(v) for k, v in raw["initial"].items()},
            loss0=float(raw["loss0"]),
            loss=float(raw["loss"]),
            n_records=int(raw["n_records"]),
            machine_spec=dict(raw["machine_spec"]),
        )


def _patched_arrays(machine: MachineSpec, overrides: dict[str, float]):
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.autotune.jaxgrid import machine_arrays

    with enable_x64():
        mp = machine_arrays((machine,))
        return mp._replace(
            **{
                name: jnp.asarray([val], dtype=jnp.float64)
                for name, val in overrides.items()
            }
        )


def fit_machine(
    machine: MachineSpec,
    records: Sequence[MeasuredRecord],
    *,
    params: tuple[str, ...] = ("link_bw", "s_half"),
    steps: int = 300,
    lr: float = 0.05,
) -> FitResult:
    """Adam on the jitted grid engine: fit ``params`` to measured times.

    Parameters descend in log-space (positivity for free, scale-free
    steps); the loss is the mean squared difference of log model time vs
    log measured time over all records.  ``records`` should span a few
    sizes and schedules — a single operator cannot separate bandwidth
    from latency terms.

    Records carrying a ``profile`` (skewed kernel timings, e.g. the
    profile-keyed ``ficco_a2a_ffn`` measurements) route the whole fit
    through the ragged grid evaluator: every record becomes one ragged
    lane with its own step-fraction row (uniform records get the uniform
    profile), so the objective stays a single differentiable
    ``(schedule, lane)`` gather.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.autotune.jaxgrid import (
        evaluate_grid_raw,
        evaluate_ragged_grid_raw,
        machine_arrays,
    )
    from repro.core.batch import RaggedBatch, ScenarioBatch
    from repro.core.engine import GRID_SCHEDULES
    from repro.core.workload import StepProfile

    for p in params:
        if p not in FITTABLE_PARAMS:
            raise ValueError(
                f"cannot fit {p!r}; fittable: {', '.join(FITTABLE_PARAMS)}"
            )
    records = list(records)
    if not records:
        raise ValueError("no measured records to fit against")
    groups = {r.group for r in records}
    if len(groups) != 1:
        raise ValueError(
            f"records span several group sizes {sorted(groups)}; "
            "fit one (machine, group) at a time"
        )
    eff = machine_for_group(machine, groups.pop())

    sb = ScenarioBatch.from_gemms([r.gemm for r in records])
    ragged = any(r.profile is not None for r in records)
    if ragged:
        profiles = [
            StepProfile(tuple(r.profile))
            if r.profile is not None
            else StepProfile.uniform(eff.group)
            for r in records
        ]
        sb = RaggedBatch.from_batch_and_profiles(sb, profiles)
    sched_idx = np.asarray(
        [GRID_SCHEDULES.index(r.schedule) for r in records], dtype=np.int64
    )
    lane = np.arange(len(records), dtype=np.int64)
    targets = np.log(np.asarray([r.seconds for r in records]))
    eval_raw = evaluate_ragged_grid_raw if ragged else evaluate_grid_raw

    with enable_x64():
        mp0 = machine_arrays((eff,))
        init = {
            name: float(np.asarray(getattr(mp0, name))[0]) for name in params
        }
        t_log = jnp.asarray(targets, dtype=jnp.float64)
        s_idx = jnp.asarray(sched_idx)
        l_idx = jnp.asarray(lane)

        def loss_fn(log_p):
            mp = mp0._replace(
                **{
                    name: jnp.exp(log_p[i])[None]
                    for i, name in enumerate(params)
                }
            )
            out = eval_raw(sb, mp, g_max=eff.group)
            total = out[0][0]  # (L, S)
            model = total[s_idx, l_idx]
            return jnp.mean((jnp.log(model) - t_log) ** 2)

        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        log_p = jnp.asarray(
            [math.log(init[name]) for name in params], dtype=jnp.float64
        )
        loss0 = float(grad_fn(log_p)[0])
        mu = jnp.zeros_like(log_p)
        nu = jnp.zeros_like(log_p)
        b1, b2, eps = 0.9, 0.999, 1e-8
        best_lp, best_loss = log_p, loss0
        for t in range(1, steps + 1):
            loss, g = grad_fn(log_p)
            if float(loss) < best_loss:
                best_loss, best_lp = float(loss), log_p
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / (1 - b1**t)
            nhat = nu / (1 - b2**t)
            log_p = log_p - lr * mhat / (jnp.sqrt(nhat) + eps)
        loss, _ = grad_fn(log_p)
        if float(loss) < best_loss:
            best_loss, best_lp = float(loss), log_p
        fitted = {
            name: float(jnp.exp(best_lp[i]))
            for i, name in enumerate(params)
        }
    return FitResult(
        machine=machine.name,
        group=eff.group,
        params=tuple(params),
        fitted=fitted,
        initial=init,
        loss0=loss0,
        loss=best_loss,
        n_records=len(records),
        machine_spec=_spec_payload(eff),
    )


def synthesize_records(
    machine: MachineSpec,
    gemms: Sequence[GemmShape],
    schedules: Sequence[Schedule],
    *,
    overrides: dict[str, float] | None = None,
    noise: float = 0.0,
    seed: int = 0,
) -> list[MeasuredRecord]:
    """Model-generated "measured" times, optionally from a perturbed
    machine — the synthetic ground truth the fit tests recover."""
    import jax.numpy as jnp  # noqa: F401 — jax presence check
    from jax.experimental import enable_x64

    from repro.autotune.jaxgrid import evaluate_grid_raw
    from repro.core.batch import ScenarioBatch
    from repro.core.engine import GRID_SCHEDULES

    mp = _patched_arrays(machine, overrides or {})
    sb = ScenarioBatch.from_gemms(gemms)
    with enable_x64():
        out = evaluate_grid_raw(sb, mp, g_max=machine.group)
        total = np.asarray(out[0][0])  # (L, S)
        valid = np.asarray(out[5][0])
    rng = np.random.default_rng(seed)
    records = []
    for l, sched in enumerate(GRID_SCHEDULES):
        if sched not in schedules:
            continue
        for i, gemm in enumerate(gemms):
            if not valid[l, i]:
                continue
            t = float(total[l, i])
            if noise:
                t *= float(np.exp(rng.normal(0.0, noise)))
            records.append(
                MeasuredRecord(gemm, sched, t, machine.group)
            )
    return records


class FittedEngine:
    """Engine over the jitted grid with one machine's *fitted* parameters.

    The fit-then-retrain bridge: wraps a :class:`FitResult` and patches
    its fitted values into the matching lanes of the packed
    :class:`~repro.autotune.jaxgrid.MachineArrays` before evaluation, so
    sweeps — and the :class:`~repro.learn.gate.LearnedGate` statistics
    they produce — see the calibrated machine instead of the registry
    default.  Machines whose name doesn't match ``fit.machine`` pass
    through untouched, so mixed-machine grids stay meaningful.
    """

    name = "fitted"
    supports_ragged = True
    jit = True
    differentiable = False
    trace_safe = False

    def __init__(self, fit: FitResult):
        self.fit = fit

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules=None,
    ):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.autotune.jaxgrid import (
            evaluate_grid_raw,
            evaluate_ragged_grid_raw,
            machine_arrays,
        )
        from repro.core import batch as _batch
        from repro.core.engine import (
            GRID_SCHEDULES,
            GridResult,
            as_scenario_sequence,
            is_ragged,
        )

        scenarios = as_scenario_sequence(scenarios)
        ragged = is_ragged(scenarios)
        sb = (
            _batch._as_ragged_batch(scenarios)
            if ragged
            else _batch._as_batch(scenarios)
        )
        machines = tuple(machines)
        schedules = (
            GRID_SCHEDULES if schedules is None else tuple(schedules)
        )
        idx = [
            j for j, mch in enumerate(machines)
            if mch.name == self.fit.machine
        ]
        with enable_x64():
            mp = machine_arrays(machines)
            for name, val in self.fit.fitted.items():
                arr = getattr(mp, name)
                for j in idx:
                    arr = arr.at[j].set(jnp.asarray(val, dtype=arr.dtype))
                mp = mp._replace(**{name: arr})
            g_max = max(mch.group for mch in machines)
            raw = (
                evaluate_ragged_grid_raw if ragged else evaluate_grid_raw
            )(
                sb, mp, g_max=g_max, dma=dma,
                dma_into_place=dma_into_place, schedules=schedules,
            )
        return GridResult.from_machine_major(
            raw, schedules=schedules, scenarios=sb, machines=machines,
            dma=dma,
        )


# ---------------------------------------------------------------------------
# Persistence (autotune-cache artifact segment).
# ---------------------------------------------------------------------------


def save_fit(fit: FitResult, *, cache=None, name: str | None = None) -> None:
    from repro.autotune.cache import AutotuneCache

    cache = cache if cache is not None else AutotuneCache()
    cache.put_artifact(
        FIT_ARTIFACT_KIND,
        name or f"{fit.machine}/g{fit.group}",
        fit.to_payload(),
    )


def load_fit(name: str, *, cache=None) -> FitResult | None:
    """Load a persisted fit; stale/mismatched artifacts yield None."""
    from repro.autotune.cache import AutotuneCache

    cache = cache if cache is not None else AutotuneCache()
    raw = cache.get_artifact(FIT_ARTIFACT_KIND, name)
    if raw is None:
        return None
    try:
        return FitResult.from_payload(raw)
    except (ValueError, KeyError, TypeError):
        return None


__all__ = [
    "FIT_SCHEMA_VERSION",
    "FIT_ARTIFACT_KIND",
    "FITTABLE_PARAMS",
    "MeasuredRecord",
    "FitResult",
    "FittedEngine",
    "records_from_cache",
    "variant_records_from_cache",
    "fit_machine",
    "synthesize_records",
    "save_fit",
    "load_fit",
]
