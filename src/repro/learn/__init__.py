"""repro.learn — learned schedule heuristics + sim-to-real calibration.

The paper's headline is that static inefficiency signatures pick
bespoke FiCCO schedules (~81% on unseen scenarios); this package turns
the reproduction's engines and sharded sweeps into a training pipeline
for such policies, and closes the sim-to-real loop per deployment:

  * :mod:`repro.learn.features` — vectorized per-scenario feature
    extraction (comm/compute ratio, chunked-AG inflation, CIL, OTB,
    profile imbalance/active steps, machine params) from any batch or
    GridResult.
  * :mod:`repro.learn.stats`    — integer per-shard *sufficient
    statistics* that plug into ``repro.sweep``'s reduce mode
    (``on_shard_grid``), so 1e6–1e7-point sweeps train gates without
    gathering a grid — and sharded training is bit-identical to
    gathered training.
  * :mod:`repro.learn.gate`     — the :class:`LearnedGate` threshold
    family (a small axis-aligned tree over ``(imbalance, active_steps,
    otb, r)`` generalizing ``calibrate_serial_gate``), trained greedily
    on regret; frozen, versioned, JSON-round-trip artifacts consumed by
    ``select_schedule{,_batch}(gate=...)`` and the autotuner.
  * :mod:`repro.learn.fit`      — gradient sim-to-real machine
    calibration: Adam on the differentiable jax engine fits
    ``link_bw``/``s_half``/CIL coefficients to measured schedule times
    (``Autotuner.measure`` records).
  * :mod:`repro.learn.measured` — the ``"measured"`` engine
    (shortlist-only measured evaluation), registered below through the
    public ``register_engine`` extension path.

Train a skew-aware gate in three lines::

    from repro.learn import sweep_stats, train_gate_from_stats
    stats, _ = sweep_stats(scenarios, machines, num_shards=64)
    gate = train_gate_from_stats(stats)   # -> select_schedule(gate=gate)
"""

from repro.learn.features import (
    FEATURE_INDEX,
    FEATURE_NAMES,
    GATE_FEATURES,
    feature_matrix,
    grid_features,
    scenario_features,
)
from repro.learn.stats import (
    FEATURE_EDGES,
    SCORE_EDGES,
    STATS_SCHEMA,
    GateStats,
    sweep_stats,
)
from repro.learn.gate import (
    GATE_SCHEMA_VERSION,
    LearnedGate,
    clear_machine_gates,
    gate_accuracy,
    get_default_gate,
    get_machine_gate,
    load_gate,
    load_machine_gate,
    machine_family,
    refine_gate,
    save_gate,
    save_machine_gates,
    set_default_gate,
    set_machine_gate,
    train_gate,
    train_gate_from_stats,
    train_machine_gates,
)
from repro.learn.fit import (
    FITTABLE_PARAMS,
    FitResult,
    FittedEngine,
    MeasuredRecord,
    fit_machine,
    load_fit,
    records_from_cache,
    save_fit,
    synthesize_records,
    variant_records_from_cache,
)
from repro.learn.measured import MeasuredEngine, register_measured_engine

# Registry-extension path: the measured engine registers through the
# same public API a third-party backend would use.  Idempotent so
# re-imports never trip the collision guard.
register_measured_engine()

__all__ = [
    "FEATURE_NAMES",
    "FEATURE_INDEX",
    "GATE_FEATURES",
    "feature_matrix",
    "scenario_features",
    "grid_features",
    "STATS_SCHEMA",
    "FEATURE_EDGES",
    "SCORE_EDGES",
    "GateStats",
    "sweep_stats",
    "GATE_SCHEMA_VERSION",
    "LearnedGate",
    "train_gate",
    "train_gate_from_stats",
    "refine_gate",
    "gate_accuracy",
    "save_gate",
    "load_gate",
    "set_default_gate",
    "get_default_gate",
    "machine_family",
    "set_machine_gate",
    "get_machine_gate",
    "clear_machine_gates",
    "train_machine_gates",
    "save_machine_gates",
    "load_machine_gate",
    "FITTABLE_PARAMS",
    "MeasuredRecord",
    "FitResult",
    "FittedEngine",
    "fit_machine",
    "synthesize_records",
    "records_from_cache",
    "variant_records_from_cache",
    "save_fit",
    "load_fit",
    "MeasuredEngine",
    "register_measured_engine",
]
