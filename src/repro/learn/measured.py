"""Measured engine: shortlist-only evaluation backed by real timings.

Proves out the engine-registry extension path (ROADMAP "engine registry
extensions"): a backend that lives entirely outside ``repro.core`` and
registers itself through the public ``register_engine`` API
(``repro.learn`` registers it as ``"measured"``).

Semantics — *shortlist-only* evaluation:

  * an analytic engine (``analytic_backend``, default ``"numpy"``)
    ranks every schedule for each (scenario, machine) point;
  * only the top-``top`` analytic candidates (plus SERIAL, the
    always-executable reference) survive — everything else is
    invalidated in the returned grid, exactly as measuring only a
    shortlist leaves the rest unknown;
  * surviving entries are **overridden with measured wall times** where
    the autotune decision cache holds a measured-tier record for the
    point's :class:`~repro.autotune.tuner.TuneKey` (what
    ``Autotuner.measure`` persists); points never measured keep the
    analytic model time.

So ``grid.best_idx()`` over a measured-engine grid prefers empirical
winners wherever the measured tier has visited, and falls back to the
model elsewhere — the grid-shaped view of the autotuner's tier-3 data,
usable by every grid consumer (``GridExploration``, the calibrators,
``repro.learn`` training).
"""

from __future__ import annotations

import numpy as np

from repro.core.schedule_types import Schedule


class MeasuredEngine:
    """Shortlist-only engine over the measured-tier record store.

    Capability flags: host-side NumPy post-processing of another
    engine's grid — not jitted, not differentiable (measured wall times
    have no gradients), but trace-safe (no jax computation is staged).
    Ragged profiles are supported: the analytic base grid comes from the
    ragged evaluator and the measured lookup keys on the per-scenario
    profile digest — exactly the profile-keyed records the skewed
    ``ficco_a2a_ffn`` variant search persists.
    """

    name = "measured"
    supports_ragged = True
    jit = False
    differentiable = False
    trace_safe = True

    def __init__(
        self,
        cache=None,
        *,
        analytic_backend: str = "numpy",
        top: int = 3,
    ):
        self._cache = cache
        self.analytic_backend = analytic_backend
        self.top = top

    def _store(self):
        if self._cache is not None:
            return self._cache
        from repro.autotune.tuner import get_tuner

        return get_tuner().cache

    def evaluate(
        self,
        scenarios,
        machines,
        *,
        dma: bool = True,
        dma_into_place: bool = False,
        schedules: tuple[Schedule, ...] | None = None,
    ):
        import dataclasses

        from repro.core.engine import (
            as_scenario_sequence,
            get_engine,
            is_ragged,
        )
        from repro.autotune.tuner import TuneKey

        scenarios = as_scenario_sequence(scenarios)
        ragged = is_ragged(scenarios)
        # Profile digests key the measured lookup for ragged scenarios.
        # Prefer the original RaggedScenario profiles (their name enters
        # the digest); a bare RaggedBatch reconstructs name-less
        # "custom" profiles, which only match records stored the same way.
        profiles = None
        if ragged:
            if isinstance(scenarios, (list, tuple)):
                profiles = [s.profile for s in scenarios]
        base = get_engine(self.analytic_backend).evaluate(
            scenarios, machines,
            dma=dma, dma_into_place=dma_into_place, schedules=schedules,
        )
        if ragged and profiles is None:
            profiles = [
                base.scenarios.profile(i) for i in range(len(base.scenarios))
            ]
        cache = self._store()
        total = base.total.copy()
        comm = base.comm_busy.copy()
        compute = base.compute_busy.copy()
        exposed = base.exposed.copy()
        valid = base.valid.copy()
        serial_l = (
            base.schedules.index(Schedule.SERIAL)
            if Schedule.SERIAL in base.schedules
            else None
        )
        L, S, M = total.shape
        for j, machine in enumerate(base.machines):
            for i in range(S):
                col = np.where(valid[:, i, j], total[:, i, j], np.inf)
                order = np.argsort(col, kind="stable")
                keep = set(int(l) for l in order[: self.top] if np.isfinite(col[l]))
                if serial_l is not None:
                    keep.add(serial_l)
                entry = cache.get(
                    str(
                        TuneKey.for_gemm(
                            base.scenarios.gemm(i),
                            machine,
                            profile=profiles[i] if profiles else None,
                        )
                    )
                )
                t_meas = entry.get("measured_total_s") if entry else None
                for l in range(L):
                    if l not in keep:
                        total[l, i, j] = np.nan
                        comm[l, i, j] = np.nan
                        compute[l, i, j] = np.nan
                        exposed[l, i, j] = np.nan
                        valid[l, i, j] = False
                        continue
                    if t_meas and entry.get("schedule") == base.schedules[
                        l
                    ].value:
                        total[l, i, j] = float(t_meas)
        return dataclasses.replace(
            base,
            total=total,
            comm_busy=comm,
            compute_busy=compute,
            exposed=exposed,
            valid=valid,
        )


def register_measured_engine(*, overwrite: bool = False) -> None:
    """Register ``"measured"`` in the engine registry (idempotent)."""
    from repro.core.engine import engine_names, register_engine

    if overwrite or "measured" not in engine_names():
        register_engine("measured", MeasuredEngine, overwrite=overwrite)


__all__ = ["MeasuredEngine", "register_measured_engine"]
