"""Sweep-learned serial-gate threshold family (axis-aligned tree).

``calibrate_serial_gate`` learns *one* scalar threshold for the
serial/overlap gate; the ragged grids showed that is not enough — the
right threshold depends on the profile's skew (ROADMAP "learned
skew-aware heuristic tranche").  :class:`LearnedGate` generalizes the
scalar gate to a small axis-aligned decision tree over the gate
features ``(imbalance, active_steps, otb, r)``: each leaf holds its own
threshold, and a scenario stays serial iff its raw gate score
(:func:`repro.core.heuristics.serial_gate_score_batch`) is ``>=`` the
threshold of the leaf its features land in.  A single-leaf tree is
exactly the scalar gate, so this strictly extends the existing family.

Training is greedy on **regret** (quantized time lost vs the analytic
optimum), driven entirely by the integer sufficient statistics of
:mod:`repro.learn.stats` — so a gate trained from merged per-shard
statistics of a reduce-mode sweep is bit-identical to one trained on
the gathered grid.  Split candidates and leaf thresholds are the fixed
bin edges, which keeps every training decision exact integer
arithmetic (deterministic across shardings, platforms and runs).

The artifact is frozen, versioned and JSON-serializable
(:meth:`LearnedGate.to_json` round-trips bit-stably); persist it in the
autotune cache's artifact segment with :func:`save_gate` /
:func:`load_gate`, and install it process-wide with
:func:`set_default_gate` so the autotuner's heuristic fallback consults
it ahead of the hand-tuned gate.
"""

from __future__ import annotations

import copy
import dataclasses
import json
import math

import numpy as np

from repro.core.machine import MachineSpec
from repro.learn import features as _features
from repro.learn.features import GATE_FEATURES, feature_matrix
from repro.learn.stats import (
    _C_COUNT,
    _C_REG_BASE,
    _C_REG_SERIAL,
    _C_W5_BASE,
    _C_W5_SERIAL,
    _quantize_regret,
    FEATURE_EDGES,
    SCORE_EDGES,
    GateStats,
)

GATE_SCHEMA_VERSION = 1

# Artifact kind under which gates persist in the autotune cache segment.
GATE_ARTIFACT_KIND = "gate"


# ---------------------------------------------------------------------------
# The frozen artifact.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LearnedGate:
    """Versioned, JSON-serializable serial-gate threshold family.

    ``tree`` is a nested node dict: internal nodes are
    ``{"feature": name, "edge": float, "lo": node, "hi": node}`` (take
    ``hi`` iff the feature value is ``>= edge``); leaves are
    ``{"leaf": True, "gate": float, ...stats...}``.  A scenario stays
    serial iff ``score >= gate`` at its leaf (``-inf`` = always serial,
    ``inf`` = never) — the ``>=`` conventions match the bin edges the
    statistics were accumulated with, so applying the gate reproduces
    the training accounting exactly.
    """

    tree: dict
    features: tuple[str, ...] = GATE_FEATURES
    version: int = GATE_SCHEMA_VERSION
    meta: dict = dataclasses.field(default_factory=dict)

    # -- application ----------------------------------------------------

    def thresholds(self, X: np.ndarray) -> np.ndarray:
        """Per-row gate thresholds for an ``(S, len(features))`` matrix."""
        X = np.asarray(X, dtype=np.float64)
        out = np.empty(X.shape[0], dtype=np.float64)
        self._apply(self.tree, X, np.arange(X.shape[0]), out)
        return out

    def _apply(self, node, X, rows, out) -> None:
        if node.get("leaf"):
            out[rows] = node["gate"]
            return
        col = self.features.index(node["feature"])
        hi = X[rows, col] >= node["edge"]
        self._apply(node["lo"], X, rows[~hi], out)
        self._apply(node["hi"], X, rows[hi], out)

    def thresholds_batch(
        self,
        m,
        n,
        k,
        dtype_bytes,
        machine: MachineSpec,
        *,
        imbalance=None,
        active_steps=None,
        terms=None,
    ) -> np.ndarray:
        """Per-scenario thresholds from raw shape arrays (what
        ``select_schedule_batch(gate=...)`` calls).

        ``terms`` forwards precomputed gate-score terms to
        :func:`~repro.learn.features.feature_matrix`.
        """
        m = np.asarray(m)
        imb = 1.0 if imbalance is None else imbalance
        act = float(machine.group) if active_steps is None else active_steps
        feats = feature_matrix(
            m, n, k, dtype_bytes, machine, imbalance=imb, active_steps=act,
            terms=terms,
        )
        cols = [_features.FEATURE_INDEX[f] for f in self.features]
        return self.thresholds(feats[:, cols])

    def threshold_for(self, gemm, machine: MachineSpec, *, profile=None):
        """Scalar threshold for one GEMM (what ``select_schedule`` calls)."""
        imb = 1.0 if profile is None else float(profile.imbalance)
        act = (
            float(machine.group)
            if profile is None
            else float(profile.active_steps)
        )
        return float(
            self.thresholds_batch(
                np.asarray([gemm.m]),
                np.asarray([gemm.n]),
                np.asarray([gemm.k]),
                np.asarray([gemm.dtype_bytes]),
                machine,
                imbalance=imb,
                active_steps=act,
            )[0]
        )

    @property
    def n_leaves(self) -> int:
        def count(node):
            if node.get("leaf"):
                return 1
            return count(node["lo"]) + count(node["hi"])

        return count(self.tree)

    # -- serialization --------------------------------------------------

    def to_json(self) -> str:
        """Bit-stable canonical JSON (sorted keys, fixed separators).

        Non-finite thresholds serialize as the strings ``"-inf"`` /
        ``"inf"`` so the payload is strict JSON.
        """
        payload = {
            "version": self.version,
            "features": list(self.features),
            "tree": _encode_node(self.tree),
            "meta": self.meta,
        }
        return json.dumps(payload, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "LearnedGate":
        """Parse a serialized gate; a schema-version mismatch raises.

        Mirrors the autotune cache's wholesale invalidation: an artifact
        written by a different gate schema can never silently steer
        schedule picks — callers (``load_gate``) treat the raised
        ``ValueError`` as "no gate".
        """
        raw = json.loads(text)
        if raw.get("version") != GATE_SCHEMA_VERSION:
            raise ValueError(
                f"LearnedGate schema {raw.get('version')!r} != "
                f"{GATE_SCHEMA_VERSION}; retrain or discard the artifact"
            )
        return cls(
            tree=_decode_node(raw["tree"]),
            features=tuple(raw["features"]),
            version=int(raw["version"]),
            meta=dict(raw.get("meta", {})),
        )


def _encode_float(x: float):
    if math.isinf(x):
        return "inf" if x > 0 else "-inf"
    return x


def _decode_float(x) -> float:
    if isinstance(x, str):
        return float(x)
    return float(x)


def _encode_node(node: dict) -> dict:
    if node.get("leaf"):
        out = dict(node)
        out["gate"] = _encode_float(node["gate"])
        return out
    return {
        "feature": node["feature"],
        "edge": _encode_float(node["edge"]),
        "lo": _encode_node(node["lo"]),
        "hi": _encode_node(node["hi"]),
    }


def _decode_node(node: dict) -> dict:
    if node.get("leaf"):
        out = dict(node)
        out["gate"] = _decode_float(node["gate"])
        return out
    return {
        "feature": node["feature"],
        "edge": _decode_float(node["edge"]),
        "lo": _decode_node(node["lo"]),
        "hi": _decode_node(node["hi"]),
    }


# ---------------------------------------------------------------------------
# Training: greedy regret-driven growth on the integer statistics.
# ---------------------------------------------------------------------------

_THRESHOLDS = (-math.inf,) + tuple(SCORE_EDGES) + (math.inf,)


def _best_threshold(reduced: np.ndarray):
    """Best gate threshold for one region.

    ``reduced`` is the ``(n_score_bins, N_STAT)`` marginal histogram.
    Threshold index ``i`` sends score bins ``>= i`` serial; the loss is
    the total quantized regret of the implied per-point choices.
    Deterministic integer tie-breaking: lowest regret, then most
    within-5% wins, then the least-serial threshold.

    Returns ``(threshold_value, loss, win5)``.
    """
    reg_s = reduced[:, _C_REG_SERIAL]
    reg_b = reduced[:, _C_REG_BASE]
    w5_s = reduced[:, _C_W5_SERIAL]
    w5_b = reduced[:, _C_W5_BASE]
    # loss(i) = sum_{bin >= i} regret_serial + sum_{bin < i} regret_base.
    serial_tail = np.concatenate(
        [np.cumsum(reg_s[::-1])[::-1], [0]]
    )  # (n_bins + 1,)
    base_head = np.concatenate([[0], np.cumsum(reg_b)])
    loss = serial_tail + base_head
    win5 = (
        np.concatenate([np.cumsum(w5_s[::-1])[::-1], [0]])
        + np.concatenate([[0], np.cumsum(w5_b)])
    )
    order = np.lexsort((-np.arange(loss.size), -win5, loss))
    i = int(order[0])
    return _THRESHOLDS[i], int(loss[i]), int(win5[i])


def _leaf_payload(reduced: np.ndarray):
    thr, loss, win5 = _best_threshold(reduced)
    return {
        "leaf": True,
        "gate": thr,
        "n": int(reduced[:, _C_COUNT].sum()),
        "win5": win5,
        "regret_q": loss,
    }


@dataclasses.dataclass
class _Region:
    """A hyper-rectangle of feature bins during greedy growth."""

    ranges: tuple[tuple[int, int], ...]  # per feature axis: [lo, hi)
    sub: np.ndarray  # restricted histogram, feature axes + (score, stat)
    loss: int
    win5: int
    threshold: float

    @classmethod
    def from_hist(cls, hist: np.ndarray, ranges) -> "_Region":
        sub = hist
        for axis, (lo, hi) in enumerate(ranges):
            sub = np.take(sub, np.arange(lo, hi), axis=axis)
        reduced = sub.sum(axis=tuple(range(len(ranges))))
        thr, loss, win5 = _best_threshold(reduced)
        return cls(tuple(ranges), sub, loss, win5, thr)

    def best_split(self, min_points: int):
        """(gain, axis, cut, left_region_args, right_region_args) or None.

        Candidate cuts are the fixed bin boundaries interior to this
        region; evaluated for all cuts of an axis at once via prefix
        sums over the axis marginal.  Deterministic: axes in feature
        order, cuts ascending, strict improvement required.
        """
        n_axes = len(self.ranges)
        best = None
        for axis in range(n_axes):
            lo, hi = self.ranges[axis]
            if hi - lo < 2:
                continue
            other = tuple(a for a in range(n_axes) if a != axis)
            marg = self.sub.sum(axis=other)  # (axis_bins, score, stat)
            prefix = np.cumsum(marg, axis=0)
            total = prefix[-1]
            for c in range(1, hi - lo):
                left = prefix[c - 1]
                right = total - left
                if (
                    left[:, _C_COUNT].sum() < min_points
                    or right[:, _C_COUNT].sum() < min_points
                ):
                    continue
                _, l_loss, _ = _best_threshold(left)
                _, r_loss, _ = _best_threshold(right)
                gain = self.loss - l_loss - r_loss
                if gain > 0 and (best is None or gain > best[0]):
                    best = (gain, axis, lo + c)
        return best


def train_gate_from_stats(
    stats: GateStats,
    *,
    max_leaves: int = 8,
    min_points: int = 32,
    meta: dict | None = None,
) -> LearnedGate:
    """Grow the threshold tree greedily on quantized regret.

    Starts from the single-leaf (scalar-gate) family and repeatedly
    applies the highest-gain axis-aligned split until ``max_leaves`` or
    no split strictly reduces total regret.  All decisions are integer
    arithmetic on the sufficient statistics, so the result is invariant
    to how the training sweep was sharded.
    """
    hist = stats.hist
    n_axes = len(GATE_FEATURES)
    root_ranges = tuple((0, hist.shape[a]) for a in range(n_axes))
    root = _Region.from_hist(hist, root_ranges)

    # Grow: each entry is (region, node_dict_holder, key).
    tree: dict = {}
    leaves: list[tuple[_Region, dict, str]] = [(root, tree, "root")]
    while len(leaves) < max_leaves:
        # Deterministic arg-best over leaves in creation order.
        candidates = [
            (leaf.best_split(min_points), idx)
            for idx, (leaf, _, _) in enumerate(leaves)
        ]
        viable = [(c, i) for c, i in candidates if c is not None]
        if not viable:
            break
        (gain, axis, cut), idx = max(
            viable, key=lambda v: (v[0][0], -v[1])
        )
        region, holder, key = leaves.pop(idx)
        lo, hi = region.ranges[axis]
        l_ranges = list(region.ranges)
        r_ranges = list(region.ranges)
        l_ranges[axis] = (lo, cut)
        r_ranges[axis] = (cut, hi)
        left = _Region.from_hist(hist, l_ranges)
        right = _Region.from_hist(hist, r_ranges)
        feature = GATE_FEATURES[axis]
        edge = float(FEATURE_EDGES[feature][cut - 1])
        node = {"feature": feature, "edge": edge, "lo": {}, "hi": {}}
        holder[key] = node
        leaves.append((left, node, "lo"))
        leaves.append((right, node, "hi"))

    for region, holder, key in leaves:
        reduced = region.sub.sum(axis=tuple(range(n_axes)))
        holder[key] = _leaf_payload(reduced)
    root_node = tree["root"]

    info = {
        "n_points": stats.n_points,
        "trained_regret_q": sum(
            leaf["regret_q"] for leaf in _iter_leaves(root_node)
        ),
        "trained_win5": sum(
            leaf["win5"] for leaf in _iter_leaves(root_node)
        ),
    }
    if meta:
        info.update(meta)
    return LearnedGate(tree=root_node, meta=info)


def _iter_leaves(node: dict):
    if node.get("leaf"):
        yield node
    else:
        yield from _iter_leaves(node["lo"])
        yield from _iter_leaves(node["hi"])


def train_gate(source, **kw) -> LearnedGate:
    """Train from a :class:`GateStats` *or* any gathered GridResult.

    The GridResult path runs through the identical sufficient-statistics
    machinery (the grid is treated as one big shard), which is what
    guarantees sharded and gathered training agree bit-for-bit.
    """
    stats = source if isinstance(source, GateStats) else GateStats.from_grid(source)
    return train_gate_from_stats(stats, **kw)


# ---------------------------------------------------------------------------
# Regret-weighted adaptive leaf thresholds (post-training refinement).
# ---------------------------------------------------------------------------


def _per_point_tables(grid, features: tuple[str, ...]):
    """Per-(scenario, machine) gate-score / regret / win5 tables.

    The flattened, *unbinned* twin of ``GateStats.update_from_grid``:
    same terms, same base picks, same regret quantization — but kept
    per point so a threshold anywhere on the real line can be scored
    exactly, not just at the fixed bin edges.  Returns
    ``(X, scores, reg_serial, reg_base, w5_serial, w5_base)`` with rows
    concatenated machine-major.
    """
    from repro.core.engine import GRID_SCHEDULES
    from repro.core.heuristics import (
        select_schedule_batch,
        serial_gate_score_from_terms,
        serial_gate_terms_batch,
    )
    from repro.core.schedule_types import Schedule
    from repro.core.engine import SCHEDULE_INDEX
    from repro.learn.features import profile_features

    if tuple(grid.schedules) != GRID_SCHEDULES:
        raise ValueError(
            "refine_gate needs the full GRID_SCHEDULES grid, got "
            f"{tuple(s.value for s in grid.schedules)}"
        )
    sb = grid.scenarios
    S = len(sb)
    imb, act = profile_features(sb)
    t = np.nan_to_num(grid.total, nan=np.inf, posinf=np.inf)
    t_best = grid.best_total()
    serial_l = SCHEDULE_INDEX[Schedule.SERIAL]
    s_idx = np.arange(S)
    cols = [_features.FEATURE_INDEX[f] for f in features]
    Xs, scs, rss, rbs, w5ss, w5bs = [], [], [], [], [], []
    for j, machine in enumerate(grid.machines):
        terms = serial_gate_terms_batch(
            sb.m, sb.n, sb.k, sb.dtype_bytes, machine
        )
        scores = serial_gate_score_from_terms(*terms)
        base = select_schedule_batch(
            sb.m, sb.n, sb.k, sb.dtype_bytes, machine,
            serial_gate=np.inf, terms=terms,
        )
        feats = feature_matrix(
            sb.m, sb.n, sb.k, sb.dtype_bytes, machine,
            imbalance=imb, active_steps=act, terms=terms,
        )
        t_serial = t[serial_l, :, j]
        t_pick = t[base, s_idx, j]
        tb = t_best[:, j]
        Xs.append(feats[:, cols])
        scs.append(np.asarray(scores, dtype=np.float64))
        rss.append(_quantize_regret(t_serial, tb))
        rbs.append(_quantize_regret(t_pick, tb))
        w5ss.append((t_serial <= 1.05 * tb).astype(np.int64))
        w5bs.append((t_pick <= 1.05 * tb).astype(np.int64))
    return (
        np.concatenate(Xs), np.concatenate(scs),
        np.concatenate(rss), np.concatenate(rbs),
        np.concatenate(w5ss), np.concatenate(w5bs),
    )


def _leaf_rows(node, X, rows, features, out) -> None:
    if node.get("leaf"):
        out.append((node, rows))
        return
    col = features.index(node["feature"])
    hi = X[rows, col] >= node["edge"]
    _leaf_rows(node["lo"], X, rows[~hi], features, out)
    _leaf_rows(node["hi"], X, rows[hi], features, out)


def refine_gate(
    gate: LearnedGate,
    grid,
    *,
    sub_bins: int = 8,
    meta: dict | None = None,
) -> LearnedGate:
    """Regret-weighted adaptive leaf thresholds.

    Training quantizes every candidate threshold to the fixed
    ``SCORE_EDGES`` geomspace — cheap and shard-exact, but the best
    threshold inside the winning bin interval is invisible to it.  This
    pass re-bins that interval per leaf: each leaf's rows (from
    ``grid``) are scored with the same terms/regret quantization the
    statistics used, ``sub_bins`` geomspaced sub-candidates between the
    leaf threshold's neighboring coarse candidates are evaluated by
    exact integer regret, and the leaf keeps the winner.  The current
    threshold is always a candidate, so the refined gate is never worse
    than ``gate`` on ``grid`` (regret and within-5% accounting).
    Infinite interval ends fall back to the leaf's observed score range.

    Returns a new :class:`LearnedGate`; ``meta["refine"]`` records the
    before/after quantized regret and win5 totals.
    """
    if sub_bins < 1:
        raise ValueError(f"sub_bins must be >= 1, got {sub_bins}")
    X, scores, reg_s, reg_b, w5_s, w5_b = _per_point_tables(
        grid, gate.features
    )
    tree = copy.deepcopy(gate.tree)
    leaves: list[tuple[dict, np.ndarray]] = []
    _leaf_rows(tree, X, np.arange(X.shape[0]), gate.features, leaves)
    ts = np.asarray(_THRESHOLDS)

    before_loss = before_win5 = after_loss = after_win5 = 0
    for leaf, rows in leaves:
        s = scores[rows]
        rs, rb = reg_s[rows], reg_b[rows]
        w5s, w5b = w5_s[rows], w5_b[rows]

        def _score(tau):
            serial = s >= tau
            return (
                int(rs[serial].sum() + rb[~serial].sum()),
                int(w5s[serial].sum() + w5b[~serial].sum()),
            )

        thr = float(leaf["gate"])
        cur_loss, cur_win5 = _score(thr)
        before_loss += cur_loss
        before_win5 += cur_win5
        # Interval between the coarse candidates bracketing the leaf's
        # threshold; the coarse search already proved thr beats both
        # neighbors, so only the inside of this bracket can improve.
        lo = float(ts[ts < thr].max()) if (ts < thr).any() else -math.inf
        hi = float(ts[ts > thr].min()) if (ts > thr).any() else math.inf
        if not math.isfinite(lo):
            lo = float(s.min()) if rows.size else math.nan
        if not math.isfinite(hi):
            hi = float(s.max()) if rows.size else math.nan
        best = (cur_loss, -cur_win5, -thr)
        if math.isfinite(lo) and math.isfinite(hi) and 0.0 < lo < hi:
            for tau in np.geomspace(lo, hi, sub_bins + 2)[1:-1]:
                tau = float(tau)
                loss, win5 = _score(tau)
                # Mirrors _best_threshold: lowest regret, most win5,
                # least-serial (largest) threshold.
                cand = (loss, -win5, -tau)
                if cand < best:
                    best = cand
        loss, win5, tau = best[0], -best[1], -best[2]
        leaf["gate"] = tau
        leaf["regret_q"] = loss
        leaf["win5"] = win5
        after_loss += loss
        after_win5 += win5

    info = dict(gate.meta)
    info["refine"] = {
        "sub_bins": int(sub_bins),
        "n_rows": int(X.shape[0]),
        "regret_q_before": int(before_loss),
        "regret_q_after": int(after_loss),
        "win5_before": int(before_win5),
        "win5_after": int(after_win5),
    }
    if meta:
        info["refine"].update(meta)
    return LearnedGate(
        tree=tree, features=gate.features, version=gate.version, meta=info
    )


# ---------------------------------------------------------------------------
# Evaluation helper.
# ---------------------------------------------------------------------------


def gate_accuracy(grid, gate=None, *, frac: float = 0.05, tau=None) -> float:
    """Within-``frac`` accuracy of the (optionally gated) heuristic on a
    grid — the §VI-D protocol, one call."""
    from repro.core.explorer import GridExploration

    return GridExploration.from_grid(grid, tau=tau, gate=gate).accuracy(frac)


# ---------------------------------------------------------------------------
# Persistence (autotune-cache artifact segment) + process default.
# ---------------------------------------------------------------------------


def save_gate(gate: LearnedGate, *, cache=None, name: str = "default") -> None:
    """Persist a gate in the autotune cache's artifact segment."""
    from repro.autotune.cache import AutotuneCache

    cache = cache if cache is not None else AutotuneCache()
    cache.put_artifact(GATE_ARTIFACT_KIND, name, json.loads(gate.to_json()))


def load_gate(*, cache=None, name: str = "default") -> LearnedGate | None:
    """Load a persisted gate; stale/mismatched artifacts yield None.

    Like the autotune decision cache, persisted gates are an
    accelerator, not a source of truth: a schema bump or corrupt
    payload means "no gate", never an error.
    """
    from repro.autotune.cache import AutotuneCache

    cache = cache if cache is not None else AutotuneCache()
    raw = cache.get_artifact(GATE_ARTIFACT_KIND, name)
    if raw is None:
        return None
    try:
        return LearnedGate.from_json(json.dumps(raw))
    except (ValueError, KeyError, TypeError):
        return None


_DEFAULT_GATE: LearnedGate | None = None


def set_default_gate(gate: LearnedGate | None) -> None:
    """Install (or clear) the process-wide learned gate.

    Once set, the autotuner's zero-cost heuristic fallback consults it
    ahead of the hand-tuned scalar gate; explicit ``gate=`` arguments
    still win.
    """
    global _DEFAULT_GATE
    _DEFAULT_GATE = gate


def get_default_gate() -> LearnedGate | None:
    return _DEFAULT_GATE


# ---------------------------------------------------------------------------
# Per-machine-family gates.
# ---------------------------------------------------------------------------
#
# One global gate blurs across link models: the score -> regret mapping
# an MI300X-class machine induces is not the one a TPU-pod slice does,
# so the greedy splitter spends leaves re-separating machines instead
# of profiles.  A *family* (the machine-name prefix up to the first
# "/": ``machine_grid`` names variants ``mi300x-8/bw0.7``,
# ``tpu-v5e-axis16/lat2x``, ...) shares a link model, so per-family
# gates are trained from per-family statistics (``GateStats`` folded
# with ``machine_indices``, or the device sweep's ``per_family``
# buckets) and installed in a process-wide registry that the heuristic
# tree's gate resolution consults between the ambient default gate and
# the hand-tuned scalar gate.

# Artifact-name prefix for persisted family gates.  Namespaced so a
# family literally named "default" can never collide with the global
# gate's artifact slot.
MACHINE_GATE_PREFIX = "machine:"

_MACHINE_GATES: dict[str, LearnedGate] = {}


def machine_family(machine) -> str:
    """Gate-family key of a machine (or machine name).

    The machine-grid naming convention puts the base machine before the
    first ``/`` and the perturbation after it (``mi300x-8/bw0.7``); the
    base machine determines the link model, hence the gate family.
    """
    name = machine if isinstance(machine, str) else machine.name
    return name.split("/", 1)[0]


def set_machine_gate(family, gate: LearnedGate | None) -> None:
    """Register (or, with ``None``, drop) the learned gate of a family.

    ``family`` may be a family key, a machine name, or a MachineSpec —
    anything :func:`machine_family` normalizes.
    """
    key = machine_family(family)
    if gate is None:
        _MACHINE_GATES.pop(key, None)
    else:
        _MACHINE_GATES[key] = gate


def get_machine_gate(machine) -> LearnedGate | None:
    """The registered family gate for a machine, or None."""
    return _MACHINE_GATES.get(machine_family(machine))


def clear_machine_gates() -> None:
    """Drop every registered family gate (test isolation hook)."""
    _MACHINE_GATES.clear()


def train_machine_gates(
    stats_by_family: dict,
    *,
    install: bool = False,
    **kw,
) -> dict[str, LearnedGate]:
    """Train one gate per family from per-family statistics.

    ``stats_by_family`` maps family keys (or machine names/specs) to
    :class:`~repro.learn.stats.GateStats`; each gate's meta records its
    family.  ``install=True`` additionally registers every trained gate
    via :func:`set_machine_gate`.  Remaining keyword arguments forward
    to :func:`train_gate_from_stats` (``max_leaves``, ``min_points``,
    ``meta``).
    """
    meta_extra = dict(kw.pop("meta", None) or {})
    gates = {}
    for fam_key, stats in stats_by_family.items():
        fam = machine_family(fam_key)
        gates[fam] = train_gate_from_stats(
            stats, meta={**meta_extra, "family": fam}, **kw
        )
    if install:
        for fam, gate in gates.items():
            set_machine_gate(fam, gate)
    return gates


def save_machine_gates(gates: dict, *, cache=None) -> None:
    """Persist family gates in the artifact segment, one per family.

    Names are ``machine:<family>`` — the segment already keys artifacts
    by name, so families ride alongside the ``"default"`` global gate.
    """
    for fam_key, gate in gates.items():
        save_gate(
            gate, cache=cache,
            name=MACHINE_GATE_PREFIX + machine_family(fam_key),
        )


def load_machine_gate(machine, *, cache=None) -> LearnedGate | None:
    """Load one family's persisted gate (None when absent or stale)."""
    return load_gate(
        cache=cache, name=MACHINE_GATE_PREFIX + machine_family(machine)
    )


__all__ = [
    "GATE_SCHEMA_VERSION",
    "GATE_ARTIFACT_KIND",
    "MACHINE_GATE_PREFIX",
    "LearnedGate",
    "train_gate",
    "train_gate_from_stats",
    "refine_gate",
    "gate_accuracy",
    "save_gate",
    "load_gate",
    "set_default_gate",
    "get_default_gate",
    "machine_family",
    "set_machine_gate",
    "get_machine_gate",
    "clear_machine_gates",
    "train_machine_gates",
    "save_machine_gates",
    "load_machine_gate",
]
